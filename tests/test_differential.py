"""Cross-backend differential harness: the exact event simulator
(``core/simulator.py``) vs the vectorized fluid simulator
(``core/jaxsim.py``).

The fluid backend is a documented approximation (gang-exclusive placement,
fixed dt, single admission per step), so agreement is *qualitative*:
completeness, bounded JCT/makespan ratios,
determinism, matching policy/placement orderings, and the no-contention
limit where both backends are exact.

Coverage (per the shared ``core/netmodel.py`` layer):

* every fluid-supported gating policy (``FLUID_POLICIES``: ada, srsf1-3,
  kway2/kway3) on the deterministic ``smoke`` scenario, the
  policy-differentiating ``contended_residue`` scenario, a downsized
  ``hetero_bandwidth`` cell with true per-server (not cluster-mean)
  bandwidth, and a downsized multi-tier ``oversub_fabric`` cell
  (``core/topology.py`` contention domains on both backends);
* the gang placement modes vs their event analogues (LWF-1 <= FF on a
  fragmentation-sensitive workload, RAND on smoke, and rack-aware
  lwf_rack/rack_pack <= plain LWF on ``rack_locality``, on both backends);
* the WFBP layer-granular cells: config-derived ``model_zoo`` profiles
  with finite tensor fusion and the ``fusion_sweep`` regression cell
  (per-bucket gating on the event side vs the static [jobs, buckets]
  chunked drain on the fluid side).

This harness is what caught the fluid gating self-deadlock (a waiting
all-reduce counted itself as an active transfer and never started under
ada/srsf1) — keep it green."""

import numpy as np
import pytest

from repro.core.cluster import TABLE_III, JobSpec
from repro.scenarios import (
    FLUID_POLICIES,
    get_scenario,
    run_scenario_event,
    run_scenario_fluid,
)
from repro.scenarios.registry import Scenario
from repro.core.contention import ContentionParams

DT = 0.02
#: fluid-vs-event tolerance on aggregate metrics (gang placement makes the
#: fluid backend pessimistic on shared-GPU scenarios)
RATIO = 2.0

#: Tightened tolerance for the WFBP fusion cells: with k-way gating now
#: *exact* on both backends (netmodel.kway_exact_start — the same closed
#: form the event integrator computes), the remaining gap is only the
#: fluid backend's non-overlap of bucket streams with backward compute
#: plus dt quantization.  Measured worst case across the fusion cells
#: (ada/srsf2/kway2/kway3 on fusion_sweep + model_zoo): 1.21.
FUSION_RATIO = 1.35

#: Downsized hetero_bandwidth cell: small enough for tier-1, large enough
#: that half the servers being 0.4x slow actually shapes the schedule.
#: (Re-smoke-sized in PR 5 from 16 jobs / 60-300 iters using the
#: --durations data: the 6-policy fluid matrices were the slowest
#: differential cells; the qualitative bounds hold unchanged.)
HETERO_KW = dict(seed=1, n_jobs=12, min_iters=50, max_iters=200)

#: Downsized oversub_fabric cell (same sizing): 16-server two-tier fabric,
#: racks of 4 behind 3x-oversubscribed uplinks.
OVERSUB_KW = dict(seed=1, n_jobs=12, min_iters=50, max_iters=200)


@pytest.fixture(scope="module")
def smoke():
    return get_scenario("smoke")


@pytest.fixture(scope="module")
def hetero():
    return get_scenario("hetero_bandwidth", **HETERO_KW)


@pytest.fixture(scope="module")
def contended():
    return get_scenario("contended_residue", seed=1)


@pytest.fixture(scope="module")
def event_res(smoke):
    return run_scenario_event(smoke, comm="ada")


@pytest.fixture(scope="module")
def fluid_res(smoke):
    return run_scenario_fluid(smoke, comm="ada", dt=DT)


def fluid_avg(out):
    return float(out["jct"][out["finished"]].mean())


class TestSmokeAgreement:
    def test_both_backends_finish_everything(self, smoke, event_res, fluid_res):
        assert len(event_res.jct) == smoke.n_jobs
        assert int(fluid_res["finished"].sum()) == smoke.n_jobs

    def test_avg_jct_within_ratio(self, event_res, fluid_res):
        ev = event_res.avg_jct()
        fl = fluid_avg(fluid_res)
        assert ev / RATIO <= fl <= ev * RATIO, (ev, fl)

    def test_makespan_within_ratio(self, event_res, fluid_res):
        ev = event_res.makespan
        fl = float(fluid_res["makespan"])
        assert ev / RATIO <= fl <= ev * RATIO, (ev, fl)

    @pytest.mark.parametrize("comm", FLUID_POLICIES)
    def test_no_policy_strands_jobs(self, smoke, comm):
        """Regression for the fluid gating self-deadlock: every policy must
        complete the smoke scenario's multi-server jobs."""
        out = run_scenario_fluid(smoke, comm=comm, dt=DT)
        assert int(out["finished"].sum()) == smoke.n_jobs, comm

    def test_fluid_deterministic(self, smoke, fluid_res):
        again = run_scenario_fluid(smoke, comm="ada", dt=DT)
        np.testing.assert_array_equal(fluid_res["jct"], again["jct"])


class TestEveryPolicyEveryBackend:
    """Each fluid-supported gating policy, event-vs-fluid, on the scenario
    built so gang placements must share servers (all-reduces collide even
    under exclusive placement — the cell where the masks actually bite)."""

    @pytest.mark.parametrize("comm", FLUID_POLICIES)
    def test_contended_cell_agrees(self, contended, comm):
        ev = run_scenario_event(contended, comm=comm)
        fl = run_scenario_fluid(contended, comm=comm, dt=DT)
        assert len(ev.jct) == contended.n_jobs
        assert int(fl["finished"].sum()) == contended.n_jobs
        assert ev.avg_jct() / RATIO <= fluid_avg(fl) <= ev.avg_jct() * RATIO

    def test_gating_differentiates_like_event(self, contended):
        """AdaDUAL refuses the always-colliding equal-size transfers (all
        messages are identical, so Theorem 2's ratio test fails) while
        SRSF(2) blindly accepts 2-way contention — on BOTH backends the
        blind policy must be no better."""
        fl_ada = fluid_avg(run_scenario_fluid(contended, comm="ada", dt=DT))
        fl_s2 = fluid_avg(run_scenario_fluid(contended, comm="srsf2", dt=DT))
        ev_ada = run_scenario_event(contended, comm="ada").avg_jct()
        ev_s2 = run_scenario_event(contended, comm="srsf2").avg_jct()
        assert fl_ada < fl_s2, (fl_ada, fl_s2)
        assert ev_ada < ev_s2, (ev_ada, ev_s2)


class TestHeteroBandwidth:
    """Per-server bandwidth on the fluid backend (the cell that previously
    could not be differentially tested: heterogeneity used to collapse to
    the cluster mean)."""

    @pytest.mark.parametrize("comm", FLUID_POLICIES)
    def test_agrees_with_event(self, hetero, comm):
        ev = run_scenario_event(hetero, comm=comm)
        fl = run_scenario_fluid(hetero, comm=comm, dt=0.05)
        assert len(ev.jct) == hetero.n_jobs
        assert int(fl["finished"].sum()) == hetero.n_jobs
        assert ev.avg_jct() / RATIO <= fluid_avg(fl) <= ev.avg_jct() * RATIO

    def test_slow_servers_slow_the_fluid_backend(self, hetero):
        """Same workload, homogeneous network: the degraded cluster must
        not finish sooner — proves per-server rates reach the drain loop
        (the old mean-collapse fluid backend got this wrong by design)."""
        import dataclasses

        homog = dataclasses.replace(hetero, params=ContentionParams())
        slow = run_scenario_fluid(hetero, comm="ada", dt=0.05)
        fast = run_scenario_fluid(homog, comm="ada", dt=0.05)
        assert fluid_avg(slow) > fluid_avg(fast)


class TestOversubFabric:
    """Every fluid-supported gating policy on a multi-tier topology: the
    per-domain contention state (NIC + oversubscribed rack uplinks) must
    keep the two backends in qualitative agreement."""

    @pytest.fixture(scope="class")
    def oversub(self):
        return get_scenario("oversub_fabric", **OVERSUB_KW)

    @pytest.mark.parametrize("comm", FLUID_POLICIES)
    def test_agrees_with_event(self, oversub, comm):
        ev = run_scenario_event(oversub, comm=comm)
        fl = run_scenario_fluid(oversub, comm=comm, dt=0.05)
        assert len(ev.jct) == oversub.n_jobs
        assert int(fl["finished"].sum()) == oversub.n_jobs
        assert ev.avg_jct() / RATIO <= fluid_avg(fl) <= ev.avg_jct() * RATIO

    def test_oversub_slows_both_backends(self, oversub):
        """Same workload without the fabric (NIC-only): the oversubscribed
        uplinks must not make anything faster — proves the topology reaches
        the drain loop of each backend, not just the config."""
        import dataclasses

        flat = dataclasses.replace(oversub, topology=None)
        assert run_scenario_event(oversub, comm="ada").avg_jct() >= (
            run_scenario_event(flat, comm="ada").avg_jct() * (1 - 1e-9)
        )
        assert fluid_avg(run_scenario_fluid(oversub, comm="ada", dt=0.05)) >= (
            fluid_avg(run_scenario_fluid(flat, comm="ada", dt=0.05)) * (1 - 1e-9)
        )


class TestRandPlacement:
    """RAND on the fluid backend (gang-random server order vs the event
    backend's per-GPU uniform sample) — closes the parity-matrix gap."""

    def test_agrees_with_event_on_smoke(self, smoke):
        ev = run_scenario_event(smoke, comm="ada", placement="rand")
        fl = run_scenario_fluid(smoke, comm="ada", placement="rand", dt=DT)
        assert len(ev.jct) == smoke.n_jobs
        assert int(fl["finished"].sum()) == smoke.n_jobs
        assert ev.avg_jct() / RATIO <= fluid_avg(fl) <= ev.avg_jct() * RATIO

    def test_deterministic_given_seed(self, smoke):
        a = run_scenario_fluid(smoke, comm="ada", placement="rand", dt=DT)
        b = run_scenario_fluid(smoke, comm="ada", placement="rand", dt=DT)
        np.testing.assert_array_equal(a["jct"], b["jct"])

    def test_every_policy_completes_under_rand(self, smoke):
        for comm in FLUID_POLICIES:
            out = run_scenario_fluid(smoke, comm=comm, placement="rand", dt=DT)
            assert int(out["finished"].sum()) == smoke.n_jobs, comm


class TestRackAwarePlacement:
    """rack_locality: rack-sized jobs behind 6x-oversubscribed uplinks.
    Rack-aware placement (event lwf_rack / fluid rack_pack) must beat the
    topology-blind LWF on both backends — the placement-side payoff of the
    fabric layer."""

    @pytest.fixture(scope="class")
    def rack(self):
        return get_scenario("rack_locality", seed=1)

    def test_rack_aware_beats_plain_lwf_event(self, rack):
        plain = run_scenario_event(rack, comm="ada", placement="lwf")
        aware = run_scenario_event(rack, comm="ada", placement="lwf_rack")
        assert len(aware.jct) == rack.n_jobs
        assert aware.makespan <= plain.makespan * 1.005
        assert aware.avg_jct() <= plain.avg_jct() * 1.005

    def test_rack_aware_beats_plain_lwf_fluid(self, rack):
        # dt=0.1: this cell is step-bound (makespans of hundreds of sim
        # seconds); both runs quantize identically so the ordering holds
        plain = run_scenario_fluid(rack, comm="ada", placement="lwf", dt=0.1)
        aware = run_scenario_fluid(rack, comm="ada", placement="lwf_rack", dt=0.1)
        assert int(aware["finished"].sum()) == rack.n_jobs
        assert float(aware["makespan"]) <= float(plain["makespan"]) * 1.005
        assert fluid_avg(aware) <= fluid_avg(plain) * 1.005


class TestPlacementModes:
    """Fluid gang placement modes vs their event analogues on a workload
    where first-fit fragments multi-server jobs across partially-occupied
    servers (comm + contention) while consolidation gives whole servers."""

    def _scenario(self):
        jobs = []
        jid = 0
        for wave in range(3):
            t = float(wave * 2)
            jobs.append(JobSpec(jid, t, 1, 80, TABLE_III["resnet50"]))
            jid += 1
            jobs.append(JobSpec(jid, t, 4, 40, TABLE_III["vgg16"]))
            jid += 1
        return Scenario(
            name="frag",
            seed=0,
            n_servers=4,
            gpus_per_server=4,
            jobs=tuple(jobs),
            params=ContentionParams(),
        )

    @pytest.mark.parametrize("placement", ["lwf", "ff"])
    def test_each_mode_completes_and_agrees(self, placement):
        scn = self._scenario()
        ev = run_scenario_event(scn, comm="ada", placement=placement)
        fl = run_scenario_fluid(scn, comm="ada", placement=placement, dt=DT)
        assert len(ev.jct) == scn.n_jobs
        assert int(fl["finished"].sum()) == scn.n_jobs
        assert ev.makespan / RATIO <= float(fl["makespan"]) <= ev.makespan * RATIO

    def test_least_loaded_completes_and_consolidates(self):
        """Gang `least_loaded` fills whole servers in L_S order, so its
        event anchor is LWF-kappa — per-GPU list scheduling (LS) instead
        *deliberately* fragments jobs across servers, a shape gang
        placement cannot express (documented parity gap)."""
        scn = self._scenario()
        fl = run_scenario_fluid(scn, comm="ada", placement="ls", dt=DT)
        ev_lwf = run_scenario_event(scn, comm="ada", placement="lwf")
        assert int(fl["finished"].sum()) == scn.n_jobs
        assert (
            ev_lwf.makespan / RATIO
            <= float(fl["makespan"])
            <= ev_lwf.makespan * RATIO
        )

    def test_lwf_beats_ff_on_both_backends(self):
        scn = self._scenario()
        fl_lwf = float(run_scenario_fluid(scn, comm="ada", placement="lwf", dt=DT)["makespan"])
        fl_ff = float(run_scenario_fluid(scn, comm="ada", placement="ff", dt=DT)["makespan"])
        ev_lwf = run_scenario_event(scn, comm="ada", placement="lwf").makespan
        ev_ff = run_scenario_event(scn, comm="ada", placement="ff").makespan
        assert fl_lwf < fl_ff, (fl_lwf, fl_ff)
        assert ev_lwf < ev_ff, (ev_lwf, ev_ff)


class TestModelZoo:
    """The config-derived model zoo (repro.workloads) with WFBP tensor
    fusion, event-vs-fluid: layer-granular profiles, per-bucket gating and
    the static [jobs, buckets] fluid drain must keep the backends in
    qualitative agreement (smoke-sized for tier-1 budget)."""

    ZOO_KW = dict(seed=1, n_jobs=8, min_iters=10, max_iters=40, horizon_s=300.0)

    @pytest.fixture(scope="class")
    def zoo(self):
        return get_scenario("model_zoo", **self.ZOO_KW)

    @pytest.mark.parametrize("comm", ["ada", "srsf2", "kway2", "kway3"])
    def test_agrees_with_event(self, zoo, comm):
        ev = run_scenario_event(zoo, comm=comm)
        fl = run_scenario_fluid(zoo, comm=comm, dt=0.02)
        assert len(ev.jct) == zoo.n_jobs
        assert int(fl["finished"].sum()) == zoo.n_jobs
        assert (
            ev.avg_jct() / FUSION_RATIO
            <= fluid_avg(fl)
            <= ev.avg_jct() * FUSION_RATIO
        )

    @pytest.mark.parametrize("comm", ["ada", "kway3"])
    def test_fusion_sweep_cell_agrees(self, comm):
        from repro.scenarios import QUICK_OVERRIDES

        # dt=0.01 shares the compiled graph with
        # test_fluid_deterministic_with_buckets below (same config)
        scn = get_scenario("fusion_sweep", seed=1, **QUICK_OVERRIDES["fusion_sweep"])
        ev = run_scenario_event(scn, comm=comm)
        fl = run_scenario_fluid(scn, comm=comm, dt=0.01)
        assert len(ev.jct) == scn.n_jobs
        assert int(fl["finished"].sum()) == scn.n_jobs
        assert (
            ev.avg_jct() / FUSION_RATIO
            <= fluid_avg(fl)
            <= ev.avg_jct() * FUSION_RATIO
        )

    def test_fluid_deterministic_with_buckets(self):
        from repro.scenarios import QUICK_OVERRIDES

        scn = get_scenario("fusion_sweep", seed=1, **QUICK_OVERRIDES["fusion_sweep"])
        a = run_scenario_fluid(scn, comm="ada", dt=0.01)
        b = run_scenario_fluid(scn, comm="ada", dt=0.01)
        np.testing.assert_array_equal(a["jct"], b["jct"])


class TestSchedScenarios:
    """The preemptive/elastic workloads under their *static* defaults,
    event-vs-fluid.  Preemption and elasticity themselves are event-only
    (the fluid backend's static traces cannot express mid-run gang
    teardown — see the parity matrix), so the differential cell pins the
    shared static baseline both regression locks are measured against."""

    @pytest.mark.parametrize(
        "name,seed", [("preemption_gain", 2), ("elastic_surge", 1)]
    )
    def test_static_mode_agrees(self, name, seed):
        scn = get_scenario(name, seed=seed)
        assert scn.sched == "static"
        ev = run_scenario_event(scn, comm="ada")
        fl = run_scenario_fluid(scn, comm="ada", dt=0.1)
        assert len(ev.jct) == scn.n_jobs
        assert ev.censored == 0
        assert int(fl["finished"].sum()) == scn.n_jobs
        assert ev.avg_jct() / RATIO <= fluid_avg(fl) <= ev.avg_jct() * RATIO


class TestNoCommLimit:
    """Single-server jobs have no communication: both backends reduce to
    pure compute and must agree to within the fluid dt quantization."""

    def _scenario(self):
        jobs = (
            JobSpec(0, 0.0, 1, 40, TABLE_III["resnet50"]),
            JobSpec(1, 0.0, 1, 25, TABLE_III["vgg16"]),
        )
        return Scenario(
            name="nocomm",
            seed=0,
            n_servers=2,
            gpus_per_server=2,
            jobs=jobs,
            params=ContentionParams(),
        )

    def test_exact_agreement_modulo_dt(self):
        scn = self._scenario()
        dt = 0.01
        ev = run_scenario_event(scn, comm="ada")
        fl = run_scenario_fluid(scn, comm="ada", dt=dt)
        assert int(fl["finished"].sum()) == 2
        for job in scn.jobs:
            expect = ev.jct[job.job_id]
            got = float(fl["jct"][job.job_id])
            # fixed-dt integration rounds every iteration up to a multiple
            # of dt, and admission lags up to a couple of steps
            assert got == pytest.approx(expect, abs=dt * (job.iterations + 5))
