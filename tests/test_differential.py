"""Cross-backend differential harness: the exact event simulator
(``core/simulator.py``) vs the vectorized fluid simulator
(``core/jaxsim.py``) on the deterministic smoke scenario.

The fluid backend is a documented approximation (gang-exclusive placement,
fixed dt, single admission per step), so agreement is *qualitative*:
completeness, bounded JCT/makespan ratios, determinism, and the
no-contention limit where both backends are exact.

This harness is what caught the fluid gating self-deadlock (a waiting
all-reduce counted itself as an active transfer and never started under
ada/srsf1) — keep it green."""

import numpy as np
import pytest

from repro.core.cluster import TABLE_III, JobSpec
from repro.scenarios import get_scenario, run_scenario_event, run_scenario_fluid
from repro.scenarios.registry import Scenario
from repro.core.contention import ContentionParams

DT = 0.02
#: fluid-vs-event tolerance on aggregate metrics (gang placement makes the
#: fluid backend pessimistic on shared-GPU scenarios)
RATIO = 2.0


@pytest.fixture(scope="module")
def smoke():
    return get_scenario("smoke")


@pytest.fixture(scope="module")
def event_res(smoke):
    return run_scenario_event(smoke, comm="ada")


@pytest.fixture(scope="module")
def fluid_res(smoke):
    return run_scenario_fluid(smoke, comm="ada", dt=DT)


class TestSmokeAgreement:
    def test_both_backends_finish_everything(self, smoke, event_res, fluid_res):
        assert len(event_res.jct) == smoke.n_jobs
        assert int(fluid_res["finished"].sum()) == smoke.n_jobs

    def test_avg_jct_within_ratio(self, event_res, fluid_res):
        ev = event_res.avg_jct()
        fl = float(fluid_res["jct"][fluid_res["finished"]].mean())
        assert ev / RATIO <= fl <= ev * RATIO, (ev, fl)

    def test_makespan_within_ratio(self, event_res, fluid_res):
        ev = event_res.makespan
        fl = float(fluid_res["makespan"])
        assert ev / RATIO <= fl <= ev * RATIO, (ev, fl)

    @pytest.mark.parametrize("comm", ["ada", "srsf1", "srsf2"])
    def test_no_policy_strands_jobs(self, smoke, comm):
        """Regression for the fluid gating self-deadlock: every policy must
        complete the smoke scenario's multi-server jobs."""
        out = run_scenario_fluid(smoke, comm=comm, dt=DT)
        assert int(out["finished"].sum()) == smoke.n_jobs, comm

    def test_fluid_deterministic(self, smoke, fluid_res):
        again = run_scenario_fluid(smoke, comm="ada", dt=DT)
        np.testing.assert_array_equal(fluid_res["jct"], again["jct"])


class TestNoCommLimit:
    """Single-server jobs have no communication: both backends reduce to
    pure compute and must agree to within the fluid dt quantization."""

    def _scenario(self):
        jobs = (
            JobSpec(0, 0.0, 1, 40, TABLE_III["resnet50"]),
            JobSpec(1, 0.0, 1, 25, TABLE_III["vgg16"]),
        )
        return Scenario(
            name="nocomm",
            seed=0,
            n_servers=2,
            gpus_per_server=2,
            jobs=jobs,
            params=ContentionParams(),
        )

    def test_exact_agreement_modulo_dt(self):
        scn = self._scenario()
        dt = 0.01
        ev = run_scenario_event(scn, comm="ada")
        fl = run_scenario_fluid(scn, comm="ada", dt=dt)
        assert int(fl["finished"].sum()) == 2
        for job in scn.jobs:
            expect = ev.jct[job.job_id]
            got = float(fl["jct"][job.job_id])
            # fixed-dt integration rounds every iteration up to a multiple
            # of dt, and admission lags up to a couple of steps
            assert got == pytest.approx(expect, abs=dt * (job.iterations + 5))
