"""Substrate tests: optimizer, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule


class TestAdamW:
    def test_quadratic_converges(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params, cfg)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(loss(params)) < 1e-2

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params, cfg)
        _, _, m = adamw_update(params, {"w": jnp.full(3, 100.0)}, state, cfg)
        assert float(m["grad_norm"]) > 1.0  # reported norm is pre-clip

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5)
        params = {"w": jnp.asarray([10.0])}
        state = adamw_init(params, cfg)
        p2, _, _ = adamw_update(params, {"w": jnp.zeros(1)}, state, cfg)
        assert float(p2["w"][0]) < 10.0

    def test_moment_dtype(self):
        cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
        state = adamw_init({"w": jnp.zeros((4, 4))}, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_cosine_schedule(self):
        sched = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
        assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
        assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


class TestDataPipeline:
    def test_deterministic_and_step_addressable(self):
        cfg = get_config("llama3.2-1b", reduced=True)
        ds = SyntheticLMDataset(cfg, batch=2, seq_len=16, seed=7)
        b1 = ds.batch_at(5)
        b2 = ds.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(ds.batch_at(6)["tokens"], b1["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = get_config("llama3.2-1b", reduced=True)
        ds = SyntheticLMDataset(cfg, batch=1, seq_len=8, seed=0)
        b = ds.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_tokens_in_vocab(self):
        cfg = get_config("mamba2-130m", reduced=True)
        ds = SyntheticLMDataset(cfg, batch=4, seq_len=32, seed=1)
        b = ds.batch_at(3)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size

    def test_modality_stub_shapes(self):
        cfg = get_config("seamless-m4t-large-v2", reduced=True)
        ds = SyntheticLMDataset(cfg, batch=2, seq_len=8, seed=0)
        b = ds.batch_at(0)
        assert b["audio_embeds"].shape == (2, cfg.audio_frames, cfg.d_model)

    def test_prefetch_iterator(self):
        cfg = get_config("llama3.2-1b", reduced=True)
        ds = SyntheticLMDataset(cfg, batch=2, seq_len=16, seed=0)
        it = make_train_iterator(ds, start_step=3)
        batch = next(it)
        it.close()
        np.testing.assert_array_equal(
            np.asarray(batch["tokens"]), ds.batch_at(3)["tokens"]
        )


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.asarray(7)},
        }
        save(str(tmp_path), 42, tree, extra={"note": "hi"})
        restored, step, extra = restore(str(tmp_path), tree)
        assert step == 42 and extra["note"] == "hi"
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        assert restored["nested"]["b"].dtype == jnp.bfloat16

    def test_latest_step(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        assert latest_step(str(tmp_path)) is None
        save(str(tmp_path), 1, tree)
        save(str(tmp_path), 5, tree)
        assert latest_step(str(tmp_path)) == 5

    def test_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 0, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            restore(str(tmp_path), {"a": jnp.zeros((3, 3))})

    def test_training_resume_equivalence(self, tmp_path):
        """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
        from repro.launch.steps import make_train_step
        from repro.models.lm import LM, RunFlags

        cfg = get_config("llama3.2-1b", reduced=True)
        lm = LM(cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        flags = RunFlags(remat="none", q_chunk=16)
        step_fn = jax.jit(make_train_step(lm, opt_cfg, flags))
        ds = SyntheticLMDataset(cfg, batch=2, seq_len=16, seed=0)

        def batch_at(i):
            return {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}

        p = lm.init(jax.random.PRNGKey(0))
        o = adamw_init(p, opt_cfg)
        for i in range(2):
            p, o, _ = step_fn(p, o, batch_at(i))
        save(str(tmp_path), 2, (p, o))
        for i in range(2, 4):
            p, o, m_straight = step_fn(p, o, batch_at(i))

        (p2, o2), _, _ = restore(str(tmp_path), (lm.init(jax.random.PRNGKey(0)), adamw_init(lm.init(jax.random.PRNGKey(0)), opt_cfg)))
        for i in range(2, 4):
            p2, o2, m_resumed = step_fn(p2, o2, batch_at(i))
        assert float(m_straight["loss"]) == pytest.approx(
            float(m_resumed["loss"]), rel=1e-5
        )
