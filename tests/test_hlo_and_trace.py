"""Unit tests: HLO collective parser, trace generator, analytic flops."""

import pytest

from repro.core.trace import is_large, is_long, paper_trace
from repro.launch.hlo import collective_bytes, _bytes_of_type


class TestHloParser:
    def test_bytes_of_type(self):
        assert _bytes_of_type("bf16[16,4096,128]{2,1,0}") == 16 * 4096 * 128 * 2
        assert _bytes_of_type("f32[]") == 4
        assert _bytes_of_type("(bf16[8,8]{1,0}, f32[4]{0})") == 8 * 8 * 2 + 16

    def test_collective_parse(self):
        hlo = """
  %ar = bf16[1024,512]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %ag = f32[64,256]{1,0} all-gather(%y), dimensions={0}
  %p.1 = pred[] compare(%a, %b)
  %rs = (bf16[32]{0}, bf16[32]{0}) reduce-scatter(%u, %v), dimensions={0}
  %done = bf16[2]{0} all-reduce-done(%start)
"""
        res = collective_bytes(hlo)
        assert res["all-reduce"] == 1024 * 512 * 2
        assert res["all-gather"] == 64 * 256 * 4
        assert res["reduce-scatter"] == 2 * 32 * 2
        assert res["op_counts"]["all-reduce"] == 1  # -done skipped
        assert res["total"] == res["all-reduce"] + res["all-gather"] + res["reduce-scatter"]

    def test_async_start_counted_once(self):
        hlo = """
  %s = bf16[128]{0} all-gather-start(%x), dimensions={0}
  %d = bf16[128]{0} all-gather-done(%s)
"""
        res = collective_bytes(hlo)
        assert res["op_counts"]["all-gather"] == 1


class TestTrace:
    def test_job_count_and_sorted(self):
        jobs = paper_trace(seed=0)
        assert len(jobs) == 160
        arr = [j.arrival for j in jobs]
        assert arr == sorted(arr)

    def test_gpu_distribution_roughly_papers(self):
        jobs = paper_trace(seed=0)
        ones = sum(1 for j in jobs if j.n_gpus == 1)
        assert 60 <= ones <= 100  # paper: 80 of 160
        assert any(j.n_gpus == 32 for j in jobs)

    def test_iterations_range(self):
        jobs = paper_trace(seed=1)
        assert all(1000 <= j.iterations <= 6000 for j in jobs)

    def test_deterministic_by_seed(self):
        a = paper_trace(seed=5)
        b = paper_trace(seed=5)
        assert [(j.arrival, j.n_gpus, j.iterations) for j in a] == [
            (j.arrival, j.n_gpus, j.iterations) for j in b
        ]

    def test_large_long_characterization(self):
        jobs = paper_trace(seed=0)
        assert any(is_large(j) for j in jobs) and any(is_long(j) for j in jobs)

    def test_scaling(self):
        jobs = paper_trace(seed=0, n_jobs=40)
        assert len(jobs) == 40


class TestAnalyticFlops:
    def test_moe_active_less_than_total(self):
        from repro.configs import get_config

        cfg = get_config("olmoe-1b-7b")
        assert cfg.active_param_count() < cfg.param_count()
        # OLMoE: ~1B active of ~7B total
        assert cfg.param_count() / 1e9 == pytest.approx(6.9, rel=0.25)
        assert cfg.active_param_count() / 1e9 == pytest.approx(1.3, rel=0.35)

    def test_dense_param_counts_sane(self):
        from repro.configs import get_config

        for arch, total_b in [
            ("llama3.2-1b", 1.24),
            ("yi-9b", 8.8),
            ("gemma-7b", 8.5),
            ("phi4-mini-3.8b", 3.8),
            ("mamba2-130m", 0.13),
        ]:
            cfg = get_config(arch)
            got = cfg.param_count() / 1e9
            assert got == pytest.approx(total_b, rel=0.30), f"{arch}: {got}B"

    def test_arctic_is_huge(self):
        from repro.configs import get_config

        cfg = get_config("arctic-480b")
        assert cfg.param_count() / 1e9 > 300
        assert cfg.active_param_count() / 1e9 < 30
