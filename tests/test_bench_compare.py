"""``benchmarks/compare.py`` gate behavior: regression detection, the
vanished-key warning (a renamed bench cell must not silently detach from
the gate), and the --max-wall absolute bound."""

import json

import pytest

from benchmarks.compare import compare_pair, main, throughput_keys, vanished_keys


def write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


class TestComparePair:
    def test_detects_regression_and_pass(self, tmp_path):
        base = write(tmp_path, "b.json", {"events_per_sec": 100.0})
        ok = write(tmp_path, "ok.json", {"events_per_sec": 95.0})
        bad = write(tmp_path, "bad.json", {"events_per_sec": 50.0})
        _, regs, warns = compare_pair(base, ok, threshold=0.2)
        assert regs == [] and warns == []
        _, regs, _ = compare_pair(base, bad, threshold=0.2)
        assert len(regs) == 1 and "events_per_sec" in regs[0]

    def test_vanished_key_warns_but_does_not_fail(self, tmp_path):
        base = write(
            tmp_path, "b.json",
            {"events_per_sec": 100.0, "stress_events_per_sec": 40.0},
        )
        cur = write(tmp_path, "c.json", {"events_per_sec": 100.0})
        lines, regs, warns = compare_pair(base, cur, threshold=0.2)
        assert regs == []
        assert len(warns) == 1 and "stress_events_per_sec" in warns[0]
        assert any("MISSING" in ln for ln in lines)
        # exit code stays 0: a warning, not a gate failure
        assert main([base, cur]) == 0

    def test_key_helpers(self):
        base = {"a_per_sec": 1.0, "b_per_sec": 2.0, "wall_s": 9.0, "note": "x"}
        cur = {"a_per_sec": 1.1, "b_per_sec": "broken"}
        assert throughput_keys(base, cur) == ["a_per_sec"]
        assert vanished_keys(base, cur) == ["b_per_sec"]

    def test_regression_exits_nonzero(self, tmp_path):
        base = write(tmp_path, "b.json", {"events_per_sec": 100.0})
        bad = write(tmp_path, "bad.json", {"events_per_sec": 10.0})
        assert main([base, bad]) == 1

    def test_max_wall_bound(self, tmp_path):
        base = write(tmp_path, "b.json", {"events_per_sec": 1.0, "wall_s": 5.0})
        cur = write(tmp_path, "c.json", {"events_per_sec": 1.0, "wall_s": 7.0})
        assert main([base, cur, "--max-wall", "wall_s=10"]) == 0
        assert main([base, cur, "--max-wall", "wall_s=6"]) == 1
        # absent bound key fails too (rename must not disarm the bound)
        assert main([base, cur, "--max-wall", "gone_s=6"]) == 1
