"""Fast-path equivalence locks for the chunked fluid simulator.

The hot-loop rebuild (``core/jaxsim.py``) introduced three switchable
mechanisms — the one-shot gating fixed point (``gating="fixedpoint"`` vs
the legacy 4-round loop), periodic lane/job compaction (``compact``), and
next-event skipping (``skip``).  This module pins the equivalences the
refactor promised:

* fixed point vs rounds: bit-exact metrics on the fusion x policy grid
  (both sides run ``skip=False`` — the two gating variants define the
  conservative ``leftover`` mask differently, which legitimately changes
  *which* ticks the skipper may jump, so skip must be held constant for a
  bit-exact comparison);
* compaction on vs off: bit-exact metrics on two registry cells (lane
  retirement + job-axis trimming are pure re-indexing);
* recompile guard: the whole 6-policy gating matrix shares at most two
  compiled chunk graphs per trace shape (threshold policies ride the
  dynamic-policy sentinel, exact k-way the second graph);
* the streaming-arrival engine stress cell scales linearly in events and
  keeps the calendar bounded by arrivals + O(cluster).
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import jaxsim
from repro.core.jaxsim import (
    simulate_traces_batched,
    stack_traces,
    trace_from_jobs,
)
from repro.scenarios import QUICK_OVERRIDES, get_scenario
from repro.scenarios.sweep import FLUID_POLICIES, fluid_config


def _run_cell(scn_name, seeds, comm="ada", placement="lwf", fusion=None,
              dt=0.05, **fast_kw):
    """One batched fluid run of a registry cell, as plain numpy arrays."""
    over = QUICK_OVERRIDES.get(scn_name, {})
    scns = [get_scenario(scn_name, seed=s, **over) for s in seeds]
    cfg = fluid_config(scns[0], comm=comm, placement=placement, dt=dt,
                       **fast_kw)
    fus = scns[0].fusion if fusion is None else fusion
    batch = stack_traces(
        [trace_from_jobs(s.job_list(), fusion=fus) for s in scns]
    )
    out = simulate_traces_batched(batch, cfg)
    return {k: np.asarray(v) for k, v in out.items()}


def _assert_identical(a, b):
    np.testing.assert_array_equal(a["finished"], b["finished"])
    np.testing.assert_array_equal(a["jct"], b["jct"])
    np.testing.assert_array_equal(a["makespan"], b["makespan"])


class TestGatingFixedPoint:
    """One-shot analytic gating == the legacy 4-round re-gating loop."""

    @pytest.mark.parametrize("fusion", ["none", 16e6])
    @pytest.mark.parametrize("comm", ["ada", "srsf2", "kway2"])
    def test_bit_exact_on_fusion_policy_grid(self, fusion, comm):
        kw = dict(seeds=(0, 1), comm=comm, fusion=fusion, skip=False)
        fp = _run_cell("model_zoo", gating="fixedpoint", **kw)
        rounds = _run_cell("model_zoo", gating="rounds", **kw)
        _assert_identical(fp, rounds)
        assert fp["finished"].any()  # the cell actually exercises gating

    def test_monolithic_trace_unaffected_by_gating_knob(self):
        # fusion="all" has one bucket: the wfbp closure never runs, so the
        # knob must be inert there (same compiled mono path)
        kw = dict(seeds=(0,), comm="ada", fusion="all", skip=False)
        fp = _run_cell("model_zoo", gating="fixedpoint", **kw)
        rounds = _run_cell("model_zoo", gating="rounds", **kw)
        _assert_identical(fp, rounds)

    def test_unknown_gating_rejected(self):
        scn = get_scenario("smoke")
        with pytest.raises(ValueError, match="gating"):
            cfg = fluid_config(scn, gating="psychic")
            batch = stack_traces([trace_from_jobs(scn.job_list())])
            simulate_traces_batched(batch, cfg)


class TestCompaction:
    """Lane retirement / job-axis trimming is pure re-indexing: metrics on
    the registry cells are bit-identical with compaction disabled."""

    def test_oversub_fabric_cell(self):
        kw = dict(seeds=(0, 1, 2, 3), comm="ada")
        on = _run_cell("oversub_fabric", compact=True, **kw)
        off = _run_cell("oversub_fabric", compact=False, **kw)
        _assert_identical(on, off)

    def test_model_zoo_wfbp_cell(self):
        kw = dict(seeds=(0, 1), comm="srsf2", fusion=16e6)
        on = _run_cell("model_zoo", compact=True, **kw)
        off = _run_cell("model_zoo", compact=False, **kw)
        _assert_identical(on, off)


class TestRecompileGuard:
    def test_policy_matrix_shares_compiled_graphs(self):
        """All six gating policies at one trace shape compile at most two
        chunk graphs: every threshold policy (ada / srsf1-3) traces through
        the dynamic-policy sentinel with thresholds as runtime arrays, and
        the exact k-way policies share the lookahead graph.  ``compact``
        is off so the whole run stays at one (lane, job, bucket) shape."""
        over = QUICK_OVERRIDES["oversub_fabric"]
        scn = get_scenario("oversub_fabric", seed=0, **over)
        batch = stack_traces([trace_from_jobs(scn.job_list())])
        before = jaxsim._chunk_jit._cache_size()
        for comm in FLUID_POLICIES:
            cfg = fluid_config(scn, comm=comm, compact=False)
            out = simulate_traces_batched(batch, cfg)
            assert np.asarray(out["finished"]).any()
        grown = jaxsim._chunk_jit._cache_size() - before
        assert grown <= 2, (
            f"6-policy matrix compiled {grown} new chunk graphs (expected "
            "<= 2: one dynamic-threshold, one exact k-way)"
        )


class TestEngineStreamStress:
    """Smoke-sized twin of the ``--only engine`` 10k-job stress cell."""

    def _run(self, n_jobs):
        from benchmarks.run import stream_trace

        from repro.core import simulate

        jobs = stream_trace(n_jobs, seed=0)
        return simulate(jobs, placement="lwf", comm="ada",
                        n_servers=16, gpus_per_server=2)

    def test_events_linear_and_calendar_bounded(self):
        small = self._run(250)
        big = self._run(500)
        assert len(small.jct) == 250 and len(big.jct) == 500
        # iteration counts are iid across jobs: events scale ~linearly
        ratio = big.events_processed / small.events_processed
        assert 1.7 < ratio < 2.3, ratio
        # the calendar holds every future arrival (pushed up front) plus a
        # bounded set of live simulation events — O(cluster), not O(jobs)
        n_gpus = 16 * 2
        assert small.peak_calendar <= 250 + 2 * n_gpus
        assert big.peak_calendar <= 500 + 2 * n_gpus
        assert big.peak_calendar >= 500  # arrivals alone reach n_jobs

    def test_streaming_feed_keeps_calendar_o_cluster(self):
        """The same workload through a TraceSource: identical results, but
        the calendar peak is bounded by live jobs + O(cluster) instead of
        growing with the trace length — the invariant that lets the nightly
        100k-job replay run in bounded memory."""
        from benchmarks.run import stream_trace

        from repro.core import simulate
        from repro.core.trace import ListTraceSource

        n_gpus = 16 * 2
        peaks = {}
        for n_jobs in (250, 500):
            jobs = stream_trace(n_jobs, seed=0)
            kw = dict(placement="lwf", comm="ada",
                      n_servers=16, gpus_per_server=2)
            lst = simulate(jobs, **kw)
            stream = simulate(ListTraceSource(jobs), **kw)
            assert stream.jct == lst.jct
            assert stream.finish == lst.finish
            assert stream.events_processed == lst.events_processed
            peaks[n_jobs] = stream.peak_calendar
            # one-ahead arrival + per-run events: O(live + cluster)
            assert stream.peak_calendar <= 4 * n_gpus, stream.peak_calendar
        # doubling the trace must NOT grow the streaming calendar —
        # footprint tracks concurrency, not trace length
        assert peaks[500] <= peaks[250] + n_gpus // 2, peaks
