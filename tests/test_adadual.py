"""Property tests for AdaDUAL (paper Theorems 1-2, Algorithm 2) against an
exact brute-force integrator of the Eq. (5) dynamics, plus sanity for the
beyond-paper k-way generalization."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.adadual import (
    adadual_should_start,
    c1_average_completion,
    c2a_average_completion,
    c2b_average_completion,
    candidate_minima,
    kway_adadual_should_start,
    simulate_task_set,
    simulate_two_tasks,
)
from repro.core.contention import ContentionParams

PARAMS = st.builds(
    ContentionParams,
    a=st.just(0.0),  # P1 neglects the latency term
    b=st.floats(1e-10, 5e-9),
    eta=st.floats(0.0, 5e-9),
)
SIZES = st.floats(1e6, 1e9)


class TestIntegrator:
    @given(PARAMS, SIZES)
    @settings(max_examples=50, deadline=None)
    def test_single_task_time(self, p, m):
        (t,) = simulate_task_set([0.0], [m], p)
        assert t == pytest.approx(p.b * m, rel=1e-9)

    @given(PARAMS, SIZES)
    @settings(max_examples=50, deadline=None)
    def test_simultaneous_equal_tasks(self, p, m):
        """Two equal tasks fully contended: both finish at (2b+eta)*M."""
        t1, t2 = simulate_two_tasks(0.0, m, m, p)
        expect = (2 * p.b + p.eta) * m
        assert t1 == pytest.approx(expect, rel=1e-9)
        assert t2 == pytest.approx(expect, rel=1e-9)

    @given(PARAMS, SIZES, SIZES)
    @settings(max_examples=50, deadline=None)
    def test_sequential_no_contention(self, p, m1, m2):
        """Second task started after the first finishes: no contention."""
        t1, t2 = simulate_two_tasks(p.b * m1, m1, m2, p)
        assert t1 == pytest.approx(p.b * m1, rel=1e-9)
        assert t2 == pytest.approx(p.b * (m1 + m2), rel=1e-9)


class TestTheorem1:
    """C1 (small task first): waiting until t1 = b*M1 is optimal, and the
    closed form Eq. (10c)/(14a) matches the exact integrator."""

    @given(PARAMS, SIZES, SIZES, st.floats(0.0, 1.0))
    @settings(max_examples=100, deadline=None)
    def test_closed_form_matches_integrator(self, p, ma, mb, frac):
        m1, m2 = sorted([ma, mb])
        t = frac * p.b * m1
        t1, t2 = simulate_two_tasks(t, m1, m2, p)
        assert (t1 + t2) / 2 == pytest.approx(
            c1_average_completion(t, m1, m2, p), rel=1e-6
        )

    @given(PARAMS, SIZES, SIZES)
    @settings(max_examples=100, deadline=None)
    def test_t1_is_optimal(self, p, ma, mb):
        m1, m2 = sorted([ma, mb])
        t_star = p.b * m1
        best = sum(simulate_two_tasks(t_star, m1, m2, p)) / 2
        for frac in np.linspace(0.0, 0.999, 8):
            t = frac * t_star
            avg = sum(simulate_two_tasks(t, m1, m2, p)) / 2
            assert best <= avg + 1e-9 * max(1.0, avg)


class TestTheorem2:
    """C2 (large task first): optimum is t=0 iff M1/M2 < b/(2(b+eta))."""

    @given(PARAMS, SIZES, SIZES, st.floats(0.0, 1.0))
    @settings(max_examples=100, deadline=None)
    def test_closed_forms_match_integrator(self, p, ma, mb, frac):
        m1, m2 = sorted([ma, mb])
        boundary = p.b * (m2 - m1)
        # sub-case (a): whole small message contended
        t = frac * boundary
        avg = sum(simulate_two_tasks(t, m2, m1, p)) / 2
        assert avg == pytest.approx(c2a_average_completion(t, m1, m2, p), rel=1e-6)
        # sub-case (b): partial contention
        t = boundary + frac * (p.b * m2 - boundary)
        avg = sum(simulate_two_tasks(t, m2, m1, p)) / 2
        assert avg == pytest.approx(c2b_average_completion(t, m1, m2, p), rel=1e-6)

    @given(PARAMS, SIZES, SIZES)
    @settings(max_examples=150, deadline=None)
    def test_threshold_decision_is_optimal(self, p, ma, mb):
        m1, m2 = sorted([ma, mb])
        if m1 == m2:
            return
        start_now = sum(simulate_two_tasks(0.0, m2, m1, p)) / 2
        wait = sum(simulate_two_tasks(p.b * m2, m2, m1, p)) / 2
        if m1 / m2 < p.dual_threshold - 1e-9:
            assert start_now <= wait + 1e-9 * wait
        elif m1 / m2 > p.dual_threshold + 1e-9:
            assert wait <= start_now + 1e-9 * start_now

    @given(PARAMS, SIZES, SIZES)
    @settings(max_examples=100, deadline=None)
    def test_eq14_ordering(self, p, ma, mb):
        """Eq. (14): the C1 candidate (run smaller first) is never worse."""
        m1, m2 = sorted([ma, mb])
        c1, c2a, c2b = candidate_minima(m1, m2, p)
        assert c1 <= c2a + 1e-12
        assert c1 <= c2b + 1e-12


class TestAlgorithm2:
    def test_no_contention_starts(self):
        assert adadual_should_start(1e8, [], 0, ContentionParams())

    def test_two_plus_existing_rejects(self):
        assert not adadual_should_start(1.0, [1e9, 1e9], 2, ContentionParams())

    def test_threshold_rule(self):
        p = ContentionParams()
        m_old = 1e8
        below = (p.dual_threshold * 0.9) * m_old
        above = (p.dual_threshold * 1.1) * m_old
        assert adadual_should_start(below, [m_old], 1, p)
        assert not adadual_should_start(above, [m_old], 1, p)

    def test_multiple_olds_conservative(self):
        """max_concurrent==1 with several disjoint olds: all must pass."""
        p = ContentionParams()
        small = p.dual_threshold * 0.5 * 1e8
        assert adadual_should_start(small, [1e8, 1e8], 1, p)
        assert not adadual_should_start(small, [1e8, small / p.dual_threshold * 0.5], 1, p)


class TestKWay:
    """Beyond-paper k-way rule: must agree with AdaDUAL on the 1-old case's
    clear regions and never start above max_ways."""

    @given(PARAMS, SIZES, SIZES)
    @settings(max_examples=100, deadline=None)
    def test_agrees_with_theorem2_on_one_old(self, p, m_new, m_old):
        ratio = m_new / m_old
        if abs(ratio - p.dual_threshold) / p.dual_threshold < 0.05:
            return  # skip the numerically-degenerate boundary
        expected = ratio < p.dual_threshold
        assert kway_adadual_should_start(m_new, [m_old], p) == expected

    def test_max_ways_guard(self):
        p = ContentionParams()
        assert not kway_adadual_should_start(1.0, [1e9] * 4, p, max_ways=4)

    def test_empty_starts(self):
        assert kway_adadual_should_start(1e8, [], ContentionParams())

    def test_tiny_vs_two_large_starts(self):
        """A tiny task against two huge ones should start (its completion
        barely hurts them) under the lookahead rule with max_ways>=3."""
        p = ContentionParams(a=0.0)
        assert kway_adadual_should_start(1e5, [1e9, 1e9], p, max_ways=3)
