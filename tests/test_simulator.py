"""End-to-end behaviour tests for the cluster simulator (Algorithm 3) —
completeness, DAG validity, analytic cross-checks, contention anecdotes,
and policy orderings from the paper."""

import math

import pytest

from repro.core import (
    Cluster,
    ContentionParams,
    JobSpec,
    PlacementPolicy,
    TABLE_III,
    paper_trace,
    simulate,
)
from repro.core.dag import build_job_dag, TaskKind, TaskRef, validate_schedule
from repro.core.simulator import AdaDual, ClusterSimulator, SrsfN

PARAMS = ContentionParams()


def mk_jobs(specs):
    return [
        JobSpec(i, arr, n, iters, TABLE_III[model])
        for i, (arr, n, iters, model) in enumerate(specs)
    ]


class TestSingleJob:
    def test_single_gpu_job_exact_jct(self):
        """One 1-GPU job: JCT == (t_f + t_b) * iters exactly."""
        jobs = mk_jobs([(0.0, 1, 100, "resnet50")])
        res = simulate(jobs)
        expect = TABLE_III["resnet50"].t_iter_compute * 100
        assert res.jct[0] == pytest.approx(expect, rel=1e-9)

    def test_single_server_job_has_no_comm(self):
        """4 GPUs on one server (LWF consolidates): no comm overhead."""
        jobs = mk_jobs([(0.0, 4, 50, "vgg16")])
        res = simulate(jobs)
        expect = TABLE_III["vgg16"].t_iter_compute * 50
        assert res.jct[0] == pytest.approx(expect, rel=1e-9)
        assert res.comm_started_clean == 0

    def test_multi_server_job_pays_allreduce(self):
        """8-GPU job spans 2 servers: JCT = (compute + a + b*M) * iters."""
        jobs = mk_jobs([(0.0, 8, 50, "resnet50")])
        res = simulate(jobs)
        m = TABLE_III["resnet50"]
        per_iter = m.t_iter_compute + PARAMS.a + PARAMS.b * m.size_bytes
        assert res.jct[0] == pytest.approx(per_iter * 50, rel=1e-6)
        assert res.comm_started_clean == 50

    def test_arrival_offsets_jct(self):
        jobs = mk_jobs([(10.0, 1, 100, "lstm_ptb")])
        res = simulate(jobs)
        assert res.finish[0] == pytest.approx(
            10.0 + TABLE_III["lstm_ptb"].t_iter_compute * 100
        )
        assert res.jct[0] == pytest.approx(TABLE_III["lstm_ptb"].t_iter_compute * 100)


class TestCompleteness:
    @pytest.mark.parametrize("comm", ["srsf1", "srsf2", "srsf3", "ada"])
    def test_all_jobs_finish(self, comm):
        jobs = paper_trace(seed=1, n_jobs=60, min_iters=50, max_iters=300)
        res = simulate(jobs, comm=comm)
        assert len(res.jct) == 60, f"{comm}: {60 - len(res.jct)} jobs never finished"

    @pytest.mark.parametrize("placement", ["rand", "ff", "ls", "lwf"])
    def test_all_jobs_finish_any_placement(self, placement):
        jobs = paper_trace(seed=2, n_jobs=40, min_iters=50, max_iters=200)
        res = simulate(jobs, placement=placement)
        assert len(res.jct) == 40

    def test_oversubscribed_memory_queueing(self):
        """More concurrent jobs than memory: they must queue, then all run."""
        jobs = mk_jobs([(0.0, 1, 50, "vgg16")] * 40)  # 4527 MB x 40 on 1 server
        res = simulate(jobs, n_servers=1, gpus_per_server=4)
        assert len(res.jct) == 40
        assert max(res.queueing_delay.values()) > 0.0


class TestDagValidity:
    def test_simulated_schedule_is_valid_dag_execution(self):
        """Record per-task intervals and validate them against the formal DAG
        of Fig. 3 for every job (barrier + chain edges)."""
        jobs = paper_trace(seed=3, n_jobs=12, min_iters=5, max_iters=20)
        res = simulate(jobs, record_trace=True, fuse_fb=False)
        assert res.task_trace is not None
        per_job = {}
        for (jid, it, kind, w, t0, t1) in res.task_trace:
            per_job.setdefault(jid, {})[
                TaskRef(jid, it, TaskKind(kind), w if kind != "c" else -1)
            ] = (t0, t1)
        assert len(res.jct) == 12
        sim_runs = {j.job_id: j for j in jobs}
        for jid, intervals in per_job.items():
            spec = sim_runs[jid]
            has_comm = any(k.kind is TaskKind.ALLREDUCE for k in intervals)
            dag = build_job_dag(jid, spec.n_gpus, spec.iterations, has_comm)
            ok, msg = validate_schedule(dag, intervals)
            assert ok, f"job {jid}: {msg}"

    def test_gpu_never_double_booked(self):
        """No two compute tasks may overlap on one GPU."""
        jobs = paper_trace(seed=4, n_jobs=15, min_iters=5, max_iters=30)
        sim = ClusterSimulator(
            jobs,
            placement=PlacementPolicy("lwf", kappa=1),
            comm_policy=AdaDual(),
            record_trace=True,
            fuse_fb=False,
        )
        res = sim.run()
        by_gpu = {}
        runs = sim._runs
        for (jid, it, kind, w, t0, t1) in res.task_trace:
            if kind == "c":
                continue
            gid = runs[jid].gpus[w]
            by_gpu.setdefault(gid, []).append((t0, t1, jid))
        for gid, ivs in by_gpu.items():
            ivs.sort()
            for (a0, a1, ja), (b0, b1, jb) in zip(ivs, ivs[1:]):
                assert b0 >= a1 - 1e-9, f"overlap on {gid}: J{ja} vs J{jb}"


class TestContentionBehaviour:
    def test_intro_anecdote_contention_slowdown(self):
        """Section I: 4 identical multi-server jobs contend and finish much
        later than one consolidated job (paper measured 295 s -> 675 s)."""
        iters = 1000
        solo = simulate(mk_jobs([(0.0, 4, iters, "resnet50")]), n_servers=4)
        assert solo.comm_started_clean == 0  # consolidated on one server
        # Force 4 jobs to span servers: 4 servers x 4 GPUs, 4 jobs x 4 GPUs
        # placed RAND so GPUs come from different servers.
        contended = simulate(
            mk_jobs([(0.0, 4, iters, "resnet50")] * 4),
            n_servers=4,
            placement="rand",
            comm="srsf3",
            seed=7,
        )
        ratio = contended.avg_jct() / solo.avg_jct()
        assert 1.3 < ratio < 10.0, f"contention slowdown ratio {ratio}"

    def test_srsf1_never_contends(self):
        jobs = paper_trace(seed=5, n_jobs=40, min_iters=50, max_iters=200)
        res = simulate(jobs, comm="srsf1")
        assert res.comm_started_contended == 0

    def test_ada_no_worse_than_blind_acceptance(self):
        jobs = paper_trace(seed=6, n_jobs=50, min_iters=100, max_iters=400)
        ada = simulate(jobs, comm="ada")
        srsf3 = simulate(jobs, comm="srsf3")
        assert ada.avg_jct() <= srsf3.avg_jct() * 1.05

    def test_result_determinism(self):
        jobs = paper_trace(seed=8, n_jobs=25, min_iters=20, max_iters=100)
        r1 = simulate(jobs, comm="ada")
        r2 = simulate(jobs, comm="ada")
        assert r1.avg_jct() == r2.avg_jct()
        assert r1.finish == r2.finish


class TestMetrics:
    def test_utilization_bounds_and_busy_conservation(self):
        jobs = paper_trace(seed=9, n_jobs=30, min_iters=20, max_iters=150)
        res = simulate(jobs)
        assert 0.0 < res.gpu_util <= 1.0
        # Total busy time == sum over jobs of compute demand.
        demand = sum(j.model.t_iter_compute * j.iterations * j.n_gpus for j in jobs)
        assert sum(res.gpu_busy.values()) == pytest.approx(demand, rel=1e-6)

    def test_percentiles_ordered(self):
        jobs = paper_trace(seed=10, n_jobs=30, min_iters=20, max_iters=150)
        res = simulate(jobs)
        assert res.median_jct() <= res.avg_jct() * 5
        assert res.median_jct() <= res.p95_jct()


class TestBandwidthAwareSrsf:
    """Beyond-paper (ROADMAP item): SRSF remaining-service estimate scaled
    by each job's slowest member NIC under server_bandwidth heterogeneity,
    behind a flag that defaults to the paper-faithful nominal estimate."""

    PARAMS_HET = ContentionParams(server_bandwidth=(0.1, 1.0))

    def _jobs(self):
        # 2 servers x 1 GPU: job 0 (vgg16) spans both servers, so its comm
        # crosses the 10x-slow server-0 NIC; job 1 (lstm) shares GPU (0,0).
        return mk_jobs([(0.0, 2, 30, "vgg16"), (0.0, 1, 500, "lstm_ptb")])

    def test_estimate_scales_with_slowest_member(self):
        from repro.core.simulator import JobRun

        spec = self._jobs()[0]
        run = JobRun(spec=spec, gpus=[(0, 0), (1, 0)], servers={0, 1}, placed_at=0.0)
        nominal = run.per_iter_service(self.PARAMS_HET)
        aware = run.per_iter_service(self.PARAMS_HET, bandwidth_aware=True)
        m = spec.model
        assert nominal == pytest.approx(
            m.t_iter_compute + self.PARAMS_HET.a + self.PARAMS_HET.b * m.size_bytes
        )
        assert aware == pytest.approx(
            m.t_iter_compute + self.PARAMS_HET.a + self.PARAMS_HET.b * m.size_bytes / 0.1
        )
        assert run.remaining_service(self.PARAMS_HET, True) == pytest.approx(
            30 * aware * 2
        )

    def test_flag_off_is_default_behavior(self):
        jobs = self._jobs()
        kw = dict(params=self.PARAMS_HET, n_servers=2, gpus_per_server=1)
        default = simulate(jobs, comm="ada", **kw)
        off = simulate(jobs, comm="ada", bandwidth_aware_srsf=False, **kw)
        assert default.jct == off.jct

    def test_flag_changes_priorities_under_heterogeneity(self):
        """Nominal SRSF ranks the short spanning job first; the
        bandwidth-aware estimate recognizes its slow NIC inflates its real
        remaining service past the colocated single-GPU job's, flipping the
        GPU-sharing order (deterministic, verified fixture)."""
        jobs = self._jobs()
        kw = dict(params=self.PARAMS_HET, n_servers=2, gpus_per_server=1)
        nominal = simulate(jobs, comm="ada", **kw)
        aware = simulate(jobs, comm="ada", bandwidth_aware_srsf=True, **kw)
        assert len(nominal.jct) == len(aware.jct) == 2
        assert nominal.jct != aware.jct
        # the deprioritized slow spanning job finishes later under aware
        assert aware.jct[0] > nominal.jct[0]

    def test_homogeneous_network_flag_is_noop(self):
        jobs = self._jobs()
        kw = dict(params=ContentionParams(), n_servers=2, gpus_per_server=1)
        a = simulate(jobs, comm="ada", **kw)
        b = simulate(jobs, comm="ada", bandwidth_aware_srsf=True, **kw)
        assert a.jct == b.jct
