"""TraceSource streaming-arrival layer: list-vs-streaming equivalence,
synthetic/CSV sources, the replay scenarios, and the windowed
steady-state metrics of long replays."""

import math

import pytest

from repro.core.simulator import simulate
from repro.core.trace import ListTraceSource, TraceSource, paper_trace
from repro.scenarios import (
    CsvTraceSource,
    SyntheticTraceSource,
    get_scenario,
    trace_source_from_spec,
)
from repro.scenarios.tracesource import DATA_DIR


def small_trace(seed=0, n_jobs=60):
    return paper_trace(
        seed=seed, n_jobs=n_jobs, horizon_s=90.0, min_iters=3, max_iters=9,
        gpu_distribution=((1, 8), (2, 4), (4, 5), (8, 3)),
    )


class TestStreamingEquivalence:
    """Streaming mode is bit-identical to list mode on every per-job
    outcome — only the calendar footprint differs."""

    @pytest.mark.parametrize("sched", ["static", "preemptive_srsf"])
    def test_list_vs_streaming(self, sched):
        jobs = small_trace()
        kw = dict(comm="ada", sched=sched, n_servers=4, gpus_per_server=4)
        lst = simulate(jobs, **kw)
        stream = simulate(ListTraceSource(jobs), **kw)
        assert stream.jct == lst.jct
        assert stream.finish == lst.finish
        assert stream.queueing_delay == lst.queueing_delay
        assert stream.events_processed == lst.events_processed
        assert stream.censored == lst.censored == 0
        assert stream.goodput == pytest.approx(lst.goodput)
        assert stream.preemptions == lst.preemptions
        # the whole point: O(live + cluster), not O(n_jobs)
        assert stream.peak_calendar < lst.peak_calendar

    def test_streaming_censoring_counts_seen_jobs_only(self):
        """Cutting a streamed run at a horizon censors only the arrivals
        the engine actually saw — jobs still inside the source are not
        phantom-censored."""
        jobs = small_trace()
        kw = dict(comm="ada", n_servers=4, gpus_per_server=4, max_time=30.0)
        lst = simulate(jobs, **kw)
        stream = simulate(ListTraceSource(jobs), **kw)
        assert lst.jct == stream.jct
        # list mode censors every never-finished job in the trace; the
        # stream only censors arrivals it actually pulled (<= one ahead
        # of the horizon)
        assert lst.censored == len(jobs) - len(lst.jct)
        assert stream.censored <= lst.censored
        arrived = len([j for j in jobs if j.arrival <= 30.0])
        assert stream.censored <= arrived + 1 - len(stream.jct)

    def test_engine_rejects_unsorted_stream(self):
        class Unsorted(TraceSource):
            def arrivals(self):
                return iter(small_trace()[::-1])

        with pytest.raises(ValueError, match="arrival"):
            simulate(Unsorted(), n_servers=4, gpus_per_server=4)

    def test_engine_rejects_duplicate_job_ids(self):
        class Duped(TraceSource):
            def arrivals(self):
                j = small_trace()[0]
                return iter([j, j])

        with pytest.raises(ValueError, match="job"):
            simulate(Duped(), n_servers=4, gpus_per_server=4)


class TestStreamingTraceRecords:
    """``record_trace=True`` through the streaming path — previously the
    task-trace recorder was only exercised with materialized job lists
    (and only fault-free)."""

    def test_streaming_trace_identical_under_preemption_and_chaos(self):
        """Streaming mode emits the bit-identical task trace, including
        the preempt markers of chaos teardowns and the re-executed
        (aborted-incarnation) iterations behind them."""
        from repro.core.chaos import ChaosSpec

        jobs = small_trace(n_jobs=30)
        # failure instants deliberately off any round number: the event
        # calendar breaks exactly-coincident timestamps by insertion
        # order, which streaming (lazy arrival pushes) permutes — a
        # failure landing exactly on a quantum tick resolves differently
        # per mode.  Both resolutions are valid simulations; bit-equality
        # is only promised for non-coincident event times.
        chaos = ChaosSpec(
            seed=3, scripted_failures=((0, 4.0314, 6.0272), (1, 9.0718, 10.0281))
        )
        kw = dict(
            comm="ada", sched="preemptive_srsf", n_servers=4,
            gpus_per_server=4, record_trace=True, fuse_fb=False,
            chaos=chaos, checkpoint_cost=0.02,
        )
        lst = simulate(jobs, **kw)
        stream = simulate(ListTraceSource(jobs), **kw)
        assert lst.preemptions > 0  # the cell actually tears gangs down
        assert lst.work_lost_samples > 0
        assert stream.task_trace == lst.task_trace
        assert stream.finish == lst.finish
        markers = [r for r in lst.task_trace if r[2] == "preempt"]
        assert markers, "no preempt markers in the recorded trace"

    def test_censored_stream_trace_stops_at_horizon(self):
        """Cutting a streamed, traced run at ``max_time``: every record
        *starts* inside the horizon, only censored jobs' in-flight work
        may end past it (compute records carry their planned end from
        schedule time), in-flight comm records are tombstoned (open end),
        and censored jobs leave partial records rather than vanishing."""
        jobs = small_trace()
        # cut mid-first-iteration of a late arrival so at least one seen
        # job is provably in flight at the horizon
        cut = jobs[40].arrival + 0.01
        res = simulate(
            ListTraceSource(jobs), comm="ada", n_servers=4,
            gpus_per_server=4, record_trace=True, fuse_fb=False,
            max_time=cut,
        )
        assert res.censored > 0
        finished = set(res.jct)
        for (jid, _it, kind, _w, t0, t1) in res.task_trace:
            assert t0 <= cut + 1e-9  # nothing is scheduled past the cut
            if t1 is None:  # comm in flight at the cut: never patched
                assert kind.startswith("c")
                assert jid not in finished
            elif t1 > cut + 1e-9:
                # planned end past the horizon: only censored in-flight work
                assert jid not in finished
        traced = {r[0] for r in res.task_trace}
        assert traced - finished, "censored jobs left no trace records"


class TestSyntheticSource:
    def test_deterministic_and_restartable(self):
        src = SyntheticTraceSource(n_jobs=50, seed=3)
        a, b = src.materialize(), src.materialize()
        assert a == b
        assert len(a) == 50 == src.n_jobs_hint()
        assert [j.job_id for j in a] == list(range(50))
        assert all(
            a[i].arrival <= a[i + 1].arrival for i in range(len(a) - 1)
        )

    def test_seed_changes_stream(self):
        a = SyntheticTraceSource(n_jobs=30, seed=0).materialize()
        b = SyntheticTraceSource(n_jobs=30, seed=1).materialize()
        assert a != b

    def test_validation(self):
        with pytest.raises(ValueError, match="n_jobs"):
            SyntheticTraceSource(n_jobs=0)
        with pytest.raises(ValueError, match="rate"):
            SyntheticTraceSource(n_jobs=1, rate=0.0)


class TestCsvSource:
    def test_philly_sample_parses(self):
        src = CsvTraceSource(str(DATA_DIR / "philly_sample.csv"), "philly")
        jobs = src.materialize()
        assert len(jobs) == 40
        assert [j.job_id for j in jobs] == list(range(40))
        assert all(j.iterations >= 1 for j in jobs)
        assert all(1 <= j.n_gpus <= 8 for j in jobs)
        # model assignment is a deterministic round-robin over sorted names
        assert jobs[0].model.name != jobs[1].model.name
        assert jobs[0].model == jobs[4].model

    def test_alibaba_gpu_percent_scaling(self):
        src = CsvTraceSource(str(DATA_DIR / "alibaba_sample.csv"), "alibaba")
        jobs = src.materialize()
        assert len(jobs) == 40
        # plan_gpu is a percentage: 100 -> 1 GPU, 800 -> 8 GPUs
        assert all(1 <= j.n_gpus <= 8 for j in jobs)
        assert {j.n_gpus for j in jobs} <= {1, 2, 4, 8}

    def test_time_scale_compresses(self):
        path = str(DATA_DIR / "philly_sample.csv")
        full = CsvTraceSource(path, "philly").materialize()
        half = CsvTraceSource(path, "philly", time_scale=0.5).materialize()
        assert half[-1].arrival == pytest.approx(full[-1].arrival * 0.5)
        assert all(
            h.iterations <= f.iterations for h, f in zip(half, full)
        )

    def test_max_jobs_truncates(self):
        src = CsvTraceSource(
            str(DATA_DIR / "philly_sample.csv"), "philly", max_jobs=7
        )
        assert len(src.materialize()) == 7

    def test_unknown_dialect_raises(self):
        with pytest.raises(ValueError, match="dialect"):
            CsvTraceSource("x.csv", dialect="borg")


class TestSourceSpec:
    def test_synth(self):
        src = trace_source_from_spec("synth", n_jobs=123, seed=9)
        assert isinstance(src, SyntheticTraceSource)
        assert src.n_jobs_hint() == 123

    def test_bundled_csvs(self):
        for name in ("philly", "alibaba"):
            src = trace_source_from_spec(name, n_jobs=5)
            assert isinstance(src, CsvTraceSource)
            assert len(src.materialize()) == 5

    def test_csv_spec(self):
        src = trace_source_from_spec(
            f"csv:alibaba:{DATA_DIR / 'alibaba_sample.csv'}", n_jobs=3
        )
        assert src.dialect == "alibaba"
        assert len(src.materialize()) == 3

    def test_bad_specs_raise(self):
        with pytest.raises(ValueError, match="trace source"):
            trace_source_from_spec("nope")
        with pytest.raises(ValueError, match="csv"):
            trace_source_from_spec("csv:only-one-colon")


class TestReplayScenarios:
    def test_materialized_jobs_match_source(self):
        scn = get_scenario("trace_replay_synth", seed=0, n_jobs=40)
        assert scn.source is not None
        assert list(scn.jobs) == scn.source.materialize()
        assert scn.n_jobs == 40

    def test_large_scale_stays_lazy(self):
        scn = get_scenario("trace_replay_synth", seed=0, n_jobs=50_000)
        assert scn.jobs == ()
        assert scn.n_jobs == 50_000  # from the hint, nothing materialized

    def test_event_sweep_runs_streaming(self):
        from repro.scenarios.sweep import run_scenario_event

        scn = get_scenario("trace_replay_synth", seed=0, n_jobs=40)
        res = run_scenario_event(scn, comm="ada")
        assert len(res.jct) == 40
        assert res.censored == 0
        # streaming: calendar stays O(cluster), far below n_jobs
        assert res.peak_calendar < 40 + 2 * scn.total_gpus

    def test_fluid_raises_on_unmaterialized_source(self):
        from repro.scenarios.sweep import fluid_config

        scn = get_scenario("trace_replay_synth", seed=0, n_jobs=50_000)
        with pytest.raises(ValueError, match="streaming"):
            fluid_config(scn, comm="ada")


class TestWindowedMetrics:
    def _res(self):
        return simulate(
            ListTraceSource(small_trace()),
            comm="ada", n_servers=4, gpus_per_server=4,
        )

    def test_windows_partition_the_run(self):
        res = self._res()
        wins = res.windowed(20.0)
        assert wins, "run produced no finishes?"
        assert sum(w["n_finished"] for w in wins) == len(res.jct)
        for w in wins:
            assert w["t1"] == pytest.approx(w["t0"] + 20.0)
            assert w["jobs_per_sec"] == pytest.approx(w["n_finished"] / 20.0)

    def test_bad_window_raises(self):
        with pytest.raises(ValueError, match="window"):
            self._res().windowed(0.0)

    def test_steady_state_summary(self):
        res = self._res()
        ss = res.steady_state(20.0)
        assert ss["n_jobs"] > 0
        assert ss["sustained_goodput"] >= 0.0
        assert ss["p99_jct"] >= max(res.jct.values()) * 0.0
        assert not math.isnan(ss["queueing_delay_mean"])
        assert ss["t_lo"] >= 0.0 and ss["t_hi"] <= res.makespan + 20.0

    def test_replay_summary_keys(self):
        from repro.scenarios.metrics import replay_summary

        out = replay_summary(self._res(), window_s=20.0)
        for key in (
            "sustained_goodput", "sustained_jobs_per_sec", "p99_jct",
            "queueing_delay_mean", "queueing_delay_p99", "makespan",
            "n_finished", "censored", "events", "peak_calendar",
        ):
            assert key in out, key
        assert out["censored"] == 0.0


class TestPhaseProfiling:
    def test_off_by_default(self):
        res = simulate(small_trace(n_jobs=10), n_servers=4, gpus_per_server=4)
        assert res.phase_seconds is None

    def test_phase_breakdown_populated(self):
        res = simulate(
            small_trace(), n_servers=4, gpus_per_server=4,
            profile_phases=True,
        )
        assert set(res.phase_seconds) == {
            "comm_advance", "dispatch", "gating", "gpu_schedule",
        }
        assert all(v >= 0.0 for v in res.phase_seconds.values())
        assert sum(res.phase_seconds.values()) > 0.0
