"""Tests for the layer-granular WFBP communication subsystem.

Covers the whole vertical slice:

* ``netmodel.fusion_plan`` / ``fusion_threshold`` (the shared tensor-fusion
  planner);
* ``repro.workloads`` config-derived layer profiles (sum invariants, zoo
  well-formedness);
* the event backend's per-bucket overlapped execution — including the
  acceptance-criteria locks: ``fusion="all"`` is bit-exact against
  layer-stripped monolithic profiles on BOTH backends, a finite fusion
  threshold measurably beats both ``"all"`` and fully-unfused under
  Ada-SRSF on the ``fusion_sweep`` regression cell, and every simulated
  trace is a valid linear extension of the layer-granular formal DAG
  (deterministic + Hypothesis property test, overlap edges included);
* the fluid backend's static ``[jobs, buckets]`` chunked drain;
* the legacy ring-edge "link" reading expressed as dynamic topology
  domains (``RingEdgeTopology``), locked against the old inline formula.
"""

import dataclasses
import math

import pytest

from repro.core import simulate
from repro.core import netmodel
from repro.core.cluster import TABLE_III, JobSpec
from repro.core.contention import ContentionParams
from repro.core.dag import TaskKind, TaskRef, build_job_dag, validate_schedule
from repro.core.topology import RingEdgeTopology
from repro.scenarios import get_scenario, run_scenario_event, run_scenario_fluid
from repro.workloads import (
    GRAD_BYTES_PER_PARAM,
    TOKENS_PER_GPU,
    ZOO_ARCHS,
    ZOO_GPU_MEM_MB,
    derive_layer_profiles,
    model_profile_from_config,
    zoo_profiles,
)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

P = ContentionParams()


def strip_layers(model):
    """The monolithic (pre-WFBP) reading of a layer-granular profile."""
    return dataclasses.replace(model, layer_grad_bytes=(), layer_t_b=())


def strip_scenario(scn):
    jobs = tuple(
        dataclasses.replace(j, model=strip_layers(j.model)) for j in scn.jobs
    )
    return dataclasses.replace(scn, jobs=jobs, fusion="all")


# ---------------------------------------------------------------------------
# Fusion planner (netmodel)
# ---------------------------------------------------------------------------


class TestFusionPlan:
    LB = (10.0, 20.0, 5.0, 40.0, 5.0)
    TB = (1.0, 2.0, 0.5, 4.0, 0.5)

    def test_threshold_normalization(self):
        assert netmodel.fusion_threshold("all") == math.inf
        assert netmodel.fusion_threshold("none") == 0.0
        assert netmodel.fusion_threshold(25e6) == 25e6
        with pytest.raises(ValueError):
            netmodel.fusion_threshold("sometimes")
        with pytest.raises(ValueError):
            netmodel.fusion_threshold(-1.0)

    def test_all_is_one_bucket(self):
        sizes, times = netmodel.fusion_plan(self.LB, self.TB, math.inf)
        assert sizes == (sum(self.LB),)
        assert times == (sum(self.TB),)

    def test_none_is_per_layer(self):
        sizes, times = netmodel.fusion_plan(self.LB, self.TB, 0.0)
        assert sizes == self.LB
        assert times == self.TB

    def test_finite_threshold_buckets_greedily(self):
        # threshold 25: [10+20]=30 seals, [5+40]=45 seals, [5] trails
        sizes, times = netmodel.fusion_plan(self.LB, self.TB, 25.0)
        assert sizes == (30.0, 45.0, 5.0)
        assert times == (3.0, 4.5, 0.5)

    def test_sums_preserved_exactly(self):
        for thr in (0.0, 7.0, 25.0, 60.0, math.inf):
            sizes, times = netmodel.fusion_plan(self.LB, self.TB, thr)
            assert sum(sizes) == pytest.approx(sum(self.LB), rel=1e-12)
            assert sum(times) == pytest.approx(sum(self.TB), rel=1e-12)
            assert all(s > 0 for s in sizes)

    def test_threshold_above_total_is_single_bucket(self):
        sizes, _ = netmodel.fusion_plan(self.LB, self.TB, 1e9)
        assert len(sizes) == 1

    def test_validation(self):
        with pytest.raises(ValueError, match="align"):
            netmodel.fusion_plan((1.0,), (1.0, 2.0), 0.0)
        with pytest.raises(ValueError, match="at least one layer"):
            netmodel.fusion_plan((), (), 0.0)

    def test_plan_for_model(self):
        zoo = zoo_profiles()
        m = zoo["mamba2_130m"]
        assert netmodel.plan_for_model(m, "all") is None
        assert netmodel.plan_for_model(TABLE_III["vgg16"], "none") is None
        sizes, times = netmodel.plan_for_model(m, "none")
        assert len(sizes) == len(m.layer_grad_bytes)
        assert sum(sizes) == pytest.approx(m.size_bytes)
        assert sum(times) == pytest.approx(m.t_b)


# ---------------------------------------------------------------------------
# Config-derived workload profiles
# ---------------------------------------------------------------------------


class TestWorkloadProfiles:
    def test_zoo_covers_the_announced_archs(self):
        zoo = zoo_profiles()
        assert set(zoo) == set(ZOO_ARCHS)
        for arch in ("mamba2_130m", "llama32_1b", "phi4_mini_3_8b", "gemma_7b"):
            assert arch in zoo

    @pytest.mark.parametrize("arch", ZOO_ARCHS)
    def test_profile_invariants(self, arch):
        m = zoo_profiles()[arch]
        assert m.has_layers
        assert len(m.layer_grad_bytes) == len(m.layer_t_b) >= 3
        assert sum(m.layer_grad_bytes) == pytest.approx(m.size_bytes, rel=1e-9)
        assert sum(m.layer_t_b) == pytest.approx(m.t_b, rel=1e-9)
        assert all(b > 0 for b in m.layer_grad_bytes)
        assert all(t > 0 for t in m.layer_t_b)
        assert m.t_b == pytest.approx(2.0 * m.t_f, rel=0.05)  # bwd ~ 2x fwd
        assert m.mem_mb < ZOO_GPU_MEM_MB  # admissible on the zoo cluster

    def test_grad_bytes_match_param_count(self):
        from repro.configs import get_config

        cfg = get_config("llama32_1b")
        m = model_profile_from_config(cfg)
        # the analytic param model and the layer sum agree to ~1%
        assert m.size_bytes == pytest.approx(
            GRAD_BYTES_PER_PARAM * cfg.param_count(), rel=0.01
        )

    def test_backward_ready_order_starts_at_the_output(self):
        from repro.configs import get_config

        layers = derive_layer_profiles(get_config("llama32_1b"), TOKENS_PER_GPU)
        assert layers[0].name == "embed"
        assert layers[1].name.startswith("layer")
        # decoder layers come out in reverse order (output side first)
        idx = [int(l.name[5:]) for l in layers[1:]]
        assert idx == sorted(idx, reverse=True)

    def test_layer_mismatch_rejected_by_model_profile(self):
        with pytest.raises(ValueError, match="align"):
            dataclasses.replace(
                TABLE_III["vgg16"], layer_grad_bytes=(1.0,), layer_t_b=()
            )

    def test_zoo_derivation_is_jax_free(self):
        """The event-simulator path stays jax-free: deriving the zoo
        profiles (configs -> models.config -> workloads) must not import
        jax — that is why the multiprocessing sweep workers start cheap
        (checked in a fresh interpreter)."""
        import subprocess
        import sys

        code = (
            "import sys\n"
            "from repro.workloads import zoo_profiles\n"
            "zoo_profiles()\n"
            "assert 'jax' not in sys.modules, 'zoo derivation imported jax'\n"
        )
        subprocess.run([sys.executable, "-c", code], check=True)


# ---------------------------------------------------------------------------
# Event backend: WFBP execution
# ---------------------------------------------------------------------------


ZOO_KW = dict(n_servers=4, gpus_per_server=4, gpu_mem_mb=ZOO_GPU_MEM_MB)


def zoo_jobs(arch="mamba2_130m", n=1, iters=30, n_gpus=8):
    m = zoo_profiles()[arch]
    return [JobSpec(i, float(i), n_gpus, iters, m) for i in range(n)]


class TestEventWfbp:
    def test_fusion_all_equals_layer_stripped_monolithic(self):
        """The acceptance-criteria lock (event side): fusion='all' on
        layer-granular profiles is bit-exact against the same workload
        with the layer data stripped — the subsystem is a strict
        generalization of the iteration-level model."""
        jobs = zoo_jobs(n=4, iters=20)
        mono = [dataclasses.replace(j, model=strip_layers(j.model)) for j in jobs]
        a = simulate(jobs, fusion="all", **ZOO_KW)
        b = simulate(mono, **ZOO_KW)
        assert a.jct == b.jct
        assert a.makespan == b.makespan
        assert a.events_processed == b.events_processed

    def test_single_bucket_plan_equals_monolithic(self):
        """A finite threshold above the total message size yields one
        bucket; the WFBP machinery must then reproduce the monolithic
        unfused (fuse_fb=False) execution exactly."""
        jobs = zoo_jobs(n=2, iters=15)
        a = simulate(jobs, fusion=1e12, fuse_fb=False, **ZOO_KW)
        b = simulate(jobs, fusion="all", fuse_fb=False, **ZOO_KW)
        assert a.jct == b.jct
        assert a.makespan == b.makespan

    def test_overlap_shortens_a_single_job(self):
        """One spanning job: per-layer WFBP overlaps all-reduce with the
        remaining backward, so the unfused JCT must undercut the
        monolithic one by roughly the overlappable backward time, while
        never beating the comm+forward lower bound."""
        m = zoo_profiles()["mamba2_130m"]
        iters = 30
        jobs = zoo_jobs(n=1, iters=iters)
        mono = simulate(jobs, fusion="all", **ZOO_KW).jct[0]
        unfused = simulate(jobs, fusion="none", **ZOO_KW).jct[0]
        assert unfused < mono
        # lower bound: forward + every bucket's latency+bytes, no compute
        # overlap can hide the serialized comm stream itself
        n_l = len(m.layer_grad_bytes)
        lb = iters * (m.t_f + n_l * P.a + P.b * m.size_bytes)
        assert unfused > lb * 0.999
        # the win is bounded by the overlappable backward compute
        assert mono - unfused <= iters * m.t_b * 1.001

    def test_finite_fusion_beats_both_extremes_on_fusion_sweep(self):
        """THE acceptance criterion: on the fusion_sweep regression cell a
        finite fusion threshold measurably beats fusion='all' (overlap)
        AND fully-unfused (per-bucket latency + gating overhead) under
        Ada-SRSF."""
        from repro.scenarios import QUICK_OVERRIDES

        for seed in (0, 1):
            base = get_scenario(  # fusion=32e6
                "fusion_sweep", seed=seed, **QUICK_OVERRIDES["fusion_sweep"]
            )
            allf = dataclasses.replace(base, fusion="all")
            none = dataclasses.replace(base, fusion="none")
            r_fin = run_scenario_event(base, comm="ada")
            r_all = run_scenario_event(allf, comm="ada")
            r_non = run_scenario_event(none, comm="ada")
            assert len(r_fin.jct) == base.n_jobs
            # measurable: >= 1% over unfused, >= 10% over monolithic
            assert r_fin.avg_jct() * 1.01 <= r_non.avg_jct(), seed
            assert r_fin.avg_jct() * 1.10 <= r_all.avg_jct(), seed

    @pytest.mark.parametrize("comm", ["ada", "srsf1", "srsf2", "kway3"])
    def test_every_policy_completes_with_fusion(self, comm):
        jobs = zoo_jobs(n=6, iters=10)
        res = simulate(jobs, comm=comm, fusion=32e6, **ZOO_KW)
        assert len(res.jct) == 6, comm

    def test_fusion_with_chunks_rejected(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            simulate(zoo_jobs(), fusion="none", comm_chunks=4, **ZOO_KW)

    def test_gating_counts_bucket_bytes(self):
        """Under SRSF(1) every bucket start is exclusive: contended starts
        must be zero even with many buckets in flight across jobs."""
        jobs = zoo_jobs(n=4, iters=8)
        res = simulate(jobs, comm="srsf1", fusion="none", **ZOO_KW)
        assert res.comm_started_contended == 0
        assert res.comm_started_clean > 0


# ---------------------------------------------------------------------------
# DAG validity of WFBP traces (satellite: Hypothesis property test)
# ---------------------------------------------------------------------------


def intervals_by_job(trace):
    """Parse the simulator's task trace into per-job TaskRef interval maps
    (legacy kinds 'f'/'b'/'c'; WFBP kinds 'b<seg>'/'c<seg>')."""
    per_job = {}
    for (jid, it, kind, w, t0, t1) in trace:
        if kind in ("f", "b", "c"):
            ref = TaskRef(jid, it, TaskKind(kind), w if kind != "c" else -1)
        else:
            seg = int(kind[1:])
            k = TaskKind(kind[0])
            ref = TaskRef(jid, it, k, w if k is not TaskKind.ALLREDUCE else -1, seg)
        per_job.setdefault(jid, {})[ref] = (t0, t1)
    return per_job


def normalize_single_bucket(intervals):
    """A one-bucket WFBP plan emits segment 0; the formal monolithic DAG
    uses segment -1 — remap when exactly one segment exists."""
    segs = {r.segment for r in intervals if r.kind is TaskKind.ALLREDUCE}
    if segs == {0}:
        return {
            dataclasses.replace(r, segment=-1): iv for r, iv in intervals.items()
        }
    return intervals


def validate_run(jobs, fusion, comm="ada", **kw):
    res = simulate(
        jobs, fusion=fusion, record_trace=True, fuse_fb=False, comm=comm, **kw
    )
    assert len(res.jct) == len(jobs)
    per_job = intervals_by_job(res.task_trace)
    specs = {j.job_id: j for j in jobs}
    for jid, intervals in per_job.items():
        spec = specs[jid]
        comm_refs = [r for r in intervals if r.kind is TaskKind.ALLREDUCE]
        has_comm = bool(comm_refs)
        n_buckets = max((r.segment for r in comm_refs), default=-1) + 1
        if n_buckets <= 1:
            intervals = normalize_single_bucket(intervals)
            n_buckets = 1
        dag = build_job_dag(jid, spec.n_gpus, spec.iterations, has_comm, n_buckets)
        ok, msg = validate_schedule(dag, intervals)
        assert ok, f"job {jid} (fusion={fusion}): {msg}"
    return res


class TestDagValidity:
    @pytest.mark.parametrize("fusion", ["all", "none", 32e6])
    def test_trace_is_valid_linear_extension(self, fusion):
        jobs = zoo_jobs(n=3, iters=4) + [
            JobSpec(3, 0.0, 1, 6, zoo_profiles()["llama32_1b"]),  # no comm
        ]
        validate_run(jobs, fusion, **ZOO_KW)

    def test_comm_overlaps_backward(self):
        """The point of WFBP: some bucket transfer must run concurrently
        with a backward segment of the same job and iteration."""
        res = simulate(
            zoo_jobs(n=1, iters=5), fusion="none", record_trace=True,
            fuse_fb=False, **ZOO_KW
        )
        per_job = intervals_by_job(res.task_trace)
        overlapped = False
        for intervals in per_job.values():
            comms = [(r, iv) for r, iv in intervals.items()
                     if r.kind is TaskKind.ALLREDUCE]
            bwds = [(r, iv) for r, iv in intervals.items()
                    if r.kind is TaskKind.BACKWARD]
            for cr, (c0, c1) in comms:
                for br, (b0, b1) in bwds:
                    if br.iteration == cr.iteration and br.segment > cr.segment:
                        if min(c1, b1) - max(c0, b0) > 1e-6:
                            overlapped = True
        assert overlapped

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=20, deadline=None)
    @given(
        n_jobs=st.integers(1, 3),
        iters=st.integers(1, 4),
        n_gpus=st.sampled_from([4, 8]),
        fusion=st.sampled_from(["all", "none", 16e6, 64e6, 1e12]),
        comm=st.sampled_from(["ada", "srsf1", "srsf2"]),
        arch=st.sampled_from(["mamba2_130m", "llama32_1b"]),
    )
    def test_property_every_trace_is_valid(
        self, n_jobs, iters, n_gpus, fusion, comm, arch
    ):
        """Hypothesis sweep: every event-sim trace — with and without
        fusion, any gating policy — is a valid linear extension of the
        layer-granular DAG (overlap edges included)."""
        m = zoo_profiles()[arch]
        jobs = [
            JobSpec(i, float(i % 2), n_gpus, iters, m) for i in range(n_jobs)
        ]
        validate_run(jobs, fusion, comm=comm, n_servers=2, gpus_per_server=4,
                     gpu_mem_mb=ZOO_GPU_MEM_MB)


# ---------------------------------------------------------------------------
# Fluid backend: chunked drain over the static [jobs, buckets] matrix
# ---------------------------------------------------------------------------


class TestFluidWfbp:
    def test_fusion_all_bit_exact_vs_layer_stripped(self):
        """Acceptance-criteria lock (fluid side): fusion='all' on
        layer-granular profiles is bit-identical to the layer-stripped
        monolithic workload."""
        import numpy as np

        scn = get_scenario("model_zoo", seed=1, n_jobs=8, min_iters=10,
                           max_iters=30, horizon_s=200.0, fusion="all")
        mono = strip_scenario(scn)
        a = run_scenario_fluid(scn, comm="ada", dt=0.05)
        b = run_scenario_fluid(mono, comm="ada", dt=0.05)
        np.testing.assert_array_equal(np.asarray(a["jct"]), np.asarray(b["jct"]))
        assert float(a["makespan"]) == float(b["makespan"])

    def test_explicit_monolithic_planes_bit_exact(self):
        """A (jobs, 1) bucket matrix is the same trajectory as no bucket
        planes at all — the generalized state machine collapses exactly."""
        import numpy as np

        from repro.core.jaxsim import simulate_trace, trace_from_jobs
        from repro.scenarios.sweep import fluid_config

        scn = get_scenario("smoke")
        cfg = fluid_config(scn, comm="ada")
        plain = trace_from_jobs(scn.job_list())
        planes = dict(plain)
        planes["bucket_bytes"] = plain["msg_bytes"][:, None]
        import jax.numpy as jnp

        planes["n_buckets"] = jnp.ones((scn.n_jobs,), jnp.int32)
        a = simulate_trace(plain, cfg)
        b = simulate_trace(planes, cfg)
        np.testing.assert_array_equal(np.asarray(a["jct"]), np.asarray(b["jct"]))

    @pytest.mark.parametrize("fusion", ["none", 32e6])
    def test_bucketed_fluid_completes_and_orders_like_event(self, fusion):
        from repro.scenarios import QUICK_OVERRIDES

        scn = dataclasses.replace(
            get_scenario("fusion_sweep", seed=1, **QUICK_OVERRIDES["fusion_sweep"]),
            fusion=fusion,
        )
        fl = run_scenario_fluid(scn, comm="ada", dt=0.01)
        ev = run_scenario_event(scn, comm="ada")
        assert int(fl["finished"].sum()) == scn.n_jobs
        fl_avg = float(fl["jct"][fl["finished"]].mean())
        assert ev.avg_jct() / 2.0 <= fl_avg <= ev.avg_jct() * 2.0

    def test_stack_traces_pads_bucket_planes(self):
        import numpy as np

        from repro.core.jaxsim import stack_traces, trace_from_jobs

        zoo = zoo_profiles()
        j1 = [JobSpec(0, 0.0, 8, 5, zoo["mamba2_130m"])]
        j2 = [JobSpec(0, 0.0, 8, 5, zoo["llama32_1b"]),
              JobSpec(1, 0.0, 4, 5, TABLE_III["vgg16"])]
        batch = stack_traces([
            trace_from_jobs(j1, fusion="none"),
            trace_from_jobs(j2, fusion="none"),
        ])
        bb = np.asarray(batch["bucket_bytes"])
        nb = np.asarray(batch["n_buckets"])
        assert bb.shape[0] == 2 and bb.shape[1] == 2  # lanes x padded jobs
        assert bb.shape[2] == 25  # mamba2: 24 layers + embed
        assert nb[0, 0] == 25 and nb[1, 0] == 17 and nb[1, 1] == 1
        # padded lane-0 job is inert
        assert not bool(np.asarray(batch["valid"])[0, 1])

    def test_mixed_lanes_without_planes_get_monolithic_ones(self):
        import numpy as np

        from repro.core.jaxsim import stack_traces, trace_from_jobs

        j = [JobSpec(0, 0.0, 4, 5, TABLE_III["vgg16"])]
        batch = stack_traces([
            trace_from_jobs(j, fusion="none"),
            trace_from_jobs(j),  # no planes
        ])
        nb = np.asarray(batch["n_buckets"])
        assert nb.shape == (2, 1) and nb[1, 0] == 1


# ---------------------------------------------------------------------------
# Legacy ring-edge "link" reading as dynamic topology domains (satellite)
# ---------------------------------------------------------------------------


class TestRingEdgeTopology:
    def legacy_edges(self, servers):
        """The exact inline formula the simulator used before PR 4."""
        ring = sorted(servers)
        return frozenset(
            (ring[i], ring[(i + 1) % len(ring)]) for i in range(len(ring))
        )

    @pytest.mark.parametrize(
        "servers",
        [(0, 1), (1, 3), (0, 1, 2), (0, 2, 5), (1, 4, 6, 7), (0, 3, 4, 5, 7)],
    )
    def test_matches_legacy_inline_formula(self, servers):
        topo = RingEdgeTopology(8)
        got = {(u, v) for (_, u, v) in topo.loaded_domains(set(servers))}
        assert got == self.legacy_edges(set(servers))

    def test_single_server_loads_nothing(self):
        assert RingEdgeTopology(4).loaded_domains({2}) == frozenset()

    def test_unit_oversub_and_no_incidence(self):
        topo = RingEdgeTopology(4)
        assert topo.oversub_of(("edge", 0, 1)) == 1.0
        with pytest.raises(NotImplementedError):
            topo.incidence()
        with pytest.raises(ValueError):
            topo.loaded_domains({0, 9})

    def test_two_server_pair_equivalent_to_nic_reading(self):
        """Every comm task spanning the same server pair: ring edges and
        NIC cuts count identical contenders, so the two readings must be
        bit-exact — the equivalence lock for the migration."""
        jobs = [
            JobSpec(0, 0.0, 8, 60, TABLE_III["vgg16"]),
            JobSpec(1, 0.5, 8, 60, TABLE_III["resnet50"]),
        ]
        kw = dict(n_servers=2, gpus_per_server=4)
        for comm in ("ada", "srsf1", "srsf2"):
            a = simulate(jobs, comm=comm, contention_domain="server", **kw)
            b = simulate(jobs, comm=comm, contention_domain="link", **kw)
            assert a.jct == b.jct, comm
            assert a.events_processed == b.events_processed

    def test_disjoint_rings_on_shared_server_still_overlap(self):
        """The behavioral point of the link reading (kept from the PR 3
        suite): rings sharing a server but no edge do not contend."""
        topo = RingEdgeTopology(3)
        a = topo.loaded_domains({0, 1})
        b = topo.loaded_domains({1, 2})
        assert not (a & b)
