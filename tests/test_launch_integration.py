"""Integration tests for the launch layer: tiny end-to-end training run,
serving loop, and the multi-job Ada-SRSF launcher with real jitted steps."""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.multi_job import FABRICS, JobRequest, profile_job, run_multi_job
from repro.launch.serve import serve_batch
from repro.launch.train import train


@pytest.mark.slow
class TestTrainDriver:
    def test_loss_decreases_and_checkpoint_resume(self, tmp_path):
        cfg = dataclasses.replace(
            get_config("llama3.2-1b", reduced=True),
            d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=256,
        )
        losses = train(
            cfg, steps=12, batch=2, seq=32, lr=3e-3,
            ckpt_dir=str(tmp_path), ckpt_every=6, log_every=0,
        )
        assert len(losses) == 12
        assert losses[-1] < losses[0]
        # resume continues from step 12 checkpoint
        more = train(cfg, steps=14, batch=2, seq=32, lr=3e-3,
                     ckpt_dir=str(tmp_path), log_every=0)
        assert len(more) == 2  # only steps 12..13 executed


class TestServeDriver:
    @pytest.mark.slow
    def test_serve_batch_generates(self):
        cfg = get_config("mamba2-130m", reduced=True)
        res = serve_batch(cfg, batch=2, prompt_len=16, gen=4)
        assert res["generated"].shape == (2, 4)
        assert (res["generated"] >= 0).all()
        assert (res["generated"] < cfg.vocab_size).all()


@pytest.mark.slow
class TestMultiJob:
    def test_profile_job_measures_real_step(self):
        pj = profile_job(JobRequest("llama3.2-1b", 2, 50, batch=2, seq=32))
        assert pj.profile.t_iter_compute > 0
        assert pj.profile.size_bytes > 1e5

    def test_ada_schedule_with_real_jobs(self):
        reqs = [
            JobRequest("llama3.2-1b", n_gpus=8, iterations=40, batch=2, seq=32),
            JobRequest("mamba2-130m", n_gpus=8, iterations=60, arrival=1.0, batch=2, seq=32),
        ]
        out = run_multi_job(reqs, policy="ada", execute_steps=2)
        res = out["schedule"]
        assert len(res.jct) == 2  # both jobs complete in the schedule
        for jid, losses in out["losses"].items():
            assert len(losses) == 2
            assert all(jnp.isfinite(jnp.asarray(losses)))

    def test_fabrics_defined(self):
        assert set(FABRICS) == {"10gbe", "tpu-dcn"}
