"""Fault-injection tests (``core/chaos.py`` + the engine's chaos paths).

* **Spec validation + zero-rate no-op**: an inactive :class:`ChaosSpec`
  is bit-exact with ``chaos=None`` — the engine keeps every chaos code
  path cold (regression lock of the docstring contract).
* **Breakdown mechanics** (directed): down servers are unplaceable,
  gang teardown at the failure instant is atomic, repair restores
  capacity, work lost to a mid-iteration breakdown is exactly the
  gang's ``n_world`` samples.
* **Censoring x faults**: a breakdown-preempted job still queued at
  ``max_time`` counts as ``censored`` (never a silent drop), while
  cancelled jobs are a separate explicit outcome.
* **Aborted-all-reduce gating fix** (directed lock): aborting an
  in-flight transfer re-runs the gating pass in the same event, so a
  gated waiter starts at the abort instant — strictly earlier than the
  aborted transfer's would-be completion (see ``engine._abort_comm``).
* **Fault invariants** (Hypothesis): under arbitrary scripted
  breakdown windows, no completed iteration is ever lost, teardowns
  stay atomic, every incarnation's trace remains a valid DAG linear
  extension, and delivered samples balance (goodput conservation).
* **Recovery-storm finding** (regression-locked, fixed seeds): the
  synchronized rack-repair storm *amplifies* Ada-SRSF's gating
  advantage on most traces (seed 11) but *inverts* the paper's
  ordering on others (seed 2) — colliding catch-up all-reduces can
  make delaying a transfer worse than joining the pile-up.
"""

import dataclasses
import math

import pytest

from repro.core import TABLE_III
from repro.core.chaos import (
    ChaosSpec,
    cancel_time,
    jitter_factor,
    nic_degradation_stream,
    server_failure_stream,
)
from repro.core.cluster import JobSpec, ModelProfile
from repro.core.schedpolicy import StaticGangPolicy
from repro.scenarios import get_scenario, run_scenario_event
from repro.scenarios.metrics import CSV_FIELDS, from_event_result

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from test_engine import (
    ScriptedPreemptPolicy,
    job_records,
    make_engine,
    validate_preempted_job_trace,
)

RESNET = TABLE_III["resnet50"]


def run_static(jobs, *, chaos=None, n_servers=2, gpus_per_server=2, **kw):
    return make_engine(
        jobs,
        StaticGangPolicy(),
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        chaos=chaos,
        **kw,
    ).run()


# ---------------------------------------------------------------------------
# Spec validation + determinism of the pure draw functions
# ---------------------------------------------------------------------------


class TestChaosSpec:
    def test_default_is_inactive(self):
        assert ChaosSpec().active is False

    @pytest.mark.parametrize(
        "kw",
        [
            {"server_mtbf_s": 100.0},
            {"scripted_failures": ((0, 1.0, 2.0),)},
            {"straggler_prob": 0.1},
            {"nic_mtbf_s": 100.0},
            {"cancel_prob": 0.1},
        ],
    )
    def test_each_process_alone_activates(self, kw):
        assert ChaosSpec(**kw).active is True

    def test_unit_scale_nic_is_inactive(self):
        # degradation windows with multiplier 1.0 inject nothing
        assert ChaosSpec(nic_mtbf_s=100.0, nic_degraded_scale=1.0).active is False

    @pytest.mark.parametrize(
        "kw",
        [
            {"server_mtbf_s": -1.0},
            {"server_mttr_s": -0.1},
            {"straggler_slowdown": -0.5},
            {"straggler_prob": 1.5},
            {"cancel_prob": -0.1},
            {"nic_degraded_scale": 0.0},
            {"nic_degraded_scale": 1.5},
            {"scripted_failures": ((-1, 0.0, 1.0),)},
            {"scripted_failures": ((0, 2.0, 1.0),)},  # fail >= repair
            {"scripted_failures": ((0, -1.0, 1.0),)},  # negative fail
            # overlapping windows on one server
            {"scripted_failures": ((0, 0.0, 5.0), (0, 3.0, 8.0))},
        ],
    )
    def test_invalid_spec_raises(self, kw):
        with pytest.raises(ValueError):
            ChaosSpec(**kw)

    def test_adjacent_windows_on_different_servers_ok(self):
        # same window on two servers is NOT an overlap
        ChaosSpec(scripted_failures=((0, 0.0, 5.0), (1, 0.0, 5.0)))

    def test_failure_stream_scripted_then_stochastic(self):
        spec = ChaosSpec(
            seed=7, server_mtbf_s=50.0, scripted_failures=((0, 2.0, 4.0),)
        )
        stream = server_failure_stream(spec, 0)
        first = next(stream)
        assert first == (2.0, 4.0)
        fail, repair = next(stream)
        assert fail >= 4.0 and repair > fail
        # other servers see only their own stochastic process
        f1, r1 = next(server_failure_stream(spec, 1))
        assert f1 >= 0.0 and r1 > f1

    def test_streams_are_seed_deterministic(self):
        spec = ChaosSpec(seed=3, server_mtbf_s=10.0, nic_mtbf_s=10.0)
        a = [next(server_failure_stream(spec, 0)) for _ in range(1)]
        b = [next(server_failure_stream(spec, 0)) for _ in range(1)]
        assert a == b
        na = next(nic_degradation_stream(spec, 0))
        nb = next(nic_degradation_stream(spec, 0))
        assert na == nb
        # a different seed draws a different schedule
        other = dataclasses.replace(spec, seed=4)
        assert next(server_failure_stream(other, 0)) != a[0]

    def test_jitter_factor_keyed_deterministic(self):
        spec = ChaosSpec(seed=1, straggler_prob=0.5, straggler_slowdown=1.0)
        vals = {(j, i): jitter_factor(spec, j, i) for j in range(4) for i in range(8)}
        for (j, i), v in vals.items():
            assert v >= 1.0
            assert jitter_factor(spec, j, i) == v  # stateless replay
        assert any(v > 1.0 for v in vals.values())
        assert any(v == 1.0 for v in vals.values())
        off = ChaosSpec(seed=1, straggler_prob=0.0)
        assert jitter_factor(off, 0, 0) == 1.0

    def test_cancel_time_gate_and_determinism(self):
        never = ChaosSpec(seed=1, cancel_prob=0.0)
        assert cancel_time(never, 0, 5.0) is None
        always = ChaosSpec(seed=1, cancel_prob=1.0, cancel_after_s=10.0)
        t = cancel_time(always, 0, 5.0)
        assert t is not None and t >= 5.0
        assert cancel_time(always, 0, 5.0) == t
        half = ChaosSpec(seed=1, cancel_prob=0.5)
        hits = sum(cancel_time(half, j, 0.0) is not None for j in range(200))
        assert 50 < hits < 150  # the gate is a real Bernoulli, not all/none


# ---------------------------------------------------------------------------
# Zero-rate no-op: inactive spec == chaos=None, bit for bit
# ---------------------------------------------------------------------------


class TestZeroRateNoOp:
    def _jobs(self):
        return [
            JobSpec(0, 0.0, 4, 6, RESNET),
            JobSpec(1, 0.5, 2, 8, TABLE_III["inception_v3"]),
            JobSpec(2, 1.0, 1, 10, TABLE_III["lstm_ptb"]),
        ]

    def test_inactive_spec_is_bit_exact(self):
        base = run_static(self._jobs(), chaos=None)
        nil = run_static(self._jobs(), chaos=ChaosSpec())
        assert nil.jct == base.jct
        assert nil.makespan == base.makespan
        assert nil.events_processed == base.events_processed
        assert nil.faults == 0 and nil.cancelled == 0
        assert nil.work_lost_samples == 0

    def test_unfaulted_chaos_scenario_config_matches(self):
        """Acceptance criterion: a chaos scenario with its fault spec
        stripped is bit-exact with the unfaulted engine on the same
        workload."""
        scn = get_scenario("chaos_steady", seed=1, n_jobs=8, n_servers=4)
        stripped = dataclasses.replace(scn, chaos=None)
        a = run_scenario_event(stripped)
        b = run_scenario_event(stripped, chaos=ChaosSpec())
        assert a.jct == b.jct and a.makespan == b.makespan
        assert a.events_processed == b.events_processed


# ---------------------------------------------------------------------------
# Breakdown mechanics (directed, scripted windows)
# ---------------------------------------------------------------------------


class TestBreakdownMechanics:
    def test_down_server_blocks_placement_until_repair(self):
        """A job needing the whole cluster and arriving mid-window cannot
        place while a server is down; it starts at the repair instant."""
        jobs = [JobSpec(0, 1.0, 4, 3, RESNET)]
        chaos = ChaosSpec(scripted_failures=((0, 0.5, 5.0),))
        res = run_static(jobs, chaos=chaos, record_trace=True, fuse_fb=False)
        base = run_static(
            [JobSpec(0, 0.0, 4, 3, RESNET)], record_trace=True, fuse_fb=False
        )
        assert len(res.jct) == 1 and res.censored == 0
        assert res.faults == 1 and res.preemptions == 0
        first_t0 = min(r[4] for r in res.task_trace)
        assert first_t0 == pytest.approx(5.0)
        # never placed before the failure => no restore penalty: the job
        # runs cleanly from the repair instant, so its JCT is the clean
        # JCT plus the 4 s it queued against the dead server
        assert res.jct[0] == pytest.approx(base.jct[0] + 4.0, rel=1e-9)

    def _breakdown_run(self, fail_t=0.5, repair_t=0.7):
        # t_f = t_b = 1.0 guarantees the gang is mid-iteration at fail_t
        model = ModelProfile("chaos_slow", 100e6, 4000.0, 32, 1.0, 1.0)
        jobs = [JobSpec(0, 0.0, 4, 2, model)]
        chaos = ChaosSpec(scripted_failures=((0, fail_t, repair_t),))
        eng = make_engine(
            jobs,
            StaticGangPolicy(),
            chaos=chaos,
            record_trace=True,
            fuse_fb=False,
            checkpoint_cost=0.01,
        )
        return jobs, eng.run()

    def test_breakdown_is_atomic_teardown_with_exact_work_lost(self):
        jobs, res = self._breakdown_run()
        assert res.faults == 1
        assert res.preemptions == 1  # breakdown preempts through preempt_job
        # mid-iteration teardown loses exactly the gang's n_world samples
        assert res.work_lost_samples == 4
        recs, markers = job_records(res.task_trace, 0)
        assert len(markers) == 1
        (t_pre, _), = markers
        assert t_pre == pytest.approx(0.5)
        for (_, _, _, _, t0, t1) in recs:
            assert t1 <= t_pre + 1e-9 or t0 >= t_pre - 1e-9
        # the job still finishes every iteration after repair
        validate_preempted_job_trace(jobs[0], recs, markers)
        assert len(res.jct) == 1 and res.censored == 0

    def test_scripted_schedule_replays_identically(self):
        _, a = self._breakdown_run()
        _, b = self._breakdown_run()
        assert a.jct == b.jct
        assert a.makespan == b.makespan
        assert a.events_processed == b.events_processed
        assert a.work_lost_samples == b.work_lost_samples

    def test_stochastic_breakdowns_differ_across_chaos_seeds(self):
        jobs = [JobSpec(i, 0.0, 2, 40, RESNET) for i in range(4)]
        mk = lambda s: run_static(
            jobs,
            chaos=ChaosSpec(seed=s, server_mtbf_s=8.0, server_mttr_s=1.0),
            checkpoint_cost=0.01,
        )
        r1, r1b, r2 = mk(1), mk(1), mk(2)
        assert r1.makespan == r1b.makespan  # same seed replays
        assert r1.faults > 0
        assert (r1.makespan, r1.faults) != (r2.makespan, r2.faults)


# ---------------------------------------------------------------------------
# Censoring x faults (satellite: censored semantics under breakdowns)
# ---------------------------------------------------------------------------


class TestCensoredUnderFaults:
    def test_breakdown_preempted_job_queued_at_horizon_is_censored(self):
        """Both servers die and never repair within the horizon: the
        preempted job sits in the queue at max_time and must surface as
        censored=1 — not vanish from the aggregates."""
        jobs = [JobSpec(0, 0.0, 4, 1000, RESNET)]
        chaos = ChaosSpec(
            scripted_failures=((0, 1.0, 1e9), (1, 1.0, 1e9))
        )
        eng = make_engine(
            jobs, StaticGangPolicy(), chaos=chaos, checkpoint_cost=0.01
        )
        res = eng.run(max_time=5.0)
        assert res.censored == 1
        assert len(res.jct) == 0
        assert res.cancelled == 0
        assert res.faults == 2 and res.preemptions == 1
        # progress made before the breakdown is carried, so the delivered
        # throughput is still visible in goodput
        assert res.goodput > 0.0

    def test_cancelled_jobs_are_not_censored(self):
        jobs = [JobSpec(i, 0.0, 2, 500, RESNET) for i in range(3)]
        chaos = ChaosSpec(seed=5, cancel_prob=1.0, cancel_after_s=0.5)
        res = run_static(jobs, chaos=chaos)
        assert res.cancelled == 3
        assert res.censored == 0
        assert len(res.jct) == 0
        # cancelled partial progress is not delivered throughput
        assert res.goodput == 0.0

    def test_cancel_after_finish_is_a_no_op(self):
        jobs = [JobSpec(0, 0.0, 2, 2, RESNET)]
        chaos = ChaosSpec(seed=5, cancel_prob=1.0, cancel_after_s=1e6)
        base = run_static(jobs)
        res = run_static(jobs, chaos=chaos)
        assert res.cancelled == 0
        assert res.jct == base.jct

    def test_cancelled_job_trace_ends_with_cancel_marker(self):
        """``record_trace=True`` through a cancellation: the victim's
        records stop at the cancel instant, a ``cancel`` marker closes its
        trace, and the bystander's trace stays a full clean incarnation."""
        jobs = [
            JobSpec(0, 0.0, 2, 500, RESNET),  # doomed long job
            JobSpec(1, 0.0, 2, 3, RESNET),  # finishes before the cancel
        ]
        chaos = ChaosSpec(seed=5, cancel_prob=0.5, cancel_after_s=2.0)
        res = run_static(jobs, chaos=chaos, record_trace=True, fuse_fb=False)
        assert res.cancelled >= 1 and res.work_lost_samples > 0
        cancelled = [r[0] for r in res.task_trace if r[2] == "cancel"]
        assert len(cancelled) == res.cancelled
        for jid in cancelled:
            recs = [r for r in res.task_trace if r[0] == jid]
            t_cancel = recs[-1][4]
            assert recs[-1][2] == "cancel", "cancel marker must close the trace"
            for (_, _, kind, _, t0, t1) in recs[:-1]:
                # nothing is scheduled after the cancel; records may END
                # past it only as the planned end of the in-flight work
                # the cancel killed (compute records carry the end they
                # were scheduled with; an in-flight all-reduce stays
                # tombstoned with an open end)
                assert t0 <= t_cancel + 1e-9
                if t1 is None:
                    assert kind.startswith("c")
        survivors = set(res.jct)
        assert survivors, "the short bystander should have finished"
        for jid in survivors:
            recs, markers = job_records(res.task_trace, jid)
            spec = jobs[jid]
            validate_preempted_job_trace(spec, recs, markers)


# ---------------------------------------------------------------------------
# Stragglers + NIC degradation (directed)
# ---------------------------------------------------------------------------


class TestStragglersAndNic:
    def test_stragglers_stretch_the_run(self):
        jobs = [JobSpec(0, 0.0, 4, 30, RESNET)]
        base = run_static(jobs)
        slow = run_static(
            jobs,
            chaos=ChaosSpec(seed=2, straggler_prob=1.0, straggler_slowdown=1.0),
        )
        # every iteration stretched by 1 + Exp(1): strictly slower
        assert slow.makespan > base.makespan * 1.2
        assert slow.faults == 0  # jitter is not a fault event
        assert len(slow.jct) == 1 and slow.censored == 0

    def test_nic_degradation_slows_comm_and_counts_faults(self):
        # comm-heavy spanning gang; frequent long windows at 0.25x NIC
        jobs = [JobSpec(0, 0.0, 4, 30, TABLE_III["vgg16"])]
        base = run_static(jobs)
        res = run_static(
            jobs,
            chaos=ChaosSpec(
                seed=3, nic_mtbf_s=2.0, nic_mttr_s=20.0, nic_degraded_scale=0.25
            ),
        )
        assert res.faults > 0  # NIC windows count as fault events
        assert res.makespan > base.makespan * 1.5
        assert len(res.jct) == 1 and res.censored == 0


# ---------------------------------------------------------------------------
# Aborted-all-reduce gating fix (the bugfix lock)
# ---------------------------------------------------------------------------


class TestAbortedCommGating:
    """Aborting an in-flight transfer must re-run the gating pass in the
    SAME event: a waiter that Ada-SRSF gated against the aborted transfer
    starts at the abort instant, not at the aborted transfer's would-be
    completion (``engine._abort_comm`` sets ``_comm_dirty``)."""

    # job 0: near-zero compute, one huge all-reduce (the gate's "old")
    BIG = ModelProfile("chaos_big", 526.4e6, 4000.0, 32, 0.005, 0.005)
    # job 1: slower compute, mid-size all-reduce — reaches its barrier
    # ~0.13 s in, while job 0's transfer is still draining, with a
    # new/old remaining-bytes ratio far above the 0.417 dual threshold
    MID = ModelProfile("chaos_mid", 300e6, 4000.0, 32, 0.05, 0.07)

    def _jobs(self):
        return [
            JobSpec(0, 0.0, 4, 1, self.BIG),
            JobSpec(1, 0.0, 4, 3, self.MID),
        ]

    @staticmethod
    def _first_comm(trace, jid):
        recs = sorted(
            (r for r in trace if r[0] == jid and r[2].startswith("c")),
            key=lambda r: r[4],
        )
        assert recs, f"job {jid} never communicated"
        return recs[0]

    def test_waiter_starts_at_abort_instant(self):
        # baseline: job 1 is gated until job 0's transfer completes
        base = make_engine(
            self._jobs(),
            StaticGangPolicy(),
            record_trace=True,
            fuse_fb=False,
        ).run()
        big_end = self._first_comm(base.task_trace, 0)[5]
        gated_start = self._first_comm(base.task_trace, 1)[4]
        assert gated_start == pytest.approx(big_end, abs=1e-9)
        assert gated_start > 0.4  # well past job 1's ~0.13 s barrier

        # fixed engine: preempt job 0 at t=0.2, mid-transfer
        res = make_engine(
            self._jobs(),
            ScriptedPreemptPolicy([0], quantum=0.2),
            record_trace=True,
            fuse_fb=False,
            checkpoint_cost=0.01,
        ).run()
        assert res.preemptions == 1
        start = self._first_comm(res.task_trace, 1)[4]
        # the waiter starts IN the abort event ...
        assert start == pytest.approx(0.2, abs=1e-9)
        # ... strictly earlier than the aborted transfer's would-be finish
        assert start < big_end - 0.2
        assert len(res.jct) == 2 and res.censored == 0


# ---------------------------------------------------------------------------
# Fault invariants (Hypothesis fuzz over scripted breakdown schedules)
# ---------------------------------------------------------------------------

MODELS = ("resnet50", "inception_v3")


def _windows(raw):
    """Turn raw (server, start, width) triples into a valid non-overlapping
    scripted_failures tuple by stacking windows per server."""
    t_next = {}
    out = []
    for srv, start, width in raw:
        t0 = max(start, t_next.get(srv, 0.0))
        t1 = t0 + width
        out.append((srv, t0, t1))
        t_next[srv] = t1
    return tuple(out)


class TestFaultInvariants:
    @given(
        jobs=st.lists(
            st.tuples(
                st.integers(min_value=1, max_value=4),  # n_gpus
                st.integers(min_value=2, max_value=5),  # iterations
                st.sampled_from(MODELS),
                st.integers(min_value=0, max_value=2),  # arrival second
            ),
            min_size=1,
            max_size=3,
        ),
        raw_windows=st.lists(
            st.tuples(
                st.integers(min_value=0, max_value=1),  # server
                st.floats(min_value=0.0, max_value=2.0),  # fail time
                st.floats(min_value=0.05, max_value=0.5),  # downtime
            ),
            max_size=4,
        ),
        chaos_seed=st.integers(min_value=0, max_value=3),
    )
    @settings(max_examples=25, deadline=None)
    def test_breakdown_trace_stays_valid(self, jobs, raw_windows, chaos_seed):
        specs = [
            JobSpec(i, float(arr), n, iters, TABLE_III[m])
            for i, (n, iters, m, arr) in enumerate(jobs)
        ]
        chaos = ChaosSpec(
            seed=chaos_seed,
            scripted_failures=_windows(raw_windows),
            straggler_prob=0.2,
            straggler_slowdown=0.5,
        )
        eng = make_engine(
            specs,
            StaticGangPolicy(),
            chaos=chaos,
            record_trace=True,
            fuse_fb=False,
            checkpoint_cost=0.02,
        )
        res = eng.run()
        # repairs always come: every job finishes despite arbitrary
        # breakdown windows, and nothing is silently censored
        assert len(res.jct) == len(specs)
        assert res.censored == 0 and res.cancelled == 0
        if res.faults == 0:
            assert res.work_lost_samples == 0 and res.preemptions == 0
        for spec in specs:
            recs, markers = job_records(res.task_trace, spec.job_id)
            # atomic gang teardown at every breakdown instant
            for (t_pre, _) in markers:
                for (_, _, _, _, t0, t1) in recs:
                    assert t1 <= t_pre + 1e-9 or t0 >= t_pre - 1e-9
            # per-incarnation DAG linear extension; iterations covered once
            validate_preempted_job_trace(spec, recs, markers)
        # conservation: all jobs finished, so delivered samples (goodput x
        # makespan) equal the total committed work exactly; the lost work
        # was re-executed on top, never double-counted as delivered
        delivered = res.goodput * res.makespan
        total = sum(s.total_samples for s in specs)
        assert delivered == pytest.approx(total, rel=1e-9)
        assert res.work_lost_samples >= 0


# ---------------------------------------------------------------------------
# Metrics threading: the SLO columns survive into the CSV layer
# ---------------------------------------------------------------------------


class TestChaosMetrics:
    def test_slo_fields_thread_into_run_metrics(self):
        scn = get_scenario("chaos_steady", seed=1, n_jobs=8, n_servers=4)
        res = run_scenario_event(scn)
        m = from_event_result(res, scenario=scn.name, seed=1, n_jobs=scn.n_jobs)
        assert m.faults == res.faults
        assert m.work_lost == res.work_lost_samples
        assert m.cancelled == res.cancelled
        assert m.goodput == res.goodput
        assert m.p99_jct == res.p99_jct()
        for col in ("faults", "cancelled", "work_lost", "p99_jct", "goodput"):
            assert col in CSV_FIELDS
        row = m.as_csv_row()
        assert len(row.split(",")) == len(CSV_FIELDS)

    def test_p99_dominates_median(self):
        scn = get_scenario("chaos_steady", seed=1, n_jobs=8, n_servers=4)
        res = run_scenario_event(scn)
        jcts = sorted(res.jct.values())
        assert res.p99_jct() >= jcts[len(jcts) // 2]  # >= median
        assert res.p99_jct() <= jcts[-1] + 1e-12  # <= max


# ---------------------------------------------------------------------------
# The recovery-storm finding (regression-locked, fixed seeds)
# ---------------------------------------------------------------------------


class TestRecoveryStormFinding:
    """Does contention-aware gating help or hurt a recovery storm?  Both,
    depending on the trace — locked on two fixed seeds of
    ``chaos_recovery_storm`` (half the servers fail at t=70 and all
    repair at t=100, re-admitting every preempted gang at once):

    * **Seed 11 (helps, amplified)**: the storm widens Ada-SRSF's win
      over ungated SRSF far beyond its fault-free margin on the same
      workload — serializing the catch-up all-reduces is exactly what
      the synchronized re-admission needs.
    * **Seed 2 (hurts, inverted)**: the same storm *inverts* the
      paper's ordering — Ada-SRSF's delayed transfers pile into the
      post-repair burst and finish later than if they had simply joined
      the contention, while on the fault-free workload Ada-SRSF is not
      worse.  Contention-aware gating is not uniformly safe under
      synchronized recovery.
    """

    @staticmethod
    def _ratio(scn):
        ada = run_scenario_event(scn, comm="ada").avg_jct()
        srsf2 = run_scenario_event(scn, comm="srsf2").avg_jct()
        return ada / srsf2

    @pytest.fixture(scope="class")
    def storm11(self):
        return get_scenario("chaos_recovery_storm", seed=11)

    @pytest.fixture(scope="class")
    def storm2(self):
        return get_scenario("chaos_recovery_storm", seed=2)

    def test_seed11_storm_amplifies_gating_win(self, storm11):
        storm = self._ratio(storm11)
        clean = self._ratio(dataclasses.replace(storm11, chaos=None))
        assert storm < 0.90  # decisive win under the storm
        assert storm < clean - 0.02  # strictly wider than fault-free

    def test_seed2_storm_inverts_gating_win(self, storm2):
        storm = self._ratio(storm2)
        clean = self._ratio(dataclasses.replace(storm2, chaos=None))
        assert storm > 1.02  # gating LOSES under the storm ...
        assert clean < 1.012  # ... but not on the fault-free workload

    def test_storm_cells_inject_and_account(self, storm11):
        res = run_scenario_event(storm11)
        assert res.faults == storm11.n_servers // 2
        assert res.preemptions > 0
        assert res.work_lost_samples > 0
        assert res.censored == 0
        assert len(res.jct) == storm11.n_jobs
