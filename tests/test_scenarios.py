"""Scenario-engine tests: registry sanity, per-scenario invariants, and the
paper's policy-ordering regressions (Ada-SRSF vs SRSF(1)/SRSF(2) avg JCT,
LWF-kappa vs first-fit makespan) locked on fixed-seed downsized scenarios."""

import dataclasses
import functools

import pytest

from repro.core.contention import ContentionParams
from repro.scenarios import (
    QUICK_OVERRIDES,
    get_scenario,
    run_scenario_event,
    scenario_names,
    summarize,
    sweep,
)

#: Fixed seeds for the regression tests, paired with the shared downsized
#: QUICK_OVERRIDES sizing.  Each (seed, overrides) cell was verified to
#: (a) finish every job and (b) satisfy the paper orderings; any scheduler
#: change that breaks one of them is a regression (or a finding worth an
#: EXPERIMENTS.md entry).
REGRESSION_SEEDS = {
    "paper": 0,
    "philly_heavy_tail": 1,
    "bursty_diurnal": 1,
    "hetero_bandwidth": 1,
    "large_job_dominated": 1,
    "adversarial_allbig": 1,
    "contended_residue": 1,
    "oversub_fabric": 1,
    "rack_locality": 1,
    "model_zoo": 1,
    "fusion_sweep": 1,
    # the preemptive/elastic cells run their *static* defaults here (the
    # generic ordering locks); the sched-policy gains are regression-locked
    # separately in tests/test_engine.py
    "preemption_gain": 2,
    "elastic_surge": 1,
    "smoke": 0,
    # chaos cells run their registered fault specs (event-only); seeds
    # verified to keep every ordering AND inject faults (faults > 0).
    # The recovery-storm gating finding is locked separately in
    # tests/test_chaos.py::TestRecoveryStormFinding on its own seeds.
    "chaos_steady": 1,
    "chaos_recovery_storm": 3,
    "chaos_stragglers": 1,
    # trace-replay cells run through the streaming TraceSource path of
    # run_scenario_event (bit-identical to list mode; the streaming engine
    # is locked separately in tests/test_tracesource.py)
    "trace_replay_synth": 0,
    "trace_replay_philly": 0,
    "trace_replay_alibaba": 0,
}

#: Scenarios whose workload does not derive from ``seed``: the fully
#: deterministic smoke cell and the CSV trace replays (a replayed file is
#: the same file at every seed).
SEED_INDEPENDENT = {"smoke", "trace_replay_philly", "trace_replay_alibaba"}
REGRESSION_CELLS = {
    name: (seed, QUICK_OVERRIDES[name]) for name, seed in REGRESSION_SEEDS.items()
}

RTOL = 5e-3  # numerical slack on the <= orderings


def small(name):
    seed, overrides = REGRESSION_CELLS[name]
    return get_scenario(name, seed=seed, **overrides)


@functools.lru_cache(maxsize=None)
def sim(name, comm="ada", placement="lwf"):
    """Memoized event-sim run of a regression cell (results are reused
    across the ordering tests; simulations are deterministic)."""
    return run_scenario_event(small(name), comm=comm, placement=placement)


class TestRegistry:
    def test_at_least_six_scenarios(self):
        assert len(scenario_names()) >= 6
        assert set(REGRESSION_CELLS) == set(scenario_names())

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("nope")

    def test_duplicate_registration_raises(self):
        from repro.scenarios import register

        with pytest.raises(ValueError, match="already registered"):
            register("smoke")(lambda seed=0: None)

    @pytest.mark.parametrize("name", sorted(REGRESSION_CELLS))
    def test_seed_determinism(self, name):
        a, b = small(name), small(name)
        assert a.jobs == b.jobs
        assert a.params == b.params

    @pytest.mark.parametrize(
        "name", [n for n in sorted(REGRESSION_CELLS) if n not in SEED_INDEPENDENT]
    )
    def test_different_seeds_differ(self, name):
        _, overrides = REGRESSION_CELLS[name]
        a = get_scenario(name, seed=100, **overrides)
        b = get_scenario(name, seed=101, **overrides)
        assert a.jobs != b.jobs


class TestScenarioInvariants:
    @pytest.mark.parametrize("name", sorted(REGRESSION_CELLS))
    def test_well_formed(self, name):
        scn = small(name)
        jobs = scn.job_list()
        assert len(jobs) > 0
        assert len({j.job_id for j in jobs}) == len(jobs)
        assert all(j.arrival >= 0 for j in jobs)
        assert all(jobs[i].arrival <= jobs[i + 1].arrival for i in range(len(jobs) - 1))
        assert all(0 < j.n_gpus <= scn.total_gpus for j in jobs)
        assert all(j.iterations >= 1 for j in jobs)
        cluster, jlist, params = scn.build()
        assert cluster.n_servers == scn.n_servers
        assert len(jlist) == scn.n_jobs
        assert isinstance(params, ContentionParams)

    def test_fresh_cluster_per_build(self):
        scn = small("smoke")
        c1, c2 = scn.make_cluster(), scn.make_cluster()
        assert c1 is not c2
        c1.gpus[(0, 0)].mem_used_mb = 999.0
        assert c2.gpus[(0, 0)].mem_used_mb == 0.0

    def test_smoke_is_fully_deterministic(self):
        assert get_scenario("smoke", seed=0).jobs == get_scenario("smoke", seed=7).jobs

    def test_hetero_bandwidth_has_slow_servers(self):
        scn = small("hetero_bandwidth")
        bw = scn.params.server_bandwidth
        assert len(bw) == scn.n_servers
        assert min(bw) < 1.0 < max(bw) + 1e-9

    def test_hetero_bandwidth_slows_jobs_down(self):
        """Same workload on a degraded network must not finish sooner."""
        scn = small("hetero_bandwidth")
        homog = dataclasses.replace(scn, params=ContentionParams())
        slow = run_scenario_event(scn, comm="ada")
        fast = run_scenario_event(homog, comm="ada")
        assert slow.avg_jct() >= fast.avg_jct() * (1 - RTOL)
        assert slow.makespan >= fast.makespan * (1 - RTOL)

    @pytest.mark.parametrize("name", sorted(REGRESSION_CELLS))
    def test_no_horizon_censoring(self, name):
        """Every regression cell must drain completely: the explicit
        ``SimResult.censored`` count (jobs cut off by a ``max_time``
        horizon, which used to vanish silently from the JCT stats) is
        asserted zero so truncation can never corrupt a locked ordering.
        This includes every chaos cell: a breakdown-preempted job still
        queued when the run drains would show up here, not vanish."""
        res = sim(name, comm="ada")
        assert res.censored == 0
        assert len(res.jct) == small(name).n_jobs

    @pytest.mark.parametrize(
        "name", [n for n in sorted(REGRESSION_CELLS) if n.startswith("chaos_")]
    )
    def test_chaos_cells_actually_inject(self, name):
        """A chaos regression cell whose spec never fires would silently
        degenerate to its fault-free baseline — require the injector to
        land at least one fault event at the locked seed."""
        scn = small(name)
        assert scn.chaos is not None and scn.chaos.active
        res = sim(name, comm="ada")
        assert res.faults > 0
        assert res.goodput > 0.0

    def test_topology_scenarios_carry_a_fabric(self):
        for name in ("oversub_fabric", "rack_locality"):
            scn = small(name)
            assert scn.topology is not None
            assert scn.topology.n_servers == scn.n_servers
            assert max(d.oversub for d in scn.topology.domains) > 1.0
            assert len(scn.topology.racks) >= 2


class TestPhillyCalibration:
    """philly_heavy_tail is calibrated against the published Philly-trace
    statistics (Jeon et al., ATC'19): the scale-free duration-quantile
    ratios and the single-GPU-dominated request mix.  Fixed seed so any
    change to the generator's shape parameters trips this lock."""

    def _durations_and_gpus(self, seed):
        import numpy as np

        scn = get_scenario("philly_heavy_tail", seed=seed, n_jobs=4000)
        dur = np.asarray([j.iterations * j.model.t_iter_compute for j in scn.jobs])
        gpus = np.asarray([j.n_gpus for j in scn.jobs])
        return dur, gpus

    def test_duration_tail_ratios_match_published(self):
        import numpy as np

        from repro.scenarios.library import (
            PHILLY_DURATION_P90_OVER_P50,
            PHILLY_DURATION_P95_OVER_P50,
        )

        dur, _ = self._durations_and_gpus(seed=7)
        p50, p90, p95 = np.percentile(dur, [50, 90, 95])
        assert p90 / p50 == pytest.approx(PHILLY_DURATION_P90_OVER_P50, rel=0.25)
        assert p95 / p50 == pytest.approx(PHILLY_DURATION_P95_OVER_P50, rel=0.30)

    def test_gpu_request_mix_matches_published(self):
        import numpy as np

        from repro.scenarios.library import PHILLY_GPU_WEIGHTS

        _, gpus = self._durations_and_gpus(seed=7)
        weights = dict(PHILLY_GPU_WEIGHTS)
        assert float(np.mean(gpus == 1)) == pytest.approx(weights[1], abs=0.03)
        assert float(np.mean(gpus >= 8)) == pytest.approx(
            weights[8] + weights[16] + weights[32], abs=0.02
        )

    def test_alpha_solves_the_p90_identity(self):
        import math

        from repro.scenarios.library import (
            PHILLY_DURATION_P90_OVER_P50,
            PHILLY_PARETO_ALPHA,
        )

        # p90/p50 of a Pareto(alpha) is 5**(1/alpha)
        assert 5.0 ** (1.0 / PHILLY_PARETO_ALPHA) == pytest.approx(
            PHILLY_DURATION_P90_OVER_P50, rel=1e-12
        )


class TestBurstyIntensityCalibration:
    """The bursty_diurnal arrival intensity is a calibrated knob
    (peak-to-mean arrival-rate ratio), not a hand-picked burst fraction
    (ROADMAP item from PR 3).  Fixed seed: any change to the
    burst_fraction identity or the generator shape trips these locks."""

    def _peak_to_mean(self, peak_to_mean, seed=11, n_jobs=4000):
        import numpy as np

        scn = get_scenario(
            "bursty_diurnal", seed=seed, n_jobs=n_jobs, peak_to_mean=peak_to_mean
        )
        arr = np.asarray([j.arrival for j in scn.jobs])
        # arrival-rate histogram at the burst width (sigma = H/60 = 20 s)
        counts, _ = np.histogram(arr, bins=60, range=(0.0, 1200.0))
        return counts.max() / counts.mean()

    def test_default_reproduces_legacy_burst_fraction(self):
        """peak_to_mean=4 at the default shape solves to the previous
        hand-picked burst_frac=0.6 (the identity's calibration anchor)."""
        import math

        from repro.scenarios.library import BURSTY_PEAK_TO_MEAN, burst_fraction

        frac = burst_fraction(BURSTY_PEAK_TO_MEAN, 1200.0, 4, 1200.0 / 60.0)
        assert frac == pytest.approx(0.6, abs=0.01)
        assert math.isclose(burst_fraction(1.0, 1200.0, 4, 20.0), 0.0)

    def test_realized_intensity_tracks_the_knob(self):
        """The realized peak-to-mean arrival-rate ratio follows the knob:
        monotone in it, and at the fixed seed the default knob's realized
        value is locked (a quantile lock like the Philly calibration).
        The realized max-bin ratio sits above the designed per-burst
        center intensity — bursts can overlap and the max over 60 bins is
        an extreme-value statistic — so the lock is on the measured value,
        not on knob == realized."""
        lo = self._peak_to_mean(1.5)
        mid = self._peak_to_mean(4.0)
        hi = self._peak_to_mean(5.5)
        assert lo < mid < hi
        assert mid == pytest.approx(7.05, rel=0.1)
        assert 1.0 * 4.0 <= mid <= 2.5 * 4.0

    def test_fixed_seed_lock(self):
        """Concrete-value lock on the default-knob workload (seed 1): any
        change to the burst_fraction identity, the RNG draw order, or the
        arrival formula shifts these pinned numbers."""
        a = get_scenario("bursty_diurnal", seed=1, n_jobs=32)
        assert [j.arrival for j in a.jobs[:6]] == [
            151.0, 203.0, 217.0, 221.0, 232.0, 235.0,
        ]
        assert [(j.n_gpus, j.iterations) for j in a.jobs[:3]] == [
            (1, 789), (1, 1317), (2, 3986),
        ]
        assert sum(j.arrival for j in a.jobs) == 17439.0

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="peak_to_mean"):
            get_scenario("bursty_diurnal", seed=0, n_jobs=4, peak_to_mean=0.5)


class TestPaperOrderings:
    """The paper's headline orderings, locked per scenario on fixed seeds."""

    #: WFBP regime shift (documented finding, not a bug): with fine-grained
    #: bucketed transfers (fusion_sweep), AdaDUAL's pairwise-overlap
    #: acceptance buys little — per-bucket overlap windows are short — while
    #: the eta penalty still accrues, so Ada-SRSF lands within ~2% of, but
    #: not strictly below, the exclusive-link SRSF(1) baseline.  The paper's
    #: strict ordering is a claim about monolithic iteration-level comm.
    SRSF1_SLACK = {"fusion_sweep": 2e-2}

    @pytest.mark.parametrize("name", sorted(REGRESSION_CELLS))
    def test_ada_beats_srsf_baselines(self, name):
        scn = small(name)
        ada = sim(name, comm="ada")
        srsf1 = sim(name, comm="srsf1")
        srsf2 = sim(name, comm="srsf2")
        assert len(ada.jct) == scn.n_jobs, "Ada-SRSF stranded jobs"
        assert len(srsf1.jct) == scn.n_jobs
        assert len(srsf2.jct) == scn.n_jobs
        slack = self.SRSF1_SLACK.get(name, RTOL)
        assert ada.avg_jct() <= srsf1.avg_jct() * (1 + slack), (
            f"{name}: Ada-SRSF {ada.avg_jct():.1f} vs SRSF(1) {srsf1.avg_jct():.1f}"
        )
        assert ada.avg_jct() <= srsf2.avg_jct() * (1 + RTOL), (
            f"{name}: Ada-SRSF {ada.avg_jct():.1f} vs SRSF(2) {srsf2.avg_jct():.1f}"
        )

    @pytest.mark.parametrize("name", sorted(REGRESSION_CELLS))
    def test_lwf_beats_first_fit_makespan(self, name):
        lwf = sim(name, comm="ada", placement="lwf")
        ff = sim(name, comm="ada", placement="ff")
        assert lwf.makespan <= ff.makespan * (1 + RTOL), (
            f"{name}: LWF-1 {lwf.makespan:.1f} vs FF {ff.makespan:.1f}"
        )


class TestSweepRunner:
    def test_matrix_shape_and_summary(self):
        records = sweep(
            ["smoke"], comms=("ada", "srsf2"), placements=("lwf", "ff"), seeds=(0, 1)
        )
        assert len(records) == 1 * 2 * 2 * 2
        agg = summarize(records)
        assert len(agg) == 4  # seeds collapse into the group key
        for v in agg.values():
            assert v["n_runs"] == 2.0
            assert v["finished_frac"] == 1.0

    def test_multiprocessing_matches_serial(self):
        kw = dict(comms=("ada",), seeds=(0, 1), overrides={})
        serial = sweep(["smoke"], processes=None, **kw)
        fanned = sweep(["smoke"], processes=2, **kw)
        assert [r.avg_jct for r in serial] == [r.avg_jct for r in fanned]
        assert [r.makespan for r in serial] == [r.makespan for r in fanned]

    def test_policy_aliases(self):
        from repro.scenarios import canonical_comm

        assert canonical_comm("adadual") == "ada"
        assert canonical_comm("Ada-SRSF") == "ada"
        assert canonical_comm("srsf2") == "srsf2"


class TestMonteCarloCI:
    """The vmap-batched Monte-Carlo path: one device launch per cell,
    per-seed records identical to serial fluid runs, CellCI aggregation."""

    def test_batched_matches_serial_fluid(self):
        from repro.scenarios import monte_carlo_fluid, run_scenario_fluid

        seeds = (0, 1)
        recs = monte_carlo_fluid("contended_residue", seeds, comm="ada", dt=0.05)
        assert [r.seed for r in recs] == list(seeds)
        for r, seed in zip(recs, seeds):
            scn = get_scenario("contended_residue", seed=seed)
            out = run_scenario_fluid(scn, comm="ada", dt=0.05)
            serial = [float(j) for j, f in zip(out["jct"], out["finished"]) if f]
            assert r.n_finished == len(serial) == scn.n_jobs
            assert r.avg_jct == pytest.approx(sum(serial) / len(serial))
            assert r.makespan == pytest.approx(float(out["makespan"]))

    def test_fluid_ci_preserves_paper_ordering(self):
        from repro.scenarios import sweep_ci

        cis = sweep_ci(
            ["contended_residue"],
            comms=("ada", "srsf2"),
            placements=("lwf",),
            seeds=(0, 1, 2),
            backend="fluid",
            dt=0.05,
        )
        by = {c.comm: c for c in cis}
        assert set(by) == {"ada", "srsf2"}
        for c in cis:
            assert c.n_seeds == 3
            assert c.finished_frac == 1.0
            assert c.avg_jct_std >= 0.0
            assert c.backend == "fluid"
        assert by["ada"].avg_jct_mean <= by["srsf2"].avg_jct_mean

    def test_ci_from_runs_math(self):
        from repro.scenarios import ci_from_runs, from_jcts

        recs = [
            from_jcts(
                [10.0 + off], scenario="s", backend="event", placement="p",
                comm="c", seed=i, n_jobs=1, makespan=20.0 + off,
            )
            for i, off in enumerate((-2.0, 0.0, 2.0))
        ]
        (ci,) = ci_from_runs(recs)
        assert ci.n_seeds == 3
        assert ci.avg_jct_mean == pytest.approx(10.0)
        assert ci.avg_jct_std == pytest.approx((8.0 / 3) ** 0.5)
        assert ci.makespan_mean == pytest.approx(20.0)
        assert ci.finished_frac == 1.0
