"""Unit tests for the communication cost/contention models (paper Eq. 2/5,
Table I, Fig. 2 fits)."""

import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.contention import (
    ALLREDUCE_ALGORITHMS,
    PAPER_A,
    PAPER_B,
    ContentionParams,
    allreduce_cost_terms,
    fit_contention_penalty,
    fit_linear_cost,
    simulate_contention_sweep,
)


class TestEq5:
    def test_k1_reduces_to_eq2(self):
        p = ContentionParams()
        m = 100e6
        assert p.allreduce_time(m, k=1) == pytest.approx(p.a + p.b * m)

    def test_monotone_in_k(self):
        p = ContentionParams()
        m = 50e6
        times = [p.allreduce_time(m, k) for k in range(1, 9)]
        assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))

    def test_penalty_term(self):
        """T(k) - (a + k*b*M) == (k-1)*eta*M exactly (the Fig. 2(b) gap)."""
        p = ContentionParams()
        m, k = 100e6, 5
        assert p.allreduce_time(m, k) - (p.a + k * p.b * m) == pytest.approx(
            (k - 1) * p.eta * m
        )

    def test_rate_consistency(self):
        """Draining M bytes at rate(k) must take the Eq. 5 time minus a."""
        p = ContentionParams()
        m, k = 123e6, 3
        assert m / p.rate(k) == pytest.approx(p.allreduce_time(m, k) - p.a)

    @given(
        st.floats(1e-11, 1e-8),
        st.floats(0, 1e-8),
        st.integers(1, 16),
    )
    @settings(max_examples=100, deadline=None)
    def test_rate_positive(self, b, eta, k):
        p = ContentionParams(a=0.0, b=b, eta=eta)
        assert p.rate(k) > 0

    def test_invalid(self):
        with pytest.raises(ValueError):
            ContentionParams(b=-1.0)
        with pytest.raises(ValueError):
            ContentionParams().allreduce_time(1.0, k=0)


class TestTableI:
    @pytest.mark.parametrize("alg", ALLREDUCE_ALGORITHMS)
    def test_positive_costs(self, alg):
        a, b = allreduce_cost_terms(alg, 8, alpha=1e-5, beta=1e-9, gamma=1e-10)
        assert a > 0 and b > 0

    def test_ring_bandwidth_optimal(self):
        """Ring's per-byte term beats the tree algorithms for large N."""
        kw = dict(alpha=1e-5, beta=1e-9, gamma=1e-10)
        _, b_ring = allreduce_cost_terms("ring", 64, **kw)
        _, b_tree = allreduce_cost_terms("binary_tree", 64, **kw)
        _, b_rd = allreduce_cost_terms("recursive_doubling", 64, **kw)
        assert b_ring < b_tree and b_ring < b_rd

    def test_ring_latency_scales_linearly(self):
        kw = dict(alpha=1e-5, beta=1e-9, gamma=0.0)
        a8, _ = allreduce_cost_terms("ring", 8, **kw)
        a16, _ = allreduce_cost_terms("ring", 16, **kw)
        assert a16 / a8 == pytest.approx(30 / 14)

    def test_unknown_algorithm(self):
        with pytest.raises(ValueError):
            allreduce_cost_terms("nope", 4, 1e-5, 1e-9, 0)


class TestFits:
    def test_linear_fit_recovers_paper_constants(self):
        ms = np.linspace(1e6, 500e6, 40)
        ts = PAPER_A + PAPER_B * ms
        a, b = fit_linear_cost(ms, ts)
        assert a == pytest.approx(PAPER_A, rel=0.05)
        assert b == pytest.approx(PAPER_B, rel=0.01)

    def test_eta_fit_recovers_truth(self):
        truth = ContentionParams(eta=3.3e-10)
        m = 100e6
        ks = np.arange(1, 9)
        times = simulate_contention_sweep(truth, m, 8)
        eta = fit_contention_penalty(ks, times, m, truth.a, truth.b)
        assert eta == pytest.approx(truth.eta, rel=1e-6)

    def test_dual_threshold_bounds(self):
        """b/(2(b+eta)) in (0, 0.5]; eta=0 gives exactly 1/2."""
        assert ContentionParams(eta=0.0).dual_threshold == pytest.approx(0.5)
        p = ContentionParams()
        assert 0 < p.dual_threshold < 0.5


class TestServerBandwidthEdges:
    """Edge cases of the per-server bandwidth multipliers (scenario-engine
    heterogeneity): servers beyond the tuple, empty tuples, degenerate
    cluster sizes."""

    def test_empty_tuple_is_nominal(self):
        p = ContentionParams()
        assert p.server_bandwidth == ()
        assert p.bandwidth_scale({0, 1, 2}) == 1.0
        assert p.mean_bandwidth_scale(16) == 1.0

    def test_servers_beyond_tuple_are_nominal(self):
        p = ContentionParams(server_bandwidth=(0.5, 2.0))
        assert p.bandwidth_scale({0}) == 0.5
        assert p.bandwidth_scale({1}) == 2.0
        assert p.bandwidth_scale({5}) == 1.0        # past the tuple
        assert p.bandwidth_scale({1, 7}) == 1.0     # nominal member binds
        assert p.bandwidth_scale({0, 7}) == 0.5     # slow member binds

    def test_mean_pads_with_nominal(self):
        p = ContentionParams(server_bandwidth=(0.5, 0.5))
        assert p.mean_bandwidth_scale(4) == pytest.approx((0.5 + 0.5 + 1 + 1) / 4)
        assert p.mean_bandwidth_scale(2) == pytest.approx(0.5)

    def test_mean_degenerate_cluster_is_nominal(self):
        p = ContentionParams(server_bandwidth=(0.5,))
        assert p.mean_bandwidth_scale(0) == 1.0
        assert p.mean_bandwidth_scale(-3) == 1.0

    def test_nonpositive_multiplier_rejected(self):
        with pytest.raises(ValueError, match="must be positive"):
            ContentionParams(server_bandwidth=(1.0, 0.0))
        with pytest.raises(ValueError, match="must be positive"):
            ContentionParams(server_bandwidth=(-0.5,))
