"""Tests for the placement algorithms (paper Algorithm 1, LWF-kappa)."""

import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.cluster import TABLE_III, Cluster, JobSpec
from repro.core.placement import (
    PlacementPolicy,
    place_first_fit,
    place_list_scheduling,
    place_lwf,
    place_random,
)


def mk_job(n_gpus, job_id=0, model="resnet50", iters=1000):
    return JobSpec(job_id, 0.0, n_gpus, iters, TABLE_III[model])


def empty_cluster():
    return Cluster(n_servers=16, gpus_per_server=4)


class TestBasics:
    @pytest.mark.parametrize("policy", ["rand", "ff", "ls", "lwf"])
    def test_returns_exact_count_and_unique(self, policy):
        c = empty_cluster()
        for n in (1, 2, 4, 8, 32):
            got = PlacementPolicy(policy, kappa=1)(c, mk_job(n))
            assert got is not None and len(got) == n and len(set(got)) == n

    def test_memory_admission(self):
        c = empty_cluster()
        # fill every GPU to leave less than a vgg16 footprint
        for g in c.gpus.values():
            g.mem_used_mb = g.mem_capacity_mb - 1000.0
        assert place_first_fit(c, mk_job(1, model="vgg16")) is None
        # resnet50 (3213 MB) also doesn't fit in 1000 MB
        assert place_list_scheduling(c, mk_job(1)) is None

    def test_ff_is_in_order(self):
        c = empty_cluster()
        got = place_first_fit(c, mk_job(6))
        assert got == sorted(c.all_gpu_ids())[:6]

    def test_ls_picks_least_loaded(self):
        c = empty_cluster()
        for gid, g in c.gpus.items():
            g.workload = 100.0
        light = [(3, 1), (7, 2), (9, 0)]
        for s, i in light:
            c.gpus[(s, i)].workload = 1.0
        got = place_list_scheduling(c, mk_job(3))
        assert set(got) == set(light)


class TestLwfKappa:
    def test_small_job_equals_ls(self):
        """n <= kappa: LWF == LS (Alg. 1 lines 2-9)."""
        c = empty_cluster()
        for gid, g in c.gpus.items():
            g.workload = float(hash(gid) % 37)
        for n, kappa in [(1, 1), (2, 2), (4, 4)]:
            assert place_lwf(c, mk_job(n), kappa) == place_list_scheduling(c, mk_job(n))

    def test_large_job_consolidates(self):
        """n > kappa: GPUs come from the fewest, least-loaded servers."""
        c = empty_cluster()
        got = place_lwf(c, mk_job(8), kappa=1)
        servers = {s for s, _ in got}
        assert len(servers) == 2  # 8 GPUs / 4 per server

    def test_large_job_prefers_idle_servers(self):
        c = empty_cluster()
        # load servers 0..13; keep 14, 15 idle
        for s in range(14):
            for g in c.gpus_of_server(s):
                g.workload = 1000.0
        got = place_lwf(c, mk_job(8), kappa=1)
        assert {s for s, _ in got} == {14, 15}

    def test_kappa_consolidation_vs_ls_spread(self):
        """The scenario motivating LWF: per-GPU workloads that trick LS into
        spreading across many servers, while LWF-1 consolidates."""
        c = empty_cluster()
        # one light GPU on each server -> LS picks 8 different servers
        for s in range(16):
            for i, g in enumerate(c.gpus_of_server(s)):
                g.workload = 1.0 if i == 0 else 50.0
        ls = place_list_scheduling(c, mk_job(8))
        lwf = place_lwf(c, mk_job(8), kappa=1)
        assert len({s for s, _ in ls}) == 8
        assert len({s for s, _ in lwf}) == 2

    @given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 2**31))
    @settings(max_examples=60, deadline=None)
    def test_lwf_respects_memory_and_count(self, n, kappa, seed):
        rng = random.Random(seed)
        c = empty_cluster()
        for g in c.gpus.values():
            g.workload = rng.uniform(0, 100)
            g.mem_used_mb = rng.choice([0.0, 14000.0])  # some GPUs nearly full
        job = mk_job(n)
        got = place_lwf(c, job, kappa)
        feasible = [g.gpu_id for g in c.available_gpus(job.model.mem_mb)]
        if got is None:
            assert len(feasible) < n
        else:
            assert len(got) == n and set(got) <= set(feasible)


class TestClusterBookkeeping:
    def test_place_release_roundtrip(self):
        c = empty_cluster()
        job = mk_job(4, model="vgg16")
        gids = place_lwf(c, job, 1)
        c.place(job, gids, workload_share=123.0)
        for gid in gids:
            assert c.gpus[gid].mem_used_mb == pytest.approx(job.model.mem_mb)
            assert job.job_id in c.gpus[gid].resident_jobs
        c.release(job, gids)
        for gid in gids:
            assert c.gpus[gid].mem_used_mb == 0.0
            assert job.job_id not in c.gpus[gid].resident_jobs

    def test_double_booking_memory_raises(self):
        c = Cluster(n_servers=1, gpus_per_server=1, gpu_mem_mb=5000.0)
        j1, j2 = mk_job(1, 1, "vgg16"), mk_job(1, 2, "vgg16")
        c.place(j1, [(0, 0)], 1.0)
        with pytest.raises(RuntimeError):
            c.place(j2, [(0, 0)], 1.0)
