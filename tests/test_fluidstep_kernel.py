"""Parity of the fused Pallas fluid-step core against the lax reference.

The reference path (``kernels/fluidstep/ref.py``) is the physics anchor —
it is what CPU CI and every differential test run.  The Pallas kernel
(``kernel.py``) must be indistinguishable through the ``ops.py`` dispatch:
same dtypes, same values (integer planes exact, float planes to f32
round-off), same ``inf`` sentinel for jobs with no overlapping in-flight
transfer.  Interpreter mode runs the kernel body on CPU, so this guards
the kernel math everywhere, not just on TPU runners.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.fluidstep import fluid_step_core
from repro.kernels.fluidstep.ops import FLUID_KERNEL_IMPLS, default_impl


def _rand_inputs(seed, n_jobs=12, n_servers=6, n_domains=9):
    rng = np.random.default_rng(seed)
    loads = rng.random((n_jobs, n_domains)) < 0.35
    # a comm-capable job loads >= 1 domain; some rows left empty on purpose
    member = rng.random((n_jobs, n_servers)) < 0.4
    active = rng.random(n_jobs) < 0.5
    rem = rng.uniform(0.05, 80.0, n_jobs)
    bw = rng.uniform(0.4, 2.5, n_servers)
    oversub = rng.uniform(1.0, 4.0, n_domains)
    return (
        jnp.asarray(loads),
        jnp.asarray(member, dtype=jnp.float32),
        jnp.asarray(active),
        jnp.asarray(rem, dtype=jnp.float32),
        jnp.asarray(bw, dtype=jnp.float32),
        jnp.asarray(oversub, dtype=jnp.float32),
    )


def _both(seed, **kw):
    loads, member, active, rem, bw, oversub = _rand_inputs(seed, **kw)
    args = dict(b=7e-10, eta=3e-10, need_overlap=True)
    ref = fluid_step_core(loads, member, active, rem, bw, oversub,
                          impl="ref", **args)
    pal = fluid_step_core(loads, member, active, rem, bw, oversub,
                          impl="interpret", **args)
    return ref, pal


class TestPallasParity:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_states_match(self, seed):
        ref, pal = _both(seed)
        np.testing.assert_array_equal(
            np.asarray(ref["counts"]), np.asarray(pal["counts"])
        )
        np.testing.assert_array_equal(
            np.asarray(ref["k_would"]), np.asarray(pal["k_would"])
        )
        np.testing.assert_array_equal(
            np.asarray(ref["overlap"]), np.asarray(pal["overlap"])
        )
        np.testing.assert_allclose(
            np.asarray(ref["k_eff"]), np.asarray(pal["k_eff"]), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(ref["ratio"]), np.asarray(pal["ratio"]), rtol=1e-6
        )
        r_min = np.asarray(ref["min_old_rem"])
        p_min = np.asarray(pal["min_old_rem"])
        np.testing.assert_array_equal(np.isinf(r_min), np.isinf(p_min))
        finite = ~np.isinf(r_min)
        np.testing.assert_allclose(r_min[finite], p_min[finite], rtol=1e-6)

    def test_dtypes_identical_across_impls(self):
        ref, pal = _both(0)
        for key in ("counts", "k_eff", "ratio", "k_would", "min_old_rem",
                    "overlap"):
            assert np.asarray(ref[key]).dtype == np.asarray(pal[key]).dtype, key

    def test_no_active_transfers(self):
        loads, member, _, rem, bw, oversub = _rand_inputs(5)
        active = jnp.zeros(loads.shape[0], dtype=bool)
        args = dict(b=7e-10, eta=3e-10, need_overlap=True)
        ref = fluid_step_core(loads, member, active, rem, bw, oversub,
                              impl="ref", **args)
        pal = fluid_step_core(loads, member, active, rem, bw, oversub,
                              impl="interpret", **args)
        assert int(np.asarray(ref["counts"]).sum()) == 0
        np.testing.assert_array_equal(
            np.asarray(ref["counts"]), np.asarray(pal["counts"])
        )
        # nothing in flight -> every job's M_old is the +inf sentinel
        assert np.isinf(np.asarray(pal["min_old_rem"])).all()

    def test_empty_loads_rows(self):
        loads, member, active, rem, bw, oversub = _rand_inputs(6)
        loads = loads.at[0].set(False)  # comm-less job
        args = dict(b=7e-10, eta=3e-10, need_overlap=True)
        ref = fluid_step_core(loads, member, active, rem, bw, oversub,
                              impl="ref", **args)
        pal = fluid_step_core(loads, member, active, rem, bw, oversub,
                              impl="interpret", **args)
        # a loadless row contends with nothing: k floors at 1, M_old = inf
        assert float(np.asarray(ref["k_eff"])[0]) == 1.0
        assert float(np.asarray(pal["k_eff"])[0]) == 1.0
        assert np.isinf(np.asarray(pal["min_old_rem"])[0])
        np.testing.assert_array_equal(
            np.asarray(ref["overlap"]), np.asarray(pal["overlap"])
        )


class TestDispatch:
    def test_unknown_impl_raises(self):
        loads, member, active, rem, bw, oversub = _rand_inputs(0)
        with pytest.raises(ValueError, match="unknown fluid step impl"):
            fluid_step_core(loads, member, active, rem, bw, oversub,
                            b=7e-10, eta=3e-10, impl="cuda")

    def test_default_is_ref(self, monkeypatch):
        monkeypatch.delenv("REPRO_FLUID_KERNEL", raising=False)
        assert default_impl() == "ref"
        monkeypatch.setenv("REPRO_FLUID_KERNEL", "interpret")
        assert default_impl() == "interpret"
        assert default_impl() in FLUID_KERNEL_IMPLS

    def test_ref_skips_overlap_unless_needed(self):
        loads, member, active, rem, bw, oversub = _rand_inputs(1)
        out = fluid_step_core(loads, member, active, rem, bw, oversub,
                              b=7e-10, eta=3e-10, need_overlap=False,
                              impl="ref")
        assert out["overlap"] is None
