"""Optional-``hypothesis`` shim for the property-test modules.

On a bare environment (no ``hypothesis`` installed) the property tests are
skipped with a clear reason while the deterministic tests in the same
modules keep running.  ``given`` becomes a decorator that replaces the test
with a skip; ``settings`` becomes a no-op; ``st`` becomes a stub whose
strategy constructors return ``None`` (the values are never drawn because
the test body is never entered).
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare envs
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``; every attribute is a
        callable returning ``None`` so module-level strategy definitions
        (e.g. ``st.builds(...)``) import cleanly."""

        def __getattr__(self, name):
            def _strategy(*_args, **_kwargs):
                return None

            return _strategy

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
