"""Network-fabric topology layer (``core/topology.py``) tests.

Three layers of coverage:

* the :class:`Topology` object itself — construction validation, the one
  load rule (a task loads a domain iff its ring crosses the domain's cut),
  incidence-matrix structure, and the numpy/jax ``netmodel.domain_loads``
  lowering agreeing with the set-based ``loaded_domains``;
* the **NIC-only parity regression** both acceptance criteria hinge on:
  an explicit ``nic_topology`` must reproduce the default (no-topology)
  event- and fluid-backend results bit for bit, and so must the two
  degenerate fabrics that reduce to it (a two-tier fabric with a single
  rack, and racks-of-one with oversub 1.0);
* behavioural checks: oversubscribed uplinks slow cross-rack traffic on
  both backends, intra-rack traffic is unaffected, and the rack-aware
  LWF placement keeps rack-sized jobs off the uplinks.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import netmodel
from repro.core.cluster import TABLE_III, Cluster, JobSpec
from repro.core.contention import ContentionParams
from repro.core.placement import PlacementPolicy, place_lwf_rack
from repro.core.topology import Domain, Topology, nic_topology, two_tier, uplink_only
from repro.scenarios import get_scenario, run_scenario_event, run_scenario_fluid
from repro.scenarios.registry import Scenario


class TestConstruction:
    def test_nic_topology_shape(self):
        t = nic_topology(4)
        assert t.n_domains == 4
        assert all(d.oversub == 1.0 for d in t.domains)
        np.testing.assert_array_equal(t.incidence(), np.eye(4, dtype=np.float32))

    def test_two_tier_shape(self):
        t = two_tier(8, 4, oversub=3.0)
        assert t.n_domains == 8 + 2  # NICs + 2 rack uplinks
        assert t.racks == ((0, 1, 2, 3), (4, 5, 6, 7))
        assert t.oversub_array()[-1] == pytest.approx(3.0)
        np.testing.assert_array_equal(t.server_rack(), [0, 0, 0, 0, 1, 1, 1, 1])

    def test_ragged_last_rack(self):
        t = two_tier(5, 2)
        assert t.racks == ((0, 1), (2, 3), (4,))

    def test_validation(self):
        with pytest.raises(ValueError, match="oversub"):
            Domain("d", (0,), oversub=0.0)
        with pytest.raises(ValueError, match="no servers"):
            Domain("d", ())
        with pytest.raises(ValueError, match="references servers outside"):
            Topology("t", 2, (Domain("d", (5,)),))
        with pytest.raises(ValueError, match="references servers outside"):
            # negative indices would silently wrap in incidence()
            Topology("t", 4, (Domain("d", (-1, 0)),))
        with pytest.raises(ValueError, match="two racks"):
            Topology("t", 2, (), racks=((0,), (0, 1)))

    def test_hashable_and_picklable(self):
        """Topology rides inside a jit-static JaxSimConfig and crosses the
        sweep runner's multiprocessing boundary."""
        import pickle

        t = two_tier(8, 4)
        assert hash(t) == hash(two_tier(8, 4))
        assert pickle.loads(pickle.dumps(t)) == t


class TestLoadRule:
    def test_single_server_task_loads_nothing(self):
        """A single-server job's traffic never leaves the server: no cut is
        crossed, no shared domain is loaded — in any topology."""
        for topo in (nic_topology(4), two_tier(4, 2, 3.0), uplink_only(4, 2)):
            assert topo.loaded_domains({2}) == frozenset()

    def test_nic_domains_are_the_member_servers(self):
        t = nic_topology(4)
        assert t.loaded_domains({0, 2}) == {0, 2}

    def test_intra_rack_task_skips_uplinks(self):
        t = two_tier(8, 4, oversub=3.0)
        # servers 0,1 are both in rack 0: NIC cuts crossed, uplink not
        assert t.loaded_domains({0, 1}) == {0, 1}

    def test_cross_rack_task_loads_both_uplinks(self):
        t = two_tier(8, 4, oversub=3.0)
        assert t.loaded_domains({0, 4}) == {0, 4, 8, 9}

    def test_non_contiguous_gang_placement(self):
        """A fragmented gang across non-adjacent servers in three racks
        loads each touched NIC and each touched rack's uplink."""
        t = two_tier(12, 4, oversub=2.0)  # racks {0-3},{4-7},{8-11}
        loaded = t.loaded_domains({1, 6, 11})
        assert loaded == {1, 6, 11, 12 + 0, 12 + 1, 12 + 2}

    def test_domain_covering_everything_never_loads(self):
        t = Topology("all", 4, (Domain("world", (0, 1, 2, 3)),))
        assert t.loaded_domains({0, 3}) == frozenset()


class TestIncidenceLowering:
    """netmodel.domain_loads (the fluid backend's branchless form) must
    agree with Topology.loaded_domains (the event backend's set form) for
    every member set — including non-contiguous gang placements."""

    @pytest.mark.parametrize(
        "topo",
        [nic_topology(6), two_tier(6, 2, 3.0), two_tier(6, 4, 2.0), uplink_only(6, 3)],
        ids=lambda t: t.name,
    )
    def test_matches_set_form(self, topo):
        inc = topo.incidence()
        rng = np.random.default_rng(0)
        member_sets = [
            {0},
            {0, 1},
            {0, 5},
            {1, 3, 5},
            {0, 1, 2, 3, 4, 5},
        ] + [set(rng.choice(6, size=rng.integers(1, 6), replace=False).tolist())
             for _ in range(20)]
        for s in member_sets:
            mask = np.zeros((6,), dtype=np.float32)
            mask[list(s)] = 1.0
            loads = netmodel.domain_loads(mask, inc)
            assert set(np.nonzero(loads)[0]) == set(topo.loaded_domains(s)), s

    def test_batched_member_masks(self):
        topo = two_tier(6, 2, 3.0)
        inc = topo.incidence()
        masks = np.asarray(
            [[1, 1, 0, 0, 0, 0], [1, 0, 0, 0, 0, 1], [0, 0, 1, 0, 0, 0]],
            dtype=np.float32,
        )
        loads = netmodel.domain_loads(masks, inc)
        assert loads.shape == (3, topo.n_domains)
        assert set(np.nonzero(loads[0])[0]) == {0, 1}          # intra-rack
        assert set(np.nonzero(loads[1])[0]) == {0, 5, 6, 8}    # cross-rack
        assert not loads[2].any()                              # single server

    def test_domain_k_counts_and_oversub(self):
        loads = np.asarray([[True, False, True], [True, True, False]])
        counts = netmodel.domain_counts(loads, np.asarray([True, True]))
        np.testing.assert_array_equal(counts, [2, 1, 1])
        k = netmodel.domain_k(loads, counts)
        np.testing.assert_array_equal(k, [2, 2])
        k_eff = netmodel.domain_k(loads, counts * np.asarray([1.0, 1.0, 4.0]))
        np.testing.assert_array_equal(k_eff, [4.0, 2.0])
        # a task loading no domain is uncontended
        k_none = netmodel.domain_k(np.zeros((1, 3), bool), counts)
        np.testing.assert_array_equal(k_none, [1])


@pytest.fixture(scope="module")
def smoke():
    return get_scenario("smoke")


@pytest.fixture(scope="module")
def contended():
    return get_scenario("contended_residue", seed=1)


class TestNicParityRegression:
    """The acceptance-criteria lock: NIC-only topology must reproduce the
    pre-topology numbers exactly on both backends."""

    @pytest.mark.parametrize("name", ["smoke", "contended_residue"])
    @pytest.mark.parametrize("comm", ["ada", "srsf1", "kway3"])
    def test_event_backend_bit_exact(self, name, comm):
        scn = get_scenario(name, seed=1)
        nic = dataclasses.replace(scn, topology=nic_topology(scn.n_servers))
        a = run_scenario_event(scn, comm=comm)
        b = run_scenario_event(nic, comm=comm)
        assert a.jct == b.jct
        assert a.makespan == b.makespan
        assert a.events_processed == b.events_processed
        assert a.comm_started_contended == b.comm_started_contended

    @pytest.mark.parametrize("comm", ["ada", "srsf2", "kway3"])
    def test_fluid_backend_bit_exact(self, contended, comm):
        nic = dataclasses.replace(contended, topology=nic_topology(contended.n_servers))
        a = run_scenario_fluid(contended, comm=comm, dt=0.02)
        b = run_scenario_fluid(nic, comm=comm, dt=0.02)
        np.testing.assert_array_equal(np.asarray(a["jct"]), np.asarray(b["jct"]))
        assert float(a["makespan"]) == float(b["makespan"])

    def test_single_rack_two_tier_degenerates_to_nic(self, smoke):
        """One rack covering every server: the uplink cut is never crossed,
        so the fabric is exactly the NIC-only model."""
        degen = dataclasses.replace(
            smoke, topology=two_tier(smoke.n_servers, smoke.n_servers, oversub=9.0)
        )
        a = run_scenario_event(smoke, comm="ada")
        b = run_scenario_event(degen, comm="ada")
        assert a.jct == b.jct
        fa = run_scenario_fluid(smoke, comm="ada", dt=0.02)
        fb = run_scenario_fluid(degen, comm="ada", dt=0.02)
        np.testing.assert_array_equal(np.asarray(fa["jct"]), np.asarray(fb["jct"]))

    def test_racks_of_one_unit_oversub_degenerates_to_nic(self, contended):
        """Racks of a single server with oversub 1.0 duplicate the NIC cuts
        at unit capacity: per-domain counts and maxima are unchanged."""
        degen = dataclasses.replace(
            contended, topology=two_tier(contended.n_servers, 1, oversub=1.0)
        )
        a = run_scenario_event(contended, comm="srsf2")
        b = run_scenario_event(degen, comm="srsf2")
        assert a.jct == b.jct
        fa = run_scenario_fluid(contended, comm="srsf2", dt=0.02)
        fb = run_scenario_fluid(degen, comm="srsf2", dt=0.02)
        np.testing.assert_array_equal(np.asarray(fa["jct"]), np.asarray(fb["jct"]))


class TestOversubBehaviour:
    def test_oversub_uplinks_slow_crossing_traffic_event_and_fluid(self, smoke):
        """Racks of one: every spanning job crosses an oversubscribed
        uplink, so the whole schedule must stretch on both backends."""
        slow = dataclasses.replace(smoke, topology=two_tier(smoke.n_servers, 1, oversub=4.0))
        ev_nic = run_scenario_event(smoke, comm="ada")
        ev_slow = run_scenario_event(slow, comm="ada")
        assert ev_slow.makespan > ev_nic.makespan
        assert len(ev_slow.jct) == smoke.n_jobs
        fl_nic = run_scenario_fluid(smoke, comm="ada", dt=0.02)
        fl_slow = run_scenario_fluid(slow, comm="ada", dt=0.02)
        assert float(fl_slow["makespan"]) > float(fl_nic["makespan"])
        assert int(fl_slow["finished"].sum()) == smoke.n_jobs

    def test_uncontended_crossing_rate_matches_oversub(self):
        """One 2-server job on a 2-rack oversub fabric: its only transfer is
        uncontended (k=1) but crosses the uplink, so it drains at the
        Eq. (5) rate of k_eff = oversub — the event backend integrates this
        exactly."""
        p = ContentionParams()
        oversub = 4.0
        jobs = [JobSpec(0, 0.0, 2, 10, TABLE_III["vgg16"])]

        def run(topology):
            scn = Scenario(
                name="one",
                seed=0,
                n_servers=2,
                gpus_per_server=1,
                jobs=tuple(jobs),
                params=p,
                topology=topology,
            )
            return run_scenario_event(scn, comm="ada")

        base = run(None)
        crossed = run(two_tier(2, 1, oversub=oversub))
        m = TABLE_III["vgg16"].size_bytes
        extra_per_iter = m * (p.seconds_per_byte(oversub) - p.seconds_per_byte(1))
        expect = base.makespan + 10 * extra_per_iter
        assert crossed.makespan == pytest.approx(expect, rel=1e-9)

    def test_uplink_only_relieves_nic_contention(self, contended):
        """Without NIC domains, intra-rack all-reduces never contend: the
        uplink_only fabric (single rack) can only be faster."""
        free = dataclasses.replace(
            contended,
            topology=uplink_only(contended.n_servers, contended.n_servers),
        )
        a = run_scenario_event(contended, comm="srsf3")
        b = run_scenario_event(free, comm="srsf3")
        assert b.avg_jct() <= a.avg_jct() * (1 + 1e-9)


class TestRackAwarePlacement:
    def _pinned_cluster(self):
        """2 racks x 2 servers x 4 GPUs with servers 1 and 2 partially
        occupied: plain LWF picks the two idle servers 0 and 3 (different
        racks) for a 6-GPU job; rack-aware placement stays inside rack 0."""
        topo = two_tier(4, 2, oversub=8.0)
        cluster = Cluster(n_servers=4, gpus_per_server=4)
        pin = JobSpec(99, 0.0, 1, 100, TABLE_III["resnet50"])
        for s in (1, 2):
            cluster.place(pin, [(s, 0)], workload_share=50.0)
        return topo, cluster

    def test_plain_lwf_crosses_racks(self):
        topo, cluster = self._pinned_cluster()
        job = JobSpec(0, 0.0, 6, 10, TABLE_III["resnet50"])
        gpus = PlacementPolicy("lwf")(cluster, job)
        assert {s for s, _ in gpus} == {0, 3}  # idle servers, racks 0 and 1
        assert len(topo.loaded_domains({s for s, _ in gpus}) - {0, 3}) > 0

    def test_rack_aware_stays_inside_one_rack(self):
        topo, cluster = self._pinned_cluster()
        job = JobSpec(0, 0.0, 6, 10, TABLE_III["resnet50"])
        gpus = place_lwf_rack(cluster, job, topo.rack_groups())
        servers = {s for s, _ in gpus}
        assert servers == {0, 1}  # all of rack 0
        # only NIC cuts crossed — no uplink domain loaded
        assert all(topo.domains[d].oversub == 1.0 for d in topo.loaded_domains(servers))

    def test_without_topology_degenerates_to_lwf(self):
        cluster = Cluster(n_servers=4, gpus_per_server=4)
        job = JobSpec(0, 0.0, 6, 10, TABLE_III["resnet50"])
        a = PlacementPolicy("lwf")(cluster, job)
        b = PlacementPolicy("lwf_rack")(cluster, job)
        assert a == b

    def test_rack_pack_rank_prefers_emptiest_rack(self):
        free = np.asarray([1.0, 1.0, 4.0, 3.0])
        server_rack = np.asarray([0, 0, 1, 1])
        rank = netmodel.rack_pack_rank(free, server_rack, 2, gpus_per_server=4)
        order = np.argsort(rank, kind="stable")
        assert order.tolist() == [2, 3, 0, 1]  # rack 1 (7 free) first, fuller-first inside
