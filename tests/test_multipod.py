"""Multi-pod mesh smoke (subprocess, 16 forced host devices): proves the
("pod","data","model") axis layout lowers and compiles with the production
sharding rules, and that batch shards over ("pod","data")."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import dataclasses, json
    import jax

    from repro.configs import get_config
    from repro.launch.dryrun import Profile, _build_and_lower, _compile_and_analyze
    from repro.models.config import InputShape
    from repro.models.lm import RunFlags

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 4), ("pod", "data", "model"))
    cfg = get_config("llama3.2-1b", reduced=True)
    flags = RunFlags(remat="none", q_chunk=32)
    out = {}
    for shape in (InputShape("t", 64, 8, "train"), InputShape("d", 128, 8, "decode")):
        res = _compile_and_analyze(_build_and_lower(
            cfg, shape, mesh, Profile(strategy="tp", remat="none", q_chunk=32), flags))
        out[shape.kind] = {
            "collectives": res["collectives"]["op_counts"],
            "temp": res["memory"]["temp_bytes"],
        }
    print(json.dumps(out))
    """
)


@pytest.mark.slow
def test_multipod_mesh_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert "train" in data and "decode" in data
    # training on a 3-axis mesh must produce gradient collectives
    assert sum(data["train"]["collectives"].values()) > 0
