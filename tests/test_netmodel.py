"""Unit tests for the shared policy/network layer (``core/netmodel.py``):
the same predicates must give identical answers on Python scalars (event
backend path) and numpy arrays (the shape the fluid backend traces), and
must agree with the event-side wrappers in ``core/adadual.py``."""

import math

import numpy as np
import pytest

from repro.core import netmodel
from repro.core.adadual import adadual_should_start, srsf_n_should_start
from repro.core.contention import ContentionParams

P = ContentionParams()


class TestRateModel:
    def test_ratio_is_one_uncontended(self):
        assert netmodel.rate_ratio(1, P.b, P.eta) == pytest.approx(1.0)

    def test_ratio_matches_params_rate(self):
        for k in (1, 2, 3, 5):
            assert netmodel.rate(k, P.b, P.eta) == pytest.approx(P.rate(k))
            assert netmodel.rate_ratio(k, P.b, P.eta) == pytest.approx(
                P.rate(k) / P.rate(1)
            )

    def test_ratio_vectorizes(self):
        ks = np.array([1, 2, 4])
        out = netmodel.rate_ratio(ks, P.b, P.eta)
        assert out.shape == (3,)
        assert out[0] == pytest.approx(1.0)
        assert np.all(np.diff(out) < 0)  # more contention, smaller share


class TestServerBandwidth:
    def test_empty_is_homogeneous(self):
        assert np.all(netmodel.server_bandwidth_array((), 4) == 1.0)

    def test_pad_and_truncate(self):
        bw = netmodel.server_bandwidth_array((0.5, 2.0), 4)
        np.testing.assert_allclose(bw, [0.5, 2.0, 1.0, 1.0])
        bw = netmodel.server_bandwidth_array((0.5, 2.0, 3.0), 2)
        np.testing.assert_allclose(bw, [0.5, 2.0])

    def test_zero_servers(self):
        assert netmodel.server_bandwidth_array((0.5,), 0).shape == (0,)

    def test_slowest_member_matches_params(self):
        params = ContentionParams(server_bandwidth=(0.4, 1.0, 0.7))
        bw = netmodel.server_bandwidth_array(params.server_bandwidth, 4)
        for servers in ({0}, {1}, {0, 2}, {2, 3}, {1, 3}):
            mask = np.zeros(4, dtype=bool)
            mask[list(servers)] = True
            assert netmodel.slowest_member_scale(bw, mask) == pytest.approx(
                params.bandwidth_scale(servers)
            ), servers

    def test_slowest_member_no_members_is_nominal(self):
        bw = netmodel.server_bandwidth_array((0.4,), 3)
        assert netmodel.slowest_member_scale(bw, np.zeros(3, bool)) == 1.0

    def test_slowest_member_batched(self):
        bw = np.array([0.4, 1.0, 0.7])
        masks = np.array([[1, 0, 1], [0, 1, 0], [0, 0, 0]], dtype=bool)
        out = netmodel.slowest_member_scale(bw, masks)
        np.testing.assert_allclose(out, [0.4, 1.0, 1.0])


class TestParsePolicy:
    def test_known(self):
        assert netmodel.parse_policy("ada") == netmodel.PolicySpec("ada", 2, True)
        assert netmodel.parse_policy("srsf1") == netmodel.PolicySpec("srsf1", 1, False)
        assert netmodel.parse_policy("srsf3") == netmodel.PolicySpec("srsf3", 3, False)
        assert netmodel.parse_policy("kway3") == netmodel.PolicySpec(
            "kway3", 3, True, exact_lookahead=True
        )

    @pytest.mark.parametrize("bad", ["", "srsf0", "kway1", "lwf", "adadual"])
    def test_unknown_raises(self, bad):
        with pytest.raises(ValueError, match="unknown comm policy"):
            netmodel.parse_policy(bad)


class TestMayStart:
    def test_matches_adadual_wrapper(self):
        """The shared predicate and the event backend's Algorithm 2 wrapper
        must be the same function."""
        cases = [
            (0.0, []),            # uncontended
            (50e6, [200e6]),      # small vs one big old -> start
            (150e6, [200e6]),     # ratio test fails -> wait
            (50e6, [200e6, 60e6]),  # binding old is the small one
            (50e6, [0.0]),        # exhausted old -> refuse (event parity)
        ]
        for new_bytes, olds in cases:
            for max_conc in (0, 1, 2, 3):
                expect = adadual_should_start(new_bytes, olds, max_conc, P)
                got = netmodel.may_start(
                    max_conc + 1,
                    new_bytes,
                    min(olds, default=math.inf),
                    max_ways=2,
                    threshold_gated=True,
                    dual_threshold=P.dual_threshold,
                )
                assert bool(got) == expect, (new_bytes, olds, max_conc)

    def test_matches_srsf_n(self):
        for n in (1, 2, 3):
            for max_conc in (0, 1, 2, 3, 4):
                expect = srsf_n_should_start(max_conc, n)
                got = netmodel.may_start(
                    max_conc + 1, 0.0, math.inf,
                    max_ways=n, threshold_gated=False, dual_threshold=0.0,
                )
                assert bool(got) == expect, (n, max_conc)

    def test_vectorized_mask(self):
        k_would = np.array([1, 2, 2, 3])
        new_cost = np.array([1.0, 1.0, 1.0, 1.0])
        min_old = np.array([np.inf, 10.0, 1.0, 10.0])
        out = netmodel.may_start(
            k_would, new_cost, min_old,
            max_ways=2, threshold_gated=True, dual_threshold=0.4,
        )
        # lane0 uncontended; lane1 passes ratio (1 < 4); lane2 fails
        # (1 !< 0.4); lane3 over the cap
        np.testing.assert_array_equal(out, [True, True, False, False])

    def test_dynamic_variant_is_boolean_identical(self):
        """may_start_dynamic (runtime policy params — how the fluid backend
        shares one compiled graph across every gating policy) must agree
        with the static-parameter predicate everywhere."""
        rng = np.random.default_rng(0)
        k_would = rng.integers(1, 5, 200)
        new_cost = rng.uniform(0.0, 300e6, 200)
        min_old = np.where(rng.random(200) < 0.2, np.inf, rng.uniform(0, 300e6, 200))
        for max_ways in (1, 2, 3):
            for gated in (False, True):
                ref = netmodel.may_start(
                    k_would, new_cost, min_old,
                    max_ways=max_ways, threshold_gated=gated,
                    dual_threshold=P.dual_threshold,
                )
                dyn = netmodel.may_start_dynamic(
                    k_would, new_cost, min_old,
                    np.float32(max_ways), np.asarray(gated),
                    P.dual_threshold,
                )
                np.testing.assert_array_equal(ref, dyn, err_msg=f"{max_ways}/{gated}")


class TestKwayExactStart:
    """The closed-form exact k-way gate must agree decision-for-decision
    with the event backend's integrator-based reference
    (``adadual.kway_adadual_should_start``)."""

    E = P.eta / P.b

    def _closed(self, new_bytes, olds, max_ways):
        k = len(olds)
        rem = np.array([new_bytes] + list(olds), dtype=np.float64)
        new_cost = np.array([new_bytes] + [0.0] * k)
        mask = np.zeros((k + 1, k + 1), dtype=bool)
        mask[0, 1:] = True
        return bool(
            netmodel.kway_exact_start(new_cost, rem, mask, float(max_ways), self.E)[0]
        )

    def test_uncontended_always_starts(self):
        assert self._closed(123e6, [], 4)

    def test_max_ways_cap(self):
        olds = [100e6, 200e6, 300e6]
        assert not self._closed(1e6, olds, 3)  # k+1 = 4 > 3

    def test_matches_integrator_reference(self):
        from repro.core.adadual import kway_adadual_should_start

        rng = np.random.default_rng(7)
        for _ in range(300):
            k = int(rng.integers(0, 5))
            olds = list(rng.uniform(1e6, 8e8, k))
            new = float(rng.uniform(1e6, 8e8))
            max_ways = int(rng.integers(2, 6))
            ref = kway_adadual_should_start(new, olds, P, max_ways=max_ways)
            assert self._closed(new, olds, max_ways) == ref, (new, olds, max_ways)

    def test_matches_integrator_on_exact_ties(self):
        from repro.core.adadual import kway_adadual_should_start

        for k in (1, 2, 3):
            for ratio in (0.01, 0.4, P.dual_threshold, 1.0, 2.0):
                s = 3e8
                olds = [s] * k
                new = ratio * s
                ref = kway_adadual_should_start(new, olds, P, max_ways=4)
                assert self._closed(new, olds, 4) == ref, (k, ratio)

    def test_batched_rows_independent(self):
        """A batch of candidate rows must reproduce the per-row answers."""
        rem = np.array([50e6, 200e6, 150e6, 400e6])
        new_cost = np.array([50e6, 0.0, 150e6, 0.0])
        mask = np.zeros((4, 4), dtype=bool)
        mask[0, [1, 3]] = True   # candidate 0 vs olds {1, 3}
        mask[2, 1] = True        # candidate 2 vs old {1}
        out = netmodel.kway_exact_start(new_cost, rem, mask, 4.0, self.E)
        assert bool(out[0]) == self._closed(50e6, [200e6, 400e6], 4)
        assert bool(out[2]) == self._closed(150e6, [200e6], 4)

    def test_finish_times_match_integrator(self):
        """The closed form T_x = (1+e)*sum_y min(s_x, s_y) - e*s_x that the
        gate is built on must match the exact piecewise integrator."""
        from repro.core.adadual import simulate_task_set

        rng = np.random.default_rng(3)
        for _ in range(50):
            k = int(rng.integers(1, 6))
            sizes = rng.uniform(1e6, 8e8, k)
            ref = simulate_task_set([0.0] * k, list(sizes), P)
            m = np.minimum(sizes[:, None], sizes[None, :])
            closed = (P.b + P.eta) * m.sum(axis=1) - P.eta * sizes
            np.testing.assert_allclose(closed, ref, rtol=1e-9)


class TestPlacementRank:
    FREE = np.array([1.0, 4.0, 0.0, 2.0])
    LOAD = np.array([9.0, 0.0, 5.0, 2.0])
    IDX = np.arange(4, dtype=float)

    def order(self, mode):
        return list(np.argsort(
            netmodel.placement_rank(mode, self.FREE, self.LOAD, self.IDX),
            kind="stable",
        ))

    def test_modes(self):
        assert self.order("consolidate") == [1, 3, 0, 2]   # most free first
        assert self.order("first_fit") == [0, 1, 2, 3]     # index order
        assert self.order("least_loaded") == [1, 3, 2, 0]  # smallest L_S first

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError, match="unknown placement mode"):
            netmodel.placement_rank("nope", self.FREE, self.LOAD, self.IDX)

    def test_extra_key_modes_require_rank_extra(self):
        for mode in ("random", "rack_pack"):
            with pytest.raises(ValueError, match="rank_extra"):
                netmodel.placement_rank(mode, self.FREE, self.LOAD, self.IDX)
        key = np.array([3.0, 0.0, 2.0, 1.0])
        out = netmodel.placement_rank("random", self.FREE, self.LOAD, self.IDX, key)
        np.testing.assert_array_equal(out, key)

    def test_canonical_placement(self):
        assert netmodel.canonical_placement("lwf") == "consolidate"
        assert netmodel.canonical_placement("FF") == "first_fit"
        assert netmodel.canonical_placement("ls") == "least_loaded"
        assert netmodel.canonical_placement("consolidate") == "consolidate"
        assert netmodel.canonical_placement("rand") == "random"
        assert netmodel.canonical_placement("lwf_rack") == "rack_pack"
        with pytest.raises(ValueError, match="fluid backend supports"):
            netmodel.canonical_placement("nope")
