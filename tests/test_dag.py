"""Tests for the formal DAG job model (paper Fig. 3)."""

import pytest

from repro.core.dag import JobDag, TaskKind, TaskRef, build_job_dag, validate_schedule


class TestStructure:
    def test_task_count(self):
        dag = build_job_dag(0, n_workers=4, iterations=3, spans_servers=True)
        assert dag.n_tasks() == 3 * (2 * 4 + 1)
        dag2 = build_job_dag(0, n_workers=2, iterations=5, spans_servers=False)
        assert dag2.n_tasks() == 5 * 4

    def test_forward_has_no_predecessor_at_start(self):
        dag = build_job_dag(0, 2, 2, True)
        assert dag.predecessors(TaskRef(0, 0, TaskKind.FORWARD, 0)) == []

    def test_allreduce_barrier_over_all_workers(self):
        dag = build_job_dag(0, 3, 2, True)
        preds = dag.predecessors(TaskRef(0, 1, TaskKind.ALLREDUCE))
        assert len(preds) == 3
        assert all(p.kind is TaskKind.BACKWARD and p.iteration == 1 for p in preds)

    def test_next_iteration_waits_for_allreduce(self):
        dag = build_job_dag(0, 2, 3, True)
        preds = dag.predecessors(TaskRef(0, 2, TaskKind.FORWARD, 1))
        assert preds == [TaskRef(0, 1, TaskKind.ALLREDUCE)]

    def test_no_comm_chain_is_per_worker(self):
        dag = build_job_dag(0, 2, 3, False)
        preds = dag.predecessors(TaskRef(0, 1, TaskKind.FORWARD, 1))
        assert preds == [TaskRef(0, 0, TaskKind.BACKWARD, 1)]


class TestValidation:
    def _valid_intervals(self, dag):
        t = 0.0
        out = {}
        for task in dag.tasks():
            out[task] = (t, t + 1.0)
            t += 1.0
        # tasks() yields f,b per worker then c, per iteration -> sequential
        # execution in that order is a valid schedule
        return out

    def test_accepts_valid_schedule(self):
        dag = build_job_dag(0, 2, 2, True)
        ok, msg = validate_schedule(dag, self._valid_intervals(dag))
        assert ok, msg

    def test_rejects_barrier_violation(self):
        dag = build_job_dag(0, 2, 1, True)
        iv = self._valid_intervals(dag)
        # start the all-reduce before worker 1's backward ends
        c = TaskRef(0, 0, TaskKind.ALLREDUCE)
        b1 = TaskRef(0, 0, TaskKind.BACKWARD, 1)
        iv[c] = (iv[b1][1] - 0.5, iv[b1][1] + 1.0)
        ok, msg = validate_schedule(dag, iv)
        assert not ok and "edge violated" in msg

    def test_rejects_missing_task(self):
        dag = build_job_dag(0, 2, 1, True)
        iv = self._valid_intervals(dag)
        iv.pop(TaskRef(0, 0, TaskKind.ALLREDUCE))
        ok, msg = validate_schedule(dag, iv)
        assert not ok and "mismatch" in msg

    def test_rejects_reversed_interval(self):
        dag = build_job_dag(0, 1, 1, False)
        iv = self._valid_intervals(dag)
        f = TaskRef(0, 0, TaskKind.FORWARD, 0)
        iv[f] = (5.0, 1.0)
        ok, _ = validate_schedule(dag, iv)
        assert not ok
