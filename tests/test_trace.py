"""Tests for the paper trace generator (``core/trace.py``): GPU-count
distribution scaling, exact ``n_jobs`` padding/trim behavior, and seed
determinism."""

import collections

import pytest

from repro.core.cluster import TABLE_III
from repro.core.trace import PAPER_GPU_DISTRIBUTION, is_large, is_long, paper_trace


class TestGpuDistribution:
    def test_full_scale_matches_paper_exactly(self):
        """At n_jobs=160 the paper's Table-like distribution is exact:
        80x1, 14x2, 26x4, 30x8, 8x16, 2x32."""
        jobs = paper_trace(seed=0, n_jobs=160)
        counts = collections.Counter(j.n_gpus for j in jobs)
        assert counts == {1: 80, 2: 14, 4: 26, 8: 30, 16: 8, 32: 2}

    def test_scaling_preserves_proportions(self):
        jobs = paper_trace(seed=1, n_jobs=320)
        counts = collections.Counter(j.n_gpus for j in jobs)
        total = sum(c for _, c in PAPER_GPU_DISTRIBUTION)
        for gpus, count in PAPER_GPU_DISTRIBUTION:
            expect = count * 320 / total
            # rounding + 1-GPU pad/trim can shift each bucket slightly
            assert abs(counts[gpus] - expect) <= max(2, 0.1 * expect), (
                gpus,
                counts[gpus],
                expect,
            )

    def test_every_bucket_survives_downscaling(self):
        """max(1, round(...)) keeps rare sizes (16/32 GPUs) represented even
        in small traces."""
        jobs = paper_trace(seed=2, n_jobs=20)
        sizes = {j.n_gpus for j in jobs}
        assert {16, 32} <= sizes

    @pytest.mark.parametrize("n_jobs", [1, 7, 10, 59, 160, 161])
    def test_exact_n_jobs(self, n_jobs):
        """Pad/trim always yields exactly n_jobs jobs with unique ids."""
        jobs = paper_trace(seed=3, n_jobs=n_jobs)
        assert len(jobs) == n_jobs
        assert len({j.job_id for j in jobs}) == n_jobs

    def test_padding_uses_single_gpu_jobs(self):
        """When rounding under-produces, the pad fills with 1-GPU jobs, so
        small traces never have fewer 1-GPU jobs than the rounded share."""
        jobs = paper_trace(seed=4, n_jobs=10)
        counts = collections.Counter(j.n_gpus for j in jobs)
        # 6 buckets, each at least 1 after max(1, ...); 10 - 5 = 5 slots at
        # most for the rest, and any shortfall is 1-GPU padded
        assert counts[1] >= 1
        assert sum(counts.values()) == 10


class TestDeterminismAndFields:
    def test_seed_determinism(self):
        assert paper_trace(seed=42) == paper_trace(seed=42)

    def test_different_seeds_differ(self):
        assert paper_trace(seed=0) != paper_trace(seed=1)

    def test_sorted_by_arrival_with_tick_granularity(self):
        jobs = paper_trace(seed=5, n_jobs=80)
        assert all(
            jobs[i].arrival <= jobs[i + 1].arrival for i in range(len(jobs) - 1)
        )
        assert all(j.arrival == float(int(j.arrival)) for j in jobs)  # 1 s ticks
        assert all(1.0 <= j.arrival < 1200.0 for j in jobs)

    def test_iteration_bounds_and_models(self):
        jobs = paper_trace(seed=6, n_jobs=50, min_iters=100, max_iters=200)
        assert all(100 <= j.iterations <= 200 for j in jobs)
        profiles = set(TABLE_III.values())
        assert all(j.model in profiles for j in jobs)

    def test_is_large_is_long(self):
        jobs = paper_trace(seed=7, n_jobs=160)
        assert all(is_large(j) == (j.n_gpus > 4) for j in jobs)
        assert all(is_long(j) == (j.iterations > 1600) for j in jobs)
        assert any(is_large(j) for j in jobs) and any(not is_large(j) for j in jobs)
