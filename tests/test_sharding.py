"""Sharding-rule unit tests (pure logic — no fake devices) plus a
subprocess-based mini dry-run on 8 forced host devices that also validates
the scan-body cost correction against a fully-unrolled compile."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# Pure-logic tests use a Mesh built lazily inside a subprocess-safe guard:
# constructing an abstract mesh for spec computation doesn't need devices —
# but jax.make_mesh does, so we use jax.sharding.AbstractMesh (via the
# version-compat wrapper in repro.launch.mesh).
import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_abstract_mesh
from repro.sharding.rules import ShardingStrategy, spec_for_param


def mesh2d():
    return make_abstract_mesh((16, 16), ("data", "model"))


def mesh3d():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestSpecForParam:
    def test_tp_shards_heads(self):
        spec = spec_for_param(
            ("embed", "q_heads", "head_dim"), (4096, 32, 128), mesh2d(),
            ShardingStrategy("tp"),
        )
        assert spec == P(None, "model", None)

    def test_divisibility_guard_drops_axis(self):
        """yi-9b: 4 kv heads on a 16-way model axis stay replicated."""
        spec = spec_for_param(
            ("embed", "kv_heads", "head_dim"), (4096, 4, 128), mesh2d(),
            ShardingStrategy("tp"),
        )
        assert spec == P(None, None, None)

    def test_mesh_axis_used_at_most_once(self):
        """MoE weights (experts, embed, ffn): experts win, ffn dropped."""
        spec = spec_for_param(
            ("experts", "embed", "ffn"), (128, 7168, 4864), mesh2d(),
            ShardingStrategy("tp"),
        )
        assert spec == P("model", None, None)

    def test_fsdp_adds_data_axis(self):
        spec = spec_for_param(
            ("embed", "ffn"), (7168, 4864), mesh2d(), ShardingStrategy("fsdp")
        )
        assert spec == P("data", "model")

    def test_fsdp_multipod_uses_both_axes(self):
        spec = spec_for_param(
            ("embed", "ffn"), (7168, 4864), mesh3d(), ShardingStrategy("fsdp")
        )
        assert spec == P(("pod", "data"), "model")

    def test_dp_replicates_everything(self):
        spec = spec_for_param(
            ("vocab", "embed"), (50280, 768), mesh2d(), ShardingStrategy("dp")
        )
        assert spec == P(None, None)

    def test_vocab_padded_shards(self):
        from repro.models.config import pad_to, VOCAB_PAD_MULTIPLE

        v = pad_to(256206, VOCAB_PAD_MULTIPLE)
        spec = spec_for_param(("vocab", "embed"), (v, 1024), mesh2d(), ShardingStrategy("tp"))
        assert spec == P("model", None)


class TestBatchAxes:
    def test_batch_specs(self):
        from repro.sharding.rules import batch_spec_axes

        assert batch_spec_axes(mesh2d(), 256) == ("data",)
        assert batch_spec_axes(mesh3d(), 256) == ("pod", "data")
        assert batch_spec_axes(mesh3d(), 16) == ("pod",)  # 32 doesn't divide 16
        assert batch_spec_axes(mesh2d(), 1) is None
        assert batch_spec_axes(mesh2d(), 256, include_model=True) == ("data", "model")


MINI_DRYRUN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax

    from repro.configs import get_config
    from repro.launch.dryrun import Profile, run_combo, with_n_blocks, _build_and_lower, _compile_and_analyze
    from repro.models.config import InputShape
    from repro.models.lm import LM, RunFlags

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    shape = InputShape("mini_train", seq_len=64, global_batch=4, kind="train")
    profile = Profile(strategy="tp", remat="none", q_chunk=32)

    cfg = dataclasses.replace(
        get_config("llama3.2-1b", reduced=True), n_layers=6)

    flags = RunFlags(remat="none", q_chunk=32)
    full = _compile_and_analyze(_build_and_lower(cfg, shape, mesh, profile, flags))
    small = with_n_blocks(cfg, 4)
    u1 = _compile_and_analyze(_build_and_lower(small, shape, mesh, profile,
                                               dataclasses.replace(flags, scan_unroll=1)))
    u2 = _compile_and_analyze(_build_and_lower(small, shape, mesh, profile,
                                               dataclasses.replace(flags, scan_unroll=2)))
    delta = u2["cost"]["flops"] - u1["cost"]["flops"]
    corrected = full["cost"]["flops"] + (6 - 1) * delta
    # ground truth: fully unrolled 6-layer model
    unrolled = _compile_and_analyze(_build_and_lower(
        cfg, shape, mesh, profile, dataclasses.replace(flags, scan_unroll=6)))
    print(json.dumps({
        "corrected": corrected,
        "unrolled": unrolled["cost"]["flops"],
        "scanned_raw": full["cost"]["flops"],
        "collectives_found": full["collectives"]["op_counts"],
    }))
    """
)


@pytest.mark.slow
class TestMiniDryrunSubprocess:
    def test_scan_correction_matches_full_unroll(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run(
            [sys.executable, "-c", MINI_DRYRUN],
            capture_output=True,
            text=True,
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=1200,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        data = json.loads(out.stdout.strip().splitlines()[-1])
        corrected, unrolled = data["corrected"], data["unrolled"]
        # corrected must land within 15% of ground truth, and be much
        # better than the raw scanned number (which counts one body).
        assert abs(corrected - unrolled) / unrolled < 0.15, data
        assert abs(data["scanned_raw"] - unrolled) / unrolled > 0.3, data
        # the partitioned module must actually contain collectives
        assert sum(data["collectives_found"].values()) > 0, data
