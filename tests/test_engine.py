"""Engine/policy split tests.

* **Bit-exactness lock**: the refactored ``core/engine.py`` +
  ``StaticGangPolicy`` must reproduce the pre-refactor monolithic
  simulator EXACTLY (``==`` on float reprs, event counts and finish-time
  digests; sha256 over full task traces) on every fixed-seed regression
  cell — the baseline was captured at the last pre-refactor commit
  (``tests/data/engine_regression_baseline.json``, see
  ``tests/gen_engine_baseline.py``).
* **Preemption regression** (acceptance criterion): Tiresias-style
  ``PreemptiveSrsfPolicy`` beats static SRSF on the heavy-tailed
  ``preemption_gain`` fixed seed.
* **Elastic regression + resize mechanics**: ``ElasticPolicy`` beats
  static on ``elastic_surge``; boundary resizes rebuild the WFBP fusion
  plan and the topology domain sets for the new world size.
* **Preemption invariants** (deterministic + Hypothesis): completed
  iterations are never lost, gangs preempt/resume atomically, and every
  preempted trace remains a valid linear extension of the
  (re-instantiated per incarnation) ``core/dag.py`` job DAG.
* The ``max_time`` horizon truncation is an explicit ``censored`` count,
  not a silent drop.
"""

import json
import math
import os

import pytest

from repro.core import TABLE_III, netmodel, simulate
from repro.core.cluster import Cluster, JobSpec
from repro.core.dag import TaskKind, TaskRef, build_job_dag, validate_schedule
from repro.core.engine import EventEngine
from repro.core.placement import PlacementPolicy
from repro.core.schedpolicy import (
    ElasticPolicy,
    PreemptiveSrsfPolicy,
    StaticGangPolicy,
    comm_policy_from_name,
    sched_policy_from_name,
)
from repro.core.topology import two_tier
from repro.scenarios import get_scenario, run_scenario_event

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from gen_engine_baseline import CELLS, TRACE_CELLS, finish_digest, trace_digest
# Shared memoized regression-cell sims: the ordering tests in
# test_scenarios and the bit-exact locks below run the SAME fixed-seed
# cells, so a serial run simulates each exactly once.  If the shared
# REGRESSION_CELLS sizing ever drifts from the frozen capture-time CELLS
# table, the digests below fail loudly instead of re-anchoring silently.
from test_scenarios import REGRESSION_CELLS, sim as cached_sim

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "data", "engine_regression_baseline.json"
)
with open(BASELINE_PATH) as _f:
    BASELINE = json.load(_f)["cells"]

#: Tier-1 locks every regression scenario under Ada-SRSF (every engine
#: feature: WFBP, topology, hetero bandwidth, rack placement, ...) plus
#: the cheap cells under SRSF(1) — the comm policy is orthogonal to the
#: engine refactor, so three cells pin that axis.  The full ada+srsf1
#: grid (captured in the baseline JSON) stays verifiable via
#: ``pytest -m slow`` without charging tier-1 ~9 s for redundant cells.
_SRSF1_TIER1 = {"smoke", "contended_residue", "adversarial_allbig"}
SCALAR_CELLS = [
    k
    if k.endswith("/ada") or k.split("/")[0] in _SRSF1_TIER1
    else pytest.param(k, marks=pytest.mark.slow)
    for k in sorted(k for k in BASELINE if not k.endswith("/trace"))
]
#: Full-trace digests: smoke (barriers), contended_residue (persistent
#: collisions), fusion_sweep (WFBP buckets).  The adversarial_allbig
#: trace is the same code paths at 10x the records — slow-marked.
TRACE_TIER1 = ("smoke", "contended_residue", "fusion_sweep")
TRACE_PARAMS = [
    t if t in TRACE_TIER1 else pytest.param(t, marks=pytest.mark.slow)
    for t in TRACE_CELLS
]


# ---------------------------------------------------------------------------
# Bit-exactness of the static path
# ---------------------------------------------------------------------------


class TestStaticBitExact:
    """StaticGangPolicy == the pre-refactor monolith, bit for bit."""

    @pytest.mark.parametrize("cell", SCALAR_CELLS)
    def test_scalar_cell(self, cell):
        name, comm = cell.split("/")
        if CELLS[name] == REGRESSION_CELLS.get(name):
            # the frozen capture table matches the live regression cell:
            # reuse the sim test_scenarios' ordering locks already ran
            res = cached_sim(name, comm=comm)
        else:
            # capture-time sizing differs (see the CELLS note in
            # gen_engine_baseline.py): run the captured workload directly
            seed, overrides = CELLS[name]
            res = run_scenario_event(
                get_scenario(name, seed=seed, **overrides), comm=comm
            )
        ref = BASELINE[cell]
        assert repr(res.avg_jct()) == ref["avg_jct"]
        assert repr(res.makespan) == ref["makespan"]
        assert res.events_processed == ref["events"]
        assert res.comm_started_contended == ref["comm_contended"]
        assert res.comm_started_clean == ref["comm_clean"]
        assert len(res.jct) == ref["n_finished"]
        assert finish_digest(res) == ref["finish_sha256"]
        assert res.censored == 0
        assert res.preemptions == 0 and res.resizes == 0
        assert res.sched_name == "static"

    @pytest.mark.parametrize("name", TRACE_PARAMS)
    def test_full_trace(self, name):
        seed, overrides = CELLS[name]
        scn = get_scenario(name, seed=seed, **overrides)
        res = run_scenario_event(scn, comm="ada", record_trace=True, fuse_fb=False)
        ref = BASELINE[f"{name}/ada/trace"]
        assert len(res.task_trace) == ref["n_records"]
        assert trace_digest(res) == ref["trace_sha256"]


# ---------------------------------------------------------------------------
# Scripted policies (test instrumentation)
# ---------------------------------------------------------------------------


class ScriptedResizePolicy(StaticGangPolicy):
    """Static admission plus a scripted sequence of resize requests for
    one job, issued one per quantum tick."""

    def __init__(self, job_id, sizes, quantum=0.4):
        self.job_id = job_id
        self.sizes = list(sizes)
        self.quantum = quantum

    def on_quantum(self, now):
        self._place_queue(now)
        if self.sizes and self.job_id in self.engine.runs:
            self.engine.request_resize(self.job_id, self.sizes.pop(0))


class ScriptedPreemptPolicy(StaticGangPolicy):
    """Static admission plus a scripted sequence of preemption victims,
    one per quantum tick.  Victims not currently running are skipped, as
    are jobs placed at this very tick — preempting a same-tick placement
    is a place/kill no-op no real policy performs (PreemptiveSrsfPolicy's
    ``min_run > 0`` guard forbids it), and the engine correctly treats
    the resulting do-nothing tick as a scheduling fixed point."""

    def __init__(self, victims, quantum=0.08):
        self.victims = list(victims)
        self.quantum = quantum

    def on_quantum(self, now):
        self._place_queue(now)
        remaining, acted = [], False
        for vid in self.victims:
            run = self.engine.runs.get(vid)
            if run is not None and run.finished_at is not None:
                continue  # finished: can never be preempted, drop it
            if (
                not acted
                and run is not None
                and run.finished_at is None
                and run.placed_at < now
            ):
                self.engine.preempt_job(vid, now)
                acted = True
                continue
            remaining.append(vid)  # queued or same-tick: retry next tick
        self.victims = remaining


def make_engine(jobs, sched, n_servers=2, gpus_per_server=2, comm="ada", **kw):
    return EventEngine(
        jobs,
        cluster=Cluster(
            n_servers=n_servers,
            gpus_per_server=gpus_per_server,
            gpu_mem_mb=kw.pop("gpu_mem_mb", 16160.0),
        ),
        placement=PlacementPolicy("lwf", kappa=1),
        comm_policy=comm_policy_from_name(comm),
        sched=sched,
        **kw,
    )


# ---------------------------------------------------------------------------
# Trace helpers: per-incarnation DAG validation
# ---------------------------------------------------------------------------


def split_segments(records, markers):
    """Partition one job's surviving task records into per-incarnation
    segments at the preempt/resize marker times."""
    times = sorted(t for (t, _it) in markers)
    segs = [[] for _ in range(len(times) + 1)]
    for rec in records:
        t0 = rec[4]
        idx = sum(1 for t in times if t0 >= t)
        segs[idx].append(rec)
    return segs


def validate_preempted_job_trace(spec, records, markers, n_workers=None):
    """Every incarnation's records must be a valid linear extension of a
    re-instantiated job DAG over exactly the iterations that incarnation
    executed; together the incarnations cover 0..iterations-1 exactly
    once (completed iterations are never lost or repeated)."""
    n_workers = n_workers if n_workers is not None else spec.n_gpus
    segs = [s for s in split_segments(records, markers) if s]
    covered = []
    for seg in segs:
        iters = sorted({r[1] for r in seg})
        assert iters == list(range(iters[0], iters[-1] + 1)), (
            f"job {spec.job_id}: incarnation covers non-contiguous "
            f"iterations {iters}"
        )
        covered.extend(iters)
        it0 = iters[0]
        has_comm = any(r[2].startswith("c") for r in seg)
        dag = build_job_dag(
            spec.job_id, n_workers, len(iters), has_comm
        )
        intervals = {}
        for (jid, it, kind, w, t0, t1) in seg:
            ref = TaskRef(
                jid, it - it0, TaskKind(kind), w if kind != "c" else -1
            )
            assert ref not in intervals, f"duplicate task {ref}"
            intervals[ref] = (t0, t1)
        ok, msg = validate_schedule(dag, intervals)
        assert ok, f"job {spec.job_id} incarnation at iter {it0}: {msg}"
    assert covered == list(range(spec.iterations)), (
        f"job {spec.job_id}: iterations covered {covered} != "
        f"0..{spec.iterations - 1}"
    )


def job_records(trace, jid):
    recs = [r for r in trace if r[0] == jid and r[2] not in ("preempt", "resize")]
    markers = [(r[4], r[1]) for r in trace if r[0] == jid and r[2] == "preempt"]
    return recs, markers


# ---------------------------------------------------------------------------
# Preemption mechanics
# ---------------------------------------------------------------------------


class TestPreemptionMechanics:
    def _jobs(self):
        # job 0 spans both servers (comm path crosses the preemption);
        # job 1 is the single-GPU bystander that keeps running throughout
        return [
            JobSpec(0, 0.0, 4, 12, TABLE_III["resnet50"]),
            JobSpec(1, 0.0, 1, 30, TABLE_III["lstm_ptb"]),
        ]

    def _run(self, victims):
        eng = make_engine(
            self._jobs(),
            ScriptedPreemptPolicy(victims, quantum=0.11),
            n_servers=2,
            gpus_per_server=4,
            record_trace=True,
            fuse_fb=False,
            checkpoint_cost=0.05,
        )
        res = eng.run()
        return eng, res

    def test_preempted_job_finishes_with_all_iterations(self):
        eng, res = self._run([0, 0])
        assert len(res.jct) == 2 and res.censored == 0
        assert res.preemptions == 2
        recs, markers = job_records(res.task_trace, 0)
        assert len(markers) == 2
        validate_preempted_job_trace(self._jobs()[0], recs, markers)
        # the untouched bystander is still one clean incarnation
        recs1, markers1 = job_records(res.task_trace, 1)
        assert markers1 == []
        validate_preempted_job_trace(self._jobs()[1], recs1, markers1)

    def test_gang_teardown_is_atomic(self):
        """No surviving task interval of the victim straddles a
        preemption instant — the whole gang stops together."""
        _, res = self._run([0])
        recs, markers = job_records(res.task_trace, 0)
        (t_pre, _), = markers
        for (_, _, _, _, t0, t1) in recs:
            assert t1 <= t_pre + 1e-9 or t0 >= t_pre - 1e-9, (
                f"interval [{t0}, {t1}] straddles preemption at {t_pre}"
            )

    def test_restore_penalty_delays_resume(self):
        """The preempted job's JCT grows by at least the checkpoint cost
        (work re-done for the aborted iteration comes on top)."""
        base = make_engine(
            self._jobs(), StaticGangPolicy(), n_servers=2, gpus_per_server=4
        ).run()
        _, res = self._run([0])
        assert res.jct[0] > base.jct[0] + 0.05 - 1e-9

    def test_preemption_cost_model(self):
        c = netmodel.preemption_cost(1.2e9)
        assert c == pytest.approx(
            netmodel.CHECKPOINT_FIXED_S
            + 1.2e9 / netmodel.CHECKPOINT_SAVE_BPS
            + 1.2e9 / netmodel.CHECKPOINT_RESTORE_BPS
        )
        assert netmodel.preemption_cost(0.0) == netmodel.CHECKPOINT_FIXED_S
        with pytest.raises(ValueError):
            netmodel.preemption_cost(-1.0)
        with pytest.raises(ValueError):
            netmodel.preemption_cost(1.0, save_bps=0.0)

    def test_preempting_finished_job_raises(self):
        eng = make_engine(
            [JobSpec(0, 0.0, 1, 2, TABLE_III["resnet50"])], StaticGangPolicy()
        )
        eng.run()
        with pytest.raises((ValueError, KeyError)):
            eng.preempt_job(0, 99.0)


# ---------------------------------------------------------------------------
# Preemption invariants (Hypothesis)
# ---------------------------------------------------------------------------

MODELS = ("resnet50", "inception_v3")


class TestPreemptionInvariants:
    @given(
        jobs=st.lists(
            st.tuples(
                st.integers(min_value=1, max_value=4),  # n_gpus
                st.integers(min_value=2, max_value=5),  # iterations
                st.sampled_from(MODELS),
                st.integers(min_value=0, max_value=2),  # arrival second
            ),
            min_size=1,
            max_size=3,
        ),
        victims=st.lists(st.integers(min_value=0, max_value=2), max_size=5),
        quantum=st.floats(min_value=0.03, max_value=0.3),
    )
    @settings(max_examples=25, deadline=None)
    def test_chaotic_preemption_trace_stays_valid(self, jobs, victims, quantum):
        specs = [
            JobSpec(i, float(arr), n, iters, TABLE_III[m])
            for i, (n, iters, m, arr) in enumerate(jobs)
        ]
        eng = make_engine(
            specs,
            ScriptedPreemptPolicy(victims, quantum=quantum),
            n_servers=2,
            gpus_per_server=2,
            record_trace=True,
            fuse_fb=False,
            checkpoint_cost=0.02,
        )
        res = eng.run()
        # completed iterations are never lost: every job still finishes
        # all its work despite arbitrary mid-iteration gang teardowns
        assert len(res.jct) == len(specs)
        assert res.censored == 0
        for spec in specs:
            recs, markers = job_records(res.task_trace, spec.job_id)
            # atomic gangs: nothing straddles a preemption instant
            for (t_pre, _) in markers:
                for (_, _, _, _, t0, t1) in recs:
                    assert t1 <= t_pre + 1e-9 or t0 >= t_pre - 1e-9
            # each incarnation is a valid linear extension of the
            # re-instantiated DAG, and iterations 0..I-1 are covered once
            validate_preempted_job_trace(spec, recs, markers)


# ---------------------------------------------------------------------------
# Preemptive SRSF regression (acceptance criterion)
# ---------------------------------------------------------------------------


class TestPreemptionGainRegression:
    """PreemptiveSrsfPolicy < static SRSF on the heavy-tailed fixed seed
    (preemption_gain, seed 2): measured ~3.7x lower avg JCT; locked with
    a conservative 25% floor so noise-free improvements can't regress
    silently."""

    @pytest.fixture(scope="class")
    def scn(self):
        return get_scenario("preemption_gain", seed=2)

    @pytest.mark.parametrize("comm", ["ada", "srsf1"])
    def test_preemptive_beats_static(self, scn, comm):
        static = run_scenario_event(scn, comm=comm)
        pre = run_scenario_event(scn, comm=comm, sched="preemptive_srsf")
        assert len(static.jct) == len(pre.jct) == scn.n_jobs
        assert pre.censored == 0
        assert pre.preemptions > 0
        assert pre.sched_name == "preemptive_srsf"
        assert pre.avg_jct() < static.avg_jct() * 0.75, (
            f"preemptive {pre.avg_jct():.1f} vs static {static.avg_jct():.1f}"
        )

    def test_preemptive_is_deterministic(self, scn):
        a = run_scenario_event(scn, comm="ada", sched="preemptive_srsf")
        b = run_scenario_event(scn, comm="ada", sched="preemptive_srsf")
        assert a.finish == b.finish and a.preemptions == b.preemptions


# ---------------------------------------------------------------------------
# Elastic scheduling
# ---------------------------------------------------------------------------


class TestElasticSurgeRegression:
    def test_elastic_beats_static_on_surge(self):
        scn = get_scenario("elastic_surge", seed=1)
        static = run_scenario_event(scn, comm="ada")
        el = run_scenario_event(scn, comm="ada", sched="elastic")
        assert len(el.jct) == scn.n_jobs and el.censored == 0
        assert el.resizes > 0
        assert el.avg_jct() < static.avg_jct() * 0.95, (
            f"elastic {el.avg_jct():.1f} vs static {static.avg_jct():.1f}"
        )


class TestElasticResizeRebuild:
    """A boundary resize must rebuild everything placement-derived: the
    WFBP fusion plan (bucket count, per-worker progress vectors) and the
    member-server/topology-domain sets for the NEW world size."""

    FUSION = 32e6

    def _run_scripted(self, sizes):
        from repro.workloads import ZOO_GPU_MEM_MB, zoo_profiles

        model = zoo_profiles()["mamba2_130m"]
        spec = JobSpec(0, 0.0, 4, 40, model, min_gpus=2, max_gpus=8)
        topo = two_tier(2, 1, oversub=2.0)
        eng = make_engine(
            [spec],
            ScriptedResizePolicy(0, sizes, quantum=0.4),
            n_servers=2,
            gpus_per_server=4,
            gpu_mem_mb=ZOO_GPU_MEM_MB,
            fusion=self.FUSION,
            topology=topo,
            checkpoint_cost=0.01,
        )
        snapshots = []
        orig = eng.place_job

        def recording_place(jid, gpu_ids, now):
            run = orig(jid, gpu_ids, now)
            snapshots.append(
                dict(
                    now=now,
                    n_world=run.n_world,
                    servers=frozenset(run.servers),
                    n_buckets=None if run.plan is None else len(run.plan[0]),
                    b_prog_len=len(run.b_prog),
                    target=run.target_iters,
                    iter_done=run.iter_done,
                    samples_done=run.samples_done,
                    domains=eng._domains_of(run.servers),
                )
            )
            return run

        eng.place_job = recording_place
        res = eng.run()
        return spec, topo, snapshots, res

    @pytest.fixture(scope="class")
    def scripted(self):
        """One scripted 4 -> 8 -> 2 resize run shared by the assertions
        below (the run is deterministic)."""
        return self._run_scripted([8, 2])

    def test_resize_rebuilds_buckets_and_domains(self, scripted):
        spec, topo, snaps, res = scripted
        assert res.resizes == 2
        assert len(res.jct) == 1 and res.censored == 0
        assert [s["n_world"] for s in snaps] == [4, 8, 2]

        expected_buckets = len(
            netmodel.fusion_plan(
                spec.model.layer_grad_bytes, spec.model.layer_t_b, self.FUSION
            )[0]
        )
        # 4 GPUs consolidate on one server: no comm, no fusion plan
        assert len(snaps[0]["servers"]) == 1
        assert snaps[0]["n_buckets"] is None
        assert snaps[0]["domains"] == frozenset()
        # grown to 8: spans both servers -> WFBP plan rebuilt at the new
        # world size, domain set now crosses the fabric cuts
        assert len(snaps[1]["servers"]) == 2
        assert snaps[1]["n_buckets"] == expected_buckets
        assert snaps[1]["b_prog_len"] == 8
        assert snaps[1]["domains"] == topo.loaded_domains(snaps[1]["servers"])
        assert len(snaps[1]["domains"]) > 0
        # shrunk to 2: back inside one server -> monolithic again
        assert len(snaps[2]["servers"]) == 1
        assert snaps[2]["n_buckets"] is None
        assert snaps[2]["domains"] == frozenset()

    def test_resize_conserves_samples_and_recomputes_target(self, scripted):
        spec, _, snaps, res = scripted
        total = spec.total_samples
        for s in snaps:
            # total work is conserved across incarnations ...
            rem = total - s["samples_done"]
            assert 0 < rem <= total
            # ... and the iteration target is recomputed for the placed
            # world size: target = iters already done + ceil(rem / world)
            assert s["target"] == s["iter_done"] + -(-rem // s["n_world"])
        # progress is monotone across incarnations (nothing lost)
        done = [s["samples_done"] for s in snaps]
        assert done == sorted(done) and done[0] == 0 and done[-1] > 0
        assert len(res.jct) == 1 and res.censored == 0

    def test_elastic_bounds_validation(self):
        with pytest.raises(ValueError, match="elastic bounds"):
            JobSpec(0, 0.0, 4, 10, TABLE_III["resnet50"], min_gpus=5)
        with pytest.raises(ValueError, match="elastic bounds"):
            JobSpec(0, 0.0, 4, 10, TABLE_III["resnet50"], max_gpus=2)
        spec = JobSpec(0, 0.0, 4, 10, TABLE_III["resnet50"], min_gpus=2, max_gpus=8)
        assert spec.is_elastic and spec.total_samples == 40
        assert not JobSpec(1, 0.0, 4, 10, TABLE_III["resnet50"]).is_elastic

    def test_request_resize_clamps_to_bounds(self):
        spec = JobSpec(0, 0.0, 4, 50, TABLE_III["resnet50"], min_gpus=2, max_gpus=8)
        eng = make_engine([spec], StaticGangPolicy(), n_servers=2, gpus_per_server=4)
        eng.queue.append(0)
        eng.sched._place_queue(0.0)
        eng.request_resize(0, 64)
        assert eng.runs[0].pending_resize == 8
        eng.request_resize(0, 1)
        assert eng.runs[0].pending_resize == 2
        eng.request_resize(0, 4)  # == current world: request cleared
        assert eng.runs[0].pending_resize is None


# ---------------------------------------------------------------------------
# Horizon censoring (explicit, not silent)
# ---------------------------------------------------------------------------


class TestCensoredHorizon:
    def test_max_time_reports_censored_jobs(self):
        jobs = [
            JobSpec(0, 0.0, 1, 10, TABLE_III["resnet50"]),     # finishes early
            JobSpec(1, 0.0, 1, 100000, TABLE_III["resnet50"]),  # runs past cut
            JobSpec(2, 50.0, 1, 10, TABLE_III["resnet50"]),    # arrives after cut
        ]
        res = simulate(jobs, n_servers=1, gpus_per_server=2, max_time=5.0)
        assert set(res.jct) == {0}
        assert res.censored == 2

    def test_full_drain_has_zero_censored(self):
        jobs = [JobSpec(0, 0.0, 1, 10, TABLE_III["resnet50"])]
        res = simulate(jobs, n_servers=1, gpus_per_server=1)
        assert res.censored == 0

    def test_censored_reaches_metrics_row(self):
        from repro.scenarios.metrics import CSV_FIELDS, from_event_result

        jobs = [
            JobSpec(0, 0.0, 1, 10, TABLE_III["resnet50"]),
            JobSpec(1, 0.0, 1, 100000, TABLE_III["resnet50"]),
        ]
        res = simulate(jobs, n_servers=1, gpus_per_server=2, max_time=5.0)
        m = from_event_result(res, scenario="x", seed=0, n_jobs=2)
        assert m.censored == 1
        assert "censored" in CSV_FIELDS and "preemptions" in CSV_FIELDS
        assert len(m.as_csv_row().split(",")) == len(CSV_FIELDS)


# ---------------------------------------------------------------------------
# Policy construction
# ---------------------------------------------------------------------------


class TestPolicyFactory:
    def test_names(self):
        assert isinstance(sched_policy_from_name("static"), StaticGangPolicy)
        p = sched_policy_from_name("preemptive_srsf", quantum=7.0)
        assert isinstance(p, PreemptiveSrsfPolicy) and p.quantum == 7.0
        assert isinstance(sched_policy_from_name("elastic"), ElasticPolicy)
        with pytest.raises(ValueError, match="unknown scheduling policy"):
            sched_policy_from_name("fifo")

    def test_preemptive_validation(self):
        with pytest.raises(ValueError):
            PreemptiveSrsfPolicy(quantum=0.0)
        with pytest.raises(ValueError):
            PreemptiveSrsfPolicy(margin=0.5)

    def test_static_never_ticks(self):
        assert StaticGangPolicy.quantum is None
