"""Shared fixtures.  NOTE: no xla_force_host_platform_device_count here —
smoke tests and benches must see the real single CPU device; only the
dry-run (a separate process) forces 512 devices.

The jax *persistent compilation cache* is enabled for the test session
(opt out with ``REPRO_NO_JAX_CACHE=1``): the suite's wall time is
dominated by XLA compiles (the first fluid-simulator graph, the MoE train
step, ...), and caching them makes every warm local rerun ~35% faster
while cold runs (CI) are unaffected.  Correctness is keyed on the HLO
hash, so stale entries cannot leak across code changes."""

import os

import jax
import pytest

if not os.environ.get("REPRO_NO_JAX_CACHE"):
    _cache_dir = os.environ.get(
        "REPRO_JAX_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_jax_compile"),
    )
    try:
        jax.config.update("jax_compilation_cache_dir", _cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    except Exception:  # older jax without the knobs: cold-compile as before
        pass


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
