"""Shared fixtures.  NOTE: no xla_force_host_platform_device_count here —
smoke tests and benches must see the real single CPU device; only the
dry-run (a separate process) forces 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
