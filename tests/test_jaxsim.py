"""Tests for the vectorized (fluid) JAX simulator — beyond-paper ext. #3.

It is an approximation of the exact event-driven simulator (gang placement,
fixed dt, one admission per step), so tests assert *qualitative* agreement:
completeness, determinism, and the policy orderings the paper establishes.
"""

import numpy as np
import pytest

from repro.core.jaxsim import JaxSimConfig, monte_carlo_jct


@pytest.mark.slow
class TestJaxSim:
    def test_completes_and_deterministic(self):
        r1 = monte_carlo_jct(n_seeds=2, n_jobs=16, policy="ada", dt=0.1)
        r2 = monte_carlo_jct(n_seeds=2, n_jobs=16, policy="ada", dt=0.1)
        # the fluid approximation can strand a minority of jobs on some
        # sampled traces (admission/gating quantization) — documented
        # approximation; the exact simulator is the reference.
        assert r1["finished_frac"] > 0.6
        np.testing.assert_allclose(r1["per_seed"], r2["per_seed"])

    def test_policy_ordering_matches_paper(self):
        """AdaDUAL gating should beat blind 2-way acceptance on average."""
        ada = monte_carlo_jct(n_seeds=3, n_jobs=24, policy="ada", dt=0.1)
        srsf2 = monte_carlo_jct(n_seeds=3, n_jobs=24, policy="srsf2", dt=0.1)
        assert ada["avg_jct_mean"] < srsf2["avg_jct_mean"] * 1.05

    def test_monte_carlo_gives_spread(self):
        r = monte_carlo_jct(n_seeds=4, n_jobs=16, policy="srsf1", dt=0.1)
        assert r["avg_jct_std"] >= 0.0
        assert len(r["per_seed"]) == 4
