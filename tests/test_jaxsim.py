"""Tests for the vectorized (fluid) JAX simulator — beyond-paper ext. #3.

It is an approximation of the exact event-driven simulator (gang placement,
fixed dt, one admission per step), so the Monte-Carlo tests assert
*qualitative* agreement: completeness, determinism, and the policy
orderings the paper establishes.  The batched-entry tests are exact:
vmapped lanes must reproduce the single-trace simulation bit-for-bit.
"""

import numpy as np
import pytest

from repro.core.cluster import TABLE_III
from repro.core.jaxsim import (
    JaxSimConfig,
    monte_carlo_jct,
    simulate_trace,
    simulate_traces_batched,
    stack_traces,
    trace_from_jobs,
)
from repro.scenarios import get_scenario

CFG = JaxSimConfig(n_servers=4, gpus_per_server=2, dt=0.02)


class TestTraceFromJobs:
    def test_round_trips_scenario_jobs(self):
        jobs = get_scenario("smoke").job_list()
        tr = trace_from_jobs(jobs)
        assert set(tr) == {"arrival", "iters", "t_iter", "msg_bytes", "n_gpus"}
        for key in tr:
            assert tr[key].shape == (len(jobs),), key
        assert tr["n_gpus"].dtype == np.int32
        for key in ("arrival", "iters", "t_iter", "msg_bytes"):
            assert tr[key].dtype == np.float32, key
        for i, j in enumerate(jobs):
            assert float(tr["arrival"][i]) == j.arrival
            assert int(tr["iters"][i]) == j.iterations
            assert int(tr["n_gpus"][i]) == j.n_gpus
            assert float(tr["t_iter"][i]) == pytest.approx(
                j.model.t_iter_compute, rel=1e-6
            )
            assert float(tr["msg_bytes"][i]) == pytest.approx(
                j.model.size_bytes, rel=1e-6
            )

    def test_empty_job_list(self):
        tr = trace_from_jobs([])
        for key, arr in tr.items():
            assert arr.shape == (0,), key
        assert tr["n_gpus"].dtype == np.int32
        assert tr["arrival"].dtype == np.float32


class TestStackTraces:
    def test_rectangular_batch_with_valid_mask(self):
        jobs = get_scenario("smoke").job_list()
        t_full = trace_from_jobs(jobs)
        t_short = trace_from_jobs(jobs[:4])
        batch = stack_traces([t_full, t_short])
        n = len(jobs)
        for key in ("arrival", "iters", "t_iter", "msg_bytes", "n_gpus", "valid"):
            assert batch[key].shape == (2, n), key
        assert bool(batch["valid"].all(axis=1)[0])
        np.testing.assert_array_equal(
            np.asarray(batch["valid"][1]), [True] * 4 + [False] * 2
        )

    def test_empty_batch_raises(self):
        with pytest.raises(ValueError, match="at least one trace"):
            stack_traces([])

    def test_batched_lanes_match_single_runs(self):
        """The padded vmap batch must reproduce each single-trace run
        exactly — including the ragged lane (padded jobs inert and
        excluded from `finished`) and the per-lane makespan (the loop
        clock keeps ticking for early-converged lanes; makespan must not)."""
        jobs = get_scenario("smoke").job_list()
        t_full = trace_from_jobs(jobs)
        t_short = trace_from_jobs(jobs[:4])
        out_b = simulate_traces_batched(stack_traces([t_full, t_short]), CFG)
        out_full = simulate_trace(t_full, CFG)
        out_short = simulate_trace(t_short, CFG)
        np.testing.assert_array_equal(
            np.asarray(out_b["jct"])[0], np.asarray(out_full["jct"])
        )
        np.testing.assert_array_equal(
            np.asarray(out_b["jct"])[1][:4], np.asarray(out_short["jct"])
        )
        assert not np.asarray(out_b["finished"])[1][4:].any()
        np.testing.assert_allclose(
            np.asarray(out_b["makespan"]),
            [float(out_full["makespan"]), float(out_short["makespan"])],
        )


@pytest.mark.slow
class TestJaxSim:
    def test_completes_and_deterministic(self):
        r1 = monte_carlo_jct(n_seeds=2, n_jobs=16, policy="ada", dt=0.1)
        r2 = monte_carlo_jct(n_seeds=2, n_jobs=16, policy="ada", dt=0.1)
        # the fluid approximation can strand a minority of jobs on some
        # sampled traces (admission/gating quantization) — documented
        # approximation; the exact simulator is the reference.
        assert r1["finished_frac"] > 0.6
        np.testing.assert_allclose(r1["per_seed"], r2["per_seed"])

    def test_policy_ordering_matches_paper(self):
        """AdaDUAL gating should beat blind 2-way acceptance on average."""
        ada = monte_carlo_jct(n_seeds=3, n_jobs=24, policy="ada", dt=0.1)
        srsf2 = monte_carlo_jct(n_seeds=3, n_jobs=24, policy="srsf2", dt=0.1)
        assert ada["avg_jct_mean"] < srsf2["avg_jct_mean"] * 1.05

    def test_monte_carlo_gives_spread(self):
        r = monte_carlo_jct(n_seeds=4, n_jobs=16, policy="srsf1", dt=0.1)
        assert r["avg_jct_std"] >= 0.0
        assert len(r["per_seed"]) == 4
