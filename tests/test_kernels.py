"""Pallas kernel validation (interpret mode) against pure-jnp oracles —
shape/dtype sweeps per the kernel-testing contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.ssd.ops import ssd_decode_step
from repro.kernels.ssd.ref import ssd_decode_step_reference
from repro.models.ssm import ssd_scan

KEY = jax.random.PRNGKey(42)


def tol_for(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-5


class TestFlashAttention:
    # Representative cases run by default; the full sweep is `-m slow`
    # (every case recompiles an interpret-mode Pallas kernel, ~1-2 s each).
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "bh,s,t,d,causal",
        [
            (4, 256, 256, 64, True),
            (3, 200, 200, 64, True),     # non-divisible by block
            pytest.param(2, 128, 384, 128, False, marks=pytest.mark.slow),
            pytest.param(1, 64, 512, 256, False, marks=pytest.mark.slow),
            pytest.param(2, 512, 512, 64, True, marks=pytest.mark.slow),
        ],
    )
    def test_matches_reference(self, bh, s, t, d, causal, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (bh, s, d), dtype)
        k = jax.random.normal(ks[1], (bh, t, d), dtype)
        v = jax.random.normal(ks[2], (bh, t, d), dtype)
        out = flash_attention(q, k, v, causal=causal)
        ref = attention_reference(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref, np.float32),
            atol=tol_for(dtype),
            rtol=tol_for(dtype),
        )

    @pytest.mark.parametrize(
        "block_q,block_k",
        [
            (64, 64),
            pytest.param(128, 256, marks=pytest.mark.slow),
            pytest.param(256, 128, marks=pytest.mark.slow),
        ],
    )
    def test_block_shape_invariance(self, block_q, block_k):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=block_q, block_k=block_k)
        ref = attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_scale_override(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, scale=0.05)
        ref = attention_reference(q, k, v, causal=False, scale=0.05)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_matches_model_attention_semantics(self):
        """The kernel and models/attention.py agree (same masking/softmax)."""
        from repro.models.attention import attention_forward
        from repro.models.config import ModelConfig

        cfg = ModelConfig(
            name="t", family="dense", n_layers=1, d_model=64, vocab_size=16,
            n_heads=2, n_kv_heads=2, d_ff=64,
        )
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (2, 128, 64), jnp.float32)
        params = {
            "wq": jax.random.normal(ks[1], (64, 2, 32)) * 0.1,
            "wk": jax.random.normal(ks[2], (64, 2, 32)) * 0.1,
            "wv": jax.random.normal(ks[3], (64, 2, 32)) * 0.1,
            "wo": jnp.eye(64).reshape(2, 32, 64),
        }
        model_out = attention_forward(x, params, cfg, mask_kind="causal", use_rope=False, q_chunk=32)
        q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"]).reshape(4, 128, 32)
        k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"]).reshape(4, 128, 32)
        v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"]).reshape(4, 128, 32)
        kern = flash_attention(q, k, v, causal=True).reshape(2, 2, 128, 32)
        kern_out = jnp.einsum("bhsk,hkd->bsd", kern, params["wo"])
        np.testing.assert_allclose(
            np.asarray(model_out), np.asarray(kern_out), atol=1e-4, rtol=1e-4
        )


class TestSsdDecode:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,h,p,n,block_h",
        [
            (2, 8, 64, 128, 8),
            (2, 6, 16, 32, 8),
            pytest.param(3, 12, 32, 64, 4, marks=pytest.mark.slow),
            pytest.param(1, 24, 64, 128, 8, marks=pytest.mark.slow),
        ],
    )
    def test_matches_reference(self, b, h, p, n, block_h, dtype):
        ks = jax.random.split(KEY, 6)
        x = jax.random.normal(ks[0], (b, h, p), dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h))).astype(dtype)
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.1)
        bb = jax.random.normal(ks[3], (b, n), dtype)
        cc = jax.random.normal(ks[4], (b, n), dtype)
        dd = jnp.ones((h,), jnp.float32)
        st = jax.random.normal(ks[5], (b, h, p, n), jnp.float32)
        y1, s1 = ssd_decode_step(x, dt, a, bb, cc, dd, st, block_h=block_h)
        y2, s2 = ssd_decode_step_reference(x, dt, a, bb, cc, dd, st)
        np.testing.assert_allclose(
            np.asarray(y1, np.float32), np.asarray(y2, np.float32),
            atol=tol_for(dtype) * 3, rtol=tol_for(dtype) * 3,
        )
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


class TestSsdScanInternalConsistency:
    """The chunked SSD scan must equal its own step-by-step recurrence —
    ties the train path to the decode path (and hence to the kernel)."""

    @pytest.mark.parametrize(
        "chunk", [4, pytest.param(8, marks=pytest.mark.slow), 16]
    )
    def test_scan_equals_stepwise(self, chunk):
        b, s, h, p, n = 2, 32, 4, 8, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.1)
        bb = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
        cc = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.5
        y_scan, final = ssd_scan(x, dt, a, bb, cc, chunk=chunk)

        from repro.models.ssm import ssd_step

        state = jnp.zeros((b, h, p, n), jnp.float32)
        ys = []
        for i in range(s):
            y, state = ssd_step(x[:, i], dt[:, i], a, bb[:, i], cc[:, i], state)
            ys.append(y)
        y_step = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step), atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(final), np.asarray(state), atol=1e-4, rtol=1e-3)

    def test_chunk_invariance(self):
        b, s, h, p, n = 1, 64, 2, 8, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.1)
        bb = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
        cc = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.5
        y8, f8 = ssd_scan(x, dt, a, bb, cc, chunk=8)
        y32, f32_ = ssd_scan(x, dt, a, bb, cc, chunk=32)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(f8), np.asarray(f32_), atol=1e-4, rtol=1e-3)
