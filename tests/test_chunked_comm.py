"""Tests for the beyond-paper chunked/preemptible communication extension."""

import pytest

from repro.core import TABLE_III, ContentionParams, JobSpec, simulate

P = ContentionParams()


def mk(jid, arrival, n_gpus, iters, model):
    return JobSpec(jid, arrival, n_gpus, iters, TABLE_III[model])


class TestChunkedComm:
    def test_single_job_exact_latency_cost(self):
        """N chunks cost exactly (N-1) extra latencies per iteration."""
        jobs = [mk(0, 0.0, 8, 40, "resnet50")]
        m = TABLE_III["resnet50"]
        for n in (1, 2, 8):
            res = simulate(jobs, comm_chunks=n)
            expect = (m.t_iter_compute + n * P.a + P.b * m.size_bytes) * 40
            assert res.jct[0] == pytest.approx(expect, rel=1e-6)

    def test_all_jobs_finish_with_chunking(self):
        from repro.core import paper_trace

        jobs = paper_trace(seed=11, n_jobs=30, min_iters=50, max_iters=200)
        for comm in ("srsf1", "ada"):
            res = simulate(jobs, comm=comm, comm_chunks=4)
            assert len(res.jct) == 30

    def test_chunking_lets_short_messages_preempt(self):
        """Under SRSF(1) (exclusive links), a small-message job queued behind
        a huge in-flight vgg transfer gets through sooner when the vgg
        all-reduce is chunked."""
        # 2 servers x 4 GPUs: both 8-GPU jobs span both servers and share
        # the same links (time-shared GPUs; memory admits both).
        jobs = [
            mk(0, 0.0, 8, 300, "vgg16"),     # 526 MB messages, hogs the link
            mk(1, 0.5, 8, 300, "resnet50"),  # 99 MB messages
        ]
        base = simulate(jobs, comm="srsf1", comm_chunks=1,
                        n_servers=2, gpus_per_server=4)
        chunked = simulate(jobs, comm="srsf1", comm_chunks=8,
                           n_servers=2, gpus_per_server=4)
        assert base.comm_started_clean > 0  # comm actually happens
        # the small job's JCT must improve; the big job pays bounded latency
        assert chunked.jct[1] < base.jct[1]
        assert chunked.jct[0] < base.jct[0] * 1.25


class TestContentionDomain:
    def test_single_job_domain_invariant(self):
        jobs = [mk(0, 0.0, 8, 50, "resnet50")]
        a = simulate(jobs, comm="srsf1", contention_domain="server")
        b = simulate(jobs, comm="srsf1", contention_domain="link")
        assert a.jct[0] == pytest.approx(b.jct[0])

    def test_link_domain_allows_disjoint_link_overlap(self):
        """Jobs on servers {0,1} and {1,2}: same server 1, but disjoint ring
        links (0,1) vs (1,2) — SRSF(1) serializes them under the server
        domain and overlaps them under the link domain."""
        from repro.core.simulator import ClusterSimulator, SrsfN
        from repro.core.cluster import Cluster
        from repro.core.placement import PlacementPolicy

        class Pin(PlacementPolicy):
            def __init__(self, mapping):
                super().__init__("ff")
                self.mapping = mapping

            def __call__(self, cluster, job):
                return self.mapping[job.job_id]

        jobs = [mk(0, 0.0, 4, 200, "vgg16"), mk(1, 0.0, 4, 200, "vgg16")]
        mapping = {
            0: [(0, 0), (0, 1), (1, 0), (1, 1)],
            1: [(1, 2), (1, 3), (2, 0), (2, 1)],
        }
        results = {}
        for dom in ("server", "link"):
            sim = ClusterSimulator(
                jobs, cluster=Cluster(n_servers=3, gpus_per_server=4),
                placement=Pin(mapping), comm_policy=SrsfN(1),
                contention_domain=dom,
            )
            results[dom] = sim.run()
        assert results["link"].avg_jct() < results["server"].avg_jct()

    def test_invalid_domain_raises(self):
        from repro.core.simulator import ClusterSimulator

        with pytest.raises(ValueError):
            ClusterSimulator([], contention_domain="nope")
