"""Observability-layer tests (``repro.obs``).

* **Off-path lock**: ``observe=None`` (and an all-channels-off
  ``ObsConfig``) reproduces the sha-locked engine regression baseline —
  the observability merge cannot have perturbed the unobserved engine.
* **Non-perturbation**: an observed run is bit-exact with the unobserved
  run (same finish times, same event count) on every grid cell — the
  recorder only *watches*.
* **Decomposition closure** (acceptance criterion): per job,
  ``queue_wait + compute + comm_serial + comm_stretch + gating_wait +
  overhead_pf == jct`` within 1e-6, parts non-negative, across the
  comm x fusion x sched x chaos grid.
* **Conservation**: the chaos cell's ``work_lost_samples`` equals the
  recorder's fault-overhead sample total.
* **Audit content**: accepts *and* rejects appear with the policy's
  ``explain`` terms (AdaDUAL ratio-vs-threshold, SRSF(n) concurrency,
  k-way lookahead costs) and the recorded terms re-derive the decision.
* **Perfetto export**: the ``paper`` and ``chaos_recovery_storm`` traces
  are loadable Chrome trace-event JSON with well-formed events.
* **Caps**: exceeding ``*_cap`` increments ``*_dropped`` counters and
  never perturbs the simulation.
* **Overhead guard** (slow-marked): full observability costs <3 %
  CPU time on the feature-complete preemptive streaming cell, measured
  with order-alternated paired rounds (the ``bench_obs`` estimator).
"""

import functools
import json
import math
import os
import time

import pytest

from repro.core import TABLE_III, simulate
from repro.core.cluster import JobSpec
from repro.obs import DECOMP_CSV_FIELDS, ObsConfig
from repro.scenarios import QUICK_OVERRIDES, get_scenario, run_scenario_event

from gen_engine_baseline import CELLS, finish_digest

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "data", "engine_regression_baseline.json"
)
with open(BASELINE_PATH) as _f:
    BASELINE = json.load(_f)["cells"]

#: closure/non-perturbation grid: every engine regime the recorder hooks
#: — persistent collisions, WFBP buckets, preemption, elastic resizes,
#: and chaos teardowns — under gating policies with distinct audit terms.
GRID = [
    ("contended_residue", "ada", "static"),
    ("contended_residue", "srsf1", "static"),
    ("contended_residue", "kway3", "static"),
    ("contended_residue", "ada", "preemptive_srsf"),
    ("fusion_sweep", "ada", "static"),
    ("fusion_sweep", "srsf2", "static"),
    ("preemption_gain", "ada", "preemptive_srsf"),
    ("elastic_surge", "ada", "elastic"),
    ("chaos_recovery_storm", "ada", "static"),
    ("chaos_recovery_storm", "srsf2", "preemptive_srsf"),
]


def quick(name, seed=1):
    return get_scenario(name, seed=seed, **QUICK_OVERRIDES[name])


@functools.lru_cache(maxsize=None)
def observed(name, comm, sched):
    """Memoized (unobserved, fully-observed) pair of one grid cell."""
    scn = quick(name)
    off = run_scenario_event(scn, comm=comm, sched=sched)
    on = run_scenario_event(scn, comm=comm, sched=sched, observe=ObsConfig.full())
    return off, on


# ---------------------------------------------------------------------------
# Off-path: observe=None is the pre-obs engine, bit for bit
# ---------------------------------------------------------------------------


class TestOffPathLock:
    """The sha-locked PR-5 regression baseline predates the observability
    merge, so digest equality IS the observe=None bit-exactness lock."""

    @pytest.mark.parametrize("cell", ["paper/ada", "contended_residue/ada"])
    def test_observe_none_matches_pre_obs_baseline(self, cell):
        name, comm = cell.split("/")
        seed, overrides = CELLS[name]
        scn = get_scenario(name, seed=seed, **overrides)
        res = run_scenario_event(scn, comm=comm, observe=None)
        ref = BASELINE[cell]
        assert repr(res.avg_jct()) == ref["avg_jct"]
        assert res.events_processed == ref["events"]
        assert finish_digest(res) == ref["finish_sha256"]
        assert res.obs is None

    def test_inactive_config_is_observe_none(self):
        cfg = ObsConfig(decompose=False)
        assert not cfg.active
        scn = quick("contended_residue")
        res = run_scenario_event(scn, comm="ada", observe=cfg)
        assert res.obs is None  # all channels off: recorder never armed

    def test_full_config_is_active(self):
        assert ObsConfig.full().active
        assert ObsConfig().active  # decompose defaults on


# ---------------------------------------------------------------------------
# Non-perturbation + decomposition closure across the grid
# ---------------------------------------------------------------------------


class TestObservedRunIsBitExact:
    @pytest.mark.parametrize("name,comm,sched", GRID)
    def test_observer_does_not_perturb(self, name, comm, sched):
        off, on = observed(name, comm, sched)
        assert on.finish == off.finish
        assert on.events_processed == off.events_processed
        assert on.preemptions == off.preemptions
        assert on.resizes == off.resizes
        assert on.work_lost_samples == off.work_lost_samples
        assert finish_digest(on) == finish_digest(off)


class TestDecompositionClosure:
    @pytest.mark.parametrize("name,comm,sched", GRID)
    def test_parts_sum_to_jct(self, name, comm, sched):
        _, on = observed(name, comm, sched)
        obs = on.obs
        assert set(obs.decomp) == set(on.jct)  # every finished job decomposed
        for jid, p in obs.decomp.items():
            assert p.jct == pytest.approx(on.jct[jid])
            assert abs(p.parts_sum - p.jct) <= 1e-6, (
                f"{name}/{comm}/{sched} job {jid}: parts sum {p.parts_sum!r} "
                f"!= jct {p.jct!r}"
            )
            for f in DECOMP_CSV_FIELDS[2:8]:
                assert getattr(p, f) >= -1e-9, f"negative {f} on job {jid}"
            assert 0.0 <= p.stretch_frac <= 1.0 + 1e-9
            assert 0.0 <= p.gating_frac <= 1.0 + 1e-9

    def test_contended_cell_attributes_stretch_and_gating(self):
        """The persistent-collision cell must show nonzero gating wait
        under exclusive-link SRSF(1) and nonzero contention stretch under
        blind 2-way SRSF(2) — else the attribution is vacuous."""
        _, on_srsf1 = observed("contended_residue", "srsf1", "static")
        scn = quick("contended_residue")
        on_srsf2 = run_scenario_event(scn, comm="srsf2", observe=ObsConfig())
        assert sum(p.gating_wait for p in on_srsf1.obs.decomp.values()) > 0
        assert sum(p.comm_stretch for p in on_srsf2.obs.decomp.values()) > 0

    def test_csv_round_trip(self):
        _, on = observed("contended_residue", "ada", "static")
        csv = on.obs.decomposition_csv()
        lines = csv.strip().split("\n")
        assert lines[0] == ",".join(DECOMP_CSV_FIELDS)
        assert len(lines) == 1 + len(on.obs.decomp)
        for row in lines[1:]:
            vals = row.split(",")
            assert len(vals) == len(DECOMP_CSV_FIELDS)
            jct, parts = float(vals[1]), [float(v) for v in vals[2:8]]
            assert sum(parts) == pytest.approx(jct, abs=2e-5)  # 6-decimal CSV

    def test_metrics_row_carries_fractions(self):
        from repro.scenarios.metrics import CSV_FIELDS, from_event_result

        _, on = observed("contended_residue", "ada", "static")
        m = from_event_result(on, scenario="x", seed=1, n_jobs=len(on.jct))
        assert "stretch_frac" in CSV_FIELDS and "gating_frac" in CSV_FIELDS
        assert m.stretch_frac == pytest.approx(on.obs.mean_stretch_frac())
        assert len(m.as_csv_row().split(",")) == len(CSV_FIELDS)


# ---------------------------------------------------------------------------
# Chaos conservation + fault overhead
# ---------------------------------------------------------------------------


class TestChaosConservation:
    def test_work_lost_equals_recorder_total(self):
        off, on = observed("chaos_recovery_storm", "ada", "static")
        assert off.work_lost_samples > 0  # the storm actually bites
        assert on.obs.work_lost_total == off.work_lost_samples

    def test_fault_events_and_overhead_recorded(self):
        _, on = observed("chaos_recovery_storm", "ada", "static")
        kinds = {k for (_, k, _) in on.obs.fault_events}
        assert "breakdown" in kinds and "repair" in kinds
        # jobs preempted by the storm carry the overhead in overhead_pf
        hit = [p for p in on.obs.decomp.values() if p.n_preempts > 0]
        assert hit and all(p.overhead_pf > 0 for p in hit)


# ---------------------------------------------------------------------------
# Gating audit log
# ---------------------------------------------------------------------------


class TestGatingAudit:
    def test_ada_terms_rederive_decision(self):
        _, on = observed("contended_residue", "ada", "static")
        audit = on.obs.audit
        assert audit and any(not d.accepted for d in audit)
        assert any(d.accepted for d in audit)
        for d in audit:
            assert d.policy == "Ada-SRSF"
            t = d.terms
            assert t is not None and "ratio" in t and "threshold" in t
            expect = t["cap_ok"] and t["ratio"] < t["threshold"]
            assert d.accepted == expect, f"terms contradict decision: {d}"
            assert d.min_old_bytes == pytest.approx(
                t["min_old_bytes"]
            ) or math.isinf(d.min_old_bytes)
            # -1 = single-waiter incremental evaluation (no pass rank)
            assert -1 <= d.queue_pos <= d.n_waiting

    def test_srsf_terms(self):
        _, on = observed("contended_residue", "srsf1", "static")
        for d in on.obs.audit:
            assert d.terms["n"] == 1
            assert d.accepted == (d.terms["max_concurrent"] + 1 <= 1)

    def test_kway_lookahead_terms(self):
        _, on = observed("contended_residue", "kway3", "static")
        contested = [
            d for d in on.obs.audit if "t_contend_avg" in (d.terms or {})
        ]
        assert contested, "no k-way lookahead evaluation was audited"
        for d in contested:
            assert d.accepted == (
                d.terms["t_contend_avg"] < d.terms["t_wait_avg"]
            )

    def test_rejects_precede_the_accept(self):
        """A transfer that waited is traceable: its audit sequence shows
        the reject(s) and then the accept that admitted it, in time
        order — the 'accept that later proved costly' requirement."""
        _, on = observed("contended_residue", "srsf1", "static")
        by_job = {}
        for d in on.obs.audit:
            by_job.setdefault((d.job_id, d.bucket), []).append(d)
        admitted_after_wait = 0
        for ds in by_job.values():
            assert [d.t for d in ds] == sorted(d.t for d in ds)
            for prev, nxt in zip(ds, ds[1:]):
                if not prev.accepted and nxt.accepted:
                    admitted_after_wait += 1
        assert admitted_after_wait, "no gated-then-admitted trace in audit"


# ---------------------------------------------------------------------------
# Timelines + Perfetto export
# ---------------------------------------------------------------------------


class TestTimelineAndPerfetto:
    def test_timeline_k_is_conserved(self):
        """Per domain, k steps by +-1 transfer deltas, stays >= 0, and the
        utilization summary is internally consistent."""
        _, on = observed("contended_residue", "ada", "static")
        obs = on.obs
        assert obs.timeline, "timelines channel recorded nothing"
        last = {}
        for t, d, k in obs.timeline:
            assert k >= 0
            last[d] = k
        util = obs.domain_utilization()
        for d, u in util.items():
            assert 0.0 <= u["busy_frac"] <= 1.0
            assert u["mean_k"] <= u["peak_k"]
        assert set(obs.domain_names) >= set(last)

    @pytest.mark.parametrize(
        "name,comm", [("paper", "ada"), ("chaos_recovery_storm", "ada")]
    )
    def test_perfetto_trace_is_loadable(self, tmp_path, name, comm):
        """Acceptance criterion: paper + recovery-storm traces are valid
        Chrome trace-event JSON."""
        scn = quick(name, seed=2 if name == "chaos_recovery_storm" else 0)
        res = run_scenario_event(scn, comm=comm, observe=ObsConfig.full())
        path = tmp_path / f"{name}.perfetto.json"
        res.obs.to_chrome_trace(str(path))
        trace = json.loads(path.read_text())
        ev = trace["traceEvents"]
        assert ev and isinstance(ev, list)
        phs = {e["ph"] for e in ev}
        assert {"X", "M", "C"} <= phs  # spans, metadata, domain counters
        for e in ev:
            assert e["ph"] in ("X", "M", "C", "i")
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        names = {
            e["args"]["name"] for e in ev if e["name"] == "process_name"
        }
        assert any(n.startswith("job ") for n in names)
        if name == "chaos_recovery_storm":
            assert any(e.get("cat") == "fault" for e in ev)

    def test_spans_match_comm_counters(self):
        """Every accepted transfer shows up as exactly one comm span."""
        off, on = observed("contended_residue", "ada", "static")
        comm_spans = [
            s for s in on.obs.spans
            if s[1] < 0 and str(s[2]).startswith("allreduce")
        ]
        started = off.comm_started_contended + off.comm_started_clean
        assert len(comm_spans) == started


# ---------------------------------------------------------------------------
# Caps: bounded memory, loud drops, zero perturbation
# ---------------------------------------------------------------------------


class TestCaps:
    def test_tiny_caps_drop_loudly_without_perturbing(self):
        scn = quick("contended_residue")
        cfg = ObsConfig.full(audit_cap=7, timeline_cap=5, span_cap=3)
        off = run_scenario_event(scn, comm="ada")
        on = run_scenario_event(scn, comm="ada", observe=cfg)
        assert on.finish == off.finish
        obs = on.obs
        assert len(obs.audit) <= 7 and obs.audit_dropped > 0
        assert len(obs.timeline) <= 5 and obs.timeline_dropped > 0
        assert len(obs.spans) <= 3 and obs.span_dropped > 0
        # the decomposition has no cap: closure still holds for every job
        for p in obs.decomp.values():
            assert abs(p.parts_sum - p.jct) <= 1e-6


# ---------------------------------------------------------------------------
# Overhead guard (slow): <3% with everything on
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestOverheadGuard:
    """Full observability on the feature-complete regime (preemptive SRSF
    + gating + WFBP over streaming arrivals) must cost <3 % CPU time.
    Measured +2.1 % on this exact cell (ratio of summed CPU times over 6
    paired rounds).  The guard takes the MINIMUM paired ratio over the
    rounds: host noise only ever inflates a ``process_time`` sample, so
    the cheapest round tracks the true overhead, while a real >=3 %
    regression inflates every round and still trips.  Single wall-clock
    timings on a shared host are 10 %+ noisy — they would drown the
    signal this test exists to bound."""

    ROUNDS = 5
    BUDGET = 0.03

    def test_full_obs_under_three_percent(self):
        from benchmarks.run import stream_trace

        jobs = stream_trace(800, seed=0)
        kw = dict(
            placement="lwf", comm="ada", n_servers=16, gpus_per_server=2,
            sched="preemptive_srsf",
        )
        cfg = ObsConfig.full()
        base = simulate(jobs, **kw)  # warm caches
        on0 = simulate(jobs, **kw, observe=cfg)
        assert on0.finish == base.finish  # guard the guard: same sim

        def timed(obs):
            t0 = time.process_time()
            simulate(jobs, **kw, observe=obs)
            return time.process_time() - t0

        ratios = []
        for i in range(self.ROUNDS):
            if i % 2 == 0:
                t_off, t_on = timed(None), timed(cfg)
            else:
                t_on, t_off = timed(cfg), timed(None)
            ratios.append(t_on / t_off - 1.0)
        overhead = min(ratios)
        assert overhead < self.BUDGET, (
            f"full observability overhead {overhead:+.2%} exceeds "
            f"{self.BUDGET:.0%} in every round "
            f"(paired ratios: {[f'{r:+.2%}' for r in ratios]})"
        )
