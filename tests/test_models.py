"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED variant runs one forward/train step and one prefill+decode step on
CPU, asserting shapes and finiteness.  Full configs are exercised only via
the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.config import ModelConfig
from repro.models.lm import LM, RunFlags

B, S = 2, 32
FLAGS = RunFlags(remat="none", q_chunk=16)


def make_batch(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            ks[2], (B, cfg.audio_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[3], (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


#: Archs whose train/decode smoke runs in the default (tier-1) path: one
#: dense and one MoE representative.  The rest recompile 10-80 s of jits
#: each and run under `-m slow` (plus the SSM family keeps default decode
#: coverage via TestDecodeMatchesPrefill[mamba2-130m]).
DEFAULT_SMOKE_ARCHS = ("llama32_1b", "olmoe_1b_7b")

SMOKE_ARCH_PARAMS = [
    arch
    if arch in DEFAULT_SMOKE_ARCHS
    else pytest.param(arch, marks=pytest.mark.slow)
    for arch in ARCH_IDS
]


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchConfigs:
    """Cheap (no-jit) per-arch checks — run for every arch by default."""

    def test_reduced_config_is_small(self, arch, key):
        cfg = get_config(arch, reduced=True)
        assert cfg.n_layers <= 8 and cfg.d_model <= 512
        if cfg.n_experts:
            assert cfg.n_experts <= 4

    def test_analytic_param_count_matches_schema(self, arch, key):
        """The roofline's analytic N must track the real parameter tree."""
        from repro.models.common import param_count

        cfg = get_config(arch, reduced=True)
        lm = LM(cfg)
        analytic = cfg.param_count(padded=True)
        # padded vocab is part of the schema; analytic uses padded too
        real = param_count(lm.schema())
        assert abs(real - analytic) / real < 0.05, (
            f"{arch}: schema {real} vs analytic {analytic}"
        )


import functools


@functools.lru_cache(maxsize=None)
def _smoke_model(arch):
    """One reduced model + initialized params per arch, shared by the
    train-step and prefill/decode smokes (both tests read the params;
    neither mutates them) — saves one jitted init per arch."""
    cfg = get_config(arch, reduced=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


@pytest.mark.parametrize("arch", SMOKE_ARCH_PARAMS)
class TestArchSmoke:
    def test_train_step(self, arch, key):
        cfg, lm, params = _smoke_model(arch)
        batch = make_batch(cfg, key)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, FLAGS), has_aux=True
        )(params)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        for leaf in jax.tree.leaves(grads):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), (
                f"{arch}: non-finite grad"
            )

    def test_prefill_then_decode(self, arch, key):
        cfg, lm, params = _smoke_model(arch)
        batch = make_batch(cfg, key)
        logits, cache = lm.prefill_fn(params, batch, max_seq=S + 8, flags=FLAGS)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(3):
            logits, cache = lm.decode_fn(params, cache, tok, FLAGS)
            assert logits.shape == (B, cfg.vocab_size)
            assert bool(jnp.isfinite(logits).all())
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert int(cache["pos"]) == S + 3


class TestDecodeMatchesPrefill:
    """Teacher-forcing consistency: decoding token t against the cache must
    produce (close to) the same logits as a fresh prefill over t+1 tokens."""

    # olmoe's MoE decode path is already exercised by the default ArchSmoke
    @pytest.mark.parametrize(
        "arch",
        [
            "llama3.2-1b",
            "mamba2-130m",
            pytest.param("olmoe-1b-7b", marks=pytest.mark.slow),
        ],
    )
    def test_consistency(self, arch, key):
        cfg = get_config(arch, reduced=True)
        lm = LM(cfg)
        params = lm.init(key)
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        batch_s = {"tokens": toks[:, :S]}
        batch_s1 = {"tokens": toks[:, : S + 1]}
        _, cache = lm.prefill_fn(params, batch_s, max_seq=S + 4, flags=FLAGS)
        dec_logits, _ = lm.decode_fn(params, cache, toks[:, S : S + 1], FLAGS)
        ref_logits, _ = lm.prefill_fn(params, batch_s1, max_seq=S + 4, flags=FLAGS)
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(ref_logits, np.float32),
            atol=0.15,
            rtol=0.15,  # bf16 accumulation differences between paths
        )


class TestSlidingWindow:
    def test_sliding_variant_limits_cache(self):
        import dataclasses

        cfg = dataclasses.replace(
            get_config("llama3.2-1b", reduced=True), sliding_window=16
        )
        lm = LM(cfg)
        cache = lm.abstract_cache(batch=2, max_seq=1024)
        assert cache["layers"]["k"].shape[2] == 16  # window, not max_seq

    def test_sliding_mask_matches_windowed_reference(self, key):
        """Sliding-window forward == full attention when S <= window."""
        import dataclasses

        base = get_config("llama3.2-1b", reduced=True)
        swa = dataclasses.replace(base, sliding_window=S * 2)
        p = LM(base).init(key)
        batch = make_batch(base, key)
        l1, _ = LM(base).loss_fn(p, batch, FLAGS)
        l2, _ = LM(swa).loss_fn(p, batch, FLAGS)
        assert float(l1) == pytest.approx(float(l2), rel=1e-3)


class TestMoE:
    def test_aux_loss_nonzero_and_finite(self, key):
        cfg = get_config("olmoe-1b-7b", reduced=True)
        lm = LM(cfg)
        params = lm.init(key)
        _, metrics = lm.loss_fn(params, make_batch(cfg, key), FLAGS)
        assert float(metrics["aux"]) > 0.0
        assert bool(jnp.isfinite(metrics["aux"]))

    def test_moe_capacity(self):
        from repro.models.ffn import expert_capacity

        cfg = get_config("olmoe-1b-7b")
        c = expert_capacity(cfg, 4096)
        assert c >= 4096 * cfg.experts_per_token / cfg.n_experts
        assert c % 4 == 0
