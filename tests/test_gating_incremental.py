"""Differential lock: incremental (dirty-domain) communication gating is
bit-identical to the legacy full rescan.

The engine's ``gating="incremental"`` path re-evaluates only waiters whose
contention domains were touched by a comm start/end/abort (plus a full
re-evaluation fallback for drain-sensitive policies like the exact k-way
lookahead, and whenever chaos dirtied the comm state).  Correctness rests
on the drain-monotonicity argument documented in
``EventEngine._try_start_comms_incremental``; this module locks the claim
differentially: same workload, both gating modes, *every* observable field
equal — including the per-task trace, so not just the aggregate stats but
the entire schedule must coincide.

Grid covered: comm policy (ada / srsf1 / srsf2 / kway2 — the last is the
non-drain-monotone fallback) x WFBP fusion (monolithic + bucketed zoo
models) x scheduling policy (static / preemptive_srsf / elastic) x chaos
(off / breakdowns+stragglers+cancellations, which exercises the
``_abort_comm`` re-gating path).  A hypothesis property fuzzes further
seeds when the library is installed.
"""

import pytest

from repro.core.chaos import ChaosSpec
from repro.core.simulator import simulate
from repro.core.trace import paper_trace

from tests._hypothesis_compat import given, settings, st

#: Every SimResult field that must coincide between the two gating modes.
#: ``task_trace`` makes the lock schedule-exact, not just stats-exact.
IDENTICAL_FIELDS = (
    "jct",
    "finish",
    "makespan",
    "queueing_delay",
    "events_processed",
    "comm_started_contended",
    "comm_started_clean",
    "peak_calendar",
    "censored",
    "preemptions",
    "resizes",
    "faults",
    "cancelled",
    "work_lost_samples",
    "goodput",
    "job_samples",
    "task_trace",
)


def tiny_trace(seed=0, n_jobs=60, horizon_s=90.0):
    """Seconds-fast differential workload: many short mixed-size jobs.

    The GPU mix tops out at 8 so every job fits the 4x4 test cluster — a
    stranded (never-placeable) job would keep ``_unfinished`` non-empty
    forever, and under chaos the self-regenerating fault events then never
    let the calendar drain."""
    return paper_trace(
        seed=seed,
        n_jobs=n_jobs,
        horizon_s=horizon_s,
        min_iters=3,
        max_iters=9,
        gpu_distribution=((1, 8), (2, 4), (4, 5), (8, 3)),
    )


def assert_bit_identical(jobs, **sim_kw):
    sim_kw.setdefault("record_trace", True)
    rescan = simulate(jobs, gating="rescan", **sim_kw)
    incr = simulate(jobs, gating="incremental", **sim_kw)
    for field in IDENTICAL_FIELDS:
        assert getattr(rescan, field) == getattr(incr, field), (
            f"gating modes diverge on {field!r}"
        )
    return rescan


class TestGatingDifferential:
    @pytest.mark.parametrize("comm", ["ada", "srsf1", "srsf2", "kway2"])
    def test_comm_policies(self, comm):
        res = assert_bit_identical(
            tiny_trace(), comm=comm, n_servers=4, gpus_per_server=4
        )
        assert res.comm_started_contended + res.comm_started_clean > 0

    @pytest.mark.parametrize("comm", ["ada", "kway2"])
    def test_wfbp_bucketed(self, comm):
        """Layer-granular WFBP buckets: per-bucket gated transfers overlap
        the backward pass, so the waiter set churns far faster than with
        monolithic messages."""
        from repro.scenarios import get_scenario
        from repro.scenarios.sweep import run_scenario_event

        scn = get_scenario("fusion_sweep", seed=1, base_iters=25)
        results = [
            run_scenario_event(scn, comm=comm, gating=mode, record_trace=True)
            for mode in ("rescan", "incremental")
        ]
        for field in IDENTICAL_FIELDS:
            assert getattr(results[0], field) == getattr(results[1], field), field

    @pytest.mark.parametrize("sched", ["static", "preemptive_srsf", "elastic"])
    def test_sched_policies(self, sched):
        assert_bit_identical(
            tiny_trace(seed=3, n_jobs=40),
            comm="ada",
            sched=sched,
            n_servers=4,
            gpus_per_server=4,
        )

    @pytest.mark.parametrize("sched", ["static", "preemptive_srsf"])
    def test_chaos_grid(self, sched):
        """Fault injection dirties comm state out-of-band (breakdown-driven
        ``_abort_comm``, NIC degradation rate changes, stochastic cancels):
        the incremental path must re-gate identically through all of it."""
        chaos = ChaosSpec(
            seed=5,
            server_mtbf_s=60.0,
            server_mttr_s=8.0,
            straggler_prob=0.1,
            straggler_slowdown=1.0,
            cancel_prob=0.15,
            cancel_after_s=4.0,
        )
        res = assert_bit_identical(
            tiny_trace(seed=7, n_jobs=40),
            comm="ada",
            sched=sched,
            chaos=chaos,
            n_servers=4,
            gpus_per_server=4,
        )
        assert res.faults > 0  # the injector actually fired

    def test_abort_regating(self):
        """A scripted mid-run breakdown aborts in-flight all-reduces; the
        freed link capacity must re-gate waiting transfers identically in
        both modes (the ``_abort_comm`` dirty-domain path)."""
        jobs = tiny_trace(seed=11, n_jobs=30, horizon_s=30.0)
        chaos = ChaosSpec(seed=0, scripted_failures=((0, 6.0, 14.0),))
        res = assert_bit_identical(
            jobs, comm="ada", chaos=chaos, n_servers=4, gpus_per_server=4
        )
        assert res.faults > 0
        assert res.work_lost_samples > 0  # a teardown hit in-flight work

    def test_streaming_source(self):
        """Both gating modes also coincide in streaming-arrival mode."""
        from repro.core.trace import ListTraceSource

        jobs = tiny_trace(seed=2, n_jobs=50)
        rescan = simulate(
            ListTraceSource(jobs), comm="ada", gating="rescan",
            n_servers=4, gpus_per_server=4,
        )
        incr = simulate(
            ListTraceSource(jobs), comm="ada", gating="incremental",
            n_servers=4, gpus_per_server=4,
        )
        assert rescan.jct == incr.jct
        assert rescan.finish == incr.finish
        assert rescan.events_processed == incr.events_processed


class TestGatingConfig:
    def test_unknown_gating_raises(self):
        with pytest.raises(ValueError, match="gating"):
            simulate(tiny_trace(n_jobs=4), gating="bogus")

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_GATING", "rescan")
        jobs = tiny_trace(seed=0, n_jobs=20)
        via_env = simulate(jobs, n_servers=4, gpus_per_server=4)
        explicit = simulate(
            jobs, gating="rescan", n_servers=4, gpus_per_server=4
        )
        assert via_env.jct == explicit.jct
        assert via_env.events_processed == explicit.events_processed
        monkeypatch.setenv("REPRO_GATING", "nonsense")
        with pytest.raises(ValueError, match="gating"):
            simulate(jobs, n_servers=4, gpus_per_server=4)

    def test_drain_monotone_attributes(self):
        """The monotonicity declarations the incremental fast path rests
        on: SRSF(n) and AdaDUAL qualify, the exact k-way lookahead (whose
        acceptance can flip as old transfers drain) must NOT."""
        from repro.core.schedpolicy import (
            AdaDual,
            CommPolicy,
            KWayAdaDual,
            SrsfN,
        )

        assert CommPolicy.drain_monotone is False  # safe default
        assert SrsfN(1).drain_monotone is True
        assert AdaDual().drain_monotone is True
        assert KWayAdaDual(2).drain_monotone is False


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_gating_differential_property(seed):
    """Property fuzz over workload seeds: rescan == incremental."""
    assert_bit_identical(
        tiny_trace(seed=seed, n_jobs=30, horizon_s=45.0),
        comm="ada",
        n_servers=4,
        gpus_per_server=4,
    )
