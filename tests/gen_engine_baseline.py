"""Generator for ``tests/data/engine_regression_baseline.json`` — the
bit-exactness lock of the engine/policy refactor.

Captured ONCE at the last pre-refactor commit (PR 4 HEAD, ccd9e44, where
``core/simulator.py`` was still the 859-line monolith) and committed; the
refactored engine under ``StaticGangPolicy`` must reproduce every number
EXACTLY (``==``, no tolerance) — see ``tests/test_engine.py``.

Regenerating this file on a post-refactor tree is meaningless (it would
lock the refactor against itself); the script is kept so the lock can be
re-anchored intentionally after a *deliberate* behaviour change, in which
case the change must be called out in CHANGES.md.

Run:  PYTHONPATH=src python tests/gen_engine_baseline.py
"""

import hashlib
import json
import os
import time

from repro.scenarios import get_scenario, run_scenario_event

# Mirrors tests/test_scenarios.py REGRESSION_CELLS at capture time — with
# one deliberate-after-the-fact exception: adversarial_allbig was captured
# at its DEFAULT sizing (n_jobs=12), not the regression cell's n_jobs=8
# (transcription slip at capture time, kept as captured: the 12-job cell
# is just as valid a pre-refactor anchor, merely a different workload, and
# the baseline cannot be re-captured post-refactor).
CELLS = {
    "paper": (0, dict(n_jobs=40, min_iters=100, max_iters=600)),
    "philly_heavy_tail": (1, dict(n_jobs=32, min_iters=80, max_iters=1500)),
    "bursty_diurnal": (1, dict(n_jobs=32, min_iters=100, max_iters=600)),
    "hetero_bandwidth": (1, dict(n_jobs=28, min_iters=100, max_iters=600)),
    "large_job_dominated": (1, dict(n_jobs=14, min_iters=100, max_iters=500)),
    "adversarial_allbig": (1, dict(base_iters=120)),
    "contended_residue": (1, {}),
    "oversub_fabric": (1, dict(n_jobs=32, min_iters=100, max_iters=600)),
    "rack_locality": (1, {}),
    "model_zoo": (1, dict(n_jobs=12, min_iters=15, max_iters=60, horizon_s=600.0)),
    "fusion_sweep": (1, dict(base_iters=25)),
    "smoke": (0, {}),
}

#: Scenarios additionally locked at full task-trace granularity (small
#: enough that record_trace stays cheap).
TRACE_CELLS = ("smoke", "contended_residue", "fusion_sweep", "adversarial_allbig")


def finish_digest(res) -> str:
    payload = json.dumps(
        sorted((jid, repr(t)) for jid, t in res.finish.items())
    ).encode()
    return hashlib.sha256(payload).hexdigest()


def trace_digest(res) -> str:
    payload = json.dumps([[str(x) for x in row] for row in res.task_trace]).encode()
    return hashlib.sha256(payload).hexdigest()


def main() -> None:
    out = {"captured_at": "pre-refactor (PR 4 HEAD ccd9e44)", "cells": {}}
    for name, (seed, overrides) in sorted(CELLS.items()):
        scn = get_scenario(name, seed=seed, **overrides)
        for comm in ("ada", "srsf1"):
            t0 = time.time()
            res = run_scenario_event(scn, comm=comm)
            wall = time.time() - t0
            key = f"{name}/{comm}"
            out["cells"][key] = {
                "avg_jct": repr(res.avg_jct()),
                "makespan": repr(res.makespan),
                "events": res.events_processed,
                "n_finished": len(res.jct),
                "comm_contended": res.comm_started_contended,
                "comm_clean": res.comm_started_clean,
                "finish_sha256": finish_digest(res),
                "wall_s": round(wall, 3),
            }
            print(key, out["cells"][key]["avg_jct"], f"{wall:.2f}s", flush=True)
    for name in TRACE_CELLS:
        seed, overrides = CELLS[name]
        scn = get_scenario(name, seed=seed, **overrides)
        res = run_scenario_event(scn, comm="ada", record_trace=True, fuse_fb=False)
        out["cells"][f"{name}/ada/trace"] = {
            "trace_sha256": trace_digest(res),
            "n_records": len(res.task_trace),
        }
        print(f"{name}/ada/trace", len(res.task_trace), flush=True)

    # events/sec of the monolithic pre-refactor simulator on the quick
    # paper cell (the BENCH_engine baseline; single CPU, fuse_fb on).
    scn = get_scenario("paper", seed=0, **CELLS["paper"][1])
    t0 = time.time()
    res = run_scenario_event(scn, comm="ada")
    wall = time.time() - t0
    out["events_per_sec_paper_quick"] = res.events_processed / wall
    print("events/sec", out["events_per_sec_paper_quick"], flush=True)

    path = os.path.join(os.path.dirname(__file__), "data", "engine_regression_baseline.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print("wrote", path)


if __name__ == "__main__":
    main()
