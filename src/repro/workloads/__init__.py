"""Model-derived workload profiles for the scheduling half of the repo.

``repro.workloads`` turns the real architecture configs under
``src/repro/configs/`` into layer-granular scheduling profiles
(per-layer gradient bytes + roofline compute times) that the WFBP
communication subsystem consumes — see ``profiles.py``.
"""

from repro.workloads.profiles import (
    GRAD_BYTES_PER_PARAM,
    LayerProfile,
    MFU,
    RESIDENT_BYTES_PER_PARAM,
    TOKENS_PER_GPU,
    ZOO_ARCHS,
    ZOO_GPU_MEM_MB,
    derive_layer_profiles,
    model_profile_from_config,
    zoo_profiles,
)

__all__ = [
    "GRAD_BYTES_PER_PARAM",
    "LayerProfile",
    "MFU",
    "RESIDENT_BYTES_PER_PARAM",
    "TOKENS_PER_GPU",
    "ZOO_ARCHS",
    "ZOO_GPU_MEM_MB",
    "derive_layer_profiles",
    "model_profile_from_config",
    "zoo_profiles",
]
