"""Model-derived workload profiles: the bridge between the repo's model
half (``repro.models.config`` + ``repro.configs``) and its scheduling half
(``repro.core``).

The paper's Table III gives four measured CNN/LSTM profiles with one
monolithic gradient message each.  This module derives *layer-granular*
profiles — per-layer gradient bytes and forward/backward compute times —
from the real architecture configs under ``src/repro/configs/`` via the
same roofline model ``launch/roofline.py`` applies to compiled artifacts:

    t_compute = FLOPs / (MFU * peak_flops)     FLOPs = 2*P*T fwd, 4*P*T bwd
    t_memory  = bytes / HBM_bandwidth          (weight reads; small-batch floor)
    t_layer   = max(t_compute, t_memory)

Parameter counts per layer come from the analytic model every config
already carries (``ModelConfig._layer_params`` — the same function behind
the roofline's MODEL_FLOPS ratio).  Layers are emitted in *backward-ready*
order (the tied embedding / LM head first, then decoder layers from the
output backwards), which is the order gradients materialize during
backprop and hence the order WFBP buckets become ready.

The derived :class:`~repro.core.cluster.ModelProfile` plugs straight into
``JobSpec``; its ``layer_grad_bytes``/``layer_t_b`` arrays feed the
tensor-fusion planner (``netmodel.fusion_plan``) on both simulator
backends.  The zoo targets a data-parallel A100-80G-class cluster: the
all-reduced message is the full bf16 gradient (2 B/param) and the resident
footprint assumes bf16 weights+grads plus a ZeRO-1-sharded fp32 optimizer
slice (6 B/param) — the reason the ``model_zoo`` scenario raises
``gpu_mem_mb`` above the paper's 16 GB V100s.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

from repro.core.cluster import ModelProfile

# Hardware constants of the roofline model (launch/mesh.py values; redefined
# here because importing launch.mesh pulls in jax and the event-simulator
# path must stay jax-free for cheap multiprocessing workers — the whole
# derivation chain is: repro.models.config is pure dataclasses and
# repro.models/__init__ resolves its jax-backed exports lazily, so
# zoo_profiles() never imports jax; guarded by a test in tests/test_wfbp.py).
PEAK_FLOPS_BF16 = 197e12  # [FLOP/s] per chip
HBM_BW = 819e9            # [B/s] per chip

#: Achieved fraction of peak FLOPs (MFU) assumed for the derived compute
#: times — trainings of this size on commodity clusters sit near 0.4.
MFU = 0.4
#: bf16 gradients: the all-reduced message is 2 B per parameter.
GRAD_BYTES_PER_PARAM = 2.0
#: Resident bytes per parameter for memory admission: bf16 weights (2) +
#: bf16 grads (2) + a ZeRO-1-sharded fp32 AdamW slice (~2 amortized).
RESIDENT_BYTES_PER_PARAM = 6.0
#: Reference per-GPU workload shape: 4 sequences x 2048 tokens.
TOKENS_PER_GPU = 4 * 2048

#: The architectures the ``model_zoo``/``fusion_sweep`` scenarios sample
#: from: the configs whose data-parallel gradient exchange is plausible on
#: the modeled fabric (the 52B/480B configs are left out — their hundreds
#: of GB per iteration are not a scheduling workload, they are a wall).
ZOO_ARCHS = (
    "mamba2_130m",
    "llama32_1b",
    "phi4_mini_3_8b",
    "olmoe_1b_7b",
    "gemma_7b",
    "yi_9b",
)

#: GPU memory of the zoo cluster [MB] (A100-80G class).
ZOO_GPU_MEM_MB = 81920.0


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """One layer's contribution to the WFBP schedule: gradient bytes plus
    roofline-derived forward/backward seconds (backward-ready order)."""

    name: str
    grad_bytes: float
    t_f: float
    t_b: float


def _roofline_time(flops: float, bytes_moved: float) -> float:
    """max(compute, memory) roofline seconds for one layer pass."""
    return max(flops / (MFU * PEAK_FLOPS_BF16), bytes_moved / HBM_BW)


def _layer_entry(
    name: str, params: float, tokens: int, active_params: float = 0.0
) -> LayerProfile:
    """Roofline terms of one layer: 2*P*T fwd / 4*P*T bwd FLOPs, weight
    reads (bf16) as the memory floor, bf16 gradient message.  For MoE
    layers ``active_params`` (routed experts only) drive the FLOPs while
    the gradient message and weight traffic cover every expert."""
    compute_p = active_params or params
    weight_bytes = GRAD_BYTES_PER_PARAM * params
    t_f = _roofline_time(2.0 * compute_p * tokens, weight_bytes)
    t_b = _roofline_time(4.0 * compute_p * tokens, 2.0 * weight_bytes)
    return LayerProfile(name, GRAD_BYTES_PER_PARAM * params, t_f, t_b)


def derive_layer_profiles(cfg, tokens: int = TOKENS_PER_GPU) -> Tuple[LayerProfile, ...]:
    """Per-layer WFBP profiles of a ``ModelConfig``, in backward-ready
    order: the tied embedding/LM-head gradient materializes first (output
    side), then decoder layers from the last to the first.  Parameter
    counts use the config's own analytic layer model (norms folded into
    each layer); encoder stacks (audio enc-dec) are appended after the
    decoder — their gradients are ready only once the decoder backward has
    propagated through the cross-attention."""
    d = cfg.d_model
    layers = [_layer_entry("embed", float(cfg.vocab_size * d), tokens)]
    for i in reversed(range(cfg.n_layers)):
        params = float(cfg._layer_params(i, False) + 2 * d)  # + the 2 norms
        active = float(cfg._layer_params(i, False, active_only=True) + 2 * d)
        layers.append(_layer_entry(f"layer{i}", params, tokens, active))
    if cfg.enc_layers:
        enc_params = float(cfg._enc_layer_params(False))
        layers.extend(
            _layer_entry(f"enc{i}", enc_params, tokens)
            for i in reversed(range(cfg.enc_layers))
        )
    return tuple(layers)


def model_profile_from_config(
    cfg, tokens: int = TOKENS_PER_GPU
) -> ModelProfile:
    """Collapse the layer profiles into a scheduling ``ModelProfile`` whose
    ``layer_grad_bytes``/``layer_t_b`` arrays carry the WFBP structure.
    Invariants (tested): ``sum(layer_grad_bytes) == size_bytes`` and
    ``sum(layer_t_b) == t_b`` — the monolithic reading of a derived
    profile is exactly its fused-all plan."""
    layers = derive_layer_profiles(cfg, tokens)
    size = sum(l.grad_bytes for l in layers)
    t_f = sum(l.t_f for l in layers)
    t_b = sum(l.t_b for l in layers)
    mem_mb = (size / GRAD_BYTES_PER_PARAM) * RESIDENT_BYTES_PER_PARAM / 1e6
    return ModelProfile(
        name=cfg.name,
        size_bytes=size,
        mem_mb=mem_mb,
        batch_size=tokens,
        t_f=t_f,
        t_b=t_b,
        layer_grad_bytes=tuple(l.grad_bytes for l in layers),
        layer_t_b=tuple(l.t_b for l in layers),
    )


@functools.lru_cache(maxsize=None)
def zoo_profiles(tokens: int = TOKENS_PER_GPU) -> Dict[str, ModelProfile]:
    """The config-derived model zoo, keyed by arch id (cached — config
    import and derivation are pure)."""
    from repro.configs import get_config

    return {
        arch: model_profile_from_config(get_config(arch), tokens)
        for arch in ZOO_ARCHS
    }


__all__ = [
    "GRAD_BYTES_PER_PARAM",
    "LayerProfile",
    "MFU",
    "RESIDENT_BYTES_PER_PARAM",
    "TOKENS_PER_GPU",
    "ZOO_ARCHS",
    "ZOO_GPU_MEM_MB",
    "derive_layer_profiles",
    "model_profile_from_config",
    "zoo_profiles",
]
