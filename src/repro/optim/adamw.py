"""AdamW with decoupled weight decay, gradient clipping, and schedules.

Plain-pytree implementation (no optax dependency).  Moment dtype is
configurable: f32 by default; the largest assigned architectures
(arctic-480b, jamba-52b) use bf16 moments so the optimizer state fits the
per-chip HBM budget — the trade-off is recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(sds, abstract_params),
        "v": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    state,
    cfg: AdamWConfig,
    lr: jax.Array | float | None = None,
):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr_t = cfg.lr if lr is None else lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr_t * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree.unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree.unflatten(treedef, [n[2] for n in new])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm},
    )


def cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule
