"""Mamba-2 (SSD — state-space duality) mixer layer [arXiv:2405.21060].

Training uses the chunked SSD algorithm: within a chunk the recurrence is
computed as a masked quadratic form (the "dual" attention-like view), and
chunk states are passed with a `lax.scan` — O(S * chunk) work, constant
memory in S.  Decode is the O(1) recurrent state update.

The recurrence (per head h, state size N, head dim P):

    h_i = exp(dt_i * A) * h_{i-1} + dt_i * B_i x_i^T
    y_i = C_i . h_i + D * x_i

``ssd_scan`` here is also the semantic reference for the Pallas kernel in
``kernels/ssd`` (its ref.py calls this).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm
from repro.models.config import ModelConfig

NEG_INF = -2.0**30


def ssm_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    h, w = cfg.ssm_n_heads, cfg.ssm_conv_width
    return {
        "w_z": ParamSpec((d, di), ("embed", "ssm_inner")),
        "w_x": ParamSpec((d, di), ("embed", "ssm_inner")),
        "w_B": ParamSpec((d, n), ("embed", None)),
        "w_C": ParamSpec((d, n), ("embed", None)),
        "w_dt": ParamSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((di, w), ("ssm_inner", None), init="normal", scale=1.0),
        "conv_B": ParamSpec((n, w), (None, None)),
        "conv_C": ParamSpec((n, w), (None, None)),
        "conv_bias_x": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "conv_bias_B": ParamSpec((n,), (None,), init="zeros"),
        "conv_bias_C": ParamSpec((n,), (None,), init="zeros"),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), scale=0.5),
    }


# ---------------------------------------------------------------------------
# Depthwise causal conv (width w), train and single-step forms
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (C, W) depthwise causal conv; returns (B, S, C)."""
    width = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # unrolled taps — width is 4; avoids conv_general_dilated layout pitfalls
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[:, i][None, None, :] for i in range(width)
    )
    return out + b[None, None, :]


def conv_step(
    x1: jax.Array, state: jax.Array, w: jax.Array, b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x1: (B, C) new input; state: (B, C, W-1) previous inputs.
    Returns (conv output (B, C), new state)."""
    width = w.shape[-1]
    full = jnp.concatenate([state, x1[:, :, None]], axis=-1)  # (B, C, W)
    y = jnp.sum(full * w[None, :, :], axis=-1) + b[None, :]
    return y, full[:, :, 1:]


# ---------------------------------------------------------------------------
# SSD chunked scan (training / prefill)
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (already softplus'd, >= 0)
    a: jax.Array,   # (H,)       (negative: -exp(A_log))
    b_in: jax.Array,  # (B, S, N)
    c_in: jax.Array,  # (B, S, N)
    chunk: int,
    h0: jax.Array = None,  # (B, H, P, N) initial state or None
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc, q = s // chunk, chunk

    dA = (dt * a[None, None, :]).astype(jnp.float32)  # (B,S,H), <= 0
    xr = x.reshape(bsz, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(bsz, nc, q, h).transpose(1, 0, 2, 3)
    dAr = dA.reshape(bsz, nc, q, h).transpose(1, 0, 2, 3)
    br = b_in.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3)
    cr = c_in.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((q, q), dtype=bool))  # j <= i

    def body(h_state, inp):
        xc, dtc, dac, bc, cc = inp  # (B,q,h,p) (B,q,h) (B,q,h) (B,q,n) (B,q,n)
        cum = jnp.cumsum(dac, axis=1)  # (B,q,h)
        total = cum[:, -1, :]  # (B,h)
        # inter-chunk: y_i += exp(cum_i) * C_i . h_state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cc.astype(jnp.float32), h_state)
        y_inter = y_inter * jnp.exp(cum)[..., None]
        # intra-chunk masked quadratic
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,h)
        diff = jnp.where(tri[None, :, :, None], diff, NEG_INF)
        el = jnp.exp(diff) * dtc[:, None, :, :]  # (B,i,j,h)
        scores = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, el, xc.astype(jnp.float32))
        # state update
        decay = jnp.exp(total[:, None, :] - cum) * dtc  # (B,j,h)
        new_state = h_state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjh,bjn,bjhp->bhpn", decay, bc.astype(jnp.float32), xc.astype(jnp.float32)
        )
        return new_state, (y_inter + y_intra).astype(x.dtype)

    h_final, ys = jax.lax.scan(body, h0, (xr, dtr, dAr, br, cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, h_final


def ssd_step(
    x1: jax.Array,   # (B, H, P)
    dt1: jax.Array,  # (B, H)
    a: jax.Array,    # (H,)
    b1: jax.Array,   # (B, N)
    c1: jax.Array,   # (B, N)
    h_state: jax.Array,  # (B, H, P, N) float32
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step.  Returns (y (B,H,P), new state)."""
    da = jnp.exp((dt1 * a[None, :]).astype(jnp.float32))  # (B,H)
    upd = jnp.einsum(
        "bh,bn,bhp->bhpn", dt1.astype(jnp.float32), b1.astype(jnp.float32), x1.astype(jnp.float32)
    )
    new_state = h_state * da[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c1.astype(jnp.float32), new_state)
    return y.astype(x1.dtype), new_state


# ---------------------------------------------------------------------------
# Full mixer layer
# ---------------------------------------------------------------------------


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (SSD chunk size)."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def ssm_forward(x: jax.Array, params: Dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    """(B, S, D) -> (B, S, D) Mamba-2 mixer (train/prefill)."""
    bsz, s, _ = x.shape
    h, p, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = x @ params["w_z"]
    xs = x @ params["w_x"]
    bp = x @ params["w_B"]
    cp = x @ params["w_C"]
    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))

    xs = jax.nn.silu(causal_conv(xs, params["conv_x"], params["conv_bias_x"]))
    bp = jax.nn.silu(causal_conv(bp, params["conv_B"], params["conv_bias_B"]))
    cp = jax.nn.silu(causal_conv(cp, params["conv_C"], params["conv_bias_C"]))

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, s, h, p)
    y, _ = ssd_scan(xh, dt.astype(xs.dtype), a, bp, cp, chunk=pick_chunk(s, cfg.ssm_chunk))
    y = y + xh * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, h * p)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"]


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, w = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv_width
    h, p = cfg.ssm_n_heads, cfg.ssm_head_dim
    return {
        "conv_x": jnp.zeros((batch, di, w - 1), dtype),
        "conv_B": jnp.zeros((batch, n, w - 1), dtype),
        "conv_C": jnp.zeros((batch, n, w - 1), dtype),
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def abstract_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, w = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv_width
    h, p = cfg.ssm_n_heads, cfg.ssm_head_dim
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, di, w - 1), dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, n, w - 1), dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, n, w - 1), dtype),
        "state": jax.ShapeDtypeStruct((batch, h, p, n), jnp.float32),
    }


def ssm_decode_step(
    x1: jax.Array, params: Dict[str, jax.Array], cache, cfg: ModelConfig
) -> Tuple[jax.Array, dict]:
    """One-token decode.  x1: (B, 1, D) -> (y (B,1,D), new cache)."""
    bsz = x1.shape[0]
    h, p = cfg.ssm_n_heads, cfg.ssm_head_dim
    x0 = x1[:, 0, :]

    z = x0 @ params["w_z"]
    xs = x0 @ params["w_x"]
    bp = x0 @ params["w_B"]
    cp = x0 @ params["w_C"]
    dt = jax.nn.softplus((x0 @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))

    xs, conv_x = conv_step(xs, cache["conv_x"], params["conv_x"], params["conv_bias_x"])
    bp, conv_b = conv_step(bp, cache["conv_B"], params["conv_B"], params["conv_bias_B"])
    cp, conv_c = conv_step(cp, cache["conv_C"], params["conv_C"], params["conv_bias_C"])
    xs, bp, cp = jax.nn.silu(xs), jax.nn.silu(bp), jax.nn.silu(cp)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, state = ssd_step(
        xs.reshape(bsz, h, p), dt.astype(xs.dtype), a, bp, cp, cache["state"]
    )
    y = y + xs.reshape(bsz, h, p) * params["D"].astype(x1.dtype)[None, :, None]
    y = y.reshape(bsz, h * p)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = (y @ params["out_proj"])[:, None, :]
    new_cache = {"conv_x": conv_x, "conv_B": conv_b, "conv_C": conv_c, "state": state}
    return out, new_cache
