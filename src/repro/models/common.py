"""Shared model building blocks: parameter schema, norms, RoPE, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every leaf is
declared once via :class:`ParamSpec` (shape + logical sharding axes + init),
so abstract shapes (dry-run), real initialization (training) and sharding
specs (pjit) all derive from the same schema and cannot drift apart.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0    # stddev multiplier for "normal"

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


Schema = Dict[str, Any]  # nested dict of ParamSpec


def tree_is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(schema: Schema, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        schema,
        is_leaf=tree_is_spec,
    )


def logical_axes(schema: Schema) -> Params:
    """Pytree of logical-axis tuples matching the param pytree."""
    return jax.tree.map(lambda s: s.axes, schema, is_leaf=tree_is_spec)


def init_params(schema: Schema, key: jax.Array, dtype=jnp.bfloat16) -> Params:
    """Materialize real parameters (smoke tests / CPU training)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=tree_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_count(schema: Schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=tree_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (seq,) or
    broadcastable to x's seq dim."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    # insert heads axis
    angles = angles[..., :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, vocab_size: int
) -> jax.Array:
    """Mean CE over valid labels; labels >= vocab_size or < 0 are masked
    (covers the padded-vocab convention)."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
