"""Grouped-query attention: training/prefill forward, decode step, cross-attn.

The training path is a chunked (flash-style) implementation — a `lax.map`
over query chunks so the S x S logits matrix is never materialized (required
to fit prefill_32k / train_4k activations in HBM; see EXPERIMENTS.md §Perf).
Semantically it matches ``kernels/flash_attention/ref.py``; on real TPU the
Pallas kernel (``kernels/flash_attention``) is selected with
``use_pallas=True``.

Supports: causal, sliding-window (sub-quadratic long-context variant) and
full (encoder / cross) masking; GQA head replication; arctic-style padded
query heads (extra heads are dead weight, masked out by zero-init output
rows).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope
from repro.models.config import ModelConfig

NEG_INF = -2.0**30  # large-but-finite; avoids NaN from all-masked rows


def attn_schema(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim_
    del cross  # same shapes; kv inputs differ at apply time
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("q_heads", "head_dim", "embed"), scale=0.5),
    }


def _pick_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of s that is <= target (q-chunk size)."""
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd
    )


def attention_forward(
    x: jax.Array,
    params: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    mask_kind: str = "causal",  # causal | sliding | full
    kv_input: Optional[jax.Array] = None,  # cross-attention source
    use_rope: bool = True,
    q_chunk: int = 512,
) -> jax.Array:
    """(B, S, D) -> (B, S, D).  Chunked over queries."""
    b, s, _ = x.shape
    kv_x = x if kv_input is None else kv_input
    t = kv_x.shape[1]
    h, kvh, hd = cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim_

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dgk->btgk", kv_x, params["wk"])
    v = jnp.einsum("btd,dgk->btgk", kv_x, params["wv"])
    if use_rope and kv_input is None:
        pos = jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k = _repeat_kv(k, h // kvh)
    v = _repeat_kv(v, h // kvh)
    scale = 1.0 / math.sqrt(hd)

    qc = _pick_chunk(s, q_chunk)
    n_chunks = s // qc
    q = q.reshape(b, n_chunks, qc, h, hd)
    kv_pos = jnp.arange(t)

    def one_chunk(args):
        q_blk, chunk_idx = args  # (b, qc, h, hd), scalar
        q_pos = chunk_idx * qc + jnp.arange(qc)
        logits = jnp.einsum("bqhk,bthk->bhqt", q_blk, k).astype(jnp.float32) * scale
        if mask_kind == "causal":
            mask = kv_pos[None, :] <= q_pos[:, None]
        elif mask_kind == "sliding":
            w = cfg.sliding_window
            mask = (kv_pos[None, :] <= q_pos[:, None]) & (
                kv_pos[None, :] > q_pos[:, None] - w
            )
        else:
            mask = jnp.ones((qc, t), dtype=bool)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        return jnp.einsum("bhqt,bthk->bqhk", probs, v)

    out = jax.lax.map(one_chunk, (q.transpose(1, 0, 2, 3, 4), jnp.arange(n_chunks)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# Decode (one token against a cache)
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ModelConfig, batch: int, window: int, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, window, kvh, hd), dtype),
        "v": jnp.zeros((batch, window, kvh, hd), dtype),
    }


def abstract_kv_cache(cfg: ModelConfig, batch: int, window: int, dtype=jnp.bfloat16):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    sds = jax.ShapeDtypeStruct((batch, window, kvh, hd), dtype)
    return {"k": sds, "v": sds}


def decode_attention(
    x1: jax.Array,  # (B, 1, D)
    params: Dict[str, jax.Array],
    cache: Dict[str, jax.Array],
    pos: jax.Array,  # scalar int32: index of the token being generated
    cfg: ModelConfig,
    *,
    use_rope: bool = True,
    constrain=None,  # None = off; tuple of mesh axes carrying the batch dim
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step of self-attention against a (ring-buffer) KV cache.

    The cache holds ``window`` slots; with full attention window == max_seq
    and slot j stores position j.  With sliding-window attention the buffer
    wraps (slot = pos % window) — RoPE is applied to keys at *write* time
    with absolute positions, so relative phases stay correct after wrap.
    """
    b = x1.shape[0]
    h, kvh, hd = cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim_
    window = cache["k"].shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x1, params["wq"])
    k1 = jnp.einsum("bsd,dgk->bsgk", x1, params["wk"])
    v1 = jnp.einsum("bsd,dgk->bsgk", x1, params["wv"])
    if use_rope:
        p = pos[None] if pos.ndim == 0 else pos
        q = apply_rope(q, p, cfg.rope_theta)
        k1 = apply_rope(k1, p, cfg.rope_theta)
    slot = jnp.mod(pos, window)
    ck = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
    if constrain is not None:
        # Flash-decode-style sharding: the cache stays seq-sharded over the
        # model axis through the update, and the (tiny) query is replicated
        # over "model" instead — so the attention contraction gathers ~1 MB
        # of q rather than the multi-GB cache, and the softmax runs as
        # partial reductions over the seq shards (§Perf, decode ladder).
        from jax.sharding import PartitionSpec as P

        bax = tuple(constrain) or None
        spec = P(bax, "model", None, None)
        ck = jax.lax.with_sharding_constraint(ck, spec)
        cv = jax.lax.with_sharding_constraint(cv, spec)
        q = jax.lax.with_sharding_constraint(q, P(bax, None, None, None))

    kk = _repeat_kv(ck, h // kvh)  # (B, W, H, hd)
    vv = _repeat_kv(cv, h // kvh)
    logits = jnp.einsum("bshk,bthk->bhst", q, kk).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if constrain is not None:
        from jax.sharding import PartitionSpec as P

        logits = jax.lax.with_sharding_constraint(
            logits, P(tuple(constrain) or None, None, None, "model")
        )
    # slot j is valid iff it has been written: j <= pos (before wrap) or
    # always (after wrap — every slot holds one of the last `window` keys).
    valid = jnp.arange(window)[None, :] <= pos
    valid = valid | (pos >= window)
    logits = jnp.where(valid[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x1.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, vv)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": ck, "v": cv}


def decode_cross_attention(
    x1: jax.Array,
    params: Dict[str, jax.Array],
    cross_k: jax.Array,  # (B, T, KV, hd) precomputed from encoder/vision output
    cross_v: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    h, kvh, hd = cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dhk->bshk", x1, params["wq"])
    kk = _repeat_kv(cross_k, h // kvh)
    vv = _repeat_kv(cross_v, h // kvh)
    logits = jnp.einsum("bshk,bthk->bhst", q, kk).astype(jnp.float32) / math.sqrt(hd)
    probs = jax.nn.softmax(logits, axis=-1).astype(x1.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, vv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def precompute_cross_kv(
    enc_out: jax.Array, params: Dict[str, jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,dgk->btgk", enc_out, params["wk"])
    v = jnp.einsum("btd,dgk->btgk", enc_out, params["wv"])
    return k, v
