"""Feed-forward layers: gated MLPs (SwiGLU / GeGLU / plain GELU) and the
token-choice top-k Mixture-of-Experts layer with capacity-based dispatch.

The MoE dispatch is scatter/gather-based (not one-hot einsum): positions
within each expert are computed with a per-sequence cumulative sum, tokens
are scattered into an (E, C, D) buffer (overflow beyond capacity C is
dropped, standard GShard semantics), experts run as one batched matmul
sharded over the ``experts`` logical axis (expert parallelism -> all-to-all
under GSPMD), and results are gathered back weighted by the router gates.
Dispatch FLOPs are O(tokens x D) instead of the O(tokens x E x C x D) of the
one-hot-matmul formulation — that difference is what keeps the MoE archs
near their roofline compute term (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_schema(cfg: ModelConfig, d_ff: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_down": ParamSpec((d_ff, d), ("ffn", "embed"), scale=0.5),
        }
    return {
        "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d), ("ffn", "embed"), scale=0.5),
    }


def _act(h: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(h)
    if kind == "geglu":
        return jax.nn.gelu(h)
    return jax.nn.gelu(h)


def mlp(x: jax.Array, params: Dict[str, jax.Array], act: str) -> jax.Array:
    if "w_gate" in params:
        h = _act(x @ params["w_gate"], act) * (x @ params["w_up"])
    else:
        h = _act(x @ params["w_up"], act)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff_
    schema: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.1),
        "w_down": ParamSpec((e, f, d), ("experts", "ffn", "embed"), scale=0.5),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
    }
    if cfg.act in ("swiglu", "geglu"):
        schema["w_gate"] = ParamSpec((e, d, f), ("experts", "embed", "ffn"))
    return schema


def expert_capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = math.ceil(seq_len * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(4, int(math.ceil(c / 4) * 4))


def moe(
    x: jax.Array, params: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE.  x: (B, S, D) -> (y, aux_loss).

    aux_loss = load-balance (switch-style) + router z-loss, already weighted
    by the config coefficients; the caller just adds it to the model loss.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    c = expert_capacity(cfg, s)

    router_logits = (x @ params["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- position of each (token, k) within its expert, per sequence --------
    sel = jax.nn.one_hot(expert_idx.reshape(b, s * k), e, dtype=jnp.int32)  # (B,SK,E)
    cum = jnp.cumsum(sel, axis=1) - sel
    pos = jnp.sum(sel * cum, axis=-1)  # (B, SK)
    flat_expert = expert_idx.reshape(b, s * k)
    overflow = pos >= c
    dest = jnp.where(overflow, e * c, flat_expert * c + pos)  # drop row at e*c

    # --- scatter tokens into (E, C) slots ------------------------------------
    x_rep = jnp.repeat(x, k, axis=1)  # (B, S*K, D): token s occupies slots s*k..s*k+k-1
    batch_ix = jnp.arange(b)[:, None]
    disp = jnp.zeros((b, e * c + 1, d), x.dtype).at[batch_ix, dest].add(x_rep)
    disp = disp[:, : e * c].reshape(b, e, c, d)

    # --- expert computation (batched matmul, sharded over experts) ----------
    if "w_gate" in params:
        h = _act(jnp.einsum("becd,edf->becf", disp, params["w_gate"]), cfg.act)
        h = h * jnp.einsum("becd,edf->becf", disp, params["w_up"])
    else:
        h = _act(jnp.einsum("becd,edf->becf", disp, params["w_up"]), cfg.act)
    out = jnp.einsum("becf,efd->becd", h, params["w_down"])

    # --- gather back, weighted by gates --------------------------------------
    out_flat = out.reshape(b, e * c, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((b, 1, d), out.dtype)], axis=1)
    y_tok = out_flat[batch_ix, dest]  # (B, S*K, D); dropped slots read zeros
    w = jnp.where(overflow, 0.0, gate_vals.reshape(b, s * k)).astype(x.dtype)
    y = (y_tok * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    # --- aux losses -----------------------------------------------------------
    # load-balance: E * sum_e mean_prob_e * frac_routed_e  (Switch, eq. 4)
    frac = sel.astype(jnp.float32).reshape(b, s, k, e).sum(2).mean((0, 1)) / k
    mean_p = probs.mean((0, 1))
    balance = e * jnp.sum(frac * mean_p)
    z = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    aux = cfg.router_aux_weight * balance + cfg.router_z_weight * z
    return y, aux
