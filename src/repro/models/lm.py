"""Model assembly: family-dispatched transformer / SSM / hybrid LMs.

One :class:`LM` object per config provides the five entry points the
launchers need, all pure functions of pytrees:

* ``schema()``        — parameter schema (shapes + logical sharding axes)
* ``loss_fn``         — training loss (causal LM; enc-dec for audio)
* ``prefill_fn``      — prompt pass producing last-token logits + KV/SSM cache
* ``decode_fn``       — one-token serve step against the cache
* ``abstract_cache`` / ``init_cache``

Layer stacks are ``lax.scan``-ed over homogeneous *blocks*; heterogeneous
families (jamba's 1-attn:7-mamba pattern, the VLM's every-5th-cross-attn
pattern) scan over the repeating pattern block, with the sub-layers stacked
inside the block and indexed statically.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamSpec,
    Schema,
    abstract_params,
    cross_entropy_loss,
    init_params,
    logical_axes,
    rms_norm,
    tree_is_spec,
)
from repro.models.config import ModelConfig


def stack_schema(schema: Schema, n: int, axis: str = "layers") -> Schema:
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis,) + s.axes, s.init, s.scale),
        schema,
        is_leaf=tree_is_spec,
    )


def _norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def _zeros_like_abstract(tree):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Perf knobs iterated in EXPERIMENTS.md §Perf (model code is identical;
    these change scheduling/memory behaviour only)."""

    remat: str = "block"  # none | block | dots
    q_chunk: int = 512
    #: lax.scan unroll over layer blocks — only used by the dry-run's
    #: scan-body cost-correction (see DESIGN.md §4).
    scan_unroll: int = 1
    #: "dense" materializes (B, S, V) logits; "chunked" scans the loss over
    #: seq chunks so only (B, loss_chunk, V) is ever live — the §Perf fix
    #: for the 200k/256k-vocab architectures whose logits dominate HBM.
    loss_impl: str = "dense"
    loss_chunk: int = 512
    #: pin the decode KV cache to its (batch, seq-over-model) layout and
    #: replicate the (tiny) query over the model axis — flash-decode-style
    #: sharding that removes the per-step cache all-gather (§Perf ladder).
    decode_constrain: bool = False
    #: mesh axes carrying the batch dim for decode constraints (set by the
    #: launcher from the actual mesh/batch; () = batch replicated).
    decode_dp: tuple = ("data",)
    #: constrain the residual stream to batch-sharded P(dp, None, None) at
    #: every block boundary (and after the embed gather).  Without this,
    #: FSDP's embed-dim param sharding propagates into the activations and
    #: GSPMD replicates the *global batch* through attention (observed:
    #: 64 GiB f32 logits on arctic-480b — EXPERIMENTS.md §Perf iteration 2).
    constrain_acts: bool = False
    act_dp: tuple = ("data",)


# ===========================================================================
# Per-family block definitions
# ===========================================================================


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        fam = cfg.family
        if fam == "hybrid":
            assert cfg.n_layers % cfg.block_len == 0
            self.n_blocks = cfg.n_layers // cfg.block_len
        elif fam == "vlm":
            assert cfg.cross_attn_every > 0
            assert cfg.n_layers % cfg.cross_attn_every == 0
            self.n_blocks = cfg.n_layers // cfg.cross_attn_every
        else:
            self.n_blocks = cfg.n_layers

    # -- schema ---------------------------------------------------------------
    def _block_schema(self) -> Schema:
        cfg = self.cfg
        d = cfg.d_model
        fam = cfg.family
        if fam == "dense":
            return {
                "attn_norm": _norm_spec(d),
                "attn": attn_mod.attn_schema(cfg),
                "mlp_norm": _norm_spec(d),
                "mlp": ffn_mod.mlp_schema(cfg, cfg.d_ff),
            }
        if fam == "moe":
            block: Schema = {
                "attn_norm": _norm_spec(d),
                "attn": attn_mod.attn_schema(cfg),
                "mlp_norm": _norm_spec(d),
                "moe": ffn_mod.moe_schema(cfg),
            }
            if cfg.dense_residual:
                block["mlp"] = ffn_mod.mlp_schema(cfg, cfg.d_ff)
            return block
        if fam == "ssm":
            return {"norm": _norm_spec(d), "ssm": ssm_mod.ssm_schema(cfg)}
        if fam == "hybrid":
            bl = cfg.block_len
            n_ssm = bl - 1
            n_moe = sum(1 for i in range(bl) if i % 2 == 1)
            n_mlp = bl - n_moe
            return {
                "ssm_norm": ParamSpec((n_ssm, d), ("sublayer", "embed"), init="ones"),
                "ssm": stack_schema(ssm_mod.ssm_schema(cfg), n_ssm, "sublayer"),
                "attn_norm": _norm_spec(d),
                "attn": attn_mod.attn_schema(cfg),
                "mlp_norm": ParamSpec((bl, d), ("sublayer", "embed"), init="ones"),
                "mlp": stack_schema(ffn_mod.mlp_schema(cfg, cfg.d_ff), n_mlp, "sublayer"),
                "moe": stack_schema(ffn_mod.moe_schema(cfg), n_moe, "sublayer"),
            }
        if fam == "vlm":
            n_self = cfg.cross_attn_every - 1
            per = cfg.cross_attn_every
            return {
                "self_norm": ParamSpec((n_self, d), ("sublayer", "embed"), init="ones"),
                "self_attn": stack_schema(attn_mod.attn_schema(cfg), n_self, "sublayer"),
                "cross_norm": _norm_spec(d),
                "cross_attn": attn_mod.attn_schema(cfg, cross=True),
                "cross_gate": ParamSpec((1,), (None,), init="zeros"),
                "mlp_norm": ParamSpec((per, d), ("sublayer", "embed"), init="ones"),
                "mlp": stack_schema(ffn_mod.mlp_schema(cfg, cfg.d_ff), per, "sublayer"),
            }
        if fam == "audio":
            return {  # decoder block
                "self_norm": _norm_spec(d),
                "self_attn": attn_mod.attn_schema(cfg),
                "cross_norm": _norm_spec(d),
                "cross_attn": attn_mod.attn_schema(cfg, cross=True),
                "mlp_norm": _norm_spec(d),
                "mlp": ffn_mod.mlp_schema(cfg, cfg.d_ff),
            }
        raise ValueError(fam)

    def _enc_block_schema(self) -> Schema:
        cfg = self.cfg
        return {
            "attn_norm": _norm_spec(cfg.d_model),
            "attn": attn_mod.attn_schema(cfg),
            "mlp_norm": _norm_spec(cfg.d_model),
            "mlp": ffn_mod.mlp_schema(cfg, cfg.d_ff),
        }

    def schema(self) -> Schema:
        cfg = self.cfg
        out: Schema = {
            "embed": ParamSpec(
                (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=1.0
            ),
            "blocks": stack_schema(self._block_schema(), self.n_blocks),
            "final_norm": _norm_spec(cfg.d_model),
        }
        if cfg.family == "audio":
            out["enc_blocks"] = stack_schema(self._enc_block_schema(), cfg.enc_layers)
            out["enc_norm"] = _norm_spec(cfg.d_model)
        return out

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_params(self.schema(), dtype)

    def init(self, key: jax.Array, dtype=jnp.bfloat16):
        return init_params(self.schema(), key, dtype)

    def logical_axes(self):
        return logical_axes(self.schema())

    # =======================================================================
    # Training / prefill block application
    # =======================================================================

    def _apply_block(
        self,
        x: jax.Array,
        bp: Dict[str, Any],
        *,
        mask_kind: str,
        cross_src: Optional[jax.Array],
        flags: RunFlags,
        collect_kv: bool,
    ) -> Tuple[jax.Array, jax.Array, Any]:
        """Returns (x, aux_loss, kv_collection or ssm/conv cache pieces)."""
        cfg = self.cfg
        fam = cfg.family
        aux = jnp.zeros((), jnp.float32)
        kv = None

        def self_attn(x, norm, ap):
            h = rms_norm(x, norm)
            y = attn_mod.attention_forward(
                h, ap, cfg, mask_kind=mask_kind, q_chunk=flags.q_chunk
            )
            out = x + y
            if collect_kv:
                k = jnp.einsum("btd,dgk->btgk", h, ap["wk"])
                k = attn_mod.apply_rope(k, jnp.arange(h.shape[1]), cfg.rope_theta)
                v = jnp.einsum("btd,dgk->btgk", h, ap["wv"])
                return out, {"k": k, "v": v}
            return out, None

        if fam in ("dense", "moe"):
            x, kv = self_attn(x, bp["attn_norm"], bp["attn"])
            h = rms_norm(x, bp["mlp_norm"])
            if fam == "dense":
                x = x + ffn_mod.mlp(h, bp["mlp"], cfg.act)
            else:
                y, a = ffn_mod.moe(h, bp["moe"], cfg)
                if cfg.dense_residual:
                    y = y + ffn_mod.mlp(h, bp["mlp"], cfg.act)
                x = x + y
                aux = aux + a
            return x, aux, kv

        if fam == "ssm":
            h = rms_norm(x, bp["norm"])
            if collect_kv:
                y, cache = ssm_mod_prefill(h, bp["ssm"], cfg)
                kv = cache
            else:
                y = ssm_mod.ssm_forward(h, bp["ssm"], cfg)
            return x + y, aux, kv

        if fam == "hybrid":
            kvs: Dict[str, Any] = {}
            ssm_i = mlp_i = moe_i = 0
            for pos in range(cfg.block_len):
                if pos == cfg.attn_index_in_block:
                    x, akv = self_attn(x, bp["attn_norm"], bp["attn"])
                    if collect_kv:
                        kvs["attn"] = akv
                else:
                    sp = jax.tree.map(lambda a: a[ssm_i], bp["ssm"])
                    h = rms_norm(x, bp["ssm_norm"][ssm_i])
                    if collect_kv:
                        y, sc = ssm_mod_prefill(h, sp, cfg)
                        kvs.setdefault("ssm", []).append(sc)
                    else:
                        y = ssm_mod.ssm_forward(h, sp, cfg)
                    x = x + y
                    ssm_i += 1
                h = rms_norm(x, bp["mlp_norm"][pos])
                if pos % 2 == 1:  # MoE on odd positions
                    mp = jax.tree.map(lambda a: a[moe_i], bp["moe"])
                    y, a = ffn_mod.moe(h, mp, cfg)
                    aux = aux + a
                    moe_i += 1
                else:
                    mp = jax.tree.map(lambda a: a[mlp_i], bp["mlp"])
                    y = ffn_mod.mlp(h, mp, cfg.act)
                    mlp_i += 1
                x = x + y
            if collect_kv and "ssm" in kvs:
                kvs["ssm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs["ssm"])
            return x, aux, (kvs if collect_kv else None)

        if fam == "vlm":
            kvs = {}
            self_kvs = []
            for i in range(cfg.cross_attn_every - 1):
                ap = jax.tree.map(lambda a: a[i], bp["self_attn"])
                x, akv = self_attn(x, bp["self_norm"][i], ap)
                if collect_kv:
                    self_kvs.append(akv)
                h = rms_norm(x, bp["mlp_norm"][i])
                mp = jax.tree.map(lambda a: a[i], bp["mlp"])
                x = x + ffn_mod.mlp(h, mp, cfg.act)
            # cross-attention sub-layer (gated, zero-init gate)
            h = rms_norm(x, bp["cross_norm"])
            y = attn_mod.attention_forward(
                h, bp["cross_attn"], cfg, mask_kind="full", kv_input=cross_src,
                use_rope=False, q_chunk=flags.q_chunk
            )
            x = x + jnp.tanh(bp["cross_gate"]).astype(x.dtype) * y
            i = cfg.cross_attn_every - 1
            h = rms_norm(x, bp["mlp_norm"][i])
            mp = jax.tree.map(lambda a: a[i], bp["mlp"])
            x = x + ffn_mod.mlp(h, mp, cfg.act)
            if collect_kv:
                kvs["self"] = jax.tree.map(lambda *xs: jnp.stack(xs), *self_kvs)
                # cross K/V from the (constant) vision tokens
                ck, cv = attn_mod.precompute_cross_kv(cross_src, bp["cross_attn"])
                kvs["cross"] = {"k": ck, "v": cv}
            return x, aux, (kvs if collect_kv else None)

        if fam == "audio":  # decoder block
            x, akv = self_attn(x, bp["self_norm"], bp["self_attn"])
            h = rms_norm(x, bp["cross_norm"])
            y = attn_mod.attention_forward(
                h, bp["cross_attn"], cfg, mask_kind="full", kv_input=cross_src,
                use_rope=False, q_chunk=flags.q_chunk
            )
            x = x + y
            h = rms_norm(x, bp["mlp_norm"])
            x = x + ffn_mod.mlp(h, bp["mlp"], cfg.act)
            kvs = None
            if collect_kv:
                ck, cv = attn_mod.precompute_cross_kv(cross_src, bp["cross_attn"])
                kvs = {"self": akv, "cross": {"k": ck, "v": cv}}
            return x, aux, kvs

        raise ValueError(fam)

    # -- stacks ---------------------------------------------------------------
    def _run_blocks(
        self,
        x: jax.Array,
        blocks,
        *,
        mask_kind: str,
        cross_src: Optional[jax.Array],
        flags: RunFlags,
        collect_kv: bool = False,
    ):
        def body(carry, bp):
            x, aux = carry
            if flags.constrain_acts:
                from jax.sharding import PartitionSpec as P

                x = jax.lax.with_sharding_constraint(
                    x, P(tuple(flags.act_dp) or None, None, None)
                )
            x2, a, kv = self._apply_block(
                x, bp, mask_kind=mask_kind, cross_src=cross_src,
                flags=flags, collect_kv=collect_kv,
            )
            return (x2, aux + a), kv

        if flags.remat == "block":
            body = jax.checkpoint(body)
        elif flags.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        (x, aux), kvs = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), blocks, unroll=flags.scan_unroll
        )
        return x, aux, kvs

    def _encode(self, params, audio_embeds, flags: RunFlags):
        cfg = self.cfg

        def body(carry, bp):
            x = carry
            h = rms_norm(x, bp["attn_norm"])
            y = attn_mod.attention_forward(
                h, bp["attn"], cfg, mask_kind="full", q_chunk=flags.q_chunk
            )
            x = x + y
            h = rms_norm(x, bp["mlp_norm"])
            x = x + ffn_mod.mlp(h, bp["mlp"], cfg.act)
            return x, None

        if flags.remat in ("block", "dots"):
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(
            body, audio_embeds, params["enc_blocks"], unroll=flags.scan_unroll
        )
        return rms_norm(x, params["enc_norm"])

    def _cross_source(self, params, batch, flags: RunFlags):
        cfg = self.cfg
        if cfg.family == "audio":
            return self._encode(params, batch["audio_embeds"], flags)
        if cfg.family == "vlm":
            return batch["image_embeds"]
        return None

    # =======================================================================
    # Public entry points
    # =======================================================================

    def loss_fn(self, params, batch, flags: RunFlags = RunFlags()):
        """batch: tokens (B,S) int32, labels (B,S) int32
        [+ audio_embeds (B,F,D) | image_embeds (B,V,D)]."""
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if flags.constrain_acts:
            from jax.sharding import PartitionSpec as P

            x = jax.lax.with_sharding_constraint(
                x, P(tuple(flags.act_dp) or None, None, None)
            )
        cross_src = self._cross_source(params, batch, flags)
        mask = "sliding" if cfg.sliding_window else "causal"
        x, aux, _ = self._run_blocks(
            x, params["blocks"], mask_kind=mask, cross_src=cross_src, flags=flags
        )
        x = rms_norm(x, params["final_norm"])
        if flags.loss_impl == "chunked":
            loss = self._chunked_ce(x, params["embed"], batch["labels"], flags)
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
            loss = cross_entropy_loss(logits, batch["labels"], cfg.vocab_size)
        total = loss + aux
        return total, {"ce": loss, "aux": aux}

    def _chunked_ce(self, x, embed, labels, flags: RunFlags):
        """CE scanned over seq chunks: the (B, chunk, V) logits tile is the
        only vocab-sized live tensor (fwd and — via checkpoint — bwd)."""
        cfg = self.cfg
        b, s, d = x.shape
        chunk = min(flags.loss_chunk, s)
        while s % chunk:
            chunk //= 2
        nc = s // chunk
        xr = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        lr = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(acc, inp):
            xc, lc = inp
            logits = jnp.einsum("bsd,vd->bsv", xc, embed).astype(jnp.float32)
            valid = (lc >= 0) & (lc < cfg.vocab_size)
            safe = jnp.where(valid, lc, 0)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            nll_sum, n_valid = acc
            return (
                nll_sum + (((lse - gold) * valid).sum()).astype(jnp.float32),
                n_valid + valid.sum(),
            ), None

        (nll, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xr, lr))
        return nll / jnp.maximum(n, 1)

    # -- caches ------------------------------------------------------------
    def kv_window(self, max_seq: int) -> int:
        cfg = self.cfg
        return min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq

    def abstract_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        w = self.kv_window(max_seq)
        nb = self.n_blocks

        def stack(tree, n):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
            )

        fam = cfg.family
        if fam in ("dense", "moe"):
            layer = attn_mod.abstract_kv_cache(cfg, batch, w, dtype)
        elif fam == "ssm":
            layer = ssm_mod.abstract_ssm_cache(cfg, batch, dtype)
        elif fam == "hybrid":
            layer = {
                "attn": attn_mod.abstract_kv_cache(cfg, batch, w, dtype),
                "ssm": stack(ssm_mod.abstract_ssm_cache(cfg, batch, dtype), cfg.block_len - 1),
            }
        elif fam == "vlm":
            kvh, hd = cfg.n_kv_heads, cfg.head_dim_
            layer = {
                "self": stack(
                    attn_mod.abstract_kv_cache(cfg, batch, w, dtype),
                    cfg.cross_attn_every - 1,
                ),
                "cross": {
                    "k": jax.ShapeDtypeStruct((batch, cfg.vision_tokens, kvh, hd), dtype),
                    "v": jax.ShapeDtypeStruct((batch, cfg.vision_tokens, kvh, hd), dtype),
                },
            }
        elif fam == "audio":
            kvh, hd = cfg.n_kv_heads, cfg.head_dim_
            layer = {
                "self": attn_mod.abstract_kv_cache(cfg, batch, w, dtype),
                "cross": {
                    "k": jax.ShapeDtypeStruct((batch, cfg.audio_frames, kvh, hd), dtype),
                    "v": jax.ShapeDtypeStruct((batch, cfg.audio_frames, kvh, hd), dtype),
                },
            }
        else:
            raise ValueError(fam)
        return {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "layers": stack(layer, nb),
        }

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return _zeros_like_abstract(self.abstract_cache(batch, max_seq, dtype))

    # -- decode -------------------------------------------------------------
    def _decode_block(self, x, bp, bc, pos, flags: RunFlags = RunFlags()):
        cfg = self.cfg
        dc = flags.decode_dp if flags.decode_constrain else None
        fam = cfg.family

        if fam in ("dense", "moe"):
            h = rms_norm(x, bp["attn_norm"])
            y, kv = attn_mod.decode_attention(h, bp["attn"], bc, pos, cfg, constrain=dc)
            x = x + y
            h = rms_norm(x, bp["mlp_norm"])
            if fam == "dense":
                x = x + ffn_mod.mlp(h, bp["mlp"], cfg.act)
            else:
                y, _ = ffn_mod.moe(h, bp["moe"], cfg)
                if cfg.dense_residual:
                    y = y + ffn_mod.mlp(h, bp["mlp"], cfg.act)
                x = x + y
            return x, kv

        if fam == "ssm":
            h = rms_norm(x, bp["norm"])
            y, cache = ssm_mod.ssm_decode_step(h, bp["ssm"], bc, cfg)
            return x + y, cache

        if fam == "hybrid":
            new_cache = {"attn": bc["attn"], "ssm": bc["ssm"]}
            ssm_i = mlp_i = moe_i = 0
            ssm_caches = []
            for p in range(cfg.block_len):
                if p == cfg.attn_index_in_block:
                    h = rms_norm(x, bp["attn_norm"])
                    y, kv = attn_mod.decode_attention(h, bp["attn"], bc["attn"], pos, cfg, constrain=dc)
                    new_cache["attn"] = kv
                    x = x + y
                else:
                    sp = jax.tree.map(lambda a: a[ssm_i], bp["ssm"])
                    sc = jax.tree.map(lambda a: a[ssm_i], bc["ssm"])
                    h = rms_norm(x, bp["ssm_norm"][ssm_i])
                    y, sc2 = ssm_mod.ssm_decode_step(h, sp, sc, cfg)
                    ssm_caches.append(sc2)
                    x = x + y
                    ssm_i += 1
                h = rms_norm(x, bp["mlp_norm"][p])
                if p % 2 == 1:
                    mp = jax.tree.map(lambda a: a[moe_i], bp["moe"])
                    y, _ = ffn_mod.moe(h, mp, cfg)
                    moe_i += 1
                else:
                    mp = jax.tree.map(lambda a: a[mlp_i], bp["mlp"])
                    y = ffn_mod.mlp(h, mp, cfg.act)
                    mlp_i += 1
                x = x + y
            new_cache["ssm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_caches)
            return x, new_cache

        if fam == "vlm":
            new_self = []
            for i in range(cfg.cross_attn_every - 1):
                ap = jax.tree.map(lambda a: a[i], bp["self_attn"])
                sc = jax.tree.map(lambda a: a[i], bc["self"])
                h = rms_norm(x, bp["self_norm"][i])
                y, kv = attn_mod.decode_attention(h, ap, sc, pos, cfg, constrain=dc)
                new_self.append(kv)
                x = x + y
                h = rms_norm(x, bp["mlp_norm"][i])
                mp = jax.tree.map(lambda a: a[i], bp["mlp"])
                x = x + ffn_mod.mlp(h, mp, cfg.act)
            h = rms_norm(x, bp["cross_norm"])
            y = attn_mod.decode_cross_attention(
                h, bp["cross_attn"], bc["cross"]["k"], bc["cross"]["v"], cfg
            )
            x = x + jnp.tanh(bp["cross_gate"]).astype(x.dtype) * y
            i = cfg.cross_attn_every - 1
            h = rms_norm(x, bp["mlp_norm"][i])
            mp = jax.tree.map(lambda a: a[i], bp["mlp"])
            x = x + ffn_mod.mlp(h, mp, cfg.act)
            cache = {
                "self": jax.tree.map(lambda *xs: jnp.stack(xs), *new_self),
                "cross": bc["cross"],
            }
            return x, cache

        if fam == "audio":
            h = rms_norm(x, bp["self_norm"])
            y, kv = attn_mod.decode_attention(h, bp["self_attn"], bc["self"], pos, cfg, constrain=dc)
            x = x + y
            h = rms_norm(x, bp["cross_norm"])
            y = attn_mod.decode_cross_attention(
                h, bp["cross_attn"], bc["cross"]["k"], bc["cross"]["v"], cfg
            )
            x = x + y
            h = rms_norm(x, bp["mlp_norm"])
            x = x + ffn_mod.mlp(h, bp["mlp"], cfg.act)
            return x, {"self": kv, "cross": bc["cross"]}

        raise ValueError(fam)

    def decode_fn(self, params, cache, token, flags: RunFlags = RunFlags()):
        """One serve step.  token: (B, 1) int32 -> (logits (B, vocab), cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][token]

        def body(x, scanned):
            bp, bc = scanned
            x2, nc = self._decode_block(x, bp, bc, pos, flags)
            return x2, nc

        x, new_layers = jax.lax.scan(
            body, x, (params["blocks"], cache["layers"]), unroll=flags.scan_unroll
        )
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0, : cfg.vocab_size]
        return logits, {"pos": pos + 1, "layers": new_layers}

    # -- prefill -------------------------------------------------------------
    def prefill_fn(self, params, batch, max_seq: int, flags: RunFlags = RunFlags()):
        """Prompt pass: batch["tokens"] (B,S) -> (last-token logits, cache).

        The returned cache is laid out exactly as ``init_cache(B, max_seq)``
        so ``decode_fn`` can continue from position S.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        w = self.kv_window(max_seq)
        x = params["embed"][tokens]
        cross_src = self._cross_source(params, batch, flags)
        mask = "sliding" if cfg.sliding_window else "causal"
        x, _, kvs = self._run_blocks(
            x, params["blocks"], mask_kind=mask, cross_src=cross_src,
            flags=flags, collect_kv=True,
        )
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"])[:, : cfg.vocab_size]
        cache = {"pos": jnp.asarray(s, jnp.int32), "layers": self._pack_cache(kvs, s, w, max_seq)}
        return logits, cache

    def _ring_pack(self, k: jax.Array, s: int, w: int) -> jax.Array:
        """Place the last w of s keys into ring-buffer slots (slot = pos % w)."""
        if s <= w:
            pad = [(0, 0), (0, w - s)] + [(0, 0)] * (k.ndim - 2)
            return jnp.pad(k, pad)
        last = k[:, s - w :]
        positions = np.arange(s - w, s)
        slots = positions % w
        inv = np.empty(w, dtype=np.int32)
        inv[slots] = np.arange(w)
        return last[:, inv]

    def _pack_cache(self, kvs, s: int, w: int, max_seq: int):
        cfg = self.cfg
        fam = cfg.family

        def pack_kv(kv):
            return {
                "k": self._ring_pack(kv["k"], s, w),
                "v": self._ring_pack(kv["v"], s, w),
            }

        if fam in ("dense", "moe"):
            return {
                "k": self._ring_pack_stacked(kvs["k"], s, w),
                "v": self._ring_pack_stacked(kvs["v"], s, w),
            }
        if fam == "ssm":
            return kvs  # stacked ssm caches from prefill
        if fam == "hybrid":
            return {
                "attn": {
                    "k": self._ring_pack_stacked(kvs["attn"]["k"], s, w),
                    "v": self._ring_pack_stacked(kvs["attn"]["v"], s, w),
                },
                "ssm": kvs["ssm"],
            }
        if fam == "vlm":
            return {
                "self": {
                    "k": self._ring_pack_stacked(kvs["self"]["k"], s, w, extra_lead=1),
                    "v": self._ring_pack_stacked(kvs["self"]["v"], s, w, extra_lead=1),
                },
                "cross": kvs["cross"],
            }
        if fam == "audio":
            return {
                "self": {
                    "k": self._ring_pack_stacked(kvs["self"]["k"], s, w),
                    "v": self._ring_pack_stacked(kvs["self"]["v"], s, w),
                },
                "cross": kvs["cross"],
            }
        raise ValueError(fam)

    def _ring_pack_stacked(self, k: jax.Array, s: int, w: int, extra_lead: int = 0):
        """k: (L[, sub], B, S, KV, hd) stacked over scan outputs."""
        lead = 1 + extra_lead
        flat = k.reshape((-1,) + k.shape[lead:])
        packed = jax.vmap(lambda kk: self._ring_pack(kk, s, w))(flat)
        return packed.reshape(k.shape[:lead] + packed.shape[1:])


def ssm_mod_prefill(h, params, cfg):
    """SSM forward that also returns the decode cache (conv + state)."""
    bsz, s, _ = h.shape
    nh, p = cfg.ssm_n_heads, cfg.ssm_head_dim
    wd = cfg.ssm_conv_width

    z = h @ params["w_z"]
    xs_pre = h @ params["w_x"]
    bp_pre = h @ params["w_B"]
    cp_pre = h @ params["w_C"]
    dt = jax.nn.softplus((h @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))

    xs = jax.nn.silu(ssm_mod.causal_conv(xs_pre, params["conv_x"], params["conv_bias_x"]))
    bp = jax.nn.silu(ssm_mod.causal_conv(bp_pre, params["conv_B"], params["conv_bias_B"]))
    cp = jax.nn.silu(ssm_mod.causal_conv(cp_pre, params["conv_C"], params["conv_bias_C"]))

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(bsz, s, nh, p)
    y, h_final = ssm_mod.ssd_scan(
        xh, dt.astype(xs.dtype), a, bp, cp,
        chunk=ssm_mod.pick_chunk(s, cfg.ssm_chunk),
    )
    y = y + xh * params["D"].astype(h.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, nh * p)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out_proj"]

    def last_w(pre):  # (B, S, C) -> (B, C, wd-1) last pre-conv inputs
        tail = pre[:, s - (wd - 1) :, :] if s >= wd - 1 else jnp.pad(
            pre, ((0, 0), (wd - 1 - s, 0), (0, 0))
        )
        return tail.transpose(0, 2, 1)

    cache = {
        "conv_x": last_w(xs_pre),
        "conv_B": last_w(bp_pre),
        "conv_C": last_w(cp_pre),
        "state": h_final,
    }
    return out, cache
