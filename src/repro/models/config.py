"""Unified model configuration for the assigned architecture pool.

One frozen dataclass covers all six families (dense / moe / ssm / hybrid /
audio enc-dec / vlm).  Family-specific fields are zero/empty when unused.
Each ``src/repro/configs/<id>.py`` instantiates exactly one of these with
the assigned hyper-parameters and a source citation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")

#: Pad vocab (and arctic's q-heads) so the 16-way model axis divides them.
VOCAB_PAD_MULTIPLE = 2048


def pad_to(x: int, multiple: int) -> int:
    return int(math.ceil(x / multiple) * multiple)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    vocab_size: int
    # -- attention ----------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full attention
    # -- mlp ----------------------------------------------------------------
    d_ff: int = 0
    act: str = "swiglu"          # swiglu | geglu | gelu (plain 2-matrix MLP)
    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # 0 -> d_ff
    moe_every: int = 1           # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # -- SSM (Mamba-2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # -- hybrid (jamba): layers per scanned block and attention position ------
    block_len: int = 0           # 0 -> homogeneous layers
    attn_index_in_block: int = -1
    # -- enc-dec (audio backbone) ---------------------------------------------
    enc_layers: int = 0
    audio_frames: int = 3000     # stub frontend output length (~60 s @ 50 Hz)
    # -- vlm ------------------------------------------------------------------
    cross_attn_every: int = 0    # every Nth layer is cross-attn (1-indexed pos N)
    vision_tokens: int = 0       # stub vision encoder output length
    # -- sharding / padding ----------------------------------------------------
    padded_heads: int = 0        # pad q heads for TP divisibility (arctic)
    # -- bookkeeping -------------------------------------------------------------
    source: str = ""
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads <= 0:
            raise ValueError(f"{self.name}: attention families need n_heads")

    # -- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def q_heads_padded(self) -> int:
        return self.padded_heads or self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def moe_d_ff_(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer_idx % self.moe_every == self.moe_offset

    @property
    def decoder_layers(self) -> int:
        """Layers of the (causal) decoder stack; == n_layers except enc-dec."""
        return self.n_layers

    # -- analytic parameter / flop model (for the scheduler's job table and
    #    the MODEL_FLOPS/HLO_FLOPs roofline ratio) ---------------------------
    def param_count(self, padded: bool = False) -> int:
        """Total parameter count (analytic; excludes padding unless asked)."""
        d = self.d_model
        vocab = self.padded_vocab if padded else self.vocab_size
        total = vocab * d  # tied embedding/lm-head
        total += sum(self._layer_params(i, padded) for i in range(self.n_layers))
        if self.enc_layers:
            total += self.enc_layers * self._enc_layer_params(padded)
        total += self.n_layers * 2 * d  # norms (approx: 2 per layer)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        d = self.d_model
        total = self.vocab_size * d
        for i in range(self.n_layers):
            total += self._layer_params(i, False, active_only=True)
        if self.enc_layers:
            total += self.enc_layers * self._enc_layer_params(False)
        total += self.n_layers * 2 * d
        return total

    def _attn_params(self, padded: bool) -> int:
        h = self.q_heads_padded if padded else self.n_heads
        hd = self.head_dim_
        d = self.d_model
        return d * h * hd + 2 * d * self.n_kv_heads * hd + h * hd * d

    def _mlp_params(self, d_ff: int) -> int:
        n_mat = 3 if self.act in ("swiglu", "geglu") else 2
        return n_mat * self.d_model * d_ff

    def _ssm_params(self) -> int:
        d, di, n = self.d_model, self.ssm_d_inner, self.ssm_state
        h = self.ssm_n_heads
        conv_dim = di + 2 * n
        in_proj = d * (2 * di + 2 * n + h)  # z, x, B, C, dt
        return in_proj + conv_dim * self.ssm_conv_width + di * d + 2 * h

    def _layer_params(self, i: int, padded: bool, active_only: bool = False) -> int:
        if self.family == "ssm":
            return self._ssm_params()
        if self.family == "hybrid":
            pos = i % self.block_len
            mixer = (
                self._attn_params(padded)
                if pos == self.attn_index_in_block
                else self._ssm_params()
            )
            if self.is_moe_layer(i):
                n_exp = self.experts_per_token if active_only else self.n_experts
                mlp = n_exp * self._mlp_params(self.moe_d_ff_) + self.d_model * self.n_experts
            else:
                mlp = self._mlp_params(self.d_ff)
            return mixer + mlp
        mixer = self._attn_params(padded)
        if self.family == "audio":
            mixer += self._attn_params(padded)  # decoder blocks add cross-attn
        if self.family == "vlm" and self.cross_attn_every and (i + 1) % self.cross_attn_every == 0:
            mixer += self._attn_params(padded)  # cross-attn has its own qkv/o
        if self.is_moe_layer(i):
            n_exp = self.experts_per_token if active_only else self.n_experts
            mlp = n_exp * self._mlp_params(self.moe_d_ff_) + self.d_model * self.n_experts
            if self.dense_residual:
                mlp += self._mlp_params(self.d_ff)
        else:
            mlp = self._mlp_params(self.d_ff)
        return mixer + mlp

    def _enc_layer_params(self, padded: bool) -> int:
        return self._attn_params(padded) + self._mlp_params(self.d_ff)

    def train_flops_per_token(self) -> float:
        """6 * N_active per token (dense fwd+bwd matmul estimate)."""
        return 6.0 * self.active_param_count()


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
