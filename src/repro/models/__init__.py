"""Model substrate: configs, layers, and family-dispatched LMs.

``LM``/``RunFlags`` resolve lazily (PEP 562): importing the analytic
config layer (``repro.models.config``, pure dataclasses — consumed by the
jax-free event-simulator path via ``repro.workloads``) must not pull in
the jax-backed layer modules.
"""

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "LM", "RunFlags"]


def __getattr__(name):
    if name in ("LM", "RunFlags"):
        from repro.models import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
