"""Model substrate: configs, layers, and family-dispatched LMs."""

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.lm import LM, RunFlags

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "LM", "RunFlags"]
