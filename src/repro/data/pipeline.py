"""Deterministic synthetic LM data pipeline with sharded device placement.

Synthetic data is the right substrate here: the paper's contribution is
scheduling, and its workloads are characterized purely by (t_f, t_b, sigma)
— token *values* never matter.  The pipeline still exercises the real
mechanics a production loader needs: deterministic seeding & resumption
(step -> batch is a pure function), host-side prefetch, per-shape stub
modality embeddings, and NamedSharding device placement.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    """step -> batch pure function (Zipf-ish unigram tokens + shifted labels)."""

    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        # Zipf-like unigram distribution over the vocab (more realistic
        # logits/loss trajectories than uniform).
        v = self.cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.uint64(self.seed * 1_000_003 + step))
        seq = rng.choice(
            self.cfg.vocab_size, size=(self.batch, self.seq_len + 1), p=self._probs
        ).astype(np.int32)
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if self.cfg.family == "audio":
            out["audio_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.audio_frames, self.cfg.d_model), dtype=np.float32
            ).astype(np.dtype("bfloat16") if hasattr(np, "bfloat16") else np.float32)
        if self.cfg.family == "vlm":
            out["image_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.vision_tokens, self.cfg.d_model), dtype=np.float32
            )
        return out


def make_train_iterator(
    ds: SyntheticLMDataset,
    start_step: int = 0,
    shardings: Optional[Dict[str, Any]] = None,
    prefetch: int = 2,
) -> Iterator[Dict[str, jax.Array]]:
    """Host-thread prefetching iterator; resumable via ``start_step``."""

    def produce(step: int):
        batch = ds.batch_at(step)
        if shardings:
            return {
                k: jax.device_put(v, shardings[k]) if k in shardings else jax.device_put(v)
                for k, v in batch.items()
            }
        return {k: jax.device_put(v) for k, v in batch.items()}

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(produce(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
