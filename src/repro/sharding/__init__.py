from repro.sharding.rules import (
    ShardingStrategy,
    batch_spec_axes,
    cache_shardings,
    embeds_sharding,
    moment_shardings,
    param_shardings,
    replicated,
    spec_for_param,
    token_sharding,
)

__all__ = [
    "ShardingStrategy",
    "batch_spec_axes",
    "cache_shardings",
    "embeds_sharding",
    "moment_shardings",
    "param_shardings",
    "replicated",
    "spec_for_param",
    "token_sharding",
]
