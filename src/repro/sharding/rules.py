"""Logical-axis -> mesh-axis sharding rules with divisibility guards.

Every parameter leaf carries logical axis names (from the model schema);
this module maps them to mesh axes per *strategy*:

* ``tp``    — megatron-style tensor parallel: heads/ffn/experts/vocab over
              "model", params replicated across "data"/"pod".
* ``fsdp``  — tp + the "embed" axis sharded over ("data",) (and "pod" on the
              multi-pod mesh): ZeRO-3-style weight sharding for the largest
              models.
* ``zero1`` — tp for params, but optimizer moments additionally sharded over
              the data axis (ZeRO-1).
* ``dp``    — everything replicated (tiny models: pure data parallel).

A mesh axis is only used when it exactly divides the dimension — otherwise
it is dropped (e.g. yi-9b's 4 kv-heads stay replicated on a 16-way model
axis).  This guard is what lets one rule table serve all 10 architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axes that shard over the tensor-parallel ("model") mesh axis.
_TP_AXES = (
    "vocab",
    "ffn",
    "q_heads",
    "kv_heads",
    "experts",
    "ssm_inner",
    "ssm_heads",
)


@dataclasses.dataclass(frozen=True)
class ShardingStrategy:
    name: str = "tp"           # tp | fsdp | zero1 | dp
    fsdp_axis: str = "embed"   # logical axis sharded over data under fsdp
    #: shard moments over data even when params are replicated over data
    zero1: bool = False

    @classmethod
    def from_name(cls, name: str) -> "ShardingStrategy":
        if name == "zero1":
            return cls(name="zero1", zero1=True)
        return cls(name=name)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for_param(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    strategy: ShardingStrategy,
) -> P:
    """PartitionSpec for one parameter from its logical axes."""
    entries = []
    used: set = set()  # a mesh axis may appear at most once per spec
    for dim, name in zip(shape, logical):
        assigned = None
        if strategy.name == "dp":
            entries.append(None)
            continue
        if (
            name in _TP_AXES
            and "model" not in used
            and dim % mesh.shape["model"] == 0
        ):
            assigned = "model"
        elif strategy.name == "fsdp" and name == strategy.fsdp_axis:
            da = _data_axes(mesh)
            if da and not used.intersection(da) and dim % _axis_size(mesh, da) == 0:
                assigned = da if len(da) > 1 else da[0]
        if assigned is not None:
            used.update([assigned] if isinstance(assigned, str) else assigned)
        entries.append(assigned)
    return P(*entries)


def param_shardings(
    axes_tree, abstract_tree, mesh: Mesh, strategy: ShardingStrategy
):
    """NamedSharding pytree for the whole parameter tree."""
    return jax.tree.map(
        lambda ax, sds: NamedSharding(
            mesh, spec_for_param(ax, sds.shape, mesh, strategy)
        ),
        axes_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def moment_shardings(param_shardings_tree, abstract_tree, mesh: Mesh, strategy: ShardingStrategy):
    """Optimizer-moment shardings.  Under zero1, add the data axis to the
    first dimension that is unsharded and divisible (ZeRO-1 partitioning)."""
    if not strategy.zero1:
        return param_shardings_tree

    da = _data_axes(mesh)
    dsz = _axis_size(mesh, da)

    def one(ns: NamedSharding, sds) -> NamedSharding:
        spec = list(ns.spec) + [None] * (len(sds.shape) - len(ns.spec))
        for i, (cur, dim) in enumerate(zip(spec, sds.shape)):
            if cur is None and dim % dsz == 0 and dim >= dsz:
                spec[i] = da if len(da) > 1 else da[0]
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, param_shardings_tree, abstract_tree)


# ---------------------------------------------------------------------------
# Activations / inputs / caches
# ---------------------------------------------------------------------------


def batch_spec_axes(
    mesh: Mesh, batch: int, include_model: bool = False
) -> Optional[Tuple[str, ...]]:
    """Largest suffix-trimmed tuple of ("pod","data"[,"model"]) dividing the
    global batch.  ``include_model`` lets tiny replicated models (mamba2-130m)
    spread the batch over the whole mesh."""
    da = _data_axes(mesh) + (("model",) if include_model else ())
    while da and batch % _axis_size(mesh, da) != 0:
        da = da[:-1]
    return da or None


def token_sharding(mesh: Mesh, batch: int, include_model: bool = False) -> NamedSharding:
    return NamedSharding(mesh, P(batch_spec_axes(mesh, batch, include_model), None))


def embeds_sharding(mesh: Mesh, batch: int, include_model: bool = False) -> NamedSharding:
    """(B, T, D) stub-embedding inputs (audio frames / vision patches)."""
    return NamedSharding(mesh, P(batch_spec_axes(mesh, batch, include_model), None, None))


def cache_sharding(
    path: Tuple[str, ...],
    shape: Sequence[int],
    mesh: Mesh,
    batch: int,
    cfg,
    mode: str = "auto",
) -> NamedSharding:
    """Decode-cache leaf sharding, keyed on the leaf's path in the cache tree.

    KV caches ``(layers, [sub,] B, W, KV, hd)``: batch over the data axes,
    then kv-heads over "model" when divisible, otherwise the sequence (W)
    dim when divisible (long-context caches), otherwise replicated.
    SSM states ``(layers, [sub,] B, H, P, N)``: batch over data, heads over
    "model" when divisible.
    """
    names = [str(p) for p in path]
    ba = batch_spec_axes(mesh, batch)
    msz = mesh.shape["model"]

    if "pos" in names[-1:]:
        return NamedSharding(mesh, P())

    spec: list = [None] * len(shape)
    # find the batch dim: first dim equal to `batch` after the leading stack dims
    try:
        bdim = list(shape).index(batch)
    except ValueError:
        bdim = None
    if bdim is not None and ba is not None:
        spec[bdim] = ba if len(ba) > 1 else ba[0]

    leaf = names[-1]
    if leaf in ("k", "v"):
        kv_dim, w_dim = len(shape) - 2, len(shape) - 3
        if mode == "batch":
            pass  # batch-only: replicate over the model axis
        elif mode == "seq":
            if shape[w_dim] % msz == 0:
                spec[w_dim] = "model"
        elif shape[kv_dim] % msz == 0:
            spec[kv_dim] = "model"
        elif shape[w_dim] % msz == 0:
            spec[w_dim] = "model"
    elif leaf == "state":
        h_dim = len(shape) - 3
        if shape[h_dim] % msz == 0:
            spec[h_dim] = "model"
    elif leaf.startswith("conv"):
        c_dim = len(shape) - 2
        if shape[c_dim] % msz == 0:
            spec[c_dim] = "model"
    return NamedSharding(mesh, P(*spec))


def cache_shardings(abstract_cache, mesh: Mesh, batch: int, cfg, mode: str = "auto"):
    return jax.tree_util.tree_map_with_path(
        lambda path, sds: cache_sharding(
            tuple(getattr(p, "key", getattr(p, "idx", "")) for p in path),
            sds.shape,
            mesh,
            batch,
            cfg,
            mode=mode,
        ),
        abstract_cache,
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
