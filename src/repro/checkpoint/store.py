"""Checkpointing: flat-key npz store with atomic writes and step indexing.

Arrays are gathered to host (fully addressable on this CPU runtime; on a
real multi-host pod this layer would hand per-shard arrays to a
per-process store — the flat-key format is already shard-friendly since
every leaf is one entry).  bfloat16 leaves are stored as uint16 views with
a dtype sidecar, since npz has no native bf16.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        if arr.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[k] = arr
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    meta = {"step": step, "dtypes": dtypes, "extra": extra or {}}
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(json.dumps(meta).encode(), np.uint8), **arrays)
    os.replace(tmp, path)  # atomic publish
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(f[len("step_") : -len(".npz")])
        for f in os.listdir(ckpt_dir)
        if f.startswith("step_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree, step: Optional[int] = None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``target_tree`` (shapes must match).
    Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with np.load(os.path.join(ckpt_dir, f"step_{step:08d}.npz")) as data:
        meta = json.loads(bytes(data["__meta__"]).decode())
        flat_target = _flatten(target_tree)
        restored = {}
        for k, ref in flat_target.items():
            arr = data[k]
            if meta["dtypes"].get(k) == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {ref.shape}")
            restored[k] = jnp.asarray(arr)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in leaves_paths
    ]
    tree = jax.tree_util.tree_unflatten(treedef, [restored[k] for k in keys])
    return tree, meta["step"], meta["extra"]
