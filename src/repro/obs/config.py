"""Observability configuration (the chaos ``active`` pattern).

``EventEngine`` keeps its recorder only when ``observe is not None and
observe.active`` — with all four channels off (or no config at all) every
observability hook stays cold and the engine is bit-exact with the
pre-observability build, at zero overhead.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """What to record during a run.

    Channels (each independently switchable):

    * ``decompose`` — per-job JCT decomposition (queue wait / compute /
      serial comm / contention stretch / gating wait / preemption-fault
      overhead), integrated exactly from the engine's piecewise-rate
      comm windows.  The cheapest channel: O(active comm) per window.
    * ``timelines`` — per-contention-domain time series of the active
      transfer count ``k`` (one sample per domain-load change).
    * ``audit`` — the gating decision log: every AdaDUAL / SRSF(n) /
      k-way accept *and reject* with the evaluated terms
      (``CommPolicy.explain``), domain state, and queue position.
    * ``spans`` — compute / comm / gating-wait span records, the input
      of the Chrome trace-event (Perfetto) exporter.  Unlike
      ``record_trace=True`` this does NOT unfuse f+b, so the event
      stream is unchanged (fused runs show one ``fb`` span).

    The ``*_cap`` bounds keep a 100k-job replay from holding an unbounded
    log; entries past a cap are counted (``ObsReport.*_dropped``), never
    silently discarded.
    """

    decompose: bool = True
    timelines: bool = False
    audit: bool = False
    spans: bool = False
    audit_cap: int = 200_000
    timeline_cap: int = 500_000
    span_cap: int = 500_000

    @property
    def active(self) -> bool:
        return self.decompose or self.timelines or self.audit or self.spans

    @classmethod
    def full(cls, **kw) -> "ObsConfig":
        """Everything on — what ``benchmarks/run.py --trace-out`` and the
        overhead guard test use."""
        return cls(decompose=True, timelines=True, audit=True, spans=True, **kw)
