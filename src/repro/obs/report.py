"""Decomposition-driven analysis of the repo's open findings.

Aggregates like avg JCT can *detect* that one gating policy beats another
on a cell; the JCT decomposition (``repro.obs.recorder``) says *why* — it
splits every job's completion time into queue wait, compute, serial comm,
contention stretch, gating wait and preemption/fault overhead, so two
policies on the same workload differ only in the buckets their mechanisms
touch.  This module runs the observed A/B and prints the side-by-side
mean-parts table plus a one-line verdict naming the dominant component.

Two regression-locked findings ship with explainers (their tables are
recorded in ``docs/observability.md``):

* :func:`explain_recovery_storm` — PR 6's seed-2 inversion: the recovery
  storm flips Ada-SRSF from winning to losing against ungated SRSF(2).
* :func:`explain_fusion_sweep` — PR 4's regime shift: fine-grained WFBP
  bucketing erases AdaDUAL's edge over exclusive-link SRSF(1).

Run both from the CLI::

    PYTHONPATH=src python -m repro.obs.report

This module imports the scenario registry, so it is intentionally NOT
re-exported from ``repro.obs`` (the engine imports ``repro.obs.recorder``;
pulling scenarios in at that level would be an import cycle).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.obs.config import ObsConfig

#: mean-parts table rows, in print order
_PART_KEYS = (
    "jct",
    "queue_wait",
    "compute",
    "comm_serial",
    "comm_stretch",
    "gating_wait",
    "overhead_pf",
)


def observed_run(scenario, comm: str, **sim_kw):
    """One event-backend run of ``scenario`` with the JCT decomposition
    armed; returns the :class:`~repro.obs.recorder.ObsReport`."""
    from repro.scenarios import run_scenario_event

    sim_kw.setdefault("observe", ObsConfig(decompose=True))
    return run_scenario_event(scenario, comm=comm, **sim_kw).obs


def mean_parts_table(
    columns: Dict[str, Dict[str, float]], title: str = ""
) -> str:
    """Markdown table of mean decomposition seconds, one column per run
    label (each value dict comes from ``ObsReport.mean_parts()``)."""
    labels = list(columns)
    lines = []
    if title:
        lines.append(title)
    lines.append("| component | " + " | ".join(labels) + " |")
    lines.append("|---|" + "---|" * len(labels))
    for key in _PART_KEYS:
        row = [f"{columns[lb].get(key, float('nan')):10.2f}" for lb in labels]
        lines.append(f"| {key} | " + " | ".join(row) + " |")
    return "\n".join(lines)


def dominant_component(
    parts_a: Dict[str, float], parts_b: Dict[str, float]
) -> Tuple[str, float]:
    """The decomposition bucket with the largest absolute mean-seconds gap
    between two runs (JCT itself excluded) and that gap (A minus B)."""
    best, gap = "", 0.0
    for key in _PART_KEYS[1:]:
        d = parts_a.get(key, 0.0) - parts_b.get(key, 0.0)
        if abs(d) > abs(gap):
            best, gap = key, d
    return best, gap


def compare_comms(
    scenario,
    comms: Sequence[str] = ("ada", "srsf2"),
    **sim_kw,
) -> Dict[str, Dict[str, float]]:
    """Mean decomposition parts of ``scenario`` under each gating policy."""
    return {
        comm: observed_run(scenario, comm, **sim_kw).mean_parts()
        for comm in comms
    }


def explain_recovery_storm(seed: int = 2, out=print) -> Dict[str, object]:
    """Decompose PR 6's recovery-storm finding on one seed.

    Runs ``chaos_recovery_storm`` under Ada-SRSF and SRSF(2), with the
    storm and fault-free (``chaos=None``), and names the component whose
    swing produces the avg-JCT ordering.  Seed 2 is the locked inversion
    (gating loses under the storm); seed 11 the locked amplification.
    """
    import dataclasses

    from repro.scenarios import get_scenario

    storm = get_scenario("chaos_recovery_storm", seed=seed)
    clean = dataclasses.replace(storm, chaos=None)
    cols = {
        "ada (storm)": observed_run(storm, "ada").mean_parts(),
        "srsf2 (storm)": observed_run(storm, "srsf2").mean_parts(),
        "ada (clean)": observed_run(clean, "ada").mean_parts(),
        "srsf2 (clean)": observed_run(clean, "srsf2").mean_parts(),
    }
    out(
        mean_parts_table(
            cols,
            title=(
                f"chaos_recovery_storm seed={seed}: mean JCT decomposition "
                "(seconds/job)"
            ),
        )
    )
    comp, gap = dominant_component(cols["ada (storm)"], cols["srsf2 (storm)"])
    ratio = cols["ada (storm)"]["jct"] / cols["srsf2 (storm)"]["jct"]
    ratio_clean = cols["ada (clean)"]["jct"] / cols["srsf2 (clean)"]["jct"]
    out(
        f"\nada/srsf2 avg-JCT ratio: storm {ratio:.3f}, fault-free "
        f"{ratio_clean:.3f}."
    )
    out(
        f"Dominant component under the storm: {comp} "
        f"({gap:+.2f} s/job, ada minus srsf2)."
    )
    return {"columns": cols, "dominant": comp, "gap_s": gap, "ratio": ratio}


def explain_fusion_sweep(seed: int = 1, out=print) -> Dict[str, object]:
    """Decompose PR 4's fine-fusion finding.

    On ``fusion_sweep`` compares Ada-SRSF against exclusive-link SRSF(1)
    at the cell's finite fusion threshold and fully-unfused
    (``fusion='none'``), showing which bucket absorbs AdaDUAL's edge when
    transfers become fine-grained.
    """
    import dataclasses

    from repro.scenarios import QUICK_OVERRIDES, get_scenario

    base = get_scenario(
        "fusion_sweep", seed=seed, **QUICK_OVERRIDES["fusion_sweep"]
    )
    none = dataclasses.replace(base, fusion="none")
    cols = {
        "ada (fused)": observed_run(base, "ada").mean_parts(),
        "srsf1 (fused)": observed_run(base, "srsf1").mean_parts(),
        "ada (unfused)": observed_run(none, "ada").mean_parts(),
        "srsf1 (unfused)": observed_run(none, "srsf1").mean_parts(),
    }
    out(
        mean_parts_table(
            cols,
            title=(
                f"fusion_sweep seed={seed}: mean JCT decomposition "
                "(seconds/job)"
            ),
        )
    )
    comp, gap = dominant_component(cols["ada (fused)"], cols["srsf1 (fused)"])
    ratio = cols["ada (fused)"]["jct"] / cols["srsf1 (fused)"]["jct"]
    out(f"\nada/srsf1 avg-JCT ratio at the finite threshold: {ratio:.3f}.")
    out(
        f"Dominant component at the finite threshold: {comp} "
        f"({gap:+.2f} s/job, ada minus srsf1)."
    )
    return {"columns": cols, "dominant": comp, "gap_s": gap, "ratio": ratio}


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--finding",
        choices=("recovery_storm", "fusion_sweep", "all"),
        default="all",
    )
    ap.add_argument("--seed", type=int, default=None)
    ns = ap.parse_args(argv)
    if ns.finding in ("recovery_storm", "all"):
        explain_recovery_storm(seed=2 if ns.seed is None else ns.seed)
        print()
    if ns.finding in ("fusion_sweep", "all"):
        explain_fusion_sweep(seed=1 if ns.seed is None else ns.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
