"""Engine-facing observability recorder and the report it produces.

The recorder is pure bookkeeping: it never mutates engine state, so a run
with observability on is bit-exact with the same run observed-off (locked
in ``tests/test_obs.py``).

Hot-path design — **record raw, analyze lazily**.  Every high-frequency
hook (piecewise-rate comm windows, transfer start/end/abort, gating
enqueue/dequeue, audit entries, compute spans) is a ``list.extend`` of a
few scalars onto one flat append-only log (see the record-tag table
below); no dict lookups, float math, dataclass construction or policy
``explain`` calls happen while the engine runs.
All processing — the per-job ledgers, the domain timelines, the Perfetto
spans, the :class:`GateDecision` audit — is a deterministic replay of
that log, run the first time a :class:`ObsReport` field is read (i.e.
after ``SimResult`` is returned, outside any timed region).  This is what
keeps full observability under the <3 % events/sec overhead budget
asserted by the benchmark guard.  Memory stays bounded on huge replays:
when the raw log exceeds a flush threshold it is folded into the replay
state incrementally (amortized O(1) per record).

The JCT decomposition is an *exact wall-clock partition* of each finished
job's lifetime.  Every second between arrival and finish lands in exactly
one bucket:

* ``queue_wait``    — arrival to first placement (the paper's queueing
  delay, unchanged).
* ``gating_wait``   — time the job's comm stream sat in the gating queue
  (barrier reached / WFBP bucket ready, transfer not yet admitted).
  Under WFBP a gated bucket may overlap the remaining backward pass; the
  gating/comm attribution takes priority and ``compute`` is the residual
  (documented in docs/observability.md).
* ``comm_serial``   — the part of in-flight comm time the job would have
  paid at the *uncontended* Eq. 5 rate: per piecewise-constant-rate
  window, the latency slice plus ``drain_dt * rate(k)/rate(1)``.
* ``comm_stretch``  — the contention stretch: ``drain_dt * (1 -
  rate(k)/rate(1))``.  Serial + stretch sum to the window's wall time
  exactly, so comm attribution inherits the integrator's exactness.
* ``overhead_pf``   — preemption/fault overhead: requeue time after a
  teardown, checkpoint-restore penalties, and comm time of transfers
  that were aborted mid-flight (reattributed out of serial/stretch —
  that bandwidth was spent but delivered nothing).
* ``compute``       — the residual placed time: forward/backward work,
  intra-iteration GPU time-sharing waits, and WFBP backward overlapped
  with comm.

``compute`` being the residual makes the closure ``sum(parts) == jct``
hold to float addition error (< 1e-6 relative, asserted across the
regression grid); the replay additionally tracks enough state that each
part is individually nonnegative.

The replay reproduces the engine's latency handling bit-for-bit: a
transfer's start record carries its ``latency_left`` (the Eq. 5 ``a``
term), and each window consumes ``min(lat_left, dt)`` of it exactly as
``EventEngine._advance_comm`` does.  ``b`` and ``eta`` are captured at
engine construction — NIC chaos only rewrites ``server_bandwidth``, and
``bandwidth_scale`` cancels out of ``rate(k)/rate(1)`` anyway (degraded
NICs slow the uncontended baseline too, so NIC-fault slowdown lands in
``comm_serial``, not stretch).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

#: column order of ``ObsReport.decomposition_csv`` rows
DECOMP_CSV_FIELDS = (
    "job_id",
    "jct",
    "queue_wait",
    "compute",
    "comm_serial",
    "comm_stretch",
    "gating_wait",
    "overhead_pf",
    "stretch_frac",
    "gating_frac",
    "n_preempts",
    "lost_samples",
)

# Raw-log record tags.  The log is ONE FLAT list of scalars (plus interned
# strings and pre-existing frozenset/str refs): each record is a fixed- or
# counted-stride run of elements starting with its tag, appended atomically
# via a single ``list.extend`` per part.  Flat scalars are the point — a
# tuple-per-record design retains one GC-tracked container per record,
# and on contended cells the resulting young-generation scans cost 3x the
# appends themselves.  Scalars (floats/ints/str) carry no GC head, so the
# hot path produces zero collector pressure.  The log is strictly
# chronological (appends happen in event order).
_WINDOW = 0  # 0, dt, n, jid_1..jid_n, k_1..k_n     one piecewise-rate window
_START = 1  # 1, now, jid, bucket, lat_left, domains  transfer admitted
_END = 2  # 2, now, jid                             transfer drained
_ABORT = 3  # 3, now, jid                           transfer died mid-flight
_GATE_IN = 4  # 4, now, jid                         entered the gating queue
_GATE_OUT = 5  # 5, now, jid                        left the gating queue
_PLACED = 6  # 6, now, jid, arrival, restore_inc, model, n_gpus
_PREEMPT = 7  # 7, now, jid, lost_samples
_CANCEL = 8  # 8, now, jid, lost_samples
_RESIZE = 9  # 9, now, jid
_FINISH = 10  # 10, now, jid

# The gating audit gets its OWN flat stream (``ObsRecorder.audit_raw``):
# it is by far the densest hook on contended cells (one record per gate
# evaluation, several per event), its records are self-contained (the
# deferred GateDecision build needs nothing else from the log), and its
# total size is already bounded by ``audit_cap`` — so keeping it out of
# the unified log removes both the record tag and the mid-run flush
# copying entirely.  Untagged stride: now, jid, bucket, new_bytes,
# max_conc, ok, qpos, n_waiting, n_old, old_1..old_n.

#: fold the raw log into the replay state when it grows past this many
#: elements — bounds memory on 100k-job streaming replays without touching
#: the common case (a benchmark cell never reaches it)
_FLUSH_AT = 1 << 19


@dataclasses.dataclass(frozen=True)
class JctParts:
    """Exact decomposition of one finished job's completion time."""

    job_id: int
    jct: float
    queue_wait: float
    compute: float
    comm_serial: float
    comm_stretch: float
    gating_wait: float
    overhead_pf: float
    n_preempts: int = 0
    lost_samples: int = 0

    @property
    def parts_sum(self) -> float:
        return (
            self.queue_wait
            + self.compute
            + self.comm_serial
            + self.comm_stretch
            + self.gating_wait
            + self.overhead_pf
        )

    @property
    def stretch_frac(self) -> float:
        return self.comm_stretch / self.jct if self.jct > 0 else 0.0

    @property
    def gating_frac(self) -> float:
        return self.gating_wait / self.jct if self.jct > 0 else 0.0

    def as_csv_row(self) -> str:
        vals = []
        for f in DECOMP_CSV_FIELDS:
            v = getattr(self, f)
            vals.append(f"{v:.6f}" if isinstance(v, float) else str(v))
        return ",".join(vals)


@dataclasses.dataclass(frozen=True)
class GateDecision:
    """One gating evaluation (accept or reject) from the audit log.

    ``terms`` is the policy's :meth:`CommPolicy.explain` output — for
    AdaDUAL the Theorem-2 ratio vs threshold, for SRSF(n) the concurrency
    test, for the k-way lookahead the integrated start-now vs wait costs.
    """

    t: float
    job_id: int
    bucket: int  # -1 = monolithic all-reduce
    new_bytes: float
    min_old_bytes: float  # inf when no in-flight task shares a domain
    n_old: int
    max_concurrent: int
    accepted: bool
    queue_pos: int  # rank in the SRSF evaluation order of this pass
    n_waiting: int
    policy: str
    terms: Optional[Dict[str, float]] = None


class _Ledger:
    """Mutable per-job wall-clock ledger (closed into JctParts at finish)."""

    __slots__ = (
        "arrival",
        "first_placed",
        "requeued_since",
        "requeue_wait",
        "gating_wait",
        "comm_serial",
        "comm_stretch",
        "aborted_comm",
        "restore_total",
        "n_preempts",
        "lost_samples",
    )

    def __init__(self, arrival: float) -> None:
        self.arrival = arrival
        self.first_placed: Optional[float] = None
        self.requeued_since: Optional[float] = None
        self.requeue_wait = 0.0
        self.gating_wait = 0.0
        self.comm_serial = 0.0
        self.comm_stretch = 0.0
        self.aborted_comm = 0.0
        self.restore_total = 0.0
        self.n_preempts = 0
        self.lost_samples = 0


class _Replay:
    """Streaming reducer over the raw log: consumes chronological chunks
    (so the recorder can flush mid-run) and owns all derived state."""

    def __init__(self, config, b: float, eta: float, policy, params) -> None:
        self.decompose_on = bool(config.decompose)
        self.timelines_on = bool(config.timelines)
        self.spans_on = bool(config.spans)
        self._b = b
        self._eta = eta
        self._policy = policy
        self._params = params
        self._timeline_cap = config.timeline_cap
        # decomposition
        self.ledgers: Dict[int, _Ledger] = {}
        self.open_tx: Dict[int, List[float]] = {}  # jid -> [lat_left, serial, stretch]
        self.gate_since: Dict[int, float] = {}
        self.decomp: Dict[int, JctParts] = {}
        # domain timelines — flat at stride 3 (t, domain_key, load):
        # mid-run flushes fold into this, and retaining one tuple per
        # sample would recreate the GC scan pressure the flat log avoids
        self.timeline_flat: List = []
        self.timeline_dropped = 0
        self._domain_load: Dict[object, int] = {}
        self._tx_domains: Dict[int, object] = {}  # jid -> frozenset
        # closed comm/gating spans, flat at stride 6 (jid, track, name,
        # t0, t1, aborted); open ones live in the scalar-valued dicts
        # below until their close record (or the horizon) arrives.
        # Compute spans are appended by the report finalizer from the
        # raw compute stream against the same shared span budget.
        self.spans_flat: List = []
        self.span_dropped = 0
        self._span_budget = config.span_cap
        self._open_comm: Dict[int, Tuple[float, int]] = {}  # jid -> (t0, bucket)
        self._open_gate: Dict[int, float] = {}  # jid -> t0
        self._bucket_names: Dict[int, str] = {}
        # lifecycle instants and Perfetto metadata
        self.job_events: List[Tuple[float, str, int]] = []
        self.job_meta: Dict[int, Tuple[str, int, float]] = {}

    # -- timeline / span helpers ------------------------------------------
    def _domain_step(self, now: float, domains, delta: int) -> None:
        loads = self._domain_load
        tl = self.timeline_flat
        cap = self._timeline_cap * 3
        for d in domains:
            k = loads.get(d, 0) + delta
            if k:
                loads[d] = k
            else:
                loads.pop(d, None)
            if len(tl) >= cap:
                self.timeline_dropped += 1
            else:
                tl.extend((now, d, k))

    def _bucket_name(self, bucket: int) -> str:
        # cache the formatted label so repeat buckets share one str ref
        name = self._bucket_names.get(bucket)
        if name is None:
            name = "allreduce" if bucket < 0 else f"allreduce[b{bucket}]"
            self._bucket_names[bucket] = name
        return name

    def _close_span(
        self, jid: int, track: int, name: str, t0: float, t1: float,
        aborted: bool,
    ) -> None:
        budget = self._span_budget
        if budget <= 0:
            self.span_dropped += 1
            return
        self._span_budget = budget - 1
        self.spans_flat.extend((jid, track, name, t0, t1, aborted))

    # -- the reducer -------------------------------------------------------
    def consume(self, log: List) -> None:
        """Cursor-walk one chronological chunk of the flat record stream.
        Chunks always end on a record boundary (every record is appended
        atomically before any flush check runs)."""
        b, eta = self._b, self._eta
        ledgers = self.ledgers
        open_tx = self.open_tx
        i, n = 0, len(log)
        while i < n:
            tag = log[i]
            if tag == _WINDOW:
                dt = log[i + 1]
                cnt = log[i + 2]
                j0 = i + 3
                k0 = j0 + cnt
                for o in range(cnt):
                    jid = log[j0 + o]
                    tx = open_tx.get(jid)
                    if tx is None:  # transfer predates the recorder: skip
                        continue
                    lat = tx[0]
                    if lat > dt:
                        lat = dt
                    tx[0] -= lat
                    drain = dt - lat
                    if drain > 0.0:
                        k = log[k0 + o]
                        ratio = b / (k * b + (k - 1.0) * eta)
                        stretch = drain * (1.0 - ratio)
                    else:
                        stretch = 0.0
                    tx[1] += dt - stretch
                    tx[2] += stretch
                i = k0 + cnt
            elif tag == _START:
                now, jid, bucket, lat0, domains = log[i + 1 : i + 6]
                i += 6
                if self.decompose_on:
                    open_tx[jid] = [lat0, 0.0, 0.0]
                if self.timelines_on:
                    self._tx_domains[jid] = domains
                    self._domain_step(now, domains, +1)
                if self.spans_on:
                    self._open_comm[jid] = (now, bucket)
            elif tag == _END or tag == _ABORT:
                now, jid = log[i + 1], log[i + 2]
                i += 3
                tx = open_tx.pop(jid, None)
                if tx is not None:
                    led = ledgers.get(jid)
                    if led is not None:
                        if tag == _END:
                            led.comm_serial += tx[1]
                            led.comm_stretch += tx[2]
                        else:
                            # aborted mid-flight: the accrued comm time
                            # delivered nothing — preemption/fault overhead
                            led.aborted_comm += tx[1] + tx[2]
                if self.timelines_on:
                    domains = self._tx_domains.pop(jid, None)
                    if domains is not None:
                        self._domain_step(now, domains, -1)
                oc = self._open_comm.pop(jid, None)
                if oc is not None:
                    self._close_span(
                        jid, -1, self._bucket_name(oc[1]), oc[0], now,
                        tag == _ABORT,
                    )
            elif tag == _GATE_IN:
                now, jid = log[i + 1], log[i + 2]
                i += 3
                self.gate_since[jid] = now
                if self.spans_on:
                    self._open_gate[jid] = now
            elif tag == _GATE_OUT:
                now, jid = log[i + 1], log[i + 2]
                i += 3
                t0 = self.gate_since.pop(jid, None)
                if t0 is not None:
                    led = ledgers.get(jid)
                    if led is not None:
                        led.gating_wait += now - t0
                g0 = self._open_gate.pop(jid, None)
                if g0 is not None:
                    self._close_span(jid, -1, "gated", g0, now, False)
            elif tag == _PLACED:
                now, jid, arrival, restore_inc, model, n_gpus = log[i + 1 : i + 7]
                i += 7
                led = ledgers.get(jid)
                if led is None:
                    led = _Ledger(arrival)
                    ledgers[jid] = led
                if led.first_placed is None:
                    led.first_placed = now
                if led.requeued_since is not None:
                    led.requeue_wait += now - led.requeued_since
                    led.requeued_since = None
                led.restore_total += restore_inc
                if jid not in self.job_meta:
                    self.job_meta[jid] = (model, n_gpus, arrival)
            elif tag == _PREEMPT:
                now, jid, lost = log[i + 1], log[i + 2], log[i + 3]
                i += 4
                led = ledgers.get(jid)
                if led is not None:
                    led.n_preempts += 1
                    led.lost_samples += lost
                    led.requeued_since = now
                self.job_events.append((now, "preempt", jid))
            elif tag == _CANCEL:
                now, jid, lost = log[i + 1], log[i + 2], log[i + 3]
                i += 4
                led = ledgers.pop(jid, None)
                if led is not None:
                    led.lost_samples += lost
                self.gate_since.pop(jid, None)
                open_tx.pop(jid, None)
                self.job_events.append((now, "cancel", jid))
            elif tag == _RESIZE:
                self.job_events.append((log[i + 1], "resize", log[i + 2]))
                i += 3
            elif tag == _FINISH:
                now, jid = log[i + 1], log[i + 2]
                i += 3
                led = ledgers.pop(jid, None)
                if led is None or not self.decompose_on:
                    continue
                jct = now - led.arrival
                queue_wait = (
                    (led.first_placed - led.arrival)
                    if led.first_placed is not None
                    else 0.0
                )
                placed_resid = (
                    jct
                    - queue_wait
                    - led.requeue_wait
                    - led.gating_wait
                    - led.comm_serial
                    - led.comm_stretch
                    - led.aborted_comm
                )
                # The restore penalty is paid per worker in parallel, so
                # its wall-clock extension is ~one restore_cost per
                # re-placement; clamp to the available residual so compute
                # stays nonnegative under extreme GPU time-sharing.
                restore = min(led.restore_total, max(0.0, placed_resid))
                self.decomp[jid] = JctParts(
                    job_id=jid,
                    jct=jct,
                    queue_wait=queue_wait,
                    compute=placed_resid - restore,
                    comm_serial=led.comm_serial,
                    comm_stretch=led.comm_stretch,
                    gating_wait=led.gating_wait,
                    overhead_pf=led.requeue_wait + led.aborted_comm + restore,
                    n_preempts=led.n_preempts,
                    lost_samples=led.lost_samples,
                )
            else:  # pragma: no cover - corrupted stream
                raise ValueError(f"bad obs record tag {tag!r} at {i}")


def _build_audit(raw: List, policy, params) -> List[GateDecision]:
    """Build the :class:`GateDecision` list (dataclass + ``explain`` terms
    per decision) from the raw audit stream — called once by
    ``ObsReport._materialize``, never inside ``run()``."""
    audit: List[GateDecision] = []
    i, n = 0, len(raw)
    while i < n:
        (now, jid, bucket, new_bytes, max_conc, ok, qpos, n_waiting,
         n_old) = raw[i : i + 9]
        old_rem = raw[i + 9 : i + 9 + n_old]
        i += 9 + n_old
        audit.append(
            GateDecision(
                t=now,
                job_id=jid,
                bucket=bucket,
                new_bytes=new_bytes,
                min_old_bytes=min(old_rem) if old_rem else math.inf,
                n_old=n_old,
                max_concurrent=max_conc,
                accepted=ok,
                queue_pos=qpos,
                n_waiting=n_waiting,
                policy=policy.name,
                terms=policy.explain(new_bytes, old_rem, max_conc, params),
            )
        )
    return audit


class ObsRecorder:
    """The engine's observability sink (armed via ``observe=ObsConfig``).

    The highest-frequency streams are not even method calls: the engine
    caches direct references to :attr:`log` / :attr:`raw_compute` (plus
    the per-family channel gates) at construction and extends flat
    scalar records inline — see ``EventEngine.__init__``.  :meth:`_flush`
    folds the log into the replay state *in place* (``del log[:]``) so
    those cached references never go stale.  Lower-frequency hooks (transfer
    starts, audit entries, job lifecycle, faults) stay methods.  The
    engine calls :meth:`bind` right after construction so the replay
    knows the Eq. 5 constants and the gating policy.
    """

    def __init__(self, config) -> None:
        self.config = config
        self.decompose_on = bool(config.decompose)
        self.timelines_on = bool(config.timelines)
        self.audit_on = bool(config.audit)
        self.spans_on = bool(config.spans)
        #: which record families the unified log needs
        self.log_comm = self.decompose_on or self.timelines_on or self.spans_on
        self.log_gate = self.decompose_on or self.spans_on
        self.flush_at = _FLUSH_AT
        #: the unified flat record stream (scalars only — see the tag
        #: table above; no retained containers = no GC scan pressure)
        self.log: List = []
        #: raw compute spans, flat at stride 6: jid, worker, kind, seg,
        #: t0, t1 — extended inline by the engine (cap-checked there
        #: against ``span_cap * 6`` elements)
        self.raw_compute: List = []
        self.span_dropped = 0
        #: raw gating-audit stream (dedicated; see the stride note above) —
        #: extended inline by the engine, which also owns the budget
        #: countdown against ``audit_cap``
        self.audit_raw: List = []
        self.audit_dropped = 0
        #: fault timeline: (t, kind, server) — rare, recorded eagerly
        self.fault_events: List[Tuple[float, str, int]] = []
        #: eager conservation counter (checked against
        #: ``SimResult.work_lost_samples``; same additions, so equality
        #: is exact)
        self.work_lost_total = 0
        self._replay: Optional[_Replay] = None
        self._b = 0.0
        self._eta = 0.0
        self._policy = None
        self._params = None

    def bind(self, params, policy) -> None:
        """Capture the Eq. 5 constants and the gating policy for the
        deferred replay.  ``b``/``eta`` never change mid-run (NIC chaos
        only rewrites ``server_bandwidth``)."""
        self._b = params.b
        self._eta = params.eta
        self._params = params
        self._policy = policy

    def _flush(self) -> None:
        """Fold the raw log into the replay state and clear it IN PLACE —
        the engine holds direct references to the list."""
        if self._replay is None:
            self._replay = _Replay(
                self.config, self._b, self._eta, self._policy, self._params
            )
        self._replay.consume(self.log)
        del self.log[:]

    # -- warm hooks (low frequency; the hot streams are engine-inlined) ----
    def comm_start(self, jid: int, bucket: int, now: float, task) -> None:
        if self.log_comm:
            log = self.log
            log.extend(
                (_START, now, jid, bucket, task.latency_left, task.domains)
            )
            if len(log) >= self.flush_at:
                self._flush()

    def comm_abort(self, jid: int, now: float) -> None:
        if self.log_comm:
            self.log.extend((_ABORT, now, jid))

    # -- job lifecycle (rare) ----------------------------------------------
    def placed(self, jid: int, run, now: float) -> None:
        spec = run.spec
        restore_inc = (
            run.restore_cost
            if (run.restore_cost > 0.0 and run.restore_need)
            else 0.0
        )
        self.log.extend(
            (
                _PLACED,
                now,
                jid,
                spec.arrival,
                restore_inc,
                getattr(spec.model, "name", "model"),
                spec.n_gpus,
            )
        )

    def preempted(self, jid: int, now: float, lost_samples: int) -> None:
        self.work_lost_total += lost_samples
        self.log.extend((_PREEMPT, now, jid, lost_samples))

    def cancelled(self, jid: int, now: float, lost_samples: int) -> None:
        self.work_lost_total += lost_samples
        self.log.extend((_CANCEL, now, jid, lost_samples))

    def resized(self, jid: int, now: float) -> None:
        self.log.extend((_RESIZE, now, jid))

    def finished(self, jid: int, run, now: float) -> None:
        self.log.extend((_FINISH, now, jid))

    def fault(self, kind: str, server: int, now: float) -> None:
        self.fault_events.append((now, kind, server))

    # -- report ------------------------------------------------------------
    def build_report(
        self, topology, params, makespan: float, horizon: float
    ) -> "ObsReport":
        """Hand the raw streams to a lazy :class:`ObsReport`.  No replay
        happens here — ``run()`` wall time stays free of analysis cost."""
        if self._replay is None:
            self._replay = _Replay(
                self.config, self._b, self._eta, self._policy, self._params
            )
        return ObsReport(
            config=self.config,
            _replay=self._replay,
            _log=self.log,
            _raw_compute=self.raw_compute,
            _audit_raw=self.audit_raw,
            _topology=topology,
            _horizon=horizon,
            work_lost_total=self.work_lost_total,
            fault_events=self.fault_events,
            makespan=makespan,
            _audit_dropped0=self.audit_dropped,
            _span_dropped0=self.span_dropped,
        )


class ObsReport:
    """What ``SimResult.obs`` carries when observability was on.

    All derived views (``decomp``, ``timeline``, ``audit``, ``spans``,
    ...) are materialized from the raw record streams on first access —
    constructing the report is free, so the simulation's wall-clock
    (``SimResult``-timed benchmarks) excludes analysis cost.
    """

    def __init__(
        self,
        config,
        _replay: _Replay,
        _log: List,
        _raw_compute: List,
        _audit_raw: List,
        _topology,
        _horizon: float,
        work_lost_total: int,
        fault_events: List[Tuple[float, str, int]],
        makespan: float,
        _audit_dropped0: int = 0,
        _span_dropped0: int = 0,
    ) -> None:
        self.config = config
        #: samples of in-progress work lost to teardowns — conservation-
        #: checked against ``SimResult.work_lost_samples``
        self.work_lost_total = work_lost_total
        self.fault_events = fault_events
        self.makespan = makespan
        self._replay = _replay
        self._log = _log
        self._raw_compute = _raw_compute
        self._audit_raw = _audit_raw
        self._topology = _topology
        self._horizon = _horizon
        self._audit_dropped0 = _audit_dropped0
        self._span_dropped0 = _span_dropped0
        self._done = False

    def _materialize(self) -> None:
        if self._done:
            return
        self._done = True
        rp = self._replay
        rp.consume(self._log)
        self._log = []
        self.audit = _build_audit(self._audit_raw, rp._policy, rp._params)
        self._audit_raw = []
        horizon = self._horizon
        if rp.spans_on:
            # close comm/gating spans left open at the horizon
            for jid, (t0, bucket) in sorted(rp._open_comm.items()):
                rp._close_span(
                    jid, -1, rp._bucket_name(bucket), t0, horizon, False
                )
            rp._open_comm.clear()
            for jid, t0 in sorted(rp._open_gate.items()):
                rp._close_span(jid, -1, "gated", t0, horizon, False)
            rp._open_gate.clear()
            # compute spans from the raw stream, teardowns clipping any
            # span still open (or scheduled past) the teardown instant —
            # the engine records gpu_done spans optimistically at
            # schedule time
            tears: Dict[int, List[float]] = {}
            for t, kind, jid in rp.job_events:
                tears.setdefault(jid, []).append(t)
            rc = self._raw_compute
            sf = rp.spans_flat
            for i in range(0, len(rc), 6):
                if rp._span_budget <= 0:
                    rp.span_dropped += (len(rc) - i) // 6
                    break
                rp._span_budget -= 1
                jid, worker, kind, seg, t0, t1 = rc[i : i + 6]
                name = kind if seg < 0 else f"{kind}{seg}"
                aborted = False
                ts = tears.get(jid)
                if ts is not None:
                    for tt in ts:
                        if t0 <= tt < t1:
                            t1 = tt
                            aborted = True
                            break
                sf.extend((jid, worker, name, t0, t1, aborted))
            self.spans = [
                tuple(sf[i : i + 6]) for i in range(0, len(sf), 6)
            ]
            rp.spans_flat = []
        else:
            self.spans = []
        self._raw_compute = []
        self.decomp = rp.decomp
        tf = rp.timeline_flat
        self.timeline = [
            (tf[i], tf[i + 1], tf[i + 2]) for i in range(0, len(tf), 3)
        ]
        rp.timeline_flat = []
        self.timeline_dropped = rp.timeline_dropped
        self.audit_dropped = self._audit_dropped0
        self.span_dropped = self._span_dropped0 + rp.span_dropped
        self.job_events = rp.job_events
        self.job_meta = rp.job_meta
        names: Dict[object, str] = {}
        topology = self._topology
        for (_, d, _) in self.timeline:
            if d in names:
                continue
            if isinstance(d, int) and 0 <= d < len(topology.domains):
                names[d] = topology.domains[d].name
            else:
                names[d] = str(d)
        self.domain_names = names

    def __getattr__(self, name: str):
        # lazy fields: first access triggers the replay
        if name in (
            "decomp",
            "timeline",
            "timeline_dropped",
            "audit",
            "audit_dropped",
            "spans",
            "span_dropped",
            "job_events",
            "job_meta",
            "domain_names",
        ):
            self._materialize()
            return self.__dict__[name]
        raise AttributeError(name)

    # -- aggregates (the new metrics CSV columns) --------------------------
    def mean_stretch_frac(self) -> float:
        if not self.decomp:
            return math.nan
        return sum(p.stretch_frac for p in self.decomp.values()) / len(self.decomp)

    def mean_gating_frac(self) -> float:
        if not self.decomp:
            return math.nan
        return sum(p.gating_frac for p in self.decomp.values()) / len(self.decomp)

    def mean_parts(self) -> Dict[str, float]:
        """Mean seconds per decomposition bucket over finished jobs."""
        n = max(1, len(self.decomp))
        out = {f: 0.0 for f in DECOMP_CSV_FIELDS[1:8]}
        for p in self.decomp.values():
            for f in out:
                out[f] += getattr(p, f)
        return {f: v / n for f, v in out.items()}

    # -- per-domain utilization from the k timeline ------------------------
    def domain_utilization(self) -> Dict[object, Dict[str, float]]:
        """Per-domain ``busy_frac`` (fraction of the makespan with k >= 1),
        ``mean_k`` (time-averaged active transfers) and ``peak_k`` from the
        step timeline."""
        horizon = self.makespan if self.makespan > 0 else 0.0
        series: Dict[object, List[Tuple[float, int]]] = {}
        for t, d, k in self.timeline:
            series.setdefault(d, []).append((t, k))
        out: Dict[object, Dict[str, float]] = {}
        for d, steps in series.items():
            busy = 0.0
            k_time = 0.0
            peak = 0
            last_t, last_k = 0.0, 0
            for t, k in steps:
                dt = t - last_t
                if dt > 0:
                    if last_k > 0:
                        busy += dt
                    k_time += last_k * dt
                last_t, last_k = t, k
                peak = max(peak, k)
            if horizon > last_t and last_k > 0:
                busy += horizon - last_t
                k_time += last_k * (horizon - last_t)
            out[d] = {
                "busy_frac": busy / horizon if horizon > 0 else 0.0,
                "mean_k": k_time / horizon if horizon > 0 else 0.0,
                "peak_k": float(peak),
            }
        return out

    # -- artifacts ---------------------------------------------------------
    def decomposition_csv(self) -> str:
        rows = [",".join(DECOMP_CSV_FIELDS)]
        for jid in sorted(self.decomp):
            rows.append(self.decomp[jid].as_csv_row())
        return "\n".join(rows) + "\n"

    def to_chrome_trace(self, path: Optional[str] = None):
        """Chrome trace-event (Perfetto-compatible) export; see
        ``repro.obs.perfetto``.  Returns the trace dict; writes JSON to
        ``path`` when given."""
        from repro.obs.perfetto import chrome_trace_dict, write_chrome_trace

        if path is not None:
            return write_chrome_trace(self, path)
        return chrome_trace_dict(self)
