"""Chrome trace-event (Perfetto-compatible) exporter.

Maps one run's :class:`~repro.obs.recorder.ObsReport` onto the Chrome
trace-event JSON format that ``ui.perfetto.dev`` (and ``chrome://tracing``)
load directly:

* each **job** becomes a *process* (pid = job_id + 1) named after its
  model and gang size;
* each job's **workers** become threads (tid = worker + 1, named
  ``gpu w<k>``) carrying the forward/backward (or fused ``fb``) duration
  spans, and tid 0 is the job's **comm stream** carrying ``gated`` waits
  and ``allreduce`` transfer spans (WFBP buckets are ``allreduce[bK]``);
* the **contention domains** become one counter track per fabric cut
  (process 0) plotting the active-transfer count ``k`` over time — the
  Eq. 5 contention input;
* preemptions / resizes / cancellations are instant events on the job's
  track; server breakdown / repair / NIC windows are global instants.

Timestamps are microseconds (the format's unit); simulated seconds map
1:1 onto trace seconds.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

#: pid of the pseudo-process that carries the per-domain counter tracks
DOMAIN_PID = 0

_CAT = {"f": "compute", "b": "compute", "fb": "compute"}


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def _span_cat(name: str) -> str:
    if name == "gated":
        return "gating"
    if name.startswith("allreduce"):
        return "comm"
    return _CAT.get(name[0], "compute")


def chrome_trace_events(report) -> List[dict]:
    """The flat ``traceEvents`` list for one report."""
    ev: List[dict] = []
    pids_seen: Dict[int, bool] = {}

    def ensure_process(jid: int) -> int:
        pid = jid + 1
        if jid not in pids_seen:
            pids_seen[jid] = True
            name, n_gpus, arrival = report.job_meta.get(
                jid, ("job", 0, 0.0)
            )
            label = f"job {jid} ({name} x{n_gpus})" if n_gpus else f"job {jid}"
            ev.append(
                {
                    "ph": "M",
                    "name": "process_name",
                    "pid": pid,
                    "tid": 0,
                    "args": {"name": label},
                }
            )
            ev.append(
                {
                    "ph": "M",
                    "name": "process_sort_index",
                    "pid": pid,
                    "tid": 0,
                    "args": {"sort_index": jid},
                }
            )
            ev.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid,
                    "tid": 0,
                    "args": {"name": "comm stream"},
                }
            )
        return pid

    tids_named: Dict[tuple, bool] = {}
    for jid, track, name, t0, t1, aborted in report.spans:
        pid = ensure_process(jid)
        tid = 0 if track < 0 else track + 1
        if track >= 0 and (jid, tid) not in tids_named:
            tids_named[(jid, tid)] = True
            ev.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid,
                    "tid": tid,
                    "args": {"name": f"gpu w{track}"},
                }
            )
        args = {}
        if aborted:
            args["aborted"] = True
        ev.append(
            {
                "ph": "X",
                "name": name,
                "cat": _span_cat(name),
                "pid": pid,
                "tid": tid,
                "ts": _us(t0),
                "dur": max(0.0, _us(t1) - _us(t0)),
                **({"args": args} if args else {}),
            }
        )

    for t, kind, jid in report.job_events:
        pid = ensure_process(jid)
        ev.append(
            {
                "ph": "i",
                "s": "p",
                "name": kind,
                "cat": "lifecycle",
                "pid": pid,
                "tid": 0,
                "ts": _us(t),
            }
        )

    if report.timeline:
        ev.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": DOMAIN_PID,
                "tid": 0,
                "args": {"name": "contention domains (active comm k)"},
            }
        )
        for t, d, k in report.timeline:
            ev.append(
                {
                    "ph": "C",
                    "name": f"k @ {report.domain_names.get(d, str(d))}",
                    "cat": "contention",
                    "pid": DOMAIN_PID,
                    "tid": 0,
                    "ts": _us(t),
                    "args": {"k": k},
                }
            )

    for t, kind, server in report.fault_events:
        ev.append(
            {
                "ph": "i",
                "s": "g",
                "name": f"{kind} s{server}",
                "cat": "fault",
                "pid": DOMAIN_PID,
                "tid": 0,
                "ts": _us(t),
            }
        )
    return ev


def chrome_trace_dict(report) -> dict:
    return {
        "traceEvents": chrome_trace_events(report),
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs.perfetto",
            "makespan_s": report.makespan,
            "n_jobs_decomposed": len(report.decomp),
            "span_dropped": report.span_dropped,
            "timeline_dropped": report.timeline_dropped,
        },
    }


def write_chrome_trace(report, path: str) -> dict:
    """Serialize the report to a Perfetto-loadable JSON file at ``path``."""
    trace = chrome_trace_dict(report)
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return trace
