"""Contention observability layer (zero overhead when off).

The paper's whole argument is about *where time goes* — contention
stretches communication (Eq. 5), and AdaDUAL trades a bounded amount of
accepted contention against waiting (Theorems 1-2) — yet aggregates like
avg/p99 JCT cannot say *which* mechanism produced a result.  This package
is the attribution layer:

* :class:`ObsConfig` / :class:`ObsRecorder` — the engine-facing recorder,
  armed via ``simulate(observe=ObsConfig(...))``.  Follows the chaos
  ``active`` pattern: an absent or inactive config keeps every hook cold,
  so the event stream and throughput are bit-exact with the
  pre-observability engine (sha-locked in ``tests/test_obs.py``).
* :class:`JctParts` — exact per-job JCT decomposition: queue wait,
  compute, serial comm at the uncontended Eq. 5 rate, contention stretch
  (integrated from the engine's piecewise-constant-rate windows), gating
  wait, and preemption/fault overhead.  The parts sum to the JCT by
  construction.
* :class:`ObsReport` — what ``SimResult.obs`` carries: the decomposition
  table, per-domain timelines (active-comm count ``k`` per fabric cut),
  the gating-decision audit log, span records, and the Chrome
  trace-event exporter (``repro.obs.perfetto``) that opens any run in
  ``ui.perfetto.dev``.
* ``repro.obs.report`` — analysis helpers (imported explicitly; it pulls
  in the scenario registry) that print the decomposition tables used to
  explain the recovery-storm inversion and the fine-fusion finding.
"""

from repro.obs.config import ObsConfig
from repro.obs.perfetto import chrome_trace_events, write_chrome_trace
from repro.obs.recorder import (
    DECOMP_CSV_FIELDS,
    GateDecision,
    JctParts,
    ObsRecorder,
    ObsReport,
)

__all__ = [
    "ObsConfig",
    "ObsRecorder",
    "ObsReport",
    "JctParts",
    "GateDecision",
    "DECOMP_CSV_FIELDS",
    "chrome_trace_events",
    "write_chrome_trace",
]
