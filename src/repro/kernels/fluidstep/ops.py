"""Impl-dispatching wrapper for the fluid step core.

``fluid_step_core`` is the single entry point the fluid simulator's hot
loop calls once per executed tick.  The implementation is chosen by the
``impl`` argument, defaulting to the ``REPRO_FLUID_KERNEL`` environment
variable and finally to ``"ref"``:

* ``ref``       — the historical lax composition (ref.py).  Default
                  everywhere, including CPU CI: XLA fuses it fine and it
                  is the bit-exactness anchor.
* ``interpret`` — the Pallas kernel in interpreter mode (runs on CPU;
                  used by the parity test, and useful for debugging).
* ``tpu``       — the compiled Pallas kernel (real TPU hardware).

The flag is read at trace time (the simulator jit-retraces per config),
so flipping the env var between calls behaves as expected.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.fluidstep.kernel import _BIG, fluid_step_core_pallas
from repro.kernels.fluidstep.ref import fluid_step_core_ref

#: Environment variable selecting the implementation ("ref" default).
FLUID_KERNEL_ENV = "REPRO_FLUID_KERNEL"

FLUID_KERNEL_IMPLS = ("ref", "interpret", "tpu")


def default_impl() -> str:
    return os.environ.get(FLUID_KERNEL_ENV, "ref") or "ref"


def fluid_step_core(loads, member, active, rem, bw, oversub, *,
                    b: float, eta: float, need_overlap: bool = False,
                    impl: str = ""):
    """Contention/rate core of one fluid step (see ref.py for semantics).

    ``loads`` is the precomputed ``(J, D)`` domain-load mask (maintained
    incrementally by the simulator).  ``impl`` = "" resolves through
    :data:`FLUID_KERNEL_ENV`; outputs are dtype-identical across
    implementations (counts/k_would int32, rates float32, absent-old
    sentinel mapped back to +inf).  ``overlap`` is None when
    ``need_overlap`` is False on the reference path; the Pallas kernel
    computes it unconditionally (one MXU matmul, free on TPU).
    """
    impl = impl or default_impl()
    if impl not in FLUID_KERNEL_IMPLS:
        raise ValueError(
            f"unknown fluid step impl {impl!r}; expected one of "
            f"{FLUID_KERNEL_IMPLS}"
        )
    if impl == "ref":
        return fluid_step_core_ref(
            loads, member, active, rem, bw, oversub,
            b=b, eta=eta, need_overlap=need_overlap,
        )
    counts, k_eff, ratio, overlap, k_would, min_old = fluid_step_core_pallas(
        loads, member, active, rem, bw, oversub,
        b=b, eta=eta, interpret=(impl == "interpret"),
    )
    return {
        "counts": counts[0].astype(jnp.int32),
        "k_eff": k_eff[:, 0],
        "ratio": ratio[:, 0],
        "overlap": overlap > 0,
        "k_would": k_would[:, 0].astype(jnp.int32),
        "min_old_rem": jnp.where(
            min_old[:, 0] >= _BIG / 2, jnp.inf, min_old[:, 0]
        ),
    }
