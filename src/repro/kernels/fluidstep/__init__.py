# Fused per-step contention/rate core of the fluid simulator's hot loop
# (domain incidence matmuls, Eq. 5 rate, slowest-member scale, gating-side
# k/min-old-rem).  The reference lax composition is the default everywhere
# (CPU CI included); the Pallas kernel is opt-in via REPRO_FLUID_KERNEL or
# JaxSimConfig.kernel ("interpret" | "tpu").
from repro.kernels.fluidstep.ops import FLUID_KERNEL_ENV, fluid_step_core

__all__ = ["FLUID_KERNEL_ENV", "fluid_step_core"]
