"""Pallas fluid-step core: the whole per-tick contention/rate evaluation
as ONE kernel launch.

The lax reference path (ref.py) emits ~10 small XLA ops per evaluation —
per-domain counts, two masked max-reductions, the slowest-member min, the
Eq. 5 rate, the J×J overlap matmul and the two-stage masked min over
in-flight remainders.  On CPU the XLA thunk overhead per op dominates at
these sizes (J ≤ 128, S ≤ 32, D ≤ 40), and on TPU each op is a separate
VMEM round-trip; fusing them keeps every intermediate in VMEM/registers
for the lifetime of the step.

Problem sizes are far below one VMEM tile, so the kernel is a single
program (no grid): all operands land in VMEM whole, the overlap matmul
hits the MXU once, and everything else is VPU mask algebra.  The domain
load mask arrives precomputed (the simulator maintains it incrementally
in the scan carry — membership only changes at admission/completion
events).  Boolean masks travel as float {0,1} (TPU-friendly layout);
ops.py restores the reference dtypes and the ``inf`` sentinel so callers
cannot tell the paths apart.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: f32-safe stand-in for +inf inside the kernel (inf * 0 would NaN in the
#: mask algebra; ops.py maps >= _BIG/2 back to inf).
_BIG = 1e30


def _fluid_step_kernel(loads_ref, member_ref, active_ref, rem_ref, bw_ref,
                       ov_ref, counts_ref, keff_ref, ratio_ref, overlap_ref,
                       kwould_ref, minold_ref, *, b: float, eta: float):
    loads = loads_ref[:]                # (J, D) float {0,1}
    member = member_ref[:]              # (J, S) float {0,1}
    active = active_ref[:]              # (J, 1) float {0,1}
    rem = rem_ref[:]                    # (J, 1)
    # Per-domain in-flight counts and the two contention levels.
    counts = jnp.sum(loads * active, axis=0, keepdims=True)  # (1, D)
    counts_ref[:] = counts
    k_eff = jnp.clip(
        jnp.max(loads * (counts * ov_ref[:]), axis=1, keepdims=True), 1.0, None
    )
    keff_ref[:] = k_eff
    kwould_ref[:] = jnp.clip(
        jnp.max(loads * (counts + 1.0), axis=1, keepdims=True), 1.0, None
    )
    # Slowest member server bottlenecks the ring (memberless jobs -> 1.0).
    masked_bw = member * bw_ref[:] + (1.0 - member) * _BIG
    lo = jnp.min(masked_bw, axis=1, keepdims=True)
    has = jnp.max(member, axis=1, keepdims=True)
    scale = lo * has + (1.0 - has)
    # Eq. 5 retained-bandwidth fraction at the effective contention.
    ratio_ref[:] = scale * (b / (k_eff * b + (k_eff - 1.0) * eta))
    # Jobs overlap iff they load a common domain; min_old_rem is the
    # smallest remainder among overlapping in-flight transfers (M_old),
    # via per-domain minima (bit-identical to the J×J form: f32 min is
    # exact, and min-of-mins over a cover equals the direct min).
    overlap = jnp.where(
        jnp.dot(loads, loads.T, preferred_element_type=jnp.float32) > 0,
        1.0, 0.0,
    )  # (J, J)
    overlap_ref[:] = overlap
    act_loads = loads * active
    dmin = jnp.min(
        act_loads * rem + (1.0 - act_loads) * _BIG, axis=0, keepdims=True
    )  # (1, D)
    minold_ref[:] = jnp.min(
        loads * dmin + (1.0 - loads) * _BIG, axis=1, keepdims=True
    )


@functools.partial(
    jax.jit, static_argnames=("b", "eta", "interpret")
)
def fluid_step_core_pallas(loads, member, active, rem, bw, oversub, *,
                           b: float, eta: float, interpret: bool = True):
    """Run the fused step core; returns raw float planes (see ops.py)."""
    n_jobs = member.shape[0]
    n_domains = loads.shape[1]
    kern = functools.partial(_fluid_step_kernel, b=b, eta=eta)
    f32 = jnp.float32
    out_shapes = (
        jax.ShapeDtypeStruct((1, n_domains), f32),       # counts
        jax.ShapeDtypeStruct((n_jobs, 1), f32),          # k_eff
        jax.ShapeDtypeStruct((n_jobs, 1), f32),          # ratio
        jax.ShapeDtypeStruct((n_jobs, n_jobs), f32),     # overlap
        jax.ShapeDtypeStruct((n_jobs, 1), f32),          # k_would
        jax.ShapeDtypeStruct((n_jobs, 1), f32),          # min_old_rem
    )
    return pl.pallas_call(kern, out_shape=out_shapes, interpret=interpret)(
        loads.astype(f32),
        member.astype(f32),
        active.astype(f32).reshape(n_jobs, 1),
        rem.astype(f32).reshape(n_jobs, 1),
        bw.astype(f32).reshape(1, -1),
        oversub.astype(f32).reshape(1, -1),
    )
