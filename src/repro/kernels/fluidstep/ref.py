"""Reference (pure-lax) fluid step core.

This is the contention/rate op sequence the fluid simulator's hot loop
historically inlined per tick (core/jaxsim.py pre-fast-path), factored
out so the Pallas kernel (kernel.py) has a bit-for-bit target to verify
against and the simulator has a single call site for the math:

* per-domain in-flight counts over the (precomputed) domain-load mask,
* the Eq. 5 contended rate at the oversub-weighted effective k,
* the slowest-member-server drain scale (per-server NIC heterogeneity),
* the gating-side quantities: ``k_would`` (contention a new start would
  see), ``min_old_rem`` (Theorem 2's M_old) and — on request — the job
  overlap matrix.

``loads`` arrives as an *input*: it only changes when ring membership
changes (admission / job completion), so the simulator maintains it
incrementally in the scan carry instead of re-deriving it via two
incidence matmuls every tick (which dominated the CPU per-tick profile).

``min_old_rem`` is computed as a min of per-domain minima instead of a
masked min over the J×J overlap matrix: ``min{rem[j] : j active,
overlaps i}`` equals ``min over domains d loaded by i of min{rem[j] : j
active, j loads d}`` (a min of mins over a cover of the same set), and
f32 ``min`` is exact, so the two forms are bit-identical while this one
is O(J·D) with no J×J intermediate.  The overlap matrix itself is only
materialized when ``need_overlap`` (WFBP gating closure / exact k-way
lookahead paths).

Keeping this path the default (CPU CI, all tests) means the fast-path
refactor cannot drift the physics: the kernel is an optional accelerator,
not a second source of truth.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import netmodel


def fluid_step_core_ref(loads, member, active, rem, bw, oversub, *,
                        b: float, eta: float, need_overlap: bool = False):
    """One evaluation of the contention/rate core.

    Args:
      loads: ``(J, D)`` bool — which contention domains each job's ring
        crosses (``netmodel.domain_loads``; maintained by the caller).
      member: ``(J, S)`` float {0,1} — GPUs-held-per-server occupancy mask.
      active: ``(J,)`` bool — transfers currently draining (started, rem>0).
      rem: ``(J,)`` float — remaining cost of each job's current phase.
      bw: ``(S,)`` float — per-server relative NIC bandwidth.
      oversub: ``(D,)`` float — per-domain oversubscription.
      b / eta: Eq. 5 per-byte cost and contention penalty (static).
      need_overlap: materialize the ``(J, J)`` overlap matrix (WFBP /
        exact k-way gating need it; the threshold fast path does not).

    Returns a dict with ``counts`` (D, int32), ``k_eff`` (J, float),
    ``ratio`` (J, float — slowest-member-scaled Eq. 5 rate fraction),
    ``k_would`` (J, int32), ``min_old_rem`` (J, float, inf where no
    overlapping in-flight task) and ``overlap`` ((J,J) bool, or None
    unless ``need_overlap``).
    """
    counts = netmodel.domain_counts(loads, active)  # (D,)
    k_eff = netmodel.domain_k(loads, counts.astype(jnp.float32) * oversub)
    scale = netmodel.slowest_member_scale(bw, member > 0)
    ratio = scale * netmodel.rate_ratio(k_eff, b, eta)
    k_would = netmodel.domain_k(loads, counts, extra=1)
    # per-domain minimum in-flight remainder, then min over loaded domains
    dmin = jnp.where(loads & active[:, None], rem[:, None], jnp.inf).min(axis=0)
    min_old_rem = jnp.where(loads, dmin[None, :], jnp.inf).min(axis=1)
    overlap = None
    if need_overlap:
        loads_f = loads.astype(jnp.float32)
        overlap = (loads_f @ loads_f.T) > 0  # (J, J) share a domain
    return {
        "counts": counts,
        "k_eff": k_eff,
        "ratio": ratio,
        "k_would": k_would,
        "min_old_rem": min_old_rem,
        "overlap": overlap,
    }
