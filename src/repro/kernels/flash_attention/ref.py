"""Pure-jnp oracle for the flash-attention kernel.

Same signature/semantics as ``kernel.flash_attention_pallas``; tests
assert_allclose the kernel (interpret=True) against this across a
shape/dtype sweep.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def attention_reference(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BH, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        logits = jnp.where(mask[None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bst,btd->bsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
