"""Blocked causal flash attention — Pallas TPU kernel.

TPU adaptation of the flash-attention idea: the (S x S) score matrix is
never materialized in HBM; each (query-block, kv-block) tile lives in VMEM,
the MXU consumes (block_q x head_dim) @ (head_dim x block_k) tiles, and the
online-softmax running max/denominator are carried in VMEM scratch across
the kv grid dimension (the "arbitrary"-semantics innermost axis).

Block sizes default to 128 — MXU-aligned (128x128 systolic array) and small
enough that q/k/v/acc tiles fit VMEM: 4 tiles x 128 x head_dim(<=256) x 4 B
~ 0.5 MB << 16 MB VMEM/core.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,                # output tile
    acc_ref, m_ref, l_ref,  # VMEM scratch carried over the kv grid dim
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Causal: skip kv blocks strictly above the diagonal band.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        # Zero OOB-padded kv rows: pad contents are undefined and
        # 0 * NaN would poison the accumulator through the p @ v matmul.
        valid_k = (k_start + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)) < kv_len
        k = jnp.where(valid_k, k, 0.0)
        v = jnp.where(valid_k, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len  # tail padding
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BH, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(t, block_k)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_len=t,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(q, k, v)
