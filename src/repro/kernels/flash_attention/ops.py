"""Jit'd public wrapper for the flash-attention kernel.

On real TPU hardware pass ``interpret=False`` to run the compiled Pallas
kernel; on CPU (this container) the kernel body executes in interpret mode
for correctness validation, and production model code defaults to the
fused-jnp reference path (``models/attention.py``), which XLA fuses well.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "impl"),
)
def flash_attention(
    q: jax.Array,  # (B*H, S, D) — callers fold batch and heads
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "interpret",  # interpret | tpu | ref
) -> jax.Array:
    if impl == "ref":
        return attention_reference(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        interpret=(impl == "interpret"),
    )
