"""Mamba-2 SSD decode-step state update — Pallas TPU kernel.

The decode hot loop is memory-bound: it streams the (B, H, P, N) f32 state
through VMEM once per token:

    state' = state * exp(dt * A)[.., None, None] + dt * (B x^T)
    y      = (state' . C) + D * x

TPU adaptation: blocks tile (batch x heads) so each program holds one
(1, bh, P, N) state tile in VMEM (bh*P*N*4 B; with bh=8, P=64, N=128 that is
256 KB), the outer product and contraction feed the VPU/MXU with the N=128
lane dimension aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_step_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, state_ref,
    y_ref, new_state_ref,
):
    x = x_ref[...].astype(jnp.float32)        # (1, bh, P)
    dt = dt_ref[...].astype(jnp.float32)      # (1, bh)
    a = a_ref[...].astype(jnp.float32)        # (bh,)
    b = b_ref[...].astype(jnp.float32)        # (1, N)
    c = c_ref[...].astype(jnp.float32)        # (1, N)
    dd = d_ref[...].astype(jnp.float32)       # (bh,)
    state = state_ref[...].astype(jnp.float32)  # (1, bh, P, N)

    decay = jnp.exp(dt * a[None, :])          # (1, bh)
    upd = (dt[..., None] * x)[..., None] * b[:, None, None, :]  # (1,bh,P,N)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("zhpn,zn->zhp", new_state, c)
    y = y + x * dd[None, :, None]

    y_ref[...] = y.astype(y_ref.dtype)
    new_state_ref[...] = new_state.astype(new_state_ref.dtype)


def ssd_decode_step_pallas(
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    a: jax.Array,      # (H,)
    b: jax.Array,      # (B, N)
    c: jax.Array,      # (B, N)
    d: jax.Array,      # (H,)
    state: jax.Array,  # (B, H, P, N) f32
    *,
    block_h: int = 8,
    interpret: bool = True,
):
    bsz, h, p = x.shape
    n = b.shape[-1]
    block_h = min(block_h, h)
    nh = pl.cdiv(h, block_h)

    return pl.pallas_call(
        _ssd_step_kernel,
        grid=(bsz, nh),
        in_specs=[
            pl.BlockSpec((1, block_h, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_h), lambda i, j: (i, j)),
            pl.BlockSpec((block_h,), lambda i, j: (j,)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((block_h,), lambda i, j: (j,)),
            pl.BlockSpec((1, block_h, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_h, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_h, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), state.dtype),
        ],
        interpret=interpret,
    )(x, dt, a, b, c, d, state)
