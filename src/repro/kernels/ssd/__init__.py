from repro.kernels.ssd.ops import ssd_decode_step

__all__ = ["ssd_decode_step"]
