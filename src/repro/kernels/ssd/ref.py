"""Pure-jnp oracle for the SSD decode-step kernel — delegates to the model's
own recurrence (`models/ssm.ssd_step`) plus the D skip term, so the kernel,
the model and the tests share one semantic definition."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_step


def ssd_decode_step_reference(x, dt, a, b, c, d, state):
    y, new_state = ssd_step(x, dt, a, b, c, state)
    y = y + x * d[None, :, None].astype(x.dtype)
    return y, new_state
