"""Jit'd public wrapper for the SSD decode-step kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_decode_step_pallas
from repro.kernels.ssd.ref import ssd_decode_step_reference


@functools.partial(jax.jit, static_argnames=("block_h", "impl"))
def ssd_decode_step(
    x, dt, a, b, c, d, state, *, block_h: int = 8, impl: str = "interpret"
):
    if impl == "ref":
        return ssd_decode_step_reference(x, dt, a, b, c, d, state)
    return ssd_decode_step_pallas(
        x, dt, a, b, c, d, state, block_h=block_h, interpret=(impl == "interpret")
    )
