"""Fault injection for the event engine (chaos scenarios).

Real clusters break in ways the paper's clean-cluster experiments never
exercise: machines fail and come back (MTBF/MTTR), NICs degrade
transiently, individual iterations straggle, and users kill jobs.  This
module is the *specification* side of that chaos: a frozen, hashable
:class:`ChaosSpec` plus pure seed-deterministic generators for each fault
process.  The *mechanism* side lives in ``core/engine.py`` — a breakdown is
an involuntary preemption (epoch tombstones, ``_Carry`` requeue, restore
penalty), a NIC degradation is a transient per-server bandwidth multiplier,
a straggler is per-iteration compute jitter.

Determinism contract: every draw is a pure function of ``ChaosSpec.seed``
and the entity's identity (server index, job id, iteration number) — never
of wall clock, dict order, or Python's randomized ``hash()``.  Two engines
built from equal specs replay the identical fault schedule.  A spec whose
``active`` property is false injects *nothing* and the engine treats it as
``chaos=None`` (bit-exact with the unfaulted engine — regression-locked in
``tests/test_chaos.py``).

Fault processes
---------------

* **Server breakdown/repair** — per-server renewal process: time-to-failure
  ~ Exp(mean ``server_mtbf_s``), downtime ~ Exp(mean ``server_mttr_s``),
  independent across servers.  ``scripted_failures`` prepends deterministic
  ``(server, fail_t, repair_t)`` windows — the recovery-storm scenarios use
  these to fail a whole rack and repair it at one synchronized instant.
* **NIC degradation** — same renewal shape (``nic_mtbf_s``/``nic_mttr_s``);
  during a window the server's bandwidth multiplier is scaled by
  ``nic_degraded_scale`` (compounding with any static topology multiplier).
* **Stragglers** — each (job, iteration) is a straggler with probability
  ``straggler_prob``; a straggler's compute segments are stretched by
  ``1 + straggler_slowdown * Exp(1)`` (mean stretch ``straggler_slowdown``).
* **Cancellation** — each job is killed with probability ``cancel_prob`` at
  ``arrival + Exp(mean cancel_after_s)`` if still unfinished then.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterator, Optional, Tuple

__all__ = [
    "ChaosSpec",
    "server_failure_stream",
    "nic_degradation_stream",
    "cancel_time",
    "jitter_factor",
]

# Minimum width of any stochastic window; keeps fail < repair strictly
# ordered in the event queue even for extreme spec values.
_MIN_WINDOW = 1e-6


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Seed-deterministic fault-injection configuration (hashable, so
    scenarios embedding one stay frozen/picklable for the sweep pool)."""

    seed: int = 0
    #: mean time between server failures; 0 disables stochastic breakdowns
    server_mtbf_s: float = 0.0
    #: mean server downtime once failed
    server_mttr_s: float = 60.0
    #: deterministic (server, fail_t, repair_t) windows, injected before any
    #: stochastic ones — the recovery-storm building block
    scripted_failures: Tuple[Tuple[int, float, float], ...] = ()
    #: per-(job, iteration) probability of a straggler iteration
    straggler_prob: float = 0.0
    #: mean extra compute stretch of a straggler iteration (multiplier - 1)
    straggler_slowdown: float = 0.5
    #: mean time between NIC degradation windows per server; 0 disables
    nic_mtbf_s: float = 0.0
    #: mean NIC degradation window length
    nic_mttr_s: float = 30.0
    #: bandwidth multiplier applied to a server while its NIC is degraded
    nic_degraded_scale: float = 0.25
    #: per-job probability of stochastic cancellation
    cancel_prob: float = 0.0
    #: mean delay after arrival before a doomed job is cancelled
    cancel_after_s: float = 300.0

    def __post_init__(self) -> None:
        for f in (
            "server_mtbf_s",
            "server_mttr_s",
            "nic_mtbf_s",
            "nic_mttr_s",
            "cancel_after_s",
            "straggler_slowdown",
        ):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0, got {getattr(self, f)}")
        for f in ("straggler_prob", "cancel_prob"):
            if not 0.0 <= getattr(self, f) <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {getattr(self, f)}")
        if not 0.0 < self.nic_degraded_scale <= 1.0:
            raise ValueError(
                f"nic_degraded_scale must be in (0, 1], got {self.nic_degraded_scale}"
            )
        last_repair: dict = {}
        for srv, fail_t, repair_t in sorted(self.scripted_failures):
            if srv < 0:
                raise ValueError(f"scripted failure on negative server {srv}")
            if not (0.0 <= fail_t < repair_t):
                raise ValueError(
                    f"scripted failure window ({fail_t}, {repair_t}) must satisfy "
                    "0 <= fail < repair"
                )
            if fail_t < last_repair.get(srv, 0.0):
                raise ValueError(
                    f"scripted failure windows overlap on server {srv}"
                )
            last_repair[srv] = repair_t

    @property
    def active(self) -> bool:
        """True iff this spec can inject *any* fault.  An inactive spec is
        treated as ``chaos=None`` by the engine — the zero-rate no-op."""
        return bool(
            self.server_mtbf_s > 0
            or self.scripted_failures
            or self.straggler_prob > 0
            or (self.nic_mtbf_s > 0 and self.nic_degraded_scale < 1.0)
            or self.cancel_prob > 0
        )


# ---------------------------------------------------------------------------
# splitmix64 — keyed deterministic uniforms for the per-iteration draws.
# random.Random would need one generator per (job, iteration) key; splitmix
# gives an O(1) stateless draw that is identical across processes (unlike
# Python's hash(), which is salted per interpreter).
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def _mix(*keys: int) -> int:
    h = 0x9E3779B97F4A7C15
    for k in keys:
        h = (h + (k & _MASK64)) & _MASK64
        h ^= h >> 30
        h = (h * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & _MASK64
        h ^= h >> 31
    return h


def _unit(*keys: int) -> float:
    """Uniform in [0, 1) keyed on the integers ``keys``."""
    return (_mix(*keys) >> 11) * (1.0 / (1 << 53))


# Domain-separation tags so the straggler, cancel-gate and cancel-delay
# draws never alias even for colliding (seed, job) keys.
_TAG_STRAGGLE_GATE = 0xA11CE
_TAG_STRAGGLE_MAG = 0x5EED5
_TAG_CANCEL_GATE = 0xCA9CE1
_TAG_CANCEL_DELAY = 0xDE1A9


def server_failure_stream(
    spec: ChaosSpec, server: int
) -> Iterator[Tuple[float, float]]:
    """Yield ``(fail_t, repair_t)`` windows for ``server`` in time order:
    scripted windows first, then (if ``server_mtbf_s > 0``) an infinite
    stochastic renewal process starting after the last scripted repair."""
    t = 0.0
    for srv, fail_t, repair_t in sorted(
        w for w in spec.scripted_failures if w[0] == server
    ):
        yield fail_t, repair_t
        t = max(t, repair_t)
    if spec.server_mtbf_s <= 0:
        return
    rng = random.Random(f"chaos:{spec.seed}:srv:{server}")
    while True:
        fail_t = t + rng.expovariate(1.0 / spec.server_mtbf_s)
        repair_t = fail_t + max(
            _MIN_WINDOW, rng.expovariate(1.0 / max(spec.server_mttr_s, _MIN_WINDOW))
        )
        yield fail_t, repair_t
        t = repair_t


def nic_degradation_stream(
    spec: ChaosSpec, server: int
) -> Iterator[Tuple[float, float]]:
    """Yield ``(start_t, end_t)`` NIC-degradation windows for ``server`` —
    an infinite stochastic renewal process (empty if disabled)."""
    if spec.nic_mtbf_s <= 0 or spec.nic_degraded_scale >= 1.0:
        return
    rng = random.Random(f"chaos:{spec.seed}:nic:{server}")
    t = 0.0
    while True:
        start_t = t + rng.expovariate(1.0 / spec.nic_mtbf_s)
        end_t = start_t + max(
            _MIN_WINDOW, rng.expovariate(1.0 / max(spec.nic_mttr_s, _MIN_WINDOW))
        )
        yield start_t, end_t
        t = end_t


def cancel_time(spec: ChaosSpec, job_id: int, arrival: float) -> Optional[float]:
    """Absolute cancellation instant for ``job_id``, or None if this job is
    never cancelled.  The engine ignores the instant if the job already
    finished by then."""
    if spec.cancel_prob <= 0:
        return None
    if _unit(spec.seed, job_id, _TAG_CANCEL_GATE) >= spec.cancel_prob:
        return None
    u = _unit(spec.seed, job_id, _TAG_CANCEL_DELAY)
    return arrival + spec.cancel_after_s * -math.log(1.0 - u)


def jitter_factor(spec: ChaosSpec, job_id: int, iteration: int) -> float:
    """Compute-time multiplier (>= 1) for iteration ``iteration`` of job
    ``job_id``.  1.0 for non-straggler iterations."""
    if spec.straggler_prob <= 0:
        return 1.0
    if _unit(spec.seed, job_id, iteration, _TAG_STRAGGLE_GATE) >= spec.straggler_prob:
        return 1.0
    u = _unit(spec.seed, job_id, iteration, _TAG_STRAGGLE_MAG)
    return 1.0 + spec.straggler_slowdown * -math.log(1.0 - u)
