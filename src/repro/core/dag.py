"""DAG representation of DDL training jobs (paper Section III, Fig. 3).

A job running ``I_k`` iterations on ``n`` workers is the chain of ``I_k``
child DAGs; child DAG ``i`` contains, per worker ``w``:

    f(i, w)  ->  b(i, w)  ->  c(i)          (c only if the job spans servers)

with ``c(i)`` a synchronization barrier over all workers' ``b(i, w)`` and
``c(i) -> f(i+1, w)`` for every worker.  A virtual global entry precedes all
jobs' first forwards and a virtual global exit follows all last all-reduces
(Fig. 3(b)).

The event-driven simulator does not literally walk this graph (it exploits
the chain structure for speed); this module provides the *formal* object so
tests can assert that any simulated execution trace is a valid linear
extension of the DAG — i.e. the fast simulator and the formal model agree.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterator, List, Sequence, Tuple


class TaskKind(enum.Enum):
    FORWARD = "f"
    BACKWARD = "b"
    ALLREDUCE = "c"


@dataclasses.dataclass(frozen=True)
class TaskRef:
    """tau^k_{l,m}: task of job ``job_id``, iteration ``iteration``; compute
    tasks carry the worker index, the all-reduce carries worker=-1."""

    job_id: int
    iteration: int
    kind: TaskKind
    worker: int = -1

    def __str__(self) -> str:
        w = "" if self.worker < 0 else f"w{self.worker}"
        return f"J{self.job_id}.i{self.iteration}.{self.kind.value}{w}"


@dataclasses.dataclass(frozen=True)
class JobDag:
    job_id: int
    n_workers: int
    iterations: int
    has_comm: bool

    def tasks(self) -> Iterator[TaskRef]:
        for i in range(self.iterations):
            for w in range(self.n_workers):
                yield TaskRef(self.job_id, i, TaskKind.FORWARD, w)
                yield TaskRef(self.job_id, i, TaskKind.BACKWARD, w)
            if self.has_comm:
                yield TaskRef(self.job_id, i, TaskKind.ALLREDUCE)

    def predecessors(self, task: TaskRef) -> List[TaskRef]:
        """Direct predecessors of ``task`` within this job's DAG."""
        i, w = task.iteration, task.worker
        if task.kind is TaskKind.FORWARD:
            if i == 0:
                return []
            if self.has_comm:
                return [TaskRef(self.job_id, i - 1, TaskKind.ALLREDUCE)]
            # without a comm task, the barrier degenerates to: next forward
            # of worker w follows its own backward (workers run free).
            return [TaskRef(self.job_id, i - 1, TaskKind.BACKWARD, w)]
        if task.kind is TaskKind.BACKWARD:
            return [TaskRef(self.job_id, i, TaskKind.FORWARD, w)]
        # ALLREDUCE: barrier over all workers' backwards of this iteration.
        return [
            TaskRef(self.job_id, i, TaskKind.BACKWARD, ww)
            for ww in range(self.n_workers)
        ]

    def n_tasks(self) -> int:
        per_iter = 2 * self.n_workers + (1 if self.has_comm else 0)
        return per_iter * self.iterations


def build_job_dag(job_id: int, n_workers: int, iterations: int, spans_servers: bool) -> JobDag:
    return JobDag(job_id, n_workers, iterations, has_comm=spans_servers)


def validate_schedule(
    dag: JobDag, intervals: Dict[TaskRef, Tuple[float, float]], eps: float = 1e-9
) -> Tuple[bool, str]:
    """Check a simulated schedule against the formal DAG: every task of the
    DAG must appear exactly once with ``start <= end``, and each task may
    start only after *all* its predecessors have ended (precedence edges of
    Fig. 3, including the all-reduce barrier).

    Used by the property tests to certify that the fast chain-structured
    simulator executes a valid schedule of the formal DAG.
    """
    expected = set(dag.tasks())
    got = set(intervals)
    if got != expected:
        missing = expected - got
        extra = got - expected
        return False, (
            f"task set mismatch: missing={[str(t) for t in list(missing)[:3]]} "
            f"extra={[str(t) for t in list(extra)[:3]]}"
        )
    for t, (start, end) in intervals.items():
        if end < start - eps:
            return False, f"task {t} ends before it starts"
        for p in dag.predecessors(t):
            if intervals[p][1] > start + eps:
                return False, f"edge violated: {p} (end {intervals[p][1]}) !<= {t} (start {start})"
    return True, "ok"
