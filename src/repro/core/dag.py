"""DAG representation of DDL training jobs (paper Section III, Fig. 3),
extended to layer granularity for the WFBP communication subsystem.

A job running ``I_k`` iterations on ``n`` workers is the chain of ``I_k``
child DAGs; child DAG ``i`` contains, per worker ``w``:

    f(i, w)  ->  b(i, w)  ->  c(i)          (c only if the job spans servers)

with ``c(i)`` a synchronization barrier over all workers' ``b(i, w)`` and
``c(i) -> f(i+1, w)`` for every worker.  A virtual global entry precedes all
jobs' first forwards and a virtual global exit follows all last all-reduces
(Fig. 3(b)).

**Layer-granular extension** (``n_buckets > 1``): wait-free backpropagation
with tensor fusion splits the backward pass into per-bucket segments and
the all-reduce into per-bucket transfers:

    f(i, w) -> b(i, w, 0) -> b(i, w, 1) -> ... -> b(i, w, B-1)
    c(i, l) <- { b(i, w, l) for every w }  ∪  { c(i, l-1) }
    f(i+1, w) <- c(i, B-1)

``c(i, l)`` is a barrier over all workers' segment-``l`` backwards plus the
previous bucket's transfer (the comm stream serializes buckets FIFO, the
PyTorch-DDP model), and **only the last bucket's transfer blocks the next
iteration's forward** — earlier transfers overlap the remaining backward
segments.  ``n_buckets=1`` degenerates task-for-task to the monolithic
Fig. 3 DAG above (segments carry index -1, the legacy naming).

The event-driven simulator does not literally walk this graph (it exploits
the chain structure for speed); this module provides the *formal* object so
tests can assert that any simulated execution trace — fused or WFBP — is a
valid linear extension of the DAG, i.e. the fast simulator and the formal
model agree.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterator, List, Sequence, Tuple


class TaskKind(enum.Enum):
    FORWARD = "f"
    BACKWARD = "b"
    ALLREDUCE = "c"


@dataclasses.dataclass(frozen=True)
class TaskRef:
    """tau^k_{l,m}: task of job ``job_id``, iteration ``iteration``; compute
    tasks carry the worker index, the all-reduce carries worker=-1.

    ``segment`` indexes the WFBP bucket (backward segment / per-bucket
    transfer) in the layer-granular DAG; -1 is the monolithic reading
    (``n_buckets == 1``), keeping legacy task identities unchanged."""

    job_id: int
    iteration: int
    kind: TaskKind
    worker: int = -1
    segment: int = -1

    def __str__(self) -> str:
        w = "" if self.worker < 0 else f"w{self.worker}"
        s = "" if self.segment < 0 else f"s{self.segment}"
        return f"J{self.job_id}.i{self.iteration}.{self.kind.value}{w}{s}"


@dataclasses.dataclass(frozen=True)
class JobDag:
    job_id: int
    n_workers: int
    iterations: int
    has_comm: bool
    #: WFBP bucket count: 1 = the monolithic Fig. 3 DAG (segment index -1
    #: everywhere, preserving legacy task identities); B > 1 = the
    #: layer-granular extension with B backward segments and B per-bucket
    #: transfers per iteration (requires has_comm).
    n_buckets: int = 1

    def __post_init__(self) -> None:
        if self.n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1, got {self.n_buckets}")
        if self.n_buckets > 1 and not self.has_comm:
            raise ValueError("layer-granular DAG (n_buckets > 1) needs comm")

    def _seg(self, l: int) -> int:
        """Segment index as stored on tasks: -1 in the monolithic DAG."""
        return -1 if self.n_buckets == 1 else l

    def tasks(self) -> Iterator[TaskRef]:
        for i in range(self.iterations):
            for w in range(self.n_workers):
                yield TaskRef(self.job_id, i, TaskKind.FORWARD, w)
                for l in range(self.n_buckets):
                    yield TaskRef(self.job_id, i, TaskKind.BACKWARD, w, self._seg(l))
            if self.has_comm:
                for l in range(self.n_buckets):
                    yield TaskRef(self.job_id, i, TaskKind.ALLREDUCE, -1, self._seg(l))

    def predecessors(self, task: TaskRef) -> List[TaskRef]:
        """Direct predecessors of ``task`` within this job's DAG."""
        i, w, s = task.iteration, task.worker, task.segment
        last = self._seg(self.n_buckets - 1)
        if task.kind is TaskKind.FORWARD:
            if i == 0:
                return []
            if self.has_comm:
                # only the LAST bucket's transfer blocks the next forward —
                # earlier buckets overlap the remaining backward segments.
                return [TaskRef(self.job_id, i - 1, TaskKind.ALLREDUCE, -1, last)]
            # without a comm task, the barrier degenerates to: next forward
            # of worker w follows its own backward (workers run free).
            return [TaskRef(self.job_id, i - 1, TaskKind.BACKWARD, w, last)]
        if task.kind is TaskKind.BACKWARD:
            if self.n_buckets > 1 and s > 0:
                return [TaskRef(self.job_id, i, TaskKind.BACKWARD, w, s - 1)]
            return [TaskRef(self.job_id, i, TaskKind.FORWARD, w)]
        # ALLREDUCE(i, l): barrier over all workers' segment-l backwards,
        # plus the previous bucket's transfer (FIFO comm stream).
        preds = [
            TaskRef(self.job_id, i, TaskKind.BACKWARD, ww, s)
            for ww in range(self.n_workers)
        ]
        if self.n_buckets > 1 and s > 0:
            preds.append(TaskRef(self.job_id, i, TaskKind.ALLREDUCE, -1, s - 1))
        return preds

    def n_tasks(self) -> int:
        per_iter = self.n_workers * (1 + self.n_buckets) + (
            self.n_buckets if self.has_comm else 0
        )
        return per_iter * self.iterations


def build_job_dag(
    job_id: int,
    n_workers: int,
    iterations: int,
    spans_servers: bool,
    n_buckets: int = 1,
) -> JobDag:
    return JobDag(job_id, n_workers, iterations, has_comm=spans_servers,
                  n_buckets=n_buckets)


def validate_schedule(
    dag: JobDag, intervals: Dict[TaskRef, Tuple[float, float]], eps: float = 1e-9
) -> Tuple[bool, str]:
    """Check a simulated schedule against the formal DAG: every task of the
    DAG must appear exactly once with ``start <= end``, and each task may
    start only after *all* its predecessors have ended (precedence edges of
    Fig. 3, including the all-reduce barrier).

    Used by the property tests to certify that the fast chain-structured
    simulator executes a valid schedule of the formal DAG.
    """
    expected = set(dag.tasks())
    got = set(intervals)
    if got != expected:
        missing = expected - got
        extra = got - expected
        return False, (
            f"task set mismatch: missing={[str(t) for t in list(missing)[:3]]} "
            f"extra={[str(t) for t in list(extra)[:3]]}"
        )
    for t, (start, end) in intervals.items():
        if end < start - eps:
            return False, f"task {t} ends before it starts"
        for p in dag.predecessors(t):
            if intervals[p][1] > start + eps:
                return False, f"edge violated: {p} (end {intervals[p][1]}) !<= {t} (start {start})"
    return True, "ok"
