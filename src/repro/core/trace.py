"""Workload trace generation (paper Section V-A, Microsoft-trace-like).

160 jobs arriving over 20 minutes (1200 s, 1 s ticks):

* GPU-count distribution: 80 x 1-GPU, 14 x 2, 26 x 4, 30 x 8, 8 x 16, 2 x 32.
* Iterations ~ U{1000..6000}.
* Model sampled uniformly from the paper's Table III profiles.
* Arrival counts per second ~ uniform, refined so the total is exactly 160
  (we draw arrival *times* uniformly over [1, 1200] and floor to the tick,
  which yields the same distribution).

A job is "large" if it needs > 4 GPUs, "long" if it runs > 1600 iterations
(paper's characterization).

Trace-replay scale: :class:`TraceSource` is the streaming-arrival protocol
the event engine accepts in place of a materialized job list — arrivals
are yielded lazily in nondecreasing order, so a 100k+-job replay holds
O(live jobs) memory instead of the whole trace.  Synthetic generators and
Philly/Alibaba-style CSV loaders live in ``repro.scenarios.tracesource``;
:class:`ListTraceSource` adapts any in-memory job list.
"""

from __future__ import annotations

import random
from typing import Iterator, List, Optional, Sequence

from repro.core.cluster import TABLE_III, JobSpec, ModelProfile


class TraceSource:
    """Streaming arrival feed: the engine pulls arrivals one at a time.

    Subclasses implement :meth:`arrivals` to yield :class:`JobSpec`s in
    **nondecreasing arrival order with unique job ids** (the engine
    validates both and raises on violations).  ``n_jobs_hint`` is the
    expected job count when knowable up front (synthetic generators), or
    None (e.g. a CSV being streamed) — callers that need the exact count
    must materialize.

    ``arrivals`` must be restartable: each call returns a fresh iterator
    over the same deterministic trace (sweeps and differential tests rely
    on replaying one source several times).
    """

    def arrivals(self) -> Iterator[JobSpec]:
        raise NotImplementedError

    def n_jobs_hint(self) -> Optional[int]:
        return None

    def materialize(self) -> List[JobSpec]:
        """The whole trace as an in-memory list (list-mode twin runs,
        fluid-backend handoff, small-scenario registry plumbing)."""
        return list(self.arrivals())


class ListTraceSource(TraceSource):
    """Adapter: an in-memory job list behind the streaming protocol."""

    def __init__(self, jobs: Sequence[JobSpec]) -> None:
        self._jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))

    def arrivals(self) -> Iterator[JobSpec]:
        return iter(self._jobs)

    def n_jobs_hint(self) -> Optional[int]:
        return len(self._jobs)

PAPER_GPU_DISTRIBUTION = ((1, 80), (2, 14), (4, 26), (8, 30), (16, 8), (2 * 16, 2))


def paper_trace(
    seed: int = 0,
    n_jobs: int = 160,
    horizon_s: float = 1200.0,
    min_iters: int = 1000,
    max_iters: int = 6000,
    models: Optional[Sequence[ModelProfile]] = None,
    gpu_distribution=PAPER_GPU_DISTRIBUTION,
) -> List[JobSpec]:
    """Generate the paper's workload (scaled when ``n_jobs != 160``)."""
    rng = random.Random(seed)
    models = list(models) if models is not None else list(TABLE_III.values())

    total = sum(c for _, c in gpu_distribution)
    gpu_counts: List[int] = []
    for gpus, count in gpu_distribution:
        scaled = max(1, round(count * n_jobs / total)) if count else 0
        gpu_counts.extend([gpus] * scaled)
    # trim/pad with 1-GPU jobs to hit n_jobs exactly
    rng.shuffle(gpu_counts)
    gpu_counts = gpu_counts[:n_jobs]
    while len(gpu_counts) < n_jobs:
        gpu_counts.append(1)

    jobs = []
    for k in range(n_jobs):
        arrival = float(int(rng.uniform(1.0, horizon_s)))  # 1 s ticks
        iters = rng.randint(min_iters, max_iters)
        model = rng.choice(models)
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=arrival,
                n_gpus=gpu_counts[k],
                iterations=iters,
                model=model,
            )
        )
    jobs.sort(key=lambda j: (j.arrival, j.job_id))
    return jobs


def is_large(job: JobSpec) -> bool:
    return job.n_gpus > 4


def is_long(job: JobSpec) -> bool:
    return job.iterations > 1600
