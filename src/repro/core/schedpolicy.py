"""Scheduling strategy layer of the event core (engine/policy split).

``core/engine.py`` owns the *mechanism* — event calendar, cluster/GPU and
comm-stream state, trace recording — and delegates every job-level decision
to a :class:`SchedPolicy` through three hooks:

* :meth:`SchedPolicy.on_arrival`    — a job was appended to the wait queue;
* :meth:`SchedPolicy.on_job_finish` — a job completed and freed resources;
* :meth:`SchedPolicy.on_quantum`    — a periodic scheduling tick (only when
  the policy sets ``quantum``).

Hooks act imperatively through the engine's small decision API
(``engine.place_job`` / ``engine.preempt_job`` / ``engine.request_resize``
plus read access to the queue, runs, cluster and SRSF keys); the engine
counts the resulting admit/preempt/resize actions for the metrics layer.

Three policies ship:

* :class:`StaticGangPolicy` — the paper's Algorithm 3 admission: the wait
  queue is scanned in SRSF order and each job's gang placement is held
  until completion.  This is the pre-split simulator's behaviour
  **bit-for-bit** (locked against captured pre-refactor traces in
  ``tests/test_engine.py``).
* :class:`PreemptiveSrsfPolicy` — beyond-paper, Tiresias-style (Gu et al.,
  NSDI'19): on every arrival and quantum tick, running jobs whose SRSF
  remaining service exceeds a waiting job's by ``margin`` are checkpointed
  and requeued so the small job runs now.  Preempted work resumes from the
  last completed iteration and pays a checkpoint/restore penalty
  (:func:`repro.core.netmodel.preemption_cost`).
* :class:`ElasticPolicy` — beyond-paper: jobs that declare
  ``JobSpec.min_gpus``/``max_gpus`` are admitted at whatever feasible size
  the bounds allow, shrunk at iteration boundaries when inelastic work
  queues, and grown into capacity freed by finishing jobs.  Total work is
  conserved in *samples* (``iterations x nominal GPUs``); the engine
  rebuilds the WFBP fusion plan and topology domain sets for the new
  world size on every resize.

The communication gating policies (AdaDUAL Algorithm 2, SRSF(n), k-way
AdaDUAL) also live here — they are the comm-task half of the strategy
layer, consulted by the engine's gating loop through
:class:`CommPolicy.should_start`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.adadual import (
    adadual_should_start,
    kway_adadual_should_start,
    kway_lookahead_costs,
    srsf_n_should_start,
)
from repro.core.cluster import JobSpec
from repro.core.contention import ContentionParams

# ---------------------------------------------------------------------------
# Communication gating policies
# ---------------------------------------------------------------------------


class CommPolicy:
    """Decides whether a ready communication task may start now.

    ``max_concurrent`` and ``old_remaining`` describe the in-flight
    communication tasks on the servers the new task touches (Alg. 2 inputs).
    """

    name = "base"
    #: Declares that a False ``should_start`` decision cannot flip to True
    #: while the in-flight transfers merely *drain* (no start/end/abort on
    #: the waiter's domains).  The engine's incremental gating skips
    #: re-evaluating stably-False waiters only when this is True; False is
    #: the safe default — the engine then re-evaluates every waiter on
    #: every event, which is the full-rescan behaviour through the
    #: incremental code path.  AdaDUAL qualifies (start iff ``new_bytes <
    #: min(old_remaining) * threshold`` under a ``max_concurrent`` cap, and
    #: drain only shrinks ``min(old_remaining)``); SRSF(n) qualifies
    #: trivially (reads ``max_concurrent`` only).  The exact k-way
    #: lookahead does NOT — it integrates the actual remaining bytes, so
    #: drain alone can flip its decision.
    drain_monotone = False

    def should_start(
        self,
        new_bytes: float,
        old_remaining: Sequence[float],
        max_concurrent: int,
        params: ContentionParams,
    ) -> bool:
        raise NotImplementedError

    def explain(
        self,
        new_bytes: float,
        old_remaining: Sequence[float],
        max_concurrent: int,
        params: ContentionParams,
    ) -> Optional[dict]:
        """The terms ``should_start`` evaluated, for the observability audit
        log (``ObsConfig(audit=True)``).  Purely diagnostic: never consulted
        by the engine's gating loop, so a policy without an override simply
        audits as decision-only (``None``)."""
        return None


class SrsfN(CommPolicy):
    """SRSF(n): accept at most n-way contention, blindly (paper baselines)."""

    drain_monotone = True  # decision reads max_concurrent only

    def __init__(self, n: int) -> None:
        self.n = n
        self.name = f"SRSF({n})"

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return srsf_n_should_start(max_concurrent, self.n)

    def explain(self, new_bytes, old_remaining, max_concurrent, params):
        return {
            "rule": "max_concurrent + 1 <= n",
            "max_concurrent": max_concurrent,
            "n": self.n,
        }


class AdaDual(CommPolicy):
    """The paper's AdaDUAL (Algorithm 2)."""

    name = "Ada-SRSF"
    #: Theorem 2's test is ``new/min(old) < threshold`` (plus the 2-way
    #: cap): drain shrinks ``min(old)``, so False decisions stay False
    #: until the active set itself changes.
    drain_monotone = True

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return adadual_should_start(new_bytes, old_remaining, max_concurrent, params)

    def explain(self, new_bytes, old_remaining, max_concurrent, params):
        min_old = min(old_remaining) if old_remaining else float("inf")
        ratio = (new_bytes / min_old) if min_old > 0 else float("inf")
        return {
            "rule": "new/min(old) < threshold and k+1 <= 2",
            "min_old_bytes": min_old,
            "ratio": ratio,
            "threshold": params.dual_threshold,
            "cap_ok": max_concurrent + 1 <= 2,
        }


class KWayAdaDual(CommPolicy):
    """Beyond-paper: exact-lookahead k-way generalization (future work #2)."""

    drain_monotone = False  # exact lookahead over remaining bytes: drain
    #                         alone can flip wait -> start

    def __init__(self, max_ways: int = 3) -> None:
        self.max_ways = max_ways
        self.name = f"KWay({max_ways})-SRSF"

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return kway_adadual_should_start(
            new_bytes, old_remaining, params, max_ways=self.max_ways
        )

    def explain(self, new_bytes, old_remaining, max_concurrent, params):
        olds = [m for m in old_remaining if m > 0]
        k = len(olds)
        terms = {
            "rule": "avg(start now) < avg(wait for first old)",
            "k_in_flight": k,
            "max_ways": self.max_ways,
        }
        if k == 0:
            terms["clean_link"] = True
        elif k + 1 > self.max_ways:
            terms["ways_capped"] = True
        else:
            avg_a, avg_b = kway_lookahead_costs(new_bytes, olds, params)
            terms["t_contend_avg"] = avg_a
            terms["t_wait_avg"] = avg_b
        return terms


def comm_policy_from_name(comm: str) -> CommPolicy:
    """'ada' (AdaDUAL), 'srsfN', or 'kwayK' -> a CommPolicy instance."""
    if comm == "ada":
        return AdaDual()
    if comm.startswith("srsf"):
        return SrsfN(int(comm[4:]))
    if comm.startswith("kway"):
        return KWayAdaDual(int(comm[4:]))
    raise ValueError(f"unknown comm policy {comm!r}")


# ---------------------------------------------------------------------------
# Job scheduling policies (the engine/policy split's strategy side)
# ---------------------------------------------------------------------------


class SchedPolicy:
    """Job-level scheduling strategy consulted by ``core/engine.py``.

    Subclasses decide *which* jobs run where (admit / place / preempt /
    resize) by calling the engine's decision API from the hooks below; the
    engine supplies all mechanism (event calendar, cluster state, comm
    streams) and never makes a placement decision itself.
    """

    name = "base"
    #: Period of the engine's "quantum" events; None disables them (the
    #: static policy needs none, keeping the event stream — and hence the
    #: pre-refactor traces — untouched).
    quantum: Optional[float] = None

    def bind(self, engine) -> None:
        """Called once by the engine before the run starts."""
        self.engine = engine

    def on_arrival(self, now: float, job_id: int) -> None:
        """``job_id`` was just appended to ``engine.queue``."""

    def on_job_finish(self, now: float, job_id: int) -> None:
        """``job_id`` completed; its memory and GPUs are free again."""

    def on_quantum(self, now: float) -> None:
        """Periodic tick (only fired when ``quantum`` is set)."""

    def on_resize(self, now: float, job_id: int) -> None:
        """The engine applied a pending resize of ``job_id`` at an
        iteration boundary (capacity may have been freed)."""

    def on_fault(self, now: float, server: int, victims: Sequence[int]) -> None:
        """A server broke down (fault injection, ``core/chaos.py``): its
        gangs (``victims``) were force-preempted and requeued, its GPUs are
        unplaceable until repair.  The surviving capacity may still admit
        the victims (or other queued jobs) elsewhere."""

    def on_recovery(self, now: float, server: int) -> None:
        """A broken server came back: its GPUs are placeable again.  This
        is the synchronized re-admission instant the chaos recovery-storm
        scenarios probe — every job queued behind the failure competes for
        placement (and then for bandwidth) at once."""


class StaticGangPolicy(SchedPolicy):
    """The paper's Algorithm 3 admission — SRSF-ordered queue scan, gang
    placement held until completion, no preemption, no elasticity.

    ``_place_queue`` is the pre-split ``ClusterSimulator._try_place`` body
    verbatim (same sort, same placement calls, same commit order), so this
    policy reproduces the monolithic simulator bit-for-bit.
    """

    name = "static"

    def bind(self, engine) -> None:
        super().bind(engine)
        self._failed_profiles: set = set()
        self._failed_epoch = -1

    def on_arrival(self, now: float, job_id: int) -> None:
        self._place_queue(now)

    def on_job_finish(self, now: float, job_id: int) -> None:
        self._place_queue(now)

    def on_quantum(self, now: float) -> None:
        self._place_queue(now)

    def on_resize(self, now: float, job_id: int) -> None:
        self._place_queue(now)

    def on_fault(self, now: float, server: int, victims: Sequence[int]) -> None:
        # surviving servers may still fit the requeued victims (or other
        # queued jobs whose LWF ranking just changed)
        self._place_queue(now)

    def on_recovery(self, now: float, server: int) -> None:
        # synchronized re-admission: everything queued behind the failure
        # competes for the repaired capacity in one SRSF-ordered scan
        self._place_queue(now)

    def _place_queue(self, now: float) -> None:
        eng = self.engine
        if not eng.queue:
            return
        # eng.queue is maintained in srsf_key_queued order by the engine
        # itself (insort on arrival and preemption requeue; the key is
        # static while a job waits), so the pre-split per-event sort became
        # a no-op and was dropped — same scan order, O(log n) per insert
        # instead of O(n log n) per event.
        placed: List[int] = []
        # Every placement policy is a pure function of (n_gpus, mem_mb)
        # given a fixed cluster state, and a failed attempt mutates nothing
        # (the rand policy draws from its rng only on success) — so a
        # resource profile that failed keeps failing until some job places.
        # Memoizing the failures makes a long blocked queue cost O(distinct
        # profiles) placement attempts per event instead of O(queue), with
        # an identical event stream.  The memo survives *across* events:
        # placement success is determined by feasible-GPU count alone
        # (workloads only order the choice), and the feasible set only
        # grows at a release/repair — which bumps ``capacity_epoch`` — so
        # at an unchanged epoch (e.g. a pure-arrival burst into a saturated
        # cluster) nothing needs re-attempting.
        failed = self._failed_profiles
        epoch = eng.cluster.capacity_epoch
        if self._failed_epoch != epoch:
            failed.clear()
            self._failed_epoch = epoch
        refreshed = False
        for jid in eng.queue:
            spec = eng.jobs[jid]
            profile = (spec.n_gpus, spec.model.mem_mb)
            if profile in failed:
                continue  # no head-of-line blocking (Alg. 3 loops the queue)
            if not refreshed:
                # Alg. 3 line 3, deferred to the first real attempt: the
                # workloads only order a placement's GPU choice, so a scan
                # the memo fully short-circuits needs no refresh at all
                # (nothing mutates between here and the scan's start)
                eng.refresh_workloads()
                refreshed = True
            gpu_ids = eng.placement(eng.cluster, spec)
            if gpu_ids is None:
                failed.add(profile)
                continue
            eng.place_job(jid, gpu_ids, now)
            failed.clear()
            placed.append(jid)
        for jid in placed:
            eng.queue.remove(jid)


class PreemptiveSrsfPolicy(StaticGangPolicy):
    """Tiresias-style preemptive SRSF (beyond-paper).

    On every arrival and quantum tick, after the normal queue scan, each
    still-waiting job may evict running jobs whose SRSF remaining service
    exceeds its own by more than ``margin`` (hysteresis against thrash).
    Victims are checkpointed (``engine.preempt_job``: gang torn down
    atomically, progress carried in completed iterations) and requeued;
    they pay the checkpoint/restore penalty when they next run.  A victim
    younger than ``min_run`` seconds is immune, bounding preemption
    frequency the way Tiresias' promotion knob does.
    """

    name = "preemptive_srsf"

    def __init__(
        self,
        quantum: float = 25.0,
        margin: float = 1.25,
        min_run: Optional[float] = None,
    ) -> None:
        if quantum <= 0:
            raise ValueError(f"quantum must be positive, got {quantum}")
        if margin < 1.0:
            raise ValueError(f"margin must be >= 1, got {margin}")
        self.quantum = quantum
        self.margin = margin
        self.min_run = quantum if min_run is None else min_run

    def on_arrival(self, now: float, job_id: int) -> None:
        self._place_queue(now)
        self._preempt_for_queue(now)

    def on_quantum(self, now: float) -> None:
        self._place_queue(now)
        self._preempt_for_queue(now)

    def _preempt_for_queue(self, now: float) -> None:
        eng = self.engine
        if not eng.queue:
            return
        eng.refresh_workloads()
        eng.queue.sort(key=eng.srsf_key_queued)
        total_gpus = len(eng.cluster.gpus)
        gpu_mem = next(iter(eng.cluster.gpus.values())).mem_capacity_mb
        placed: List[int] = []
        for jid in list(eng.queue):
            spec = eng.jobs[jid]
            need = spec.n_gpus
            if need > total_gpus or spec.model.mem_mb > gpu_mem:
                continue  # can never be placed: evicting for it is pure churn
            # capacity freed by an earlier waiter's evictions may already
            # fit this one — always retry plain placement before evicting
            gpu_ids = eng.placement(eng.cluster, spec)
            if gpu_ids is not None:
                eng.place_job(jid, gpu_ids, now)
                placed.append(jid)
                continue
            waiter_rem = eng.srsf_key_queued(jid)[0]
            victims = sorted(
                (
                    (eng.srsf_key_running(rid)[0], rid)
                    for rid, run in eng.runs.items()
                    if run.finished_at is None
                    and now - run.placed_at >= self.min_run
                    and eng.srsf_key_running(rid)[0] > waiter_rem * self.margin
                ),
                reverse=True,
            )
            if not victims:
                continue
            gpu_ids = None
            evicted = 0
            for _, rid in victims:
                evicted += eng.runs[rid].n_world
                eng.preempt_job(rid, now)
                # re-rank with the victim's workload actually gone, so the
                # waiter lands on the just-freed GPUs instead of LWF still
                # seeing them as loaded (cluster.release keeps L_g)
                eng.refresh_workloads()
                gpu_ids = eng.placement(eng.cluster, eng.jobs[jid])
                if gpu_ids is not None:
                    break
                if evicted >= need:
                    break  # enough GPUs torn down; memory still blocks us
            if gpu_ids is not None:
                eng.place_job(jid, gpu_ids, now)
                placed.append(jid)
        for jid in placed:
            if jid in eng.queue:
                eng.queue.remove(jid)


class ElasticPolicy(StaticGangPolicy):
    """Elastic gang scheduling (beyond-paper).

    Jobs that declare ``JobSpec.min_gpus``/``max_gpus`` are *elastic*:
    their total work is fixed in samples (``iterations x nominal GPUs``)
    and their world size may change at iteration boundaries.  The policy

    * admits an elastic job at the largest feasible size within its
      bounds (preferring max, then the nominal request, then min);
    * **shrinks** running elastic gangs toward ``min_gpus`` when queued
      work cannot be placed (resize requests applied by the engine at the
      next iteration boundary, freeing GPUs for the queue);
    * **grows** the running elastic job with the most remaining service
      into capacity freed by a finishing job.

    Every resize tears the gang down at a boundary and re-places it, so
    the WFBP fusion plan and the topology domain sets are rebuilt for the
    new world size by the same code path as a fresh admission.
    """

    name = "elastic"

    def __init__(self, quantum: Optional[float] = None) -> None:
        # a quantum is optional: arrivals/finishes/resizes already trigger
        # re-evaluation; a tick adds periodic growth on long-idle clusters
        self.quantum = quantum

    # -- admission ---------------------------------------------------------
    def _candidate_sizes(self, spec: JobSpec) -> List[int]:
        if not spec.is_elastic:
            return [spec.n_gpus]
        lo, hi = spec.gpu_bounds
        return sorted({hi, spec.n_gpus, lo}, reverse=True)

    def _place_queue(self, now: float) -> None:
        eng = self.engine
        if not eng.queue:
            return
        eng.refresh_workloads()
        eng.queue.sort(key=eng.srsf_key_queued)
        placed: List[int] = []
        for jid in eng.queue:
            spec = eng.jobs[jid]
            for n in self._candidate_sizes(spec):
                trial = (
                    spec if n == spec.n_gpus else dataclasses.replace(spec, n_gpus=n)
                )
                gpu_ids = eng.placement(eng.cluster, trial)
                if gpu_ids is not None:
                    eng.place_job(jid, gpu_ids, now)
                    placed.append(jid)
                    break
        for jid in placed:
            eng.queue.remove(jid)

    # -- elasticity --------------------------------------------------------
    def on_arrival(self, now: float, job_id: int) -> None:
        self._place_queue(now)
        self._shrink_for_queue(now)

    def on_job_finish(self, now: float, job_id: int) -> None:
        self._place_queue(now)
        self._grow_into_free(now)

    def on_quantum(self, now: float) -> None:
        self._place_queue(now)
        self._shrink_for_queue(now)
        self._grow_into_free(now)

    def on_resize(self, now: float, job_id: int) -> None:
        self._place_queue(now)

    def on_fault(self, now: float, server: int, victims: Sequence[int]) -> None:
        # capacity just shrank: re-place what fits, then shrink elastic
        # gangs so the breakdown's victims get back in sooner
        self._place_queue(now)
        self._shrink_for_queue(now)

    def on_recovery(self, now: float, server: int) -> None:
        # repaired capacity: queue first, then grow elastic gangs into
        # whatever the re-admitted jobs left free
        self._place_queue(now)
        self._grow_into_free(now)

    def _shrink_for_queue(self, now: float) -> None:
        """Request boundary shrinks of elastic gangs until the freed GPU
        count covers the smallest waiting job's requirement."""
        eng = self.engine
        if not eng.queue:
            return
        needed = min(eng.jobs[jid].gpu_bounds[0] for jid in eng.queue)
        freeable = 0
        shrinkable = sorted(
            (
                (run.n_world, rid)
                for rid, run in eng.runs.items()
                if run.finished_at is None
                and eng.jobs[rid].is_elastic
                and run.pending_resize is None
                and run.n_world > eng.jobs[rid].gpu_bounds[0]
            ),
            reverse=True,
        )
        for n_world, rid in shrinkable:
            lo = eng.jobs[rid].gpu_bounds[0]
            eng.request_resize(rid, lo)
            freeable += n_world - lo
            if freeable >= needed:
                break

    def _grow_into_free(self, now: float) -> None:
        """Grow the running elastic job with the most remaining service
        into currently-free feasible GPUs (one job per event; the resize
        hook re-evaluates, so growth cascades without overcommitting)."""
        eng = self.engine
        if eng.queue:
            return  # queued work has first claim on free capacity
        candidates = sorted(
            (
                (eng.srsf_key_running(rid)[0], rid)
                for rid, run in eng.runs.items()
                if run.finished_at is None
                and eng.jobs[rid].is_elastic
                and run.pending_resize is None
                and run.n_world < eng.jobs[rid].gpu_bounds[1]
            ),
            reverse=True,
        )
        for _, rid in candidates:
            run = eng.runs[rid]
            free = len(eng.cluster.available_gpus(eng.jobs[rid].model.mem_mb))
            if free <= 0:
                return
            hi = eng.jobs[rid].gpu_bounds[1]
            eng.request_resize(rid, min(hi, run.n_world + free))
            return


SCHED_POLICIES = ("static", "preemptive_srsf", "elastic")


def sched_policy_from_name(
    sched: str,
    quantum: Optional[float] = None,
    **kw,
) -> SchedPolicy:
    """'static' | 'preemptive_srsf' | 'elastic' -> a :class:`SchedPolicy`.

    ``quantum`` overrides the policy's default tick period (ignored by
    ``static``, which never ticks)."""
    s = sched.lower()
    if s == "static":
        return StaticGangPolicy()
    if s in ("preemptive_srsf", "preemptive"):
        if quantum is not None:
            kw["quantum"] = quantum
        return PreemptiveSrsfPolicy(**kw)
    if s == "elastic":
        return ElasticPolicy(quantum=quantum, **kw)
    raise ValueError(
        f"unknown scheduling policy {sched!r}; expected one of {SCHED_POLICIES}"
    )
