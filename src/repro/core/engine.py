"""Event engine for multi-job DDL cluster simulation (the mechanism half
of the engine/policy split; paper Algorithm 3 and Section V, exact
continuous-time variant).

This module owns everything *mechanical*: the event calendar, cluster/GPU
occupancy, the communication streams (Eq. 5 contention with exact
piecewise-constant-rate integration, WFBP bucket pipelines, topology
domain sets), trace recording, and result collection.  Every job-level
*decision* — admit, place, preempt, resize — is delegated to a
:class:`~repro.core.schedpolicy.SchedPolicy` through its
``on_arrival`` / ``on_job_finish`` / ``on_quantum`` hooks; the engine
exposes a small decision API for them:

* :meth:`EventEngine.place_job`       — commit a gang placement (rebuilds
  the WFBP fusion plan and topology domain sets for the placed world);
* :meth:`EventEngine.preempt_job`     — atomically tear a running gang
  down: cancel its in-flight compute and communication, release memory,
  carry its *completed* iterations, requeue it (the in-progress iteration
  is lost; the next placement pays the checkpoint/restore penalty
  :func:`repro.core.netmodel.preemption_cost`);
* :meth:`EventEngine.request_resize`  — schedule an elastic world-size
  change, applied by the engine at the job's next iteration boundary
  (where no in-iteration work exists to lose).

The default :class:`~repro.core.schedpolicy.StaticGangPolicy` reproduces
the pre-split monolithic ``ClusterSimulator`` bit-for-bit (no quantum
events, no preemption, no elasticity — the event stream is untouched);
``core/simulator.py`` remains the compatibility entry point.

Semantics preserved from the paper (see the original module docstring,
now in ``core/simulator.py``): online arrivals, SRSF priority everywhere,
memory admission with GPU time-sharing, pluggable communication gating
(AdaDUAL / SRSF(n) / k-way) and placement, and the beyond-paper WFBP
tensor-fusion subsystem.

Fault injection (beyond-paper, ``core/chaos.py``): a :class:`ChaosSpec`
arms seed-deterministic server breakdown/repair processes (a breakdown
force-preempts every gang touching the dead server and marks its GPUs
unplaceable until repair), transient per-server NIC degradation windows
(per-server bandwidth multipliers, integrated exactly), per-iteration
straggler jitter, and stochastic job cancellation.  Policies observe
faults through the ``on_fault`` / ``on_recovery`` hooks.  An absent or
inactive spec leaves the event stream bit-exact with the unfaulted
engine.

Progress accounting is in *samples* (per-GPU batches): a job's total work
is ``iterations x nominal GPUs`` and each completed iteration contributes
the current world size, so rigid jobs count exactly their ``iterations``
while elastic resizes conserve total work.  Jobs still running (or still
queued) when ``run(max_time=...)``'s horizon ends are reported as the
explicit ``SimResult.censored`` count instead of silently vanishing from
the JCT statistics.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import os
import time
from bisect import insort
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple, Union

from repro.core import netmodel
from repro.core.chaos import (
    ChaosSpec,
    cancel_time,
    jitter_factor,
    nic_degradation_stream,
    server_failure_stream,
)
from repro.core.cluster import Cluster, GpuId, JobSpec
from repro.core.contention import ContentionParams
from repro.core.trace import TraceSource
from repro.obs.recorder import ObsRecorder
from repro.core.placement import PlacementPolicy
from repro.core.schedpolicy import (
    AdaDual,
    CommPolicy,
    SchedPolicy,
    StaticGangPolicy,
    sched_policy_from_name,
)
from repro.core.topology import RingEdgeTopology, Topology, nic_topology

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Runtime state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommTask:
    job_id: int
    servers: Set[int]
    remaining_bytes: float
    latency_left: float  # the fixed 'a' consumed in wall time before draining
    #: contention domains this task loads: topology domain indices (the
    #: fabric cuts its ring crosses — NICs, rack uplinks, ...; see
    #: core/topology.py) or, under the legacy "link" reading
    #: (``RingEdgeTopology``), the directed ring edges themselves (the
    #: paper's "each link between two nodes" wording)
    domains: frozenset = frozenset()
    #: WFBP bucket index this transfer carries (-1 = the monolithic
    #: iteration-level all-reduce)
    bucket: int = -1


@dataclasses.dataclass
class JobRun:
    spec: JobSpec
    gpus: List[GpuId]
    servers: Set[int]
    placed_at: float
    #: contention domains this placement's ring loads — a pure function of
    #: (topology, servers), so computed once per placement instead of per
    #: gating evaluation (``EventEngine.place_job`` fills it in)
    domains: frozenset = frozenset()
    iter_done: int = 0
    # Per-worker progress within the current iteration:
    f_done: Set[int] = dataclasses.field(default_factory=set)
    b_done: Set[int] = dataclasses.field(default_factory=set)
    comm_ready_at: Optional[float] = None  # all-reduce ready, not yet started
    comm_active: bool = False
    #: chunks of the current iteration's all-reduce still to send (beyond-
    #: paper: tensor-fusion-style chunked, hence preemptible, communication)
    comm_chunks_left: int = 0
    #: WFBP fusion plan ``(bucket_bytes, bucket_t_b)`` from
    #: ``netmodel.fusion_plan`` — None = the monolithic legacy path (the
    #: paper's iteration-level all-reduce, bit-for-bit).
    plan: Optional[Tuple[Tuple[float, ...], Tuple[float, ...]]] = None
    #: WFBP per-worker backward progress: completed segments (len n_world).
    b_prog: List[int] = dataclasses.field(default_factory=list)
    #: WFBP comm pipeline: next bucket to hand to the (FIFO) comm stream
    #: and buckets whose transfer already completed this iteration.
    next_bucket: int = 0
    buckets_done: int = 0
    finished_at: Optional[float] = None
    #: Progress in samples (per-GPU batches): total work carried by the
    #: job (conserved across preemptions and elastic resizes) and the part
    #: already done.  Each completed iteration contributes ``n_world``.
    samples_total: int = 0
    samples_done: int = 0
    #: Iterations this incarnation will have completed when the remaining
    #: samples drain at the current world size (None = the rigid
    #: ``spec.iterations`` — direct-constructed runs in tests).
    target_iters: Optional[int] = None
    #: Workers that still owe the checkpoint-restore penalty (charged on
    #: each worker's first compute task after a preemption/resize).
    restore_need: Set[int] = dataclasses.field(default_factory=set)
    restore_cost: float = 0.0
    #: Elastic world size requested for the next iteration boundary.
    pending_resize: Optional[int] = None
    #: memo for the nominal (non-bandwidth-aware) per-iteration service
    #: time — the SRSF keys recompute it on every comparison, but for one
    #: incarnation it only changes with the fusion plan / gang span (a
    #: re-placement builds a fresh JobRun, so staleness is impossible)
    _svc_cache: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        if self.samples_total == 0:
            self.samples_total = self.spec.total_samples

    @property
    def n_world(self) -> int:
        """Current world size (== ``spec.n_gpus`` for rigid jobs)."""
        return len(self.gpus)

    @property
    def has_comm(self) -> bool:
        return len(self.servers) > 1

    @property
    def n_buckets(self) -> int:
        return len(self.plan[0]) if self.plan is not None else 1

    @property
    def _target(self) -> int:
        return (
            self.target_iters if self.target_iters is not None
            else self.spec.iterations
        )

    def per_iter_service(
        self, params: ContentionParams, bandwidth_aware: bool = False
    ) -> float:
        """Per-iteration service time: compute + contention-free comm (the
        per-message latency ``a`` is paid once per WFBP bucket).

        ``bandwidth_aware`` (beyond-paper, ROADMAP item) divides the
        per-byte term by the slowest member server's NIC multiplier, so a
        job placed on degraded links is recognized as having more service
        left.  Default False = the paper-faithful nominal estimate.
        """
        if not bandwidth_aware:
            # nominal estimate: pure function of (gang span, bucket count,
            # a, b) for this incarnation — memoized, recomputed only when
            # the fusion plan or span changes (bandwidth-aware estimates
            # read the mutable degradation state and are never cached)
            key = (len(self.servers) > 1, self.n_buckets, params.a, params.b)
            cached = self._svc_cache
            if cached is not None and cached[0] == key:
                return cached[1]
        t = self.spec.model.t_iter_compute
        if self.has_comm:
            scale = params.bandwidth_scale(self.servers) if bandwidth_aware else 1.0
            t += self.n_buckets * params.a + params.b * self.spec.model.size_bytes / scale
        if not bandwidth_aware:
            self._svc_cache = (key, t)
        return t

    def remaining_service(
        self, params: ContentionParams, bandwidth_aware: bool = False
    ) -> float:
        """SRSF key: remaining time x allocated GPUs (Tiresias-style)."""
        rem_iters = self._target - self.iter_done
        return rem_iters * self.per_iter_service(params, bandwidth_aware) * self.n_world


@dataclasses.dataclass(frozen=True)
class _Carry:
    """Progress of a preempted/resized job between placements."""

    iter_done: int
    samples_done: int
    samples_total: int
    restore_cost: float


def median(xs: Sequence[float]) -> float:
    """Median (mean of the middle two for even-length lists)."""
    if not xs:
        return math.nan
    ys = sorted(xs)
    n = len(ys)
    return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile, q in [0, 1] (the convention all JCT
    reporting in this repo shares)."""
    if not xs:
        return math.nan
    ys = sorted(xs)
    idx = min(len(ys) - 1, int(math.ceil(q * len(ys))) - 1)
    return ys[max(0, idx)]


@dataclasses.dataclass
class SimResult:
    policy_name: str
    placement_name: str
    jct: Dict[int, float]  # job_id -> completion - arrival
    finish: Dict[int, float]
    makespan: float
    gpu_busy: Dict[GpuId, float]
    gpu_util: float  # mean busy fraction over makespan
    queueing_delay: Dict[int, float]
    events_processed: int
    comm_started_contended: int
    comm_started_clean: int
    #: high-water mark of the event calendar (heap length) over the run —
    #: the engine's memory footprint driver.  With a materialized job list
    #: every arrival is pushed up front, so this is >= n_jobs; with a
    #: streaming :class:`~repro.core.trace.TraceSource` feed at most one
    #: future arrival is in the calendar at a time, so the high-water mark
    #: is O(cluster), independent of trace length.
    peak_calendar: int = 0
    #: name of the job scheduling policy (engine/policy split)
    sched_name: str = "static"
    #: jobs with no finish time: cut off by the simulation horizon
    #: (``run``'s ``max_time``), or stranded because they could never be
    #: placed (more GPUs/memory than the cluster has).  Excluded from the
    #: JCT statistics — this count makes the truncation explicit instead
    #: of silent.  0 whenever every job ran to completion.
    censored: int = 0
    #: gang preemptions (checkpoint + requeue) performed by the policy
    preemptions: int = 0
    #: elastic world-size changes applied at iteration boundaries
    resizes: int = 0
    #: fault injection (``core/chaos.py``): server breakdowns + NIC
    #: degradation windows suffered, stochastic job cancellations, and the
    #: samples of in-progress work thrown away by involuntary restarts
    #: (every teardown loses the in-flight iteration; the carry keeps only
    #: completed ones)
    faults: int = 0
    cancelled: int = 0
    work_lost_samples: int = 0
    #: delivered training throughput: samples completed by finished or
    #: still-live jobs per second of makespan.  Cancelled jobs contribute
    #: nothing — their partial progress was never delivered to anyone.
    goodput: float = 0.0
    task_trace: Optional[List[Tuple]] = None  # (job, iter, kind, worker, t0, t1)
    #: per-job delivered samples at finish time — the basis of the windowed
    #: goodput view (long replays care about *sustained* throughput, not the
    #: single makespan-frame average)
    job_samples: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: opt-in (``profile_phases=True``) wall seconds per engine phase over
    #: the whole run: comm_advance / dispatch / gating / gpu_schedule.
    #: None when profiling was off (the default — zero overhead).
    phase_seconds: Optional[Dict[str, float]] = None
    #: opt-in (``observe=ObsConfig(...)``) observability report
    #: (``repro.obs.ObsReport``): exact per-job JCT decomposition,
    #: per-domain contention timelines, the gating audit log, and the
    #: Perfetto span records.  None when observability was off (the
    #: default — zero overhead, bit-exact event stream either way).
    obs: Optional[object] = None

    def avg_jct(self) -> float:
        return sum(self.jct.values()) / len(self.jct)

    def median_jct(self) -> float:
        return median(list(self.jct.values()))

    def p95_jct(self) -> float:
        return percentile(list(self.jct.values()), 0.95)

    def p99_jct(self) -> float:
        """Tail JCT — the SLO statistic the chaos scenarios report (fault
        restarts hit the tail far harder than the mean)."""
        return percentile(list(self.jct.values()), 0.99)

    # -- windowed steady-state view (trace-replay scale) ----------------------
    def windowed(self, window_s: float) -> List[Dict[str, float]]:
        """Bucket finished jobs into ``[i*w, (i+1)*w)`` windows over the run
        and report per-window completion stats.

        The finite-makespan frame (one average over the whole run) is the
        wrong lens for a 100k-arrival replay: it mixes the empty ramp-up,
        the steady state, and the final drain.  Each window row carries::

            t0, t1              window bounds (seconds)
            n_finished          jobs completing in the window
            goodput             delivered samples / window_s
            jobs_per_sec        completion rate
            p99_jct             nearest-rank p99 JCT of the window's jobs
            queueing_delay_mean mean queueing delay of the window's jobs

        Jobs are attributed to the window containing their *finish* time
        (the only instant at which JCT exists).
        """
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        if not self.finish:
            return []
        rows = sorted(
            (
                f,
                self.jct[j],
                self.queueing_delay.get(j, 0.0),
                self.job_samples.get(j, 0),
            )
            for j, f in self.finish.items()
        )
        n_win = int(self.makespan // window_s) + 1
        out: List[Dict[str, float]] = []
        i = 0
        for w in range(n_win):
            t0, t1 = w * window_s, (w + 1) * window_s
            jcts: List[float] = []
            qds: List[float] = []
            samples = 0
            while i < len(rows) and rows[i][0] < t1:
                _, jct, qd, s = rows[i]
                jcts.append(jct)
                qds.append(qd)
                samples += s
                i += 1
            out.append(
                {
                    "t0": t0,
                    "t1": t1,
                    "n_finished": float(len(jcts)),
                    "goodput": samples / window_s,
                    "jobs_per_sec": len(jcts) / window_s,
                    "p99_jct": percentile(jcts, 0.99),
                    "queueing_delay_mean": (
                        sum(qds) / len(qds) if qds else math.nan
                    ),
                }
            )
        return out

    def steady_state(
        self, window_s: float, warmup_frac: float = 0.1
    ) -> Dict[str, float]:
        """Sliding-horizon summary for long replays: drop the warmup prefix
        (first ``warmup_frac`` of the makespan) and the trailing partial
        window (the drain), then summarize the surviving body windows.

        ``sustained_goodput`` / ``sustained_jobs_per_sec`` are *medians* over
        the body windows (robust to a single empty or bursty window); the
        JCT/queueing-delay tails are nearest-rank percentiles over every job
        finishing inside the body interval.  Falls back to all windows when
        the run is too short for a warmup cut to leave anything."""
        wins = self.windowed(window_s)
        if not wins:
            return {}
        warmup_t = warmup_frac * self.makespan
        body = [w for w in wins[:-1] if w["t0"] >= warmup_t] or wins
        t_lo, t_hi = body[0]["t0"], body[-1]["t1"]
        jcts = [self.jct[j] for j, f in self.finish.items() if t_lo <= f < t_hi]
        qds = [
            self.queueing_delay.get(j, 0.0)
            for j, f in self.finish.items()
            if t_lo <= f < t_hi
        ]
        return {
            "window_s": window_s,
            "t_lo": t_lo,
            "t_hi": t_hi,
            "n_windows": float(len(body)),
            "n_jobs": float(len(jcts)),
            "sustained_goodput": median([w["goodput"] for w in body]),
            "sustained_jobs_per_sec": median([w["jobs_per_sec"] for w in body]),
            "p99_jct": percentile(jcts, 0.99),
            "queueing_delay_mean": sum(qds) / len(qds) if qds else math.nan,
            "queueing_delay_p99": percentile(qds, 0.99),
        }


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class EventEngine:
    """Exact event-driven simulation of Algorithm 3's dynamics, with all
    job-level decisions delegated to a pluggable
    :class:`~repro.core.schedpolicy.SchedPolicy`."""

    def __init__(
        self,
        jobs: Union[Sequence[JobSpec], TraceSource],
        cluster: Optional[Cluster] = None,
        placement: Optional[PlacementPolicy] = None,
        comm_policy: Optional[CommPolicy] = None,
        params: Optional[ContentionParams] = None,
        fuse_fb: bool = True,
        record_trace: bool = False,
        comm_chunks: int = 1,
        contention_domain: str = "server",  # server (NIC) | link (ring edges)
        exclusive_gpus: bool = False,  # paper assumption 3 reading
        bandwidth_aware_srsf: bool = False,  # hetero-aware remaining-service
        topology: Optional[Topology] = None,  # fabric contention domains
        fusion: object = "all",  # WFBP tensor fusion: 'all' | 'none' | bytes
        sched: Union[SchedPolicy, str, None] = None,  # job scheduling policy
        preemption_quantum: Optional[float] = None,  # tick for named scheds
        checkpoint_cost: Optional[float] = None,  # None = netmodel model
        chaos: Optional[ChaosSpec] = None,  # fault injection (core/chaos.py)
        gating: Optional[str] = None,  # incremental (default) | rescan
        profile_phases: bool = False,  # per-phase wall-clock counters
        observe: Optional[object] = None,  # repro.obs.ObsConfig | None
    ) -> None:
        # Streaming arrival feed (trace-replay scale): a TraceSource yields
        # arrivals lazily, so the calendar holds at most ONE future arrival
        # instead of the whole trace — O(cluster) memory at 100k+ jobs.
        # A materialized job list keeps the legacy all-up-front behaviour
        # bit-for-bit.
        if isinstance(jobs, TraceSource):
            self._source: Optional[TraceSource] = jobs
            self.jobs: Dict[int, JobSpec] = {}
        else:
            self._source = None
            self.jobs = {j.job_id: j for j in jobs}
        self.cluster = cluster or Cluster()
        self.placement = placement or PlacementPolicy("lwf", kappa=1)
        self.comm_policy = comm_policy or AdaDual()
        self.params = params or ContentionParams()
        # Fusing f+b into one GPU occupancy halves event count; a newly
        # placed higher-priority job can then preempt only at (f+b)
        # boundaries instead of f|b boundaries (distortion <= t_b ~ 50 ms).
        # Fidelity tests set fuse_fb=False.
        self.fuse_fb = fuse_fb and not record_trace
        self.record_trace = record_trace
        # Beyond-paper (future-work #3 adjacent): split each all-reduce into
        # N chunks scheduled independently — a long transfer can lose the
        # link to a shorter job's message at every chunk boundary, making
        # communication effectively preemptible.  The per-message latency
        # `a` is charged per chunk (that is the real cost of chunking).
        self.comm_chunks = max(1, comm_chunks)
        # WFBP tensor fusion (layer-granular communication subsystem):
        # 'all' = one monolithic all-reduce per iteration (the paper's model
        # and the legacy behaviour bit-for-bit); 'none' / a byte threshold =
        # per-bucket transfers (netmodel.fusion_plan) that overlap the
        # remaining backward pass, gated per bucket.  Only jobs whose
        # ModelProfile carries layer data (repro.workloads) are affected;
        # Table III profiles always run monolithic.
        self._fusion_threshold = netmodel.fusion_threshold(fusion)
        self.fusion = fusion
        if self._fusion_threshold != math.inf and self.comm_chunks > 1:
            raise ValueError(
                "comm_chunks and WFBP fusion are mutually exclusive — the "
                "fusion plan already chunks the all-reduce"
            )
        self._plan_cache: Dict[int, Optional[tuple]] = {}
        # "server": the server's NIC is the shared resource (conservative —
        # all flows through one 10GbE port contend).  "link": the paper's
        # wording — contention only between tasks sharing a ring edge
        # (server pair), allowing disjoint transfers to proceed in parallel.
        if contention_domain not in ("server", "link"):
            raise ValueError(f"unknown contention domain {contention_domain!r}")
        self.contention_domain = contention_domain
        # An explicit fabric topology (core/topology.py) supersedes the
        # contention_domain string; the default NIC-only topology is the
        # identical computation as "server" (one domain per server, all
        # oversub 1.0), so behaviour is bit-for-bit unchanged.  The legacy
        # ring-edge "link" reading is the dynamic RingEdgeTopology: the same
        # per-task domains the old inline code produced (regression-locked
        # in tests/test_chunked_comm.py), expressed as topology domains.
        if topology is not None and topology.n_servers != self.cluster.n_servers:
            raise ValueError(
                f"topology covers {topology.n_servers} servers, cluster has "
                f"{self.cluster.n_servers}"
            )
        if topology is None:
            topology = (
                nic_topology(self.cluster.n_servers)
                if contention_domain == "server"
                else RingEdgeTopology(self.cluster.n_servers)
            )
        self.topology = topology
        self.cluster.exclusive = exclusive_gpus
        # SRSF priority estimate under server_bandwidth heterogeneity: the
        # paper's nominal homogeneous comm time (False, default) or scaled
        # by the slowest member NIC (True) — see JobRun.per_iter_service.
        self.bandwidth_aware_srsf = bandwidth_aware_srsf
        # Job scheduling strategy (engine/policy split).  The static
        # default schedules no quantum events and never preempts/resizes,
        # so the event stream matches the pre-split simulator exactly.
        if sched is None:
            sched = StaticGangPolicy()
        elif isinstance(sched, str):
            sched = sched_policy_from_name(sched, quantum=preemption_quantum)
        self.sched = sched
        self.checkpoint_cost = checkpoint_cost
        # Communication gating strategy: "incremental" re-evaluates only
        # waiters whose contention domains were touched since their last
        # evaluation (bit-exact with the full rescan — see
        # _try_start_comms_incremental); "rescan" is the legacy
        # every-waiter-every-event reference the differential tests lock
        # against.  REPRO_GATING overrides the default for A/B runs.
        if gating is None:
            gating = os.environ.get("REPRO_GATING", "incremental")
        if gating not in ("incremental", "rescan"):
            raise ValueError(
                f"unknown gating mode {gating!r} (expected 'incremental' or "
                "'rescan')"
            )
        self.gating = gating
        self.profile_phases = profile_phases
        self._phase_seconds: Optional[Dict[str, float]] = (
            {"comm_advance": 0.0, "dispatch": 0.0, "gating": 0.0,
             "gpu_schedule": 0.0}
            if profile_phases
            else None
        )

        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._peak_heap = 0
        self._seq = itertools.count()
        self._queue: List[int] = []  # unplaced job ids
        self._runs: Dict[int, JobRun] = {}
        #: placed-and-unfinished job ids in the same (insertion) order their
        #: runs sit in ``_runs`` — the workload refresh walks this instead
        #: of all of ``_runs`` (which keeps every finished run for result
        #: collection and so grows with the whole trace); identical float
        #: accumulation order, O(live) instead of O(total jobs) per refresh
        self._live: Dict[int, None] = {}
        self._active_comm: Dict[int, CommTask] = {}
        #: In-flight transfers per contention domain, maintained
        #: incrementally on every comm start/finish/abort — the same
        #: integers the old per-event scans over ``_active_comm``
        #: produced (bit-exact), without the O(active^2) rescans.
        self._domain_load: Dict[object, int] = {}
        self._waiting_comm: List[int] = []  # job ids with gated all-reduce
        self._waiting_set: Set[int] = set()  # same ids, O(1) membership
        #: incremental gating indexes: waiters per contention domain, and
        #: the set of waiters whose gating decision may have changed since
        #: their last evaluation (new waiters + waiters on domains touched
        #: by a comm start/end/abort) — see _try_start_comms_incremental
        self._domain_waiters: Dict[object, Set[int]] = {}
        self._gate_candidates: Set[int] = set()
        self._comm_epoch = 0
        self._last_comm_update = 0.0
        self._dirty_gpus: Set[GpuId] = set()
        self._events = 0
        self._comm_contended = 0
        self._comm_clean = 0
        self._trace: List[Tuple] = []
        self._unfinished = set(self.jobs)
        # Streaming-feed state: the lazy arrival iterator, how many arrival
        # events are in the calendar but not yet processed (at most 1), the
        # monotonicity check on source order, how many jobs have *entered*
        # the system (== len(jobs) in list mode), and runs awaiting
        # end-of-event retirement (streaming keeps memory O(live jobs)).
        self._stream: Optional[Iterator[JobSpec]] = None
        self._arrivals_pending = 0
        self._last_arrival = -math.inf
        self._n_seen = len(self.jobs)
        self._retire_buf: List[int] = []
        # Per-job results recorded at finish time (the streaming feed
        # retires finished runs, so results cannot be collected from _runs
        # at the end the way list mode does).
        self._jct_at_finish: Dict[int, float] = {}
        self._finish_at: Dict[int, float] = {}
        self._qdelay_at_finish: Dict[int, float] = {}
        self._job_samples: Dict[int, int] = {}
        # Preemption/elasticity mechanism state:
        self._carry: Dict[int, _Carry] = {}  # progress of requeued jobs
        self._epoch_of: Dict[int, int] = {}  # run incarnation (tombstones)
        self._first_placed: Dict[int, float] = {}
        self._preemptions = 0
        self._resizes = 0
        self._comm_dirty = False  # active comm set mutated outside gating
        # Fault injection (core/chaos.py).  An absent or inactive spec keeps
        # every chaos code path cold: no chaos events are ever pushed, so the
        # event stream is bit-exact with the unfaulted engine (the zero-rate
        # no-op, regression-locked in tests/test_chaos.py).
        self._chaos = chaos if (chaos is not None and chaos.active) else None
        # Observability (repro.obs).  Same pattern as chaos: an absent or
        # inactive config keeps every obs hook cold — the recorder never
        # mutates engine state, so the event stream is bit-exact with
        # observability on OR off (locked in tests/test_obs.py).
        self._obs = (
            ObsRecorder(observe)
            if (observe is not None and observe.active)
            else None
        )
        if self._obs is not None:
            # the deferred replay needs the Eq. 5 constants and the gating
            # policy (for audit `explain` terms) — both fixed for the run
            self._obs.bind(self.params, self.comm_policy)
        # Hot-stream caches: the highest-frequency obs hooks (comm windows,
        # compute spans, gating audits, gating queue enter/leave, transfer
        # ends) are plain flat-list extends inlined at the call sites below
        # — a None cache means that record family is off and costs one
        # is-check.  The recorder's flush clears the log in place, so these
        # references never go stale.
        o = self._obs
        self._obs_win = o.log if (o is not None and o.decompose_on) else None
        self._obs_comm = o.log if (o is not None and o.log_comm) else None
        self._obs_gate = o.log if (o is not None and o.log_gate) else None
        self._obs_rc = o.raw_compute if (o is not None and o.spans_on) else None
        # raw_compute is flat at stride 6, so the element cap is 6x
        self._obs_rc_cap = o.config.span_cap * 6 if o is not None else 0
        self._obs_audit = o.audit_raw if (o is not None and o.audit_on) else None
        self._obs_audit_left = o.config.audit_cap if o is not None else 0
        self._faults = 0
        self._cancelled = 0
        self._work_lost_samples = 0
        self._down_servers: Set[int] = set()
        self._fail_streams: Dict[int, Iterator[Tuple[float, float]]] = {}
        self._nic_streams: Dict[int, Iterator[Tuple[float, float]]] = {}
        self._nic_degraded: Set[int] = set()
        self._base_server_bw: Tuple[float, ...] = ()
        self.sched.bind(self)

    # -- policy-facing state views -------------------------------------------
    @property
    def queue(self) -> List[int]:
        """Unplaced job ids, mutated in place by the scheduling policy."""
        return self._queue

    @property
    def runs(self) -> Dict[int, JobRun]:
        """Live job runs (read-only for policies; mutate via the API)."""
        return self._runs

    # -- event helpers -------------------------------------------------------
    def _push(self, t: float, kind: str, data: tuple) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))
        if len(self._heap) > self._peak_heap:
            self._peak_heap = len(self._heap)

    # -- SRSF priority ---------------------------------------------------------
    def srsf_key_queued(self, job_id: int):
        """SRSF key of a queued job.  Fresh jobs use the paper's
        convention (E_J = 0 before placement, Section IV-A); requeued
        preempted jobs use their carried remaining work in samples."""
        spec = self.jobs[job_id]
        carry = self._carry.get(job_id)
        if carry is None:
            rem = spec.compute_time * spec.n_gpus
        else:
            rem_samples = carry.samples_total - carry.samples_done
            rem = spec.model.t_iter_compute * rem_samples
        return (rem, spec.arrival, job_id)

    def srsf_key_running(self, job_id: int):
        run = self._runs[job_id]
        rem = run.remaining_service(self.params, self.bandwidth_aware_srsf)
        return (rem, run.spec.arrival, job_id)

    # backwards-compatible private aliases (pre-split internal names)
    _srsf_key_queued = srsf_key_queued
    _srsf_key_running = srsf_key_running

    # -- communication bookkeeping --------------------------------------------
    def _domains_of(self, servers: Set[int]) -> frozenset:
        """Contention domains a comm task over ``servers`` loads: the
        topology cuts its ring crosses (domain indices), or — under the
        legacy "link" reading, now ``RingEdgeTopology`` — the directed ring
        edges themselves."""
        return self.topology.loaded_domains(servers)

    def _comm_started(self, task: CommTask) -> None:
        for d in task.domains:
            self._domain_load[d] = self._domain_load.get(d, 0) + 1
        self._mark_domains_dirty(task.domains)

    def _comm_ended(self, task: CommTask) -> None:
        for d in task.domains:
            left = self._domain_load[d] - 1
            if left:
                self._domain_load[d] = left
            else:
                del self._domain_load[d]
        self._mark_domains_dirty(task.domains)

    # -- incremental gating indexes -------------------------------------------
    def _waiter_add(self, jid: int, run: JobRun) -> None:
        """Enqueue a gated all-reduce: the waiter list (SRSF evaluation
        order lives there), the per-domain index, and the candidate set —
        a fresh waiter always gets its first evaluation."""
        self._waiting_comm.append(jid)
        self._waiting_set.add(jid)
        for d in run.domains:
            self._domain_waiters.setdefault(d, set()).add(jid)
        self._gate_candidates.add(jid)
        lg = self._obs_gate
        if lg is not None:
            # _advance_comm unconditionally stamps _last_comm_update with
            # the current event time before any dispatch reaches here
            lg.extend((4, self._last_comm_update, jid))

    def _waiter_drop(self, jid: int, domains: frozenset) -> None:
        """Remove a waiter from every gating index (started / preempted /
        cancelled).  ``domains`` is passed explicitly because teardown
        paths pop the run from ``_runs`` before cleaning the indexes."""
        self._waiting_comm.remove(jid)
        self._waiting_set.discard(jid)
        for d in domains:
            ws = self._domain_waiters.get(d)
            if ws is not None:
                ws.discard(jid)
                if not ws:
                    del self._domain_waiters[d]
        self._gate_candidates.discard(jid)
        lg = self._obs_gate
        if lg is not None:
            lg.extend((5, self._last_comm_update, jid))

    def _mark_domains_dirty(self, domains: frozenset) -> None:
        """A comm start/end/abort touched these domains: every waiter
        sharing one must be re-evaluated (its ``olds`` set or ``max_conc``
        input just changed)."""
        for d in domains:
            ws = self._domain_waiters.get(d)
            if ws:
                self._gate_candidates.update(ws)

    def _comm_k_eff(self, task: CommTask) -> float:
        """Effective contention for the Eq. (5) *rate*: per-domain count
        scaled by that domain's oversubscription factor (an uplink with
        oversub f delivers 1/f of nominal bandwidth, so k tasks crossing it
        drain like k*f tasks on a NIC).  All-1.0 oversub (the NIC-only
        topology, and the legacy ring-link reading) reduces to the raw k.

        ``_domain_load`` carries exactly the counts the old scans over
        ``_active_comm`` computed, so the result is bit-identical."""
        k = 1.0
        for d in task.domains:
            k = max(k, self._domain_load.get(d, 0) * self.topology.oversub_of(d))
        return k

    def _advance_comm(self, now: float) -> List[int]:
        """Drain all in-flight comm tasks from the last update to ``now``.
        Returns job ids whose all-reduce completed in this window."""
        dt = now - self._last_comm_update
        self._last_comm_update = now
        finished: List[int] = []
        if dt <= 0 or not self._active_comm:
            return finished
        # Rates are piecewise constant between events because the active set
        # only changes at events (domain loads are a pure function of the
        # active set); use the rate as of the window start — this stays an
        # exact piecewise-rate integration under any topology.
        ks = {jid: self._comm_k_eff(t) for jid, t in self._active_comm.items()}
        lg = self._obs_win
        if lg is not None:
            # decomposition record: the window's per-task rates, logged
            # before the drain loop consumes latency_left (the deferred
            # replay re-consumes the latency slice identically).  Flat
            # layout — 0, dt, n, jid*n, k*n — so only scalars are
            # retained (a retained tuple per window is real GC pressure)
            lg.extend((0, dt, len(ks)))
            lg.extend(ks)
            lg.extend(ks.values())
            if len(lg) >= self._obs.flush_at:
                self._obs._flush()
        for jid, task in list(self._active_comm.items()):
            lat = min(task.latency_left, dt)
            task.latency_left -= lat
            drain_t = dt - lat
            if drain_t > 0:
                rate = self.params.rate(ks[jid]) * self.params.bandwidth_scale(
                    task.servers
                )
                task.remaining_bytes -= drain_t * rate
            if task.latency_left <= _EPS and task.remaining_bytes <= 1.0:
                # tolerance: 1 byte ~ 1e-9 s — absorbs float drift in the
                # piecewise integration
                finished.append(jid)
        lg = self._obs_comm
        for jid in finished:
            self._comm_ended(self._active_comm[jid])
            del self._active_comm[jid]
            if lg is not None:
                lg.extend((2, now, jid))
        return finished

    def _next_comm_finish(self) -> Optional[float]:
        if not self._active_comm:
            return None
        t_min = math.inf
        for task in self._active_comm.values():
            k = self._comm_k_eff(task)
            rate = self.params.rate(k) * self.params.bandwidth_scale(task.servers)
            t = self._last_comm_update + task.latency_left + task.remaining_bytes / rate
            t_min = min(t_min, t)
        return t_min

    def _reschedule_comm_check(self) -> None:
        self._comm_epoch += 1
        t = self._next_comm_finish()
        if t is not None:
            self._push(t, "comm_check", (self._comm_epoch,))

    def _abort_comm(self, job_id: int) -> None:
        """Abort ``job_id``'s in-flight all-reduce (preemption, breakdown,
        cancellation).  Beyond dropping the task and its domain loads, this
        flags ``_comm_dirty`` so the main loop both re-predicts the finish
        times of the survivors (their rates just improved) *and* re-runs the
        gating pass — a waiter that was gated against the aborted transfer
        must get its lookahead re-evaluated against the freed domains in the
        same event, not at the next unrelated comm event.  Locked by
        ``tests/test_chaos.py::TestAbortedCommGating``."""
        task = self._active_comm.pop(job_id)
        self._comm_ended(task)
        self._comm_dirty = True
        if self._obs is not None:
            # the aborted transfer's accrued comm time delivered nothing:
            # reattribute it to preemption/fault overhead
            self._obs.comm_abort(job_id, self._last_comm_update)

    # -- WFBP fusion plans -------------------------------------------------------
    def _assign_plan(self, run: JobRun) -> None:
        """Attach the WFBP fusion plan to a freshly-placed run: per-bucket
        (bytes, backward-segment seconds) when fusion is finite, the model
        carries layer data, and the placement actually spans servers —
        otherwise the monolithic legacy path (plan None)."""
        if self._fusion_threshold == math.inf or not run.has_comm:
            return
        model = run.spec.model
        if not getattr(model, "has_layers", False):
            return
        key = id(model)
        if key not in self._plan_cache:
            self._plan_cache[key] = netmodel.fusion_plan(
                model.layer_grad_bytes, model.layer_t_b, self._fusion_threshold
            )
        run.plan = self._plan_cache[key]
        run.b_prog = [0] * run.n_world

    def _maybe_enqueue_bucket(self, run: JobRun) -> None:
        """Hand the next WFBP bucket to the gating queue once (a) all
        workers have finished its backward segment and (b) the job's comm
        stream is free (buckets serialize FIFO, the PyTorch-DDP model)."""
        jid = run.spec.job_id
        if run.comm_active or jid in self._waiting_set:
            return
        if run.next_bucket >= run.n_buckets:
            return
        if run.next_bucket < min(run.b_prog):
            self._waiter_add(jid, run)

    # -- the decision API (called by SchedPolicy hooks) ------------------------
    def refresh_workloads(self) -> None:
        """Alg. 3 line 3: recompute every GPU's remaining workload L_g as the
        sum of its resident jobs' remaining service (shared per GPU)."""
        for g in self.cluster.gpus.values():
            g.workload = 0.0
        for jid in self._live:
            run = self._runs[jid]
            share = run.remaining_service(self.params, self.bandwidth_aware_srsf)
            for gid in run.gpus:
                self.cluster.gpus[gid].workload += share

    _refresh_workloads = refresh_workloads  # pre-split internal name

    def place_job(self, job_id: int, gpu_ids: Sequence[GpuId], now: float) -> JobRun:
        """Commit a gang placement chosen by the scheduling policy.

        Rebuilds everything placement-derived — the member-server set (and
        hence topology domain sets), the WFBP fusion plan for the placed
        world size, and the SRSF workload share — and restores carried
        progress (plus the restore penalty) for requeued jobs."""
        spec = self.jobs[job_id]
        servers = self.cluster.servers_of(gpu_ids)
        run = JobRun(
            spec=spec,
            gpus=list(gpu_ids),
            servers=servers,
            placed_at=now,
            domains=self._domains_of(servers),
        )
        carry = self._carry.pop(job_id, None)
        if carry is not None:
            run.iter_done = carry.iter_done
            run.samples_done = carry.samples_done
            run.samples_total = carry.samples_total
            run.restore_cost = carry.restore_cost
            run.restore_need = set(range(run.n_world))
        rem_samples = run.samples_total - run.samples_done
        run.target_iters = run.iter_done + max(0, -(-rem_samples // run.n_world))
        self._assign_plan(run)
        workload = run.remaining_service(self.params, self.bandwidth_aware_srsf)
        self.cluster.place(spec, gpu_ids, workload)
        self._runs[job_id] = run
        self._live[job_id] = None
        self._dirty_gpus.update(gpu_ids)
        self._first_placed.setdefault(job_id, now)
        if self._obs is not None:
            self._obs.placed(job_id, run, now)
        return run

    def _checkpoint_cost_of(self, run: JobRun) -> float:
        if self.checkpoint_cost is not None:
            return self.checkpoint_cost
        return netmodel.preemption_cost(run.spec.model.size_bytes)

    def preempt_job(self, job_id: int, now: float) -> None:
        """Atomically tear a running gang down and requeue the job.

        The whole gang stops together: every in-flight compute task is
        cancelled (pending ``gpu_done`` events are tombstoned by epoch),
        any in-flight or waiting all-reduce is aborted, memory is
        released.  Progress is carried at the last *completed* iteration —
        the in-progress iteration is lost, exactly a checkpoint-restart —
        and the next placement pays the checkpoint/restore penalty."""
        run = self._runs.pop(job_id)
        self._live.pop(job_id, None)
        if run.finished_at is not None:
            raise ValueError(f"cannot preempt finished job {job_id}")
        lost = self._lost_in_progress(run)
        self._work_lost_samples += lost
        self._epoch_of[job_id] = self._epoch_of.get(job_id, 0) + 1
        for gid in run.gpus:
            g = self.cluster.gpus[gid]
            if g.busy_job == job_id:
                if g.busy_until is not None and g.busy_until > now:
                    g.busy_accum -= g.busy_until - now  # un-accrue lost work
                g.busy_until = None
                g.busy_job = None
            self._dirty_gpus.add(gid)
        self.cluster.release(run.spec, run.gpus)
        if job_id in self._waiting_set:
            self._waiter_drop(job_id, run.domains)
        if job_id in self._active_comm:
            self._abort_comm(job_id)
        self._carry[job_id] = _Carry(
            iter_done=run.iter_done,
            samples_done=run.samples_done,
            samples_total=run.samples_total,
            restore_cost=self._checkpoint_cost_of(run),
        )
        # the queue is kept sorted by srsf_key_queued (the carry above is
        # what the key reads, so it must be set before this insort)
        insort(self._queue, job_id, key=self.srsf_key_queued)
        self._preemptions += 1
        if self._obs is not None:
            # after the waiter-drop/abort hooks above, so the aborted
            # transfer's reattribution already landed in the ledger
            self._obs.preempted(job_id, now, lost)
        if self.record_trace:
            # drop the aborted in-progress iteration's records (they will
            # be re-executed after resume) and mark the preemption point
            self._trace = [
                r
                for r in self._trace
                if r[2] in ("preempt", "resize")
                or not (r[0] == job_id and r[1] >= run.iter_done)
            ]
            self._trace.append((job_id, run.iter_done, "preempt", -1, now, now))

    def request_resize(self, job_id: int, n_new: int) -> None:
        """Ask for an elastic world-size change, applied at the job's next
        iteration boundary (clamped to the job's declared bounds)."""
        run = self._runs[job_id]
        lo, hi = run.spec.gpu_bounds
        n_new = max(lo, min(hi, int(n_new)))
        run.pending_resize = None if n_new == run.n_world else n_new

    def _apply_resize(self, run: JobRun, now: float) -> None:
        """Apply a pending resize at an iteration boundary: tear the gang
        down (nothing in-iteration exists to lose here), re-place at the
        new size through the normal placement path — rebuilding the WFBP
        fusion plan and topology domain sets — and charge the
        checkpoint/restore penalty for the state redistribution."""
        job_id = run.spec.job_id
        n_new = run.pending_resize
        run.pending_resize = None
        self._epoch_of[job_id] = self._epoch_of.get(job_id, 0) + 1
        self.cluster.release(run.spec, run.gpus)
        self._dirty_gpus.update(run.gpus)
        del self._runs[job_id]
        self._live.pop(job_id, None)
        # re-rank with this gang's workload gone (cluster.release keeps the
        # per-GPU L_g; the freed GPUs must look free to the placement)
        self.refresh_workloads()
        spec = run.spec
        trial = spec if n_new == spec.n_gpus else dataclasses.replace(spec, n_gpus=n_new)
        gpu_ids = self.placement(self.cluster, trial)
        applied = gpu_ids is not None
        if not applied:
            # a failed grow is a *cancelled* resize: keep EXACTLY the old
            # GPUs (just freed, so they fit) — no migration, no
            # checkpoint/restore penalty, no resize counted
            gpu_ids = list(run.gpus)
        self._carry[job_id] = _Carry(
            iter_done=run.iter_done,
            samples_done=run.samples_done,
            samples_total=run.samples_total,
            restore_cost=self._checkpoint_cost_of(run) if applied else 0.0,
        )
        self.place_job(job_id, gpu_ids, now)
        if applied:
            self._resizes += 1
            if self._obs is not None:
                self._obs.resized(job_id, now)
            if self.record_trace:
                self._trace.append((job_id, run.iter_done, "resize", -1, now, now))
        self.sched.on_resize(now, job_id)

    # -- fault injection (core/chaos.py) ------------------------------------------
    def _lost_in_progress(self, run: JobRun) -> int:
        """Samples of in-iteration work a teardown throws away: the whole
        gang's current iteration counts as lost if *any* worker made
        progress in it (the carry keeps only completed iterations).  Must
        be called before the per-GPU busy state is cleaned up."""
        in_prog = bool(
            run.f_done
            or run.b_done
            or run.comm_active
            or run.comm_ready_at is not None
            or (
                run.plan is not None
                and (run.next_bucket or run.buckets_done or any(run.b_prog))
            )
        )
        if not in_prog:
            # nothing recorded done yet, but a worker may be mid-task
            in_prog = any(
                self.cluster.gpus[gid].busy_job == run.spec.job_id
                for gid in run.gpus
            )
        return run.n_world if in_prog else 0

    def _seed_chaos_events(self) -> None:
        """Arm the fault processes at run start: one outstanding breakdown /
        NIC window per server (advanced lazily, so the infinite stochastic
        streams never flood the calendar) plus every job's cancellation
        instant."""
        spec = self._chaos
        self._base_server_bw = tuple(self.params.server_bandwidth)
        for s in range(self.cluster.n_servers):
            self._fail_streams[s] = server_failure_stream(spec, s)
            self._advance_failure(s)
            self._nic_streams[s] = nic_degradation_stream(spec, s)
            self._advance_nic(s)
        for job in self.jobs.values():
            t_c = cancel_time(spec, job.job_id, job.arrival)
            if t_c is not None:
                # the arrival event was pushed first, so a same-instant
                # cancellation still finds the job in the queue
                self._push(max(t_c, job.arrival), "cancel", (job.job_id,))

    def _advance_failure(self, server: int) -> None:
        win = next(self._fail_streams[server], None)
        if win is not None:
            self._push(win[0], "breakdown", (server, win[1]))

    def _advance_nic(self, server: int) -> None:
        win = next(self._nic_streams[server], None)
        if win is not None:
            self._push(win[0], "nic_down", (server, win[1]))

    def _on_breakdown(self, server: int, repair_t: float, now: float) -> None:
        """A server died: force-preempt every gang touching it (atomic
        teardown through the normal preempt machinery — epoch tombstones,
        carry at the last completed iteration, restore penalty on resume)
        and mark its GPUs unplaceable until repair."""
        self._faults += 1
        self._down_servers.add(server)
        for g in self.cluster.gpus_of_server(server):
            g.down = True
        victims = sorted(
            jid
            for jid, run in self._runs.items()
            if run.finished_at is None and server in run.servers
        )
        for jid in victims:
            self.preempt_job(jid, now)
        self._push(repair_t, "repair", (server,))
        if self._obs is not None:
            self._obs.fault("breakdown", server, now)
        self.sched.on_fault(now, server, victims)

    def _on_repair(self, server: int, now: float) -> None:
        self._down_servers.discard(server)
        for g in self.cluster.gpus_of_server(server):
            g.down = False
        self.cluster.capacity_epoch += 1  # placeable capacity grew
        self._advance_failure(server)
        if self._obs is not None:
            self._obs.fault("repair", server, now)
        self.sched.on_recovery(now, server)

    def _apply_nic_bandwidth(self) -> None:
        """Rebuild ``params.server_bandwidth`` from the base multipliers and
        the currently-degraded set.  The main loop integrated all in-flight
        transfers up to ``now`` *before* dispatching this event, so the
        piecewise-constant-rate integration stays exact across the change;
        ``_comm_dirty`` forces the finish-time re-prediction."""
        scale = self._chaos.nic_degraded_scale
        base = self._base_server_bw
        self.params = dataclasses.replace(
            self.params,
            server_bandwidth=tuple(
                (base[s] if s < len(base) else 1.0)
                * (scale if s in self._nic_degraded else 1.0)
                for s in range(self.cluster.n_servers)
            ),
        )
        self._comm_dirty = True

    def _on_nic_down(self, server: int, end_t: float, now: float) -> None:
        self._faults += 1
        self._nic_degraded.add(server)
        self._apply_nic_bandwidth()
        self._push(end_t, "nic_up", (server,))
        if self._obs is not None:
            self._obs.fault("nic_down", server, now)

    def _on_nic_up(self, server: int, now: float) -> None:
        self._nic_degraded.discard(server)
        self._apply_nic_bandwidth()
        self._advance_nic(server)
        if self._obs is not None:
            self._obs.fault("nic_up", server, now)

    def _on_cancel(self, job_id: int, now: float) -> None:
        """Stochastic cancellation: the job leaves the system — running
        gangs are torn down atomically (same mechanics as a preemption,
        without the requeue), queued jobs just leave the queue.  Cancelled
        jobs are counted separately from ``censored`` (they are not silent
        truncation) and contribute nothing to JCT stats or goodput."""
        if job_id not in self._unfinished:
            return  # finished before the axe fell
        run = self._runs.get(job_id)
        lost = 0.0
        if run is not None:
            self._epoch_of[job_id] = self._epoch_of.get(job_id, 0) + 1
            lost = self._lost_in_progress(run)
            self._work_lost_samples += lost
            del self._runs[job_id]
            self._live.pop(job_id, None)
            for gid in run.gpus:
                g = self.cluster.gpus[gid]
                if g.busy_job == job_id:
                    if g.busy_until is not None and g.busy_until > now:
                        g.busy_accum -= g.busy_until - now
                    g.busy_until = None
                    g.busy_job = None
                self._dirty_gpus.add(gid)
            self.cluster.release(run.spec, run.gpus)
            if job_id in self._waiting_set:
                self._waiter_drop(job_id, run.domains)
            if job_id in self._active_comm:
                self._abort_comm(job_id)
            if self.record_trace:
                self._trace.append((job_id, run.iter_done, "cancel", -1, now, now))
        elif job_id in self._queue:
            self._queue.remove(job_id)
            self._carry.pop(job_id, None)
        self._cancelled += 1
        self._unfinished.discard(job_id)
        if self._obs is not None:
            self._obs.cancelled(job_id, now, lost)
        # freed memory/GPUs (or a shorter queue) may admit other jobs
        self.sched.on_job_finish(now, job_id)

    # -- communication gating -----------------------------------------------------
    def _gate_try_one(
        self, jid: int, run: JobRun, now: float, qpos: int = -1
    ) -> bool:
        """Evaluate the gating policy for one waiter and commit the start
        when it accepts.  Returns True iff a transfer started.  This body
        is shared verbatim by the rescan and incremental paths, so the two
        modes can only differ in *which* waiters they evaluate.  ``qpos``
        is the waiter's rank in the pass's SRSF evaluation order — audit
        metadata only, never a decision input."""
        servers = run.servers
        domains = run.domains
        olds = [t for t in self._active_comm.values() if t.domains & domains]
        old_rem = [t.remaining_bytes for t in olds]
        max_conc = 0
        for d in domains:
            max_conc = max(max_conc, self._domain_load.get(d, 0))
        # WFBP: the gating decision and the transfer carry the
        # current *bucket's* bytes, not the whole message.
        if run.plan is not None:
            bucket = run.next_bucket
            new_bytes = run.plan[0][bucket]
        else:
            bucket = -1
            new_bytes = run.spec.model.size_bytes
        ok = self.comm_policy.should_start(
            new_bytes,
            old_rem,
            max_conc,
            self.params,
        )
        obs = self._obs
        lg = self._obs_audit
        if lg is not None:
            # audit record, inlined — the densest hook on contended cells
            # (one per gate evaluation); dedicated flat stream, engine-
            # side budget countdown
            n = self._obs_audit_left
            if n > 0:
                self._obs_audit_left = n - 1
                lg.extend(
                    (
                        now,
                        jid,
                        bucket,
                        new_bytes,
                        max_conc,
                        ok,
                        qpos,
                        len(self._waiting_comm),
                        len(old_rem),
                    )
                )
                lg.extend(old_rem)
            else:
                obs.audit_dropped += 1
        if not ok:
            return False
        self._waiter_drop(jid, domains)
        task = CommTask(
            job_id=jid,
            servers=set(servers),
            remaining_bytes=(
                new_bytes
                if run.plan is not None
                else run.spec.model.size_bytes / self.comm_chunks
            ),
            latency_left=self.params.a,
            domains=domains,
            bucket=bucket,
        )
        self._active_comm[jid] = task
        self._comm_started(task)
        if run.plan is not None:
            run.next_bucket += 1
        else:
            run.comm_chunks_left -= 1
        run.comm_active = True
        if max_conc > 0:
            self._comm_contended += 1
        else:
            self._comm_clean += 1
        if obs is not None:
            obs.comm_start(jid, bucket, now, task)
        if self.record_trace:
            kind = "c" if bucket < 0 else f"c{bucket}"
            self._trace.append((jid, run.iter_done, kind, -1, now, None))
        return True

    def _try_start_comms(self, now: float) -> bool:
        if not self._waiting_comm:
            return False
        if self.gating == "rescan":
            return self._try_start_comms_rescan(now)
        return self._try_start_comms_incremental(now)

    def _try_start_comms_rescan(self, now: float) -> bool:
        """Legacy reference gating: evaluate EVERY waiter in SRSF order on
        every call, restarting from the top after each start.  O(waiters x
        evaluations) per event — kept as the differential-test oracle for
        the incremental path (REPRO_GATING=rescan)."""
        any_started = False
        # Alg. 3 line 16: consider ready communication tasks in SRSF order.
        self._waiting_comm.sort(key=self.srsf_key_running)
        started_any = True
        while started_any:
            started_any = False
            for qpos, jid in enumerate(list(self._waiting_comm)):
                run = self._runs[jid]
                if run.comm_active or jid in self._active_comm:
                    self._waiter_drop(jid, run.domains)
                    continue
                if self._gate_try_one(jid, run, now, qpos):
                    started_any = True
                    any_started = True
                    break  # re-evaluate contention state after each start
        return any_started

    def _try_start_comms_incremental(self, now: float) -> bool:
        """Dirty-domain gating: evaluate only waiters whose decision inputs
        may have changed — fresh waiters, plus waiters sharing a contention
        domain with any comm start/end/abort since their last evaluation
        (``_gate_candidates``, maintained by ``_comm_started`` /
        ``_comm_ended`` / ``_waiter_add``).

        Bit-exactness with the rescan rests on three facts:

        1. Within one pass, candidates are evaluated in the same SRSF order
           the rescan sorts the full waiter list into (identical keys), and
           a start restarts evaluation with the fresh contention state —
           waiters woken by the start (its domains just got dirtied) merge
           into the candidate set, exactly the waiters whose inputs the
           start changed.  A waiter NOT sharing a domain with the start has
           an unchanged ``olds`` list (``_active_comm`` is insertion-
           ordered and only appended to here) and unchanged ``max_conc``,
           so re-evaluating it (as the rescan does) provably returns the
           same False as its last evaluation this pass.
        2. Between events under a *fixed* active set, in-flight transfers
           only drain.  For the drain-monotone policies (AdaDUAL: start iff
           ``new < min(olds) * threshold`` with a ``max_conc`` cap — drain
           shrinks ``min(olds)``; SRSF(n): depends on ``max_conc`` only) a
           False decision stays False until a start/end/abort touches the
           waiter's domains, which is precisely when it re-enters the
           candidate set.  Skipping the re-evaluation is unobservable.
        3. Policies that are NOT drain-monotone (the k-way exact lookahead
           integrates the actual remaining bytes, so mere drain can flip
           its decision) declare ``drain_monotone = False`` and are
           re-evaluated in full every event — the rescan itself, through
           the shared ``_gate_try_one`` body.

        Chaos paths that mutate comm state outside this function
        (``_abort_comm``, NIC bandwidth changes replacing ``params``) set
        ``_comm_dirty``, which forces a full-waiter pass for that event.

        Locked by tests/test_gating_incremental.py across the fusion x
        policy x chaos x sched grid."""
        if self._comm_dirty or not self.comm_policy.drain_monotone:
            cand = set(self._waiting_comm)
            self._gate_candidates.clear()
        else:
            if not self._gate_candidates:
                return False
            cand = self._gate_candidates
            self._gate_candidates = set()
        any_started = False
        while cand:
            restart = False
            for qpos, jid in enumerate(sorted(cand, key=self.srsf_key_running)):
                run = self._runs[jid]
                if run.comm_active or jid in self._active_comm:
                    # defensive mirror of the rescan's cleanup path
                    self._waiter_drop(jid, run.domains)
                    cand.discard(jid)
                    restart = True
                    break
                if self._gate_try_one(jid, run, now, qpos):
                    any_started = True
                    cand.discard(jid)
                    # the start dirtied its domains: merge the woken
                    # waiters and restart with fresh contention state
                    cand |= self._gate_candidates
                    self._gate_candidates.clear()
                    restart = True
                    break
                cand.discard(jid)
            if not restart:
                break  # every candidate evaluated False — pass complete
        return any_started

    # -- iteration/worker state machine ---------------------------------------------
    def _begin_iteration(self, run: JobRun, now: float) -> None:
        run.f_done.clear()
        run.b_done.clear()
        run.comm_ready_at = None
        run.comm_active = False
        if run.plan is not None:
            run.b_prog = [0] * run.n_world
            run.next_bucket = 0
            run.buckets_done = 0
        self._dirty_gpus.update(run.gpus)

    def _complete_iteration(self, run: JobRun, now: float) -> None:
        run.iter_done += 1
        run.samples_done += run.n_world
        if run.samples_done >= run.samples_total:
            self._finish_job(run, now)
        elif run.pending_resize is not None:
            self._apply_resize(run, now)
        else:
            self._begin_iteration(run, now)

    def _finish_job(self, run: JobRun, now: float) -> None:
        run.finished_at = now
        jid = run.spec.job_id
        self.cluster.release(run.spec, run.gpus)
        self._dirty_gpus.update(run.gpus)
        self._unfinished.discard(jid)
        self._live.pop(jid, None)
        # Results are recorded at finish time (list mode re-derives them
        # from _runs at collection for the legacy float-order guarantees;
        # streaming mode retires the run below, so this is the only copy).
        self._finish_at[jid] = now
        self._jct_at_finish[jid] = now - run.spec.arrival
        self._qdelay_at_finish[jid] = (
            self._first_placed.get(jid, run.placed_at) - run.spec.arrival
        )
        self._job_samples[jid] = run.samples_done
        if self._obs is not None:
            self._obs.finished(jid, run, now)
        if self._source is not None:
            # streaming feed: drop the finished run's state at the end of
            # this event so memory stays O(live jobs) over a 100k+ replay
            # (not immediately — the current event's handlers may still
            # hold references, e.g. the finished-comms loop)
            self._retire_buf.append(jid)

    def _on_backward_done(self, run: JobRun, now: float) -> None:
        if len(run.b_done) < run.n_world:
            return
        # Barrier reached (Fig. 3: all-reduce waits for all backprops).
        if run.has_comm:
            jid = run.spec.job_id
            assert jid not in self._waiting_set and not run.comm_active, (
                f"duplicate barrier for job {jid}"
            )
            run.comm_ready_at = now
            run.comm_chunks_left = self.comm_chunks
            self._waiter_add(jid, run)
        else:
            self._complete_iteration(run, now)

    # -- GPU scheduling (Alg. 3 lines 22-30) -------------------------------------
    def _restore_extra(self, run: JobRun, w: int) -> float:
        """Checkpoint-restore penalty owed by worker ``w``: charged on its
        first compute task after a preemption/resize (state reload delays
        the forward pass)."""
        return run.restore_cost if w in run.restore_need else 0.0

    def _ready_compute_tasks(self, gid: GpuId):
        """Yield (job_id, worker, kind, duration, segment) ready on this
        GPU; segment is the WFBP backward-segment index (-1 = monolithic)."""
        g = self.cluster.gpus[gid]
        for jid in g.resident_jobs:
            run = self._runs.get(jid)
            if run is None or run.finished_at is not None:
                continue
            try:
                w = run.gpus.index(gid)
            except ValueError:
                continue
            # Straggler jitter (core/chaos.py): per-(job, iteration) compute
            # stretch, identical for every worker and segment of the
            # iteration.  The restore penalty is a state reload, not
            # compute — never jittered.
            jit = (
                jitter_factor(self._chaos, jid, run.iter_done)
                if self._chaos is not None
                else 1.0
            )
            if run.plan is not None:
                # WFBP: backward runs in per-bucket segments that overlap
                # in-flight transfers — comm never blocks compute within
                # the iteration (only the iteration boundary barriers).
                if w not in run.f_done:
                    yield (jid, w, "f", run.spec.model.t_f * jit + self._restore_extra(run, w), -1)
                elif run.b_prog[w] < run.n_buckets:
                    s = run.b_prog[w]
                    yield (jid, w, "b", run.plan[1][s] * jit, s)
                continue
            if run.comm_ready_at is not None or run.comm_active:
                continue  # between barrier and next iteration
            if w not in run.f_done:
                if self.fuse_fb:
                    yield (jid, w, "fb", run.spec.model.t_iter_compute * jit + self._restore_extra(run, w), -1)
                else:
                    yield (jid, w, "f", run.spec.model.t_f * jit + self._restore_extra(run, w), -1)
            elif w not in run.b_done:
                yield (jid, w, "b", run.spec.model.t_b * jit, -1)

    def _schedule_gpus(self, now: float) -> None:
        for gid in list(self._dirty_gpus):
            self._dirty_gpus.discard(gid)
            g = self.cluster.gpus[gid]
            if g.down:
                continue  # broken server: nothing runs until repair
            # busy_job is cleared only by this GPU's own gpu_done event, so a
            # task ending exactly at `now` (event still in the heap) cannot be
            # double-scheduled by another same-timestamp event.
            if g.busy_job is not None:
                continue
            candidates = list(self._ready_compute_tasks(gid))
            if not candidates:
                g.busy_until = None
                g.busy_job = None
                continue
            # SRSF among resident jobs' ready tasks.
            candidates.sort(key=lambda c: self.srsf_key_running(c[0]))
            jid, w, kind, dur, seg = candidates[0]
            run = self._runs[jid]
            if kind in ("f", "fb") and w in run.restore_need:
                run.restore_need.discard(w)  # penalty committed with this task
            g.busy_until = now + dur
            g.busy_job = jid
            g.busy_accum += dur
            self._push(
                now + dur,
                "gpu_done",
                (gid, jid, w, kind, seg, self._epoch_of.get(jid, 0)),
            )
            rc = self._obs_rc
            if rc is not None:
                if len(rc) < self._obs_rc_cap:
                    rc.extend((jid, w, kind, seg, now, now + dur))
                else:
                    self._obs.span_dropped += 1
            if self.record_trace:
                if kind == "fb":
                    self._trace.append((jid, run.iter_done, "f", w, now, now + run.spec.model.t_f))
                    self._trace.append((jid, run.iter_done, "b", w, now + run.spec.model.t_f, now + dur))
                else:
                    tkind = kind if seg < 0 else f"{kind}{seg}"
                    self._trace.append((jid, run.iter_done, tkind, w, now, now + dur))

    # -- streaming arrival feed (TraceSource) -------------------------------------
    def _push_next_arrival(self) -> None:
        """Pull ONE arrival ahead from the streaming source into the
        calendar.  Exactly one future arrival is outstanding at a time, so
        the calendar stays O(cluster) regardless of trace length."""
        spec = next(self._stream, None)
        if spec is None:
            self._stream = None
            return
        if spec.arrival < self._last_arrival:
            raise ValueError(
                f"TraceSource must yield arrivals in nondecreasing order: "
                f"job {spec.job_id} arrives at {spec.arrival} after "
                f"{self._last_arrival}"
            )
        if spec.job_id in self.jobs:
            raise ValueError(f"TraceSource repeated job_id {spec.job_id}")
        self._last_arrival = spec.arrival
        self._push(spec.arrival, "arrival", (spec,))
        self._arrivals_pending += 1

    def _register_arrival(self, spec: JobSpec, now: float) -> None:
        """A streamed arrival event fired: the job enters the system now
        (list mode registers everything in __init__ instead)."""
        jid = spec.job_id
        self.jobs[jid] = spec
        self._unfinished.add(jid)
        self._n_seen += 1
        self._arrivals_pending -= 1
        if self._chaos is not None:
            # per-arrival twin of _seed_chaos_events' cancellation seeding
            t_c = cancel_time(self._chaos, jid, spec.arrival)
            if t_c is not None:
                self._push(max(t_c, spec.arrival), "cancel", (jid,))
        self._push_next_arrival()

    def _retire_finished(self) -> None:
        """Streaming-only end-of-event cleanup: drop finished runs' state so
        a 100k-job replay holds O(live jobs) memory.  Results were already
        recorded at finish time; gpu_done tombstones survive via the
        ``_runs.get`` guard in the main loop (a stale event of a retired
        job simply finds no run)."""
        for jid in self._retire_buf:
            self._runs.pop(jid, None)
            self.jobs.pop(jid, None)
            self._first_placed.pop(jid, None)
            self._epoch_of.pop(jid, None)
        self._retire_buf.clear()

    # -- main loop ----------------------------------------------------------------
    def run(self, max_time: float = math.inf) -> SimResult:
        if self._source is not None:
            self._stream = iter(self._source.arrivals())
            self._push_next_arrival()
        else:
            for spec in self.jobs.values():
                self._push(spec.arrival, "arrival", (spec.job_id,))
        if self.sched.quantum is not None:
            if self.jobs:
                first = min(s.arrival for s in self.jobs.values())
            elif self._heap:
                first = self._heap[0][0]  # streaming: the one-ahead arrival
            else:
                first = None
            if first is not None:
                self._push(first + self.sched.quantum, "quantum", ())
        if self._chaos is not None:
            self._seed_chaos_events()
        prof = self._phase_seconds
        perf = time.perf_counter
        streaming = self._source is not None
        now = 0.0
        while self._heap and (self._unfinished or self._arrivals_pending):
            t, _, kind, data = heapq.heappop(self._heap)
            if kind == "comm_check" and data[0] != self._comm_epoch:
                continue
            if t > max_time:
                break
            now = t
            self._events += 1
            self._comm_dirty = False
            if prof is not None:
                t0 = perf()

            finished_comms = self._advance_comm(now)
            for jid in finished_comms:
                run = self._runs[jid]
                run.comm_active = False
                if self.record_trace:
                    # patch the open comm record ("c" or a WFBP "c<bucket>")
                    for i in range(len(self._trace) - 1, -1, -1):
                        r = self._trace[i]
                        if r[0] == jid and r[2].startswith("c") and r[5] is None:
                            self._trace[i] = (r[0], r[1], r[2], r[3], r[4], now)
                            break
                if run.plan is not None:
                    # WFBP: bucket done; the iteration completes with the
                    # LAST bucket's transfer (earlier ones only overlapped
                    # the remaining backward), else hand the next ready
                    # bucket to the FIFO comm stream.
                    run.buckets_done += 1
                    if run.buckets_done >= run.n_buckets:
                        self._complete_iteration(run, now)
                    else:
                        self._maybe_enqueue_bucket(run)
                elif run.comm_chunks_left > 0:
                    # chunked comm: re-queue the next chunk (it competes for
                    # the link like a fresh task — preemption point)
                    self._waiter_add(jid, run)
                else:
                    self._complete_iteration(run, now)
            if prof is not None:
                t1 = perf()
                prof["comm_advance"] += t1 - t0

            if kind == "arrival":
                if streaming:
                    spec = data[0]
                    jid = spec.job_id
                    self._register_arrival(spec, now)
                else:
                    jid = data[0]
                # the queue is kept in srsf_key_queued order (the key is
                # static while a job waits, so one insort here replaces the
                # pre-split full sort on every placement scan)
                insort(self._queue, jid, key=self.srsf_key_queued)
                self.sched.on_arrival(now, jid)
            elif kind == "gpu_done":
                gid, jid, w, tkind, seg, epoch = data
                run = self._runs.get(jid)
                if run is not None and epoch == self._epoch_of.get(jid, 0):
                    g = self.cluster.gpus[gid]
                    g.busy_until = None
                    g.busy_job = None
                    self._dirty_gpus.add(gid)
                    if run.plan is not None:
                        if tkind == "f":
                            run.f_done.add(w)
                        else:  # backward segment `seg` of worker w
                            run.b_prog[w] += 1
                            self._maybe_enqueue_bucket(run)
                    elif tkind == "fb":
                        run.f_done.add(w)
                        run.b_done.add(w)
                        self._on_backward_done(run, now)
                    elif tkind == "f":
                        run.f_done.add(w)
                    elif tkind == "b":
                        run.b_done.add(w)
                        self._on_backward_done(run, now)
                    if run.finished_at is not None:
                        # memory freed -> queued jobs may fit now
                        self.sched.on_job_finish(now, jid)
                # else: stale event of a preempted/resized incarnation — the
                # GPU was already freed (and possibly rebooked) at teardown
            elif kind == "quantum":
                self.sched.on_quantum(now)
                # keep ticking only while progress is possible — a live run
                # or a pending event; otherwise the tick would spin forever
                # on a stuck (never-placeable) queue the way the pre-split
                # simulator's drained heap never could
                if (self._unfinished or self._arrivals_pending) and (
                    self._heap
                    or any(r.finished_at is None for r in self._runs.values())
                ):
                    self._push(now + self.sched.quantum, "quantum", ())
            elif kind == "comm_check":
                pass  # generic comm processing above already handled it
            elif kind == "breakdown":
                self._on_breakdown(data[0], data[1], now)
            elif kind == "repair":
                self._on_repair(data[0], now)
            elif kind == "nic_down":
                self._on_nic_down(data[0], data[1], now)
            elif kind == "nic_up":
                self._on_nic_up(data[0], now)
            elif kind == "cancel":
                self._on_cancel(data[0], now)

            if finished_comms:
                # job finishing via comm also frees memory
                for j in finished_comms:
                    run = self._runs.get(j)
                    if run is not None and run.finished_at is not None:
                        self.sched.on_job_finish(now, j)
                        break  # one re-evaluation per event (pre-split shape)
            if prof is not None:
                t2 = perf()
                prof["dispatch"] += t2 - t1

            # Gating re-evaluated whenever comm state may have changed or new
            # barriers were reached this event.
            started = self._try_start_comms(now)
            if prof is not None:
                t3 = perf()
                prof["gating"] += t3 - t2
            self._schedule_gpus(now)
            if prof is not None:
                prof["gpu_schedule"] += perf() - t3
            # Rates only change when the active comm set changes, so the
            # pending finish prediction stays valid otherwise.  A comm_check
            # that finished nothing (float drift) must still reschedule, or
            # the in-flight task would stall forever.  Policy actions that
            # abort an active transfer (preemption) also change the rates.
            if started or finished_comms or kind == "comm_check" or self._comm_dirty:
                if prof is not None:
                    # The finish-time re-prediction belongs to gating when it
                    # was forced by a gating/abort action this event (a new
                    # transfer started or the rate set was invalidated), and
                    # to comm integration when it merely tracks transfers
                    # draining on a stable rate set.
                    t4 = perf()
                    self._reschedule_comm_check()
                    phase = (
                        "gating" if (self._comm_dirty or started) else "comm_advance"
                    )
                    prof[phase] += perf() - t4
                else:
                    self._reschedule_comm_check()
            if self._retire_buf:
                self._retire_finished()

        return self._collect(now)

    # -- results ------------------------------------------------------------------
    def _collect(self, now: float) -> SimResult:
        if self._source is None:
            # List mode: re-derive results from the (never-retired) runs in
            # their _runs insertion order — the pre-split float accumulation
            # order, kept bit-exact for the captured-baseline locks.
            jct, finish, qdelay = {}, {}, {}
            for jid, run in self._runs.items():
                if run.finished_at is not None:
                    finish[jid] = run.finished_at
                    jct[jid] = run.finished_at - run.spec.arrival
                    qdelay[jid] = (
                        self._first_placed.get(jid, run.placed_at)
                        - run.spec.arrival
                    )
            # Delivered throughput: samples completed by finished or still-
            # live jobs (runs + requeued carries).  Cancelled jobs left the
            # system with their partial progress — not delivered, not
            # counted.
            delivered = sum(r.samples_done for r in self._runs.values()) + sum(
                c.samples_done for c in self._carry.values()
            )
        else:
            # Streaming mode: finished runs were retired as the replay went,
            # so the finish-time records are the only copy (finish order).
            jct = self._jct_at_finish
            finish = self._finish_at
            qdelay = self._qdelay_at_finish
            delivered = (
                sum(self._job_samples.values())
                + sum(r.samples_done for r in self._runs.values())
                + sum(c.samples_done for c in self._carry.values())
            )
        makespan = max(finish.values()) if finish else now
        busy = {gid: g.busy_accum for gid, g in self.cluster.gpus.items()}
        util = (
            sum(busy.values()) / (len(busy) * makespan) if makespan > 0 else 0.0
        )
        obs_report = None
        if self._obs is not None:
            obs_report = self._obs.build_report(
                topology=self.topology,
                params=self.params,
                makespan=makespan,
                horizon=now,
            )
        return SimResult(
            policy_name=self.comm_policy.name,
            placement_name=repr(self.placement),
            jct=jct,
            finish=finish,
            makespan=makespan,
            gpu_busy=busy,
            gpu_util=util,
            queueing_delay=qdelay,
            events_processed=self._events,
            comm_started_contended=self._comm_contended,
            comm_started_clean=self._comm_clean,
            peak_calendar=self._peak_heap,
            sched_name=self.sched.name,
            # cancelled jobs are an explicit outcome, not silent truncation:
            # censored counts only jobs cut off by the horizon or stranded
            # unplaced (a breakdown-preempted job still queued at max_time
            # lands here — it must not vanish from the aggregates).
            # _n_seen is the number of jobs that ENTERED the system: all of
            # them in list mode, only processed arrivals in streaming mode
            # (an un-yielded arrival past the horizon was never censored —
            # it never existed).
            censored=self._n_seen - len(finish) - self._cancelled,
            preemptions=self._preemptions,
            resizes=self._resizes,
            faults=self._faults,
            cancelled=self._cancelled,
            work_lost_samples=self._work_lost_samples,
            goodput=(delivered / makespan) if makespan > 0 else 0.0,
            task_trace=self._trace if self.record_trace else None,
            job_samples=dict(self._job_samples),
            phase_seconds=(
                dict(self._phase_seconds) if self._phase_seconds else None
            ),
            obs=obs_report,
        )
