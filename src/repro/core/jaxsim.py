"""Vectorized JAX cluster simulator — Monte-Carlo over traces in one jit.

Beyond-paper extension #3 (DESIGN.md §7): a fixed-timestep, fully-batched
("fluid") approximation of the Ada-SRSF dynamics in pure ``jax.lax``
control flow, ``vmap``-able over seeds, so JCT confidence intervals over
dozens of sampled workloads cost one XLA compilation and one device launch.

The policy/network math (Eq. 5 rate model, per-server bandwidth, gating
predicates, placement ranking) lives in ``core/netmodel.py`` and is shared
with the exact event simulator; this module only supplies the fluid state
machine around it.  Feature parity with the event backend:

* every gating policy: AdaDUAL, SRSF(n), and k-way AdaDUAL (``kway2``/
  ``kway3``/...) — k-way runs the *exact* per-bucket lookahead
  (``netmodel.kway_exact_start``, the closed form of the event backend's
  option-A/option-B average-finish comparison, vectorized over the
  overlap mask), not a threshold approximation;
* per-server heterogeneous NIC bandwidth: each communication task drains
  at the rate of its slowest member server (no cluster-mean collapse);
* fabric contention domains (``core/topology.py``): the topology's cut
  load-rule lowers to a static ``[domains, servers]`` incidence matrix
  (``netmodel.domain_loads`` — two matmuls, no branching), and drain rates
  use the oversub-weighted effective k; the NIC-only topology is
  bit-identical to the pre-topology backend;
* pluggable gang placement: ``consolidate`` (LWF-1 shape), ``first_fit``
  (FF shape), ``least_loaded`` (LS/LWF L_S ordering), ``random`` (RAND
  shape: fresh uniform server order per admission), ``rack_pack``
  (LWF_RACK shape: pack the emptiest rack, stay off the uplinks).

Remaining approximations vs the event simulator (``core/simulator.py``),
all documented and tested for *qualitative* agreement:

* gang placement — a job occupies whole GPUs exclusively (no task-level
  time-sharing of one GPU between resident jobs);
* time advances in fixed dt steps; compute/comm remainders drain linearly
  (the Eq. 5 rate model is exact within a step as long as the active comm
  set is unchanged, so dt only quantizes *transition* times);
* at most one queued job is admitted and one gated all-reduce started per
  step (admissions/starts are rare relative to dt, so this rarely binds);
  bucketed WFBP traces get several gating rounds per step instead — one
  start per dt would throttle the per-bucket streams artificially;
* WFBP tensor-fusion buckets (``trace_from_jobs(..., fusion=...)``) drain
  as a chunked FIFO stream over a static ``[jobs, buckets]`` size matrix,
  each bucket gated afresh; the event backend's *overlap* of transfers
  with the remaining backward compute is NOT modeled — the fluid backend
  charges full compute, then the bucket stream (documented pessimism,
  bounded by the differential harness);
* the fixed all-reduce latency ``a`` is folded into the bandwidth term, so
  a slow server also stretches ``a`` (a ≪ dt, negligible; under WFBP it is
  charged once per bucket, the real cost of finer granularity).

State is a struct-of-arrays over jobs plus per-server occupancy; policies
are branchless masks parameterized by the shared layer.  Traces may carry
a boolean ``valid`` mask so ragged per-seed traces can be padded to one
rectangular batch (see :func:`stack_traces`) and swept in a single
``vmap`` (:func:`simulate_traces_batched`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netmodel
from repro.core.cluster import TABLE_III
from repro.core.contention import ContentionParams
from repro.core.topology import Topology, nic_topology
from repro.core.trace import PAPER_GPU_DISTRIBUTION

# job phases
QUEUED, COMPUTE, COMM, DONE = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class JaxSimConfig:
    n_servers: int = 16
    gpus_per_server: int = 4
    dt: float = 0.05          # [s]
    max_steps: int = 400_000  # dt * max_steps = simulated horizon cap
    policy: str = "ada"       # ada | srsfN | kwayK (netmodel.parse_policy)
    #: consolidate | first_fit | least_loaded | random | rack_pack
    placement: str = "consolidate"
    a: float = ContentionParams().a
    b: float = ContentionParams().b
    eta: float = ContentionParams().eta
    dual_threshold: float = ContentionParams().dual_threshold
    #: per-server relative NIC bandwidth multipliers (1.0 = nominal);
    #: servers beyond the tuple are nominal, () = homogeneous network.
    server_bandwidth: Tuple[float, ...] = ()
    #: fabric contention domains (core/topology.py); None = the paper's
    #: NIC-only model (bit-identical to pre-topology behaviour).  Topology
    #: is frozen/hashable, so it rides along as part of this jit-static
    #: config and lowers to *static* incidence/oversub matrices.
    topology: Optional[Topology] = None
    #: PRNG seed for the ``random`` gang placement mode (fold_in per step).
    placement_seed: int = 0


def sample_trace(key, n_jobs: int, horizon: float = 1200.0,
                 min_iters: int = 1000, max_iters: int = 6000) -> Dict[str, jnp.ndarray]:
    """Paper-distribution workload as arrays (vmap-able over keys)."""
    models = list(TABLE_III.values())
    t_iter = jnp.asarray([m.t_iter_compute for m in models])
    sizes = jnp.asarray([m.size_bytes for m in models])

    gpu_choices, probs = [], []
    total = sum(c for _, c in PAPER_GPU_DISTRIBUTION)
    for g, c in PAPER_GPU_DISTRIBUTION:
        gpu_choices.append(g)
        probs.append(c / total)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    arrival = jnp.floor(jax.random.uniform(k1, (n_jobs,), minval=1.0, maxval=horizon))
    iters = jax.random.randint(k2, (n_jobs,), min_iters, max_iters + 1)
    midx = jax.random.randint(k3, (n_jobs,), 0, len(models))
    gidx = jax.random.choice(
        k4, jnp.asarray(gpu_choices), (n_jobs,), p=jnp.asarray(probs)
    )
    return {
        "arrival": arrival,
        "iters": iters.astype(jnp.float32),
        "t_iter": t_iter[midx],
        "msg_bytes": sizes[midx],
        "n_gpus": gidx.astype(jnp.int32),
    }


def _place(free: jnp.ndarray, n_gpus: jnp.ndarray,
           rank_key: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gang placement: fill servers in ascending ``rank_key`` order (the
    shared :func:`netmodel.placement_rank` key; stable sort, server-index
    ties).  Returns (per-server takes, feasible flag)."""
    order = jnp.argsort(rank_key)
    sorted_free = free[order]
    cum = jnp.cumsum(sorted_free)
    want = n_gpus.astype(free.dtype)
    take_sorted = jnp.clip(want - (cum - sorted_free), 0, sorted_free)
    feasible = cum[-1] >= want
    take = jnp.zeros_like(free).at[order].set(take_sorted)
    return jnp.where(feasible, take, 0), feasible


#: Sentinel for the policy field of the jit-static config key: the gating
#: policy rides along as *runtime* scalars (max_ways, threshold_gated), so
#: every policy shares one compiled graph per trace shape (see
#: :func:`_policy_args`); the inner simulator must never read cfg.policy.
_DYNAMIC_POLICY = "<dynamic>"

#: Sentinel for exact-lookahead (``kwayK``) policies: the per-candidate
#: overlap mask and pairwise-min matmuls of ``netmodel.kway_exact_start``
#: are a materially different graph, so exact k-way compiles separately
#: while ada/srsf keep sharing the cheap threshold graph above.
_EXACT_KWAY_POLICY = "<exact-kway>"


def _policy_args(cfg: JaxSimConfig):
    """(max_ways, threshold_gated) as arrays + the policy-stripped static
    config key; threshold policies (ada/srsfN) all share one compiled
    graph, exact-lookahead ``kwayK`` policies share another."""
    spec = netmodel.parse_policy(cfg.policy)
    sentinel = _EXACT_KWAY_POLICY if spec.exact_lookahead else _DYNAMIC_POLICY
    return (
        jnp.asarray(spec.max_ways, jnp.float32),
        jnp.asarray(spec.threshold_gated, bool),
        dataclasses.replace(cfg, policy=sentinel),
    )


def _simulate(trace: Dict[str, jnp.ndarray], cfg: JaxSimConfig, max_ways, gated):
    n_jobs = trace["arrival"].shape[0]
    ns = cfg.n_servers
    assert cfg.policy in (_DYNAMIC_POLICY, _EXACT_KWAY_POLICY), (
        "callers go through _policy_args"
    )
    exact_kway = cfg.policy == _EXACT_KWAY_POLICY
    placement = netmodel.canonical_placement(cfg.placement)
    bw = jnp.asarray(
        netmodel.server_bandwidth_array(cfg.server_bandwidth, ns), jnp.float32
    )
    # Fabric topology as STATIC matrices (cfg is jit-static, so these are
    # compile-time constants): domain incidence (n_domains, n_servers),
    # per-domain oversubscription, and each server's rack for rack_pack.
    topo = cfg.topology if cfg.topology is not None else nic_topology(ns)
    if topo.n_servers != ns:
        raise ValueError(
            f"topology covers {topo.n_servers} servers, config has {ns}"
        )
    incidence = jnp.asarray(topo.incidence(), jnp.float32)
    oversub = jnp.asarray(topo.oversub_array(), jnp.float32)
    server_rack = jnp.asarray(topo.server_rack(), jnp.int32)
    n_racks = len(topo.rack_groups())
    place_key = jax.random.PRNGKey(cfg.placement_seed)
    server_index = jnp.arange(ns, dtype=jnp.float32)
    valid = trace.get("valid")
    if valid is None:
        valid = jnp.ones((n_jobs,), bool)

    # WFBP tensor-fusion buckets (layer-granular comm subsystem): a static
    # ``(jobs, B)`` size matrix plus a per-job bucket count.  ``wfbp`` is a
    # COMPILE-TIME flag: without multi-bucket planes (fusion="all" / legacy
    # traces, and (jobs, 1) planes) the emitted graph is exactly the
    # pre-bucket backend's — bit-identical results AND compile
    # (regression-locked in tests/test_wfbp.py).
    bucket_bytes = trace.get("bucket_bytes")
    b_max = 1 if bucket_bytes is None else int(bucket_bytes.shape[-1])
    wfbp = b_max > 1
    if wfbp:
        n_buckets = trace["n_buckets"].astype(jnp.int32)
        # per-bucket contention-free seconds; the latency `a` is paid per
        # bucket (the real cost of finer granularity), folded into the drain
        bucket_t = cfg.a + cfg.b * bucket_bytes  # (jobs, B)
        bucket_live = jnp.arange(b_max) < n_buckets[:, None]
        comm_total = jnp.where(bucket_live, bucket_t, 0.0).sum(axis=-1)
    else:
        comm_total = cfg.a + cfg.b * trace["msg_bytes"]  # contention-free s

    state = {
        "phase": jnp.where(valid, QUEUED, DONE).astype(jnp.int32),
        "iters_left": trace["iters"],
        "rem": jnp.zeros((n_jobs,), jnp.float32),       # remaining sec/bytes-time in phase
        "servers": jnp.zeros((n_jobs, ns), jnp.int32),  # GPUs taken per server
        "finish": jnp.full((n_jobs,), jnp.inf, jnp.float32),
        "free": jnp.full((ns,), float(cfg.gpus_per_server), jnp.float32),
        "t": jnp.asarray(0.0, jnp.float32),
        "n_done": jnp.asarray(0, jnp.int32),
    }

    def srsf_key(st):
        # E_J = 0 before placement (paper Section IV-A): queued-job priority
        # is compute-only, matching the event backend's _srsf_key_queued.
        rem_service = st["iters_left"] * trace["t_iter"] * trace["n_gpus"]
        return jnp.where(st["phase"] == QUEUED, rem_service, jnp.inf)

    def step(st, step_i):
        t = st["t"] + cfg.dt
        phase, rem = st["phase"], st["rem"]

        spans0 = (st["servers"] > 0).sum(axis=1) > 1
        # Running-job SRSF key mirrors the event backend's remaining_service:
        # remaining iters x (compute + contention-free comm) x GPUs.
        rem_service = (
            st["iters_left"]
            * (trace["t_iter"] + jnp.where(spans0, comm_total, 0.0))
            * trace["n_gpus"]
        )
        # Per-server remaining workload (Alg. 3 line 3's L_S in gang form):
        # each job contributes its remaining service per occupied GPU.
        load = (rem_service[:, None] * st["servers"]).sum(0)

        # ---- admission: smallest-SRSF arrived job that FITS (no head-of-
        # line blocking: infeasible jobs don't stall smaller ones) ---------
        fits = trace["n_gpus"].astype(jnp.float32) <= st["free"].sum()
        arrived = (phase == QUEUED) & (trace["arrival"] <= t) & fits
        pick = jnp.argmin(jnp.where(arrived, srsf_key(st), jnp.inf))
        can_pick = arrived[pick]
        if placement == "random":
            # fresh uniform server order per step: the gang analogue of the
            # event backend's per-GPU RAND placement
            rank_extra = jax.random.uniform(
                jax.random.fold_in(place_key, step_i), (ns,)
            )
        elif placement == "rack_pack":
            rank_extra = netmodel.rack_pack_rank(
                st["free"], server_rack, n_racks, cfg.gpus_per_server
            )
        else:
            rank_extra = None
        rank_key = netmodel.placement_rank(
            placement, st["free"], load, server_index, rank_extra
        )
        take, feasible = _place(st["free"], trace["n_gpus"][pick], rank_key)
        admit = can_pick & feasible
        free = st["free"] - jnp.where(admit, take, 0)
        servers = st["servers"].at[pick].set(
            jnp.where(admit, take.astype(jnp.int32), st["servers"][pick])
        )
        phase = phase.at[pick].set(jnp.where(admit, COMPUTE, phase[pick]))
        rem = rem.at[pick].set(jnp.where(admit, trace["t_iter"][pick], rem[pick]))

        spans = (servers > 0).sum(axis=1) > 1

        # ---- communication contention state --------------------------------
        started = st["started"]
        in_comm = phase == COMM
        # Only *started* transfers occupy links: a job that reached its
        # barrier but is still gated must not count toward contention (it
        # would otherwise see itself and deadlock under ada/srsf1).
        active = in_comm & started & (rem > 0)
        # Which fabric domains each job's ring crosses (static incidence,
        # branchless): for the NIC-only topology this is exactly the old
        # per-server membership of spanning jobs.
        member = (servers > 0).astype(jnp.float32)  # (jobs, ns)
        loads = netmodel.domain_loads(member, incidence)  # (jobs, n_domains)
        counts = netmodel.domain_counts(loads, active)  # (n_domains,)
        # Effective contention for the Eq. (5) rate: per-domain count scaled
        # by that domain's oversubscription (float; NIC-only => raw count).
        k_eff = netmodel.domain_k(loads, counts.astype(jnp.float32) * oversub)

        # ---- drain compute ---------------------------------------------------
        is_comp = phase == COMPUTE
        rem = jnp.where(is_comp, rem - cfg.dt, rem)
        comp_done = is_comp & (rem <= 0)
        # -> job with comm enters COMM (waiting: rem = full message time);
        #    single-server job completes the iteration directly.
        to_comm = comp_done & spans
        iter_done_direct = comp_done & ~spans

        # ---- comm gating (on jobs in COMM with rem == full, i.e. waiting) ---
        # One start per gating round, smallest remaining service first —
        # mirrors the event sim's sorted re-evaluate-after-each-start loop.
        # Without this, barriers landing on the same step would all start
        # against a contention state that excludes their co-starters,
        # violating the srsf1/ada caps.  Each round recomputes the
        # contention state including the jobs started in earlier rounds.
        # Monolithic traces keep the single legacy round (bit-exact);
        # bucketed WFBP traces get several rounds per step, since per-bucket
        # starts are far more frequent than whole-message starts and one
        # start per dt would throttle the bucket streams artificially.
        loads_f = loads.astype(jnp.float32)
        overlap = (loads_f @ loads_f.T) > 0  # (jobs, jobs) share a domain

        def one_start_round(started_now, active_now=None, counts_now=None):
            waiting_now = in_comm & ~started_now
            if active_now is None:  # later rounds: refresh the contention state
                active_now = in_comm & started_now & (rem > 0)
                counts_now = netmodel.domain_counts(loads, active_now)
            # raw contention the job would see if it started now (gating
            # counts contenders, not link capacity — oversub only reshapes
            # the rate)
            k_would = netmodel.domain_k(loads, counts_now, extra=1)
            # Remaining size of the single most-finished overlapping
            # in-flight task ~ min rem of overlapping started jobs (Theorem
            # 2's M_old; conservative when several olds overlap, matching
            # the event backend's all()-quantified Alg. 2 reading).  Two
            # tasks overlap iff they load a common contention domain.
            min_old_rem = jnp.where(
                overlap & active_now[None, :], rem[None, :], jnp.inf
            ).min(axis=1)
            # proportional to M_new — the gates are unit-free.  For a
            # waiting WFBP job ``rem`` is the current *bucket's* size
            # (equal to comm_total while a monolithic job waits), so
            # gating decides per bucket like the event backend.
            new_cost = rem if wfbp else comm_total
            if exact_kway:
                # Exact per-bucket k-way lookahead: row i of the mask marks
                # the in-flight transfers overlapping candidate i's domains
                # — the closed-form option-A/option-B comparison replaces
                # the Theorem-2 threshold approximation.  Costs are comm
                # *seconds* (the folded latency ``a`` rides along per
                # bucket); the decision is scale-invariant, so the unit
                # mismatch vs the event backend's raw bytes only perturbs
                # borderline calls by the a-fold (documented in the module
                # docstring).
                may_start = netmodel.may_start_dynamic(
                    k_would,
                    new_cost,
                    min_old_rem,
                    max_ways,
                    gated,
                    cfg.dual_threshold,
                    exact_kway_olds=overlap & active_now[None, :],
                    rem=rem,
                    eta_over_b=cfg.eta / cfg.b,
                )
            else:
                may_start = netmodel.may_start_dynamic(
                    k_would,
                    new_cost,
                    min_old_rem,
                    max_ways,
                    gated,
                    cfg.dual_threshold,
                )
            start_ok = waiting_now & may_start
            pick_c = jnp.argmin(jnp.where(start_ok, rem_service, jnp.inf))
            start_now = (
                jnp.zeros_like(start_ok).at[pick_c].set(True) & start_ok
            )
            return started_now | start_now

        # round 1 reuses the contention state already computed for the
        # drain rates (the exact legacy graph); later WFBP rounds refresh
        started = one_start_round(started, active, counts)
        if wfbp:
            for _ in range(3):
                started = one_start_round(started)
        # ---- drain comm (started only), at the Eq. 5 rate evaluated at the
        # effective (oversub-weighted) contention and scaled by the slowest
        # member server's NIC (per-server heterogeneity) ----------------------
        scale = netmodel.slowest_member_scale(bw, servers > 0)
        ratio = scale * netmodel.rate_ratio(k_eff, cfg.b, cfg.eta)
        draining = in_comm & started
        rem = jnp.where(draining, rem - cfg.dt * ratio, rem)
        comm_done = draining & (rem <= 0)

        # ---- iteration bookkeeping ------------------------------------------
        # WFBP bucket stream: a finished bucket with buckets left hands the
        # next one to gating afresh (started resets — the FIFO comm stream
        # competes for the fabric per bucket, like the event backend);
        # only the LAST bucket's completion ends the iteration.  All of
        # this is gated on the static ``wfbp`` flag, so monolithic traces
        # compile the exact legacy graph.
        if wfbp:
            next_b = st["bucket"] + 1
            more_buckets = comm_done & (next_b < n_buckets)
            iter_done = iter_done_direct | (comm_done & ~more_buckets)
        else:
            iter_done = iter_done_direct | comm_done
        iters_left = st["iters_left"] - iter_done.astype(jnp.float32)
        job_done = iter_done & (iters_left <= 0)
        next_compute = iter_done & ~job_done

        phase = jnp.where(to_comm, COMM, phase)
        rem = jnp.where(to_comm, bucket_t[:, 0] if wfbp else comm_total, rem)
        if wfbp:
            bucket = jnp.where(to_comm, 0, st["bucket"])
            next_t = jnp.take_along_axis(
                bucket_t, jnp.clip(next_b, 0, b_max - 1)[:, None], axis=-1
            )[:, 0]
            rem = jnp.where(more_buckets, next_t, rem)
            bucket = jnp.where(more_buckets, next_b, bucket)
            started = started & ~(to_comm | iter_done | more_buckets)
        else:
            started = started & ~(to_comm | iter_done)
        phase = jnp.where(next_compute, COMPUTE, phase)
        rem = jnp.where(next_compute, trace["t_iter"], rem)
        phase = jnp.where(job_done, DONE, phase)
        finish = jnp.where(job_done, t, st["finish"])
        free = free + (servers * job_done[:, None].astype(jnp.int32)).sum(0)
        servers = jnp.where(job_done[:, None], 0, servers)

        new_state = {
            "phase": phase,
            "iters_left": iters_left,
            "rem": rem,
            "servers": servers,
            "finish": finish,
            "free": free,
            "t": t,
            "n_done": (phase == DONE).sum().astype(jnp.int32),
            "started": started,
        }
        if wfbp:
            new_state["bucket"] = bucket
        return new_state, None

    state["started"] = jnp.zeros((n_jobs,), bool)
    if wfbp:
        state["bucket"] = jnp.zeros((n_jobs,), jnp.int32)

    def cond(carry):
        st, i = carry
        return (st["n_done"] < n_jobs) & (i < cfg.max_steps)

    def body(carry):
        st, i = carry
        st, _ = step(st, i)
        return (st, i + 1)

    final, _ = jax.lax.while_loop(cond, body, (state, jnp.asarray(0)))
    finished = (final["phase"] == DONE) & valid
    jct = final["finish"] - trace["arrival"]
    # Makespan from recorded finish times, not the loop clock: under vmap
    # the while_loop keeps ticking lanes that finished early until the whole
    # batch converges, so final["t"] would report the slowest lane's clock.
    makespan = jnp.max(jnp.where(finished, final["finish"], 0.0))
    makespan = jnp.where(finished.any(), makespan, final["t"])
    return {"jct": jct, "finished": finished, "makespan": makespan}


@functools.partial(jax.jit, static_argnames=("n_jobs", "cfg"))
def _simulate_one_jit(key, n_jobs: int, cfg: JaxSimConfig, max_ways, gated):
    trace = sample_trace(key, n_jobs)
    return _simulate(trace, cfg, max_ways, gated)


def simulate_one(key, n_jobs: int, cfg: JaxSimConfig):
    max_ways, gated, cfg_key = _policy_args(cfg)
    return _simulate_one_jit(key, n_jobs, cfg_key, max_ways, gated)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _simulate_trace_jit(trace, cfg: JaxSimConfig, max_ways, gated):
    return _simulate(trace, cfg, max_ways, gated)


def simulate_trace(trace: Dict[str, jnp.ndarray], cfg: JaxSimConfig):
    """Fluid-simulate a *fixed* workload (scenario-engine entry point).

    The gating policy enters the jitted graph as runtime scalars
    (:func:`_policy_args`), so sweeping policies over one trace shape
    reuses a single XLA compilation."""
    max_ways, gated, cfg_key = _policy_args(cfg)
    return _simulate_trace_jit(trace, cfg_key, max_ways, gated)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _simulate_batched_jit(traces, cfg: JaxSimConfig, max_ways, gated):
    return jax.vmap(lambda tr: _simulate(tr, cfg, max_ways, gated))(traces)


def simulate_traces_batched(traces: Dict[str, jnp.ndarray], cfg: JaxSimConfig):
    """One vmapped launch over a stacked batch of traces (leading axis =
    seed; see :func:`stack_traces`).  Returns per-lane jct/finished arrays
    and a per-lane makespan vector — the scenario Monte-Carlo entry point.
    Policy-dynamic like :func:`simulate_trace`."""
    max_ways, gated, cfg_key = _policy_args(cfg)
    return _simulate_batched_jit(traces, cfg_key, max_ways, gated)


def trace_from_jobs(jobs, fusion: object = "all") -> Dict[str, jnp.ndarray]:
    """Convert ``JobSpec`` lists (trace generator / scenario engine output)
    into the struct-of-arrays layout the fluid simulator consumes.

    ``fusion`` ('all' | 'none' | a byte threshold) adds the WFBP bucket
    planes: a static ``(jobs, B)`` ``bucket_bytes`` matrix (zero-padded)
    plus per-job ``n_buckets``, from ``netmodel.fusion_plan`` over each
    model's layer data.  Models without layer data (the paper's Table III
    profiles) stay one monolithic bucket; ``fusion="all"`` omits the
    planes entirely, which is bit-identical to the legacy trace."""
    tr = {
        "arrival": jnp.asarray([j.arrival for j in jobs], jnp.float32),
        "iters": jnp.asarray([j.iterations for j in jobs], jnp.float32),
        "t_iter": jnp.asarray([j.model.t_iter_compute for j in jobs], jnp.float32),
        "msg_bytes": jnp.asarray([j.model.size_bytes for j in jobs], jnp.float32),
        "n_gpus": jnp.asarray([j.n_gpus for j in jobs], jnp.int32),
    }
    thr = netmodel.fusion_threshold(fusion)
    if thr == float("inf"):
        return tr
    plans = []
    for j in jobs:
        m = j.model
        if getattr(m, "has_layers", False):
            plans.append(netmodel.fusion_plan(m.layer_grad_bytes, m.layer_t_b, thr)[0])
        else:
            plans.append((m.size_bytes,))
    b_max = max(len(p) for p in plans)
    bb = np.zeros((len(plans), b_max), np.float32)
    for i, p in enumerate(plans):
        bb[i, : len(p)] = p
    tr["bucket_bytes"] = jnp.asarray(bb)
    tr["n_buckets"] = jnp.asarray([len(p) for p in plans], jnp.int32)
    return tr


def stack_traces(traces: Sequence[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack per-seed traces into one rectangular batch for
    :func:`simulate_traces_batched`, padding ragged job counts with inert
    jobs masked out by a boolean ``valid`` plane (padded lanes start DONE
    and are excluded from ``finished``).  WFBP bucket planes
    (``bucket_bytes``/``n_buckets``, see :func:`trace_from_jobs`) are
    padded along both the job and the bucket axis; lanes missing the
    planes get monolithic ones when any lane carries them."""
    if not traces:
        raise ValueError("need at least one trace to stack")
    n_max = max(int(tr["arrival"].shape[0]) for tr in traces)
    has_buckets = any("bucket_bytes" in tr for tr in traces)
    b_max = max(
        (int(tr["bucket_bytes"].shape[-1]) for tr in traces if "bucket_bytes" in tr),
        default=1,
    )

    def pad(x, fill):
        pad_n = n_max - x.shape[0]
        if x.ndim == 2:  # (jobs, buckets): zero-fill both axes
            return jnp.pad(
                x, ((0, pad_n), (0, b_max - x.shape[1])), constant_values=fill
            )
        return jnp.concatenate([x, jnp.full((pad_n,), fill, x.dtype)])

    out: Dict[str, List[jnp.ndarray]] = {}
    for tr in traces:
        n = int(tr["arrival"].shape[0])
        lane = dict(tr)
        lane.setdefault("valid", jnp.ones((n,), bool))
        if has_buckets and "bucket_bytes" not in lane:
            lane["bucket_bytes"] = lane["msg_bytes"][:, None]
            lane["n_buckets"] = jnp.ones((n,), jnp.int32)
        fills = {"arrival": 0.0, "iters": 1.0, "t_iter": 1.0,
                 "msg_bytes": 0.0, "n_gpus": 1, "valid": False,
                 "bucket_bytes": 0.0, "n_buckets": 1}
        for k, v in lane.items():
            out.setdefault(k, []).append(pad(v, fills[k]))
    return {k: jnp.stack(vs) for k, vs in out.items()}


def simulate_jobs(jobs, cfg: JaxSimConfig, fusion: object = "all") -> Dict[str, np.ndarray]:
    """One fluid simulation of a fixed job list; numpy outputs."""
    out = simulate_trace(trace_from_jobs(jobs, fusion=fusion), cfg)
    return {
        "jct": np.asarray(out["jct"]),
        "finished": np.asarray(out["finished"]),
        "makespan": float(out["makespan"]),
    }


def monte_carlo_jct(
    n_seeds: int = 16,
    n_jobs: int = 64,
    policy: str = "ada",
    base_seed: int = 0,
    **cfg_kw,
) -> Dict[str, np.ndarray]:
    """vmap over seeds; returns mean/std of avg-JCT across sampled traces.

    One jitted launch through :func:`simulate_traces_batched` (sampling is
    vmapped too) — no per-seed recompiles or redundant jit nesting."""
    cfg = JaxSimConfig(policy=policy, **cfg_kw)
    keys = jax.random.split(jax.random.PRNGKey(base_seed), n_seeds)
    traces = jax.vmap(lambda k: sample_trace(k, n_jobs))(keys)
    out = simulate_traces_batched(traces, cfg)
    jct = np.asarray(out["jct"])
    fin = np.asarray(out["finished"])
    avg = np.array([jct[i][fin[i]].mean() for i in range(n_seeds)])
    return {
        "avg_jct_mean": float(avg.mean()),
        "avg_jct_std": float(avg.std()),
        "per_seed": avg,
        "finished_frac": float(fin.mean()),
    }
