"""Vectorized JAX cluster simulator — Monte-Carlo over traces in one jit.

Beyond-paper extension #3 (DESIGN.md §7): a fixed-timestep, fully-batched
("fluid") approximation of the Ada-SRSF dynamics in pure ``jax.lax``
control flow, ``vmap``-able over seeds, so JCT confidence intervals over
dozens of sampled workloads cost one XLA compilation and one device launch.

The policy/network math (Eq. 5 rate model, per-server bandwidth, gating
predicates, placement ranking) lives in ``core/netmodel.py`` and is shared
with the exact event simulator; this module only supplies the fluid state
machine around it.  Feature parity with the event backend:

* every gating policy: AdaDUAL, SRSF(n), and k-way AdaDUAL (``kway2``/
  ``kway3``/...) — k-way runs the *exact* per-bucket lookahead
  (``netmodel.kway_exact_start``);
* per-server heterogeneous NIC bandwidth (slowest-member drain rate);
* fabric contention domains (``core/topology.py``) via a static
  ``[domains, servers]`` incidence matrix;
* pluggable gang placement: ``consolidate`` / ``first_fit`` /
  ``least_loaded`` / ``random`` / ``rack_pack``.

Fast-path architecture (the raw-speed program)
----------------------------------------------

The hot loop is no longer one monolithic ``lax.while_loop`` over fixed dt
ticks.  It is a *segmented* driver:

* **Chunked scan** — lanes advance through ``cfg.chunk_steps``-step
  ``lax.scan`` segments (one jitted launch per segment); finished lanes
  freeze via a per-lane ``live`` guard.  Between segments the host checks
  for all-lanes-done early exit and (``cfg.compact``) retires finished
  lanes, shrinks the lane axis to the next power of two, and trims
  trailing all-invalid job columns (multiples of 8) and dead bucket
  columns.  Compaction is bit-exact: lanes are computationally
  independent, and padded jobs are inert in every reduction (zero member
  rows, ``inf`` priority keys, ``x + 0.0`` exact in any order).

* **Next-event skip** (``cfg.skip``) — each executed tick is the exact
  legacy tick; afterwards the step computes, per lane, how many following
  ticks are *eventless* (pure linear drains: no admission, no phase
  transition, no gating re-evaluation that could flip) and advances the
  drains in bulk.  Safety of skipping gating re-evaluations follows from
  the threshold predicate being antitone in the active set and monotone
  (non-increasing) in time while the active set is fixed — see
  :func:`netmodel.gating_fixed_point`; exact-lookahead k-way policies are
  a cost *comparison*, not a monotone threshold, so the skip is disabled
  while any transfer waits under exact k-way.  Bulk advancement computes
  remainders as ``rem - n*dt`` instead of n sequential subtractions, so a
  skip run may drift from a tick-by-tick run by ulps (≤ one tick per
  phase segment) — within the differential-harness tolerances; runs with
  the *same* config remain bit-exact across batching, padding and
  compaction.

* **One-shot gating fixed point** — bucketed WFBP traces used to run four
  sequential gating rounds per tick; ``cfg.gating="fixedpoint"`` computes
  the greedy closure in a single masked pass
  (:func:`netmodel.gating_fixed_point`), ``"rounds"`` keeps the legacy
  loop (equivalence locked in tests/test_fastpath.py).

* **Fused step core** — the per-tick contention/rate evaluation (domain
  incidence matmuls, Eq. 5 rate, slowest-member scale, gating-side
  ``k_would``/``min_old_rem``) is one call into
  ``repro.kernels.fluidstep`` with a lax reference path (default, CPU CI)
  and an optional Pallas kernel (``cfg.kernel`` / ``REPRO_FLUID_KERNEL``
  = ``"interpret"`` | ``"tpu"``).

Remaining approximations vs the event simulator (``core/simulator.py``),
all documented and tested for *qualitative* agreement:

* gang placement — a job occupies whole GPUs exclusively;
* time advances in fixed dt steps; compute/comm remainders drain linearly
  (the Eq. 5 rate model is exact within a step as long as the active comm
  set is unchanged, so dt only quantizes *transition* times);
* at most one queued job is admitted per step and (monolithic traces) one
  gated all-reduce started per step; bucketed WFBP traces start the full
  gating closure per step instead;
* WFBP tensor-fusion buckets drain as a chunked FIFO stream over a static
  ``[jobs, buckets]`` size matrix; overlap of transfers with remaining
  backward compute is NOT modeled (documented pessimism);
* the fixed all-reduce latency ``a`` is folded into the bandwidth term
  (under WFBP it is charged once per bucket).

State is a struct-of-arrays over jobs plus per-server occupancy; policies
are branchless masks parameterized by the shared layer.  Traces may carry
a boolean ``valid`` mask so ragged per-seed traces can be padded to one
rectangular batch (see :func:`stack_traces`) and swept in a single
``vmap`` (:func:`simulate_traces_batched`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netmodel
from repro.core.cluster import TABLE_III
from repro.core.contention import ContentionParams
from repro.core.topology import Topology, nic_topology
from repro.core.trace import PAPER_GPU_DISTRIBUTION
from repro.kernels import fluidstep

# job phases
QUEUED, COMPUTE, COMM, DONE = 0, 1, 2, 3

#: Safety margin (in ticks) for float tick-count conversions:
#: ``floor(x/dt - margin) + 1`` never *overestimates* ``ceil(x/dt)``
#: (proof: ``floor(y - m) + 1 <= ceil(y)`` for all ``y > 0, 0 < m < 1``),
#: and the margin absorbs f32 division error for counts up to ~1e5 ticks.
#: Underestimating only delays an event detection by <= 1 executed tick
#: (the safe direction — the event fires on the ``rem <= 0`` test).
_TICK_MARGIN = 1e-2

#: "No event" sentinel for per-job tick caps (far above any max_steps).
_BIG_TICKS = 1 << 30


@dataclasses.dataclass(frozen=True)
class JaxSimConfig:
    n_servers: int = 16
    gpus_per_server: int = 4
    dt: float = 0.05          # [s]
    max_steps: int = 400_000  # dt * max_steps = simulated horizon cap
    policy: str = "ada"       # ada | srsfN | kwayK (netmodel.parse_policy)
    #: consolidate | first_fit | least_loaded | random | rack_pack
    placement: str = "consolidate"
    a: float = ContentionParams().a
    b: float = ContentionParams().b
    eta: float = ContentionParams().eta
    dual_threshold: float = ContentionParams().dual_threshold
    #: per-server relative NIC bandwidth multipliers (1.0 = nominal);
    #: servers beyond the tuple are nominal, () = homogeneous network.
    server_bandwidth: Tuple[float, ...] = ()
    #: fabric contention domains (core/topology.py); None = the paper's
    #: NIC-only model (bit-identical to pre-topology behaviour).  Topology
    #: is frozen/hashable, so it rides along as part of this jit-static
    #: config and lowers to *static* incidence/oversub matrices.
    topology: Optional[Topology] = None
    #: PRNG seed for the ``random`` gang placement mode (fold_in per step).
    placement_seed: int = 0
    # ---- fast-path knobs (all jit-static; see module docstring) --------
    #: ticks per jitted scan segment between host early-exit/compaction
    #: checks.
    chunk_steps: int = 256
    #: WFBP per-tick re-gating: "fixedpoint" (one-shot greedy closure) or
    #: "rounds" (legacy 4-round loop); monolithic traces always use the
    #: single legacy round.
    gating: str = "fixedpoint"
    #: bulk-advance eventless ticks (next-event skip).
    skip: bool = True
    #: retire finished lanes / trim padding between chunks.
    compact: bool = True
    #: fluid step core impl ("" = REPRO_FLUID_KERNEL env, default "ref").
    kernel: str = ""

    def __post_init__(self) -> None:
        if self.gating not in ("fixedpoint", "rounds"):
            raise ValueError(
                f"unknown gating mode {self.gating!r}: expected "
                "'fixedpoint' or 'rounds'"
            )
        if self.chunk_steps < 1:
            raise ValueError(f"chunk_steps must be >= 1, got {self.chunk_steps}")


def sample_trace(key, n_jobs: int, horizon: float = 1200.0,
                 min_iters: int = 1000, max_iters: int = 6000) -> Dict[str, jnp.ndarray]:
    """Paper-distribution workload as arrays (vmap-able over keys)."""
    models = list(TABLE_III.values())
    t_iter = jnp.asarray([m.t_iter_compute for m in models])
    sizes = jnp.asarray([m.size_bytes for m in models])

    gpu_choices, probs = [], []
    total = sum(c for _, c in PAPER_GPU_DISTRIBUTION)
    for g, c in PAPER_GPU_DISTRIBUTION:
        gpu_choices.append(g)
        probs.append(c / total)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    arrival = jnp.floor(jax.random.uniform(k1, (n_jobs,), minval=1.0, maxval=horizon))
    iters = jax.random.randint(k2, (n_jobs,), min_iters, max_iters + 1)
    midx = jax.random.randint(k3, (n_jobs,), 0, len(models))
    gidx = jax.random.choice(
        k4, jnp.asarray(gpu_choices), (n_jobs,), p=jnp.asarray(probs)
    )
    return {
        "arrival": arrival,
        "iters": iters.astype(jnp.float32),
        "t_iter": t_iter[midx],
        "msg_bytes": sizes[midx],
        "n_gpus": gidx.astype(jnp.int32),
    }


def _place(free: jnp.ndarray, n_gpus: jnp.ndarray,
           rank_key: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gang placement: fill servers in ascending ``rank_key`` order (the
    shared :func:`netmodel.placement_rank` key; stable order, server-index
    ties).  Returns (per-server takes, feasible flag).

    Sort-free formulation: ``cum[s]`` (GPUs available on servers at or
    before s in rank order) is a masked sum over the lexicographic
    comparison matrix instead of a cumsum over ``argsort`` output — pure
    elementwise + one (S,S) reduction, so XLA fuses it into the
    surrounding step instead of emitting sort/scatter thunks (the hot-loop
    profile was dominated by exactly those).  Bit-identical to the sorted
    version: free counts are small integers, exact in f32 under any
    summation order."""
    # before[s, u]: server u precedes-or-equals s in (rank_key, index) order
    key_u = rank_key[None, :]
    key_s = rank_key[:, None]
    idx = jnp.arange(free.shape[0])
    before = (key_u < key_s) | ((key_u == key_s) & (idx[None, :] <= idx[:, None]))
    cum = (before * free[None, :]).sum(axis=1)
    want = n_gpus.astype(free.dtype)
    take = jnp.clip(want - (cum - free), 0, free)
    feasible = free.sum() >= want
    return jnp.where(feasible, take, 0), feasible


#: Sentinel for the policy field of the jit-static config key: the gating
#: policy rides along as *runtime* scalars (max_ways, threshold_gated), so
#: every policy shares one compiled graph per trace shape (see
#: :func:`_policy_args`); the inner simulator must never read cfg.policy.
_DYNAMIC_POLICY = "<dynamic>"

#: Sentinel for exact-lookahead (``kwayK``) policies: the per-candidate
#: overlap mask and pairwise-min matmuls of ``netmodel.kway_exact_start``
#: are a materially different graph, so exact k-way compiles separately
#: while ada/srsf keep sharing the cheap threshold graph above.
_EXACT_KWAY_POLICY = "<exact-kway>"


def _policy_args(cfg: JaxSimConfig):
    """(max_ways, threshold_gated) as arrays + the policy-stripped static
    config key; threshold policies (ada/srsfN) all share one compiled
    graph, exact-lookahead ``kwayK`` policies share another."""
    spec = netmodel.parse_policy(cfg.policy)
    sentinel = _EXACT_KWAY_POLICY if spec.exact_lookahead else _DYNAMIC_POLICY
    return (
        jnp.asarray(spec.max_ways, jnp.float32),
        jnp.asarray(spec.threshold_gated, bool),
        dataclasses.replace(cfg, policy=sentinel),
    )


def _ticks_to_zero(x, inv_dt):
    """Safe underestimate of ``ceil(x / dt)`` (see :data:`_TICK_MARGIN`)."""
    return jnp.floor(x * inv_dt - _TICK_MARGIN).astype(jnp.int32) + 1


def _init_lane_state(trace: Dict[str, jnp.ndarray], cfg: JaxSimConfig):
    """Initial per-lane state (legacy layout + the tick counter ``i``)."""
    n_jobs = trace["arrival"].shape[0]
    ns = cfg.n_servers
    valid = trace.get("valid")
    if valid is None:
        valid = jnp.ones((n_jobs,), bool)
    bucket_bytes = trace.get("bucket_bytes")
    wfbp = bucket_bytes is not None and int(bucket_bytes.shape[-1]) > 1
    topo = cfg.topology if cfg.topology is not None else nic_topology(ns)
    n_domains = np.asarray(topo.incidence()).shape[0]
    state = {
        "phase": jnp.where(valid, QUEUED, DONE).astype(jnp.int32),
        # domain-load mask, maintained incrementally (membership only
        # changes at admission / completion) so the hot loop never
        # re-derives it via incidence matmuls
        "loads": jnp.zeros((n_jobs, n_domains), bool),
        "iters_left": trace["iters"],
        "rem": jnp.zeros((n_jobs,), jnp.float32),
        "servers": jnp.zeros((n_jobs, ns), jnp.int32),
        "finish": jnp.full((n_jobs,), jnp.inf, jnp.float32),
        "free": jnp.full((ns,), float(cfg.gpus_per_server), jnp.float32),
        "t": jnp.asarray(0.0, jnp.float32),
        "n_done": jnp.asarray(0, jnp.int32),
        "i": jnp.asarray(0, jnp.int32),
        "started": jnp.zeros((n_jobs,), bool),
    }
    if wfbp:
        state["bucket"] = jnp.zeros((n_jobs,), jnp.int32)
    return state


def _make_lane_step(trace: Dict[str, jnp.ndarray], cfg: JaxSimConfig,
                    max_ways, gated):
    """Build the per-lane step function: one *legacy-exact* tick followed
    (``cfg.skip``) by the bulk advancement of eventless ticks."""
    n_jobs = trace["arrival"].shape[0]
    ns = cfg.n_servers
    assert cfg.policy in (_DYNAMIC_POLICY, _EXACT_KWAY_POLICY), (
        "callers go through _policy_args"
    )
    exact_kway = cfg.policy == _EXACT_KWAY_POLICY
    placement = netmodel.canonical_placement(cfg.placement)
    bw = jnp.asarray(
        netmodel.server_bandwidth_array(cfg.server_bandwidth, ns), jnp.float32
    )
    # Fabric topology as STATIC matrices (cfg is jit-static, so these are
    # compile-time constants): domain incidence (n_domains, n_servers),
    # per-domain oversubscription, and each server's rack for rack_pack.
    topo = cfg.topology if cfg.topology is not None else nic_topology(ns)
    if topo.n_servers != ns:
        raise ValueError(
            f"topology covers {topo.n_servers} servers, config has {ns}"
        )
    incidence = jnp.asarray(topo.incidence(), jnp.float32)
    inc_t = incidence.T  # (S, D) for the incremental loads-row update
    oversub = jnp.asarray(topo.oversub_array(), jnp.float32)
    server_rack = jnp.asarray(topo.server_rack(), jnp.int32)
    n_racks = len(topo.rack_groups())
    place_key = jax.random.PRNGKey(cfg.placement_seed)
    server_index = jnp.arange(ns, dtype=jnp.float32)
    inv_dt = np.float32(1.0 / cfg.dt)

    # WFBP tensor-fusion buckets (layer-granular comm subsystem): a static
    # ``(jobs, B)`` size matrix plus a per-job bucket count.  ``wfbp`` is a
    # COMPILE-TIME flag: without multi-bucket planes (fusion="all" / legacy
    # traces, and (jobs, 1) planes) the emitted graph is exactly the
    # pre-bucket backend's (regression-locked in tests/test_wfbp.py).
    bucket_bytes = trace.get("bucket_bytes")
    b_max = 1 if bucket_bytes is None else int(bucket_bytes.shape[-1])
    wfbp = b_max > 1
    if wfbp:
        n_buckets = trace["n_buckets"].astype(jnp.int32)
        # per-bucket contention-free seconds; the latency `a` is paid per
        # bucket (the real cost of finer granularity), folded into the drain
        bucket_t = cfg.a + cfg.b * bucket_bytes  # (jobs, B)
        bucket_live = jnp.arange(b_max) < n_buckets[:, None]
        comm_total = jnp.where(bucket_live, bucket_t, 0.0).sum(axis=-1)
    else:
        comm_total = cfg.a + cfg.b * trace["msg_bytes"]  # contention-free s
    # Ticks per full-iteration compute segment (loop-invariant, hoisted
    # out of the scan by XLA) — the bulk fast-forward quantum for
    # non-spanning jobs, whose iteration boundaries are externally
    # invisible (their rings cross no cut => zero domain loads).
    k_iter = jnp.maximum(_ticks_to_zero(trace["t_iter"], inv_dt), 1)

    def step(st):
        step_i = st["i"]
        # Derive t from the integer tick counter instead of accumulating
        # `t += dt`: one f32 multiply has no cumulative rounding, so the
        # clock is bit-identical whether ticks execute one-by-one or jump
        # in bulk (next-event skip) — accumulated drift vs exact arrival
        # times (which sit on dt multiples) would otherwise shift
        # admissions by a tick and butterfly through placement.
        t = (step_i + 1).astype(jnp.float32) * cfg.dt
        phase, rem = st["phase"], st["rem"]

        spans0 = (st["servers"] > 0).sum(axis=1) > 1
        # Running-job SRSF key mirrors the event backend's remaining_service:
        # remaining iters x (compute + contention-free comm) x GPUs.
        rem_service = (
            st["iters_left"]
            * (trace["t_iter"] + jnp.where(spans0, comm_total, 0.0))
            * trace["n_gpus"]
        )
        # Per-server remaining workload (Alg. 3 line 3's L_S in gang form).
        load = (rem_service[:, None] * st["servers"]).sum(0)

        # ---- admission: smallest-SRSF arrived job that FITS (no head-of-
        # line blocking: infeasible jobs don't stall smaller ones) ---------
        fits = trace["n_gpus"].astype(jnp.float32) <= st["free"].sum()
        # Strict '<': a job arriving exactly on a tick boundary is seen at
        # the *next* tick.  The accumulated-f32 clock of the original loop
        # summed to slightly below k*dt, so its `<=` behaved exactly like
        # this on lattice arrivals; with the drift-free derived clock the
        # strictness must be explicit to keep admission timing (and the
        # placement decisions racing against same-tick completions) stable.
        arrived = (phase == QUEUED) & (trace["arrival"] < t) & fits
        # E_J = 0 before placement (paper Section IV-A): queued-job priority
        # is compute-only, matching the event backend's _srsf_key_queued.
        queued_key = jnp.where(
            phase == QUEUED,
            st["iters_left"] * trace["t_iter"] * trace["n_gpus"],
            jnp.inf,
        )
        pick = jnp.argmin(jnp.where(arrived, queued_key, jnp.inf))
        can_pick = arrived[pick]
        if placement == "random":
            # fresh uniform server order per step: the gang analogue of the
            # event backend's per-GPU RAND placement
            rank_extra = jax.random.uniform(
                jax.random.fold_in(place_key, step_i), (ns,)
            )
        elif placement == "rack_pack":
            rank_extra = netmodel.rack_pack_rank(
                st["free"], server_rack, n_racks, cfg.gpus_per_server
            )
        else:
            rank_extra = None
        rank_key = netmodel.placement_rank(
            placement, st["free"], load, server_index, rank_extra
        )
        take, feasible = _place(st["free"], trace["n_gpus"][pick], rank_key)
        admit = can_pick & feasible
        # one-hot select instead of .at[pick].set scatters: selects fuse
        # into the elementwise step graph, scatters are standalone thunks
        # that dominated the per-tick profile on CPU
        hot = (jnp.arange(n_jobs) == pick) & admit
        free = st["free"] - jnp.where(admit, take, 0)
        servers = jnp.where(
            hot[:, None], take.astype(jnp.int32)[None, :], st["servers"]
        )
        phase = jnp.where(hot, COMPUTE, phase)
        rem = jnp.where(hot, trace["t_iter"], rem)
        # incremental domain-load update: only the admitted job's row
        # changes (one S-vector against the static incidence — the full
        # (J,S)x(S,D) matmuls per tick dominated the CPU profile).
        # Bit-exact vs recomputing from scratch: pure boolean algebra on
        # exact {0,1} sums.
        row_member = (take > 0).astype(jnp.float32)
        row_in = row_member @ inc_t
        row_out = row_member @ (1.0 - inc_t)
        row_loads = (row_in > 0) & (row_out > 0)
        loads = jnp.where(hot[:, None], row_loads[None, :], st["loads"])

        spans = (servers > 0).sum(axis=1) > 1

        # ---- communication contention state --------------------------------
        started = st["started"]
        in_comm = phase == COMM
        # Only *started* transfers occupy links: a job that reached its
        # barrier but is still gated must not count toward contention (it
        # would otherwise see itself and deadlock under ada/srsf1).
        active = in_comm & started & (rem > 0)
        member = (servers > 0).astype(jnp.float32)  # (jobs, ns)
        # ONE fused evaluation of the contention/rate core: in-flight
        # counts over the carried domain-load mask, oversub-weighted
        # effective k, Eq. 5 drain ratio, and the gating-side k_would /
        # min_old_rem (+ the overlap matrix where gating needs it).
        # Dispatches to the lax reference or the Pallas kernel
        # (repro.kernels.fluidstep).  Evaluated pre-compute-drain:
        # min_old_rem/k_would only read COMM rows, whose ``rem`` the
        # compute drain below cannot touch — bit-exact with the legacy
        # post-drain evaluation.
        core = fluidstep.fluid_step_core(
            loads, member, active, rem, bw, oversub,
            b=cfg.b, eta=cfg.eta,
            need_overlap=(wfbp or exact_kway), impl=cfg.kernel,
        )
        counts = core["counts"]
        k_eff, overlap = core["k_eff"], core["overlap"]

        # ---- drain compute ---------------------------------------------------
        is_comp = phase == COMPUTE
        rem = jnp.where(is_comp, rem - cfg.dt, rem)
        comp_done = is_comp & (rem <= 0)
        # -> job with comm enters COMM (waiting: rem = full message time);
        #    single-server job completes the iteration directly.
        to_comm = comp_done & spans
        iter_done_direct = comp_done & ~spans

        # ---- comm gating (on jobs in COMM with rem == full, i.e. waiting) ---
        # Candidate cost is proportional to M_new — the gates are unit-free.
        # For a waiting WFBP job ``rem`` is the current *bucket's* size
        # (equal to comm_total while a monolithic job waits), so gating
        # decides per bucket like the event backend.
        new_cost = rem if wfbp else comm_total
        waiting = in_comm & ~started

        def may_start_vs(k_would, min_old_rem, olds_mask):
            if exact_kway:
                # Exact per-bucket k-way lookahead (closed-form option-A/
                # option-B comparison); costs are comm *seconds* (the folded
                # latency ``a`` rides along per bucket) — scale-invariant,
                # so the unit mismatch vs the event backend's raw bytes only
                # perturbs borderline calls by the a-fold.
                return netmodel.may_start_dynamic(
                    k_would, new_cost, min_old_rem, max_ways, gated,
                    cfg.dual_threshold, exact_kway_olds=olds_mask, rem=rem,
                    eta_over_b=cfg.eta / cfg.b,
                )
            return netmodel.may_start_dynamic(
                k_would, new_cost, min_old_rem, max_ways, gated,
                cfg.dual_threshold,
            )

        # round 1 against the base active set, reusing the core outputs
        # (the exact legacy contention state)
        olds0 = overlap & active[None, :] if overlap is not None else None
        start_ok = waiting & may_start_vs(
            core["k_would"], core["min_old_rem"], olds0
        )
        if wfbp and cfg.gating == "fixedpoint":
            # One-shot greedy closure (see netmodel.gating_fixed_point for
            # the antitone-predicate argument); replaces the 4-round loop.
            accept = netmodel.gating_fixed_point(
                start_ok, rem_service, loads, counts, overlap, active, rem,
                new_cost, max_ways, gated, cfg.dual_threshold,
                exact_kway=exact_kway, eta_over_b=cfg.eta / cfg.b,
            )
            started = started | accept
            leftover = start_ok & ~accept
        else:
            # Legacy single-start round: smallest remaining service first —
            # mirrors the event sim's sorted re-evaluate-after-each-start
            # loop (admissions/starts are rare relative to dt for
            # monolithic traces, so one start per tick rarely binds).
            pick_c = jnp.argmin(jnp.where(start_ok, rem_service, jnp.inf))
            start_now = (jnp.arange(n_jobs) == pick_c) & start_ok
            started = started | start_now
            leftover = start_ok & ~start_now
            if wfbp:
                # legacy 4-round loop (cfg.gating == "rounds"): each extra
                # round refreshes the contention state including the jobs
                # started in earlier rounds and starts one more candidate.
                for _ in range(3):
                    active_now = in_comm & started & (rem > 0)
                    counts_now = netmodel.domain_counts(loads, active_now)
                    k_would = netmodel.domain_k(loads, counts_now, extra=1)
                    min_old_rem = jnp.where(
                        overlap & active_now[None, :], rem[None, :], jnp.inf
                    ).min(axis=1)
                    ok = (in_comm & ~started) & may_start_vs(
                        k_would, min_old_rem, overlap & active_now[None, :]
                    )
                    pick_c = jnp.argmin(jnp.where(ok, rem_service, jnp.inf))
                    started = started | ((jnp.arange(n_jobs) == pick_c) & ok)
                # conservative skip guard for the legacy path: any waiter
                # blocks bulk advancement (the closure membership is not
                # re-derived here)
                leftover = in_comm & ~started

        # ---- drain comm (started only), at the Eq. 5 rate evaluated at the
        # effective (oversub-weighted) contention and scaled by the slowest
        # member server's NIC (per-server heterogeneity) ----------------------
        ratio = core["ratio"]
        draining = in_comm & started
        rem = jnp.where(draining, rem - cfg.dt * ratio, rem)
        comm_done = draining & (rem <= 0)

        # ---- iteration bookkeeping ------------------------------------------
        # WFBP bucket stream: a finished bucket with buckets left hands the
        # next one to gating afresh (started resets — the FIFO comm stream
        # competes for the fabric per bucket, like the event backend);
        # only the LAST bucket's completion ends the iteration.
        if wfbp:
            next_b = st["bucket"] + 1
            more_buckets = comm_done & (next_b < n_buckets)
            iter_done = iter_done_direct | (comm_done & ~more_buckets)
        else:
            more_buckets = jnp.zeros_like(comm_done)
            iter_done = iter_done_direct | comm_done
        iters_left = st["iters_left"] - iter_done.astype(jnp.float32)
        job_done = iter_done & (iters_left <= 0)
        next_compute = iter_done & ~job_done

        phase = jnp.where(to_comm, COMM, phase)
        rem = jnp.where(to_comm, bucket_t[:, 0] if wfbp else comm_total, rem)
        if wfbp:
            bucket = jnp.where(to_comm, 0, st["bucket"])
            next_t = jnp.take_along_axis(
                bucket_t, jnp.clip(next_b, 0, b_max - 1)[:, None], axis=-1
            )[:, 0]
            rem = jnp.where(more_buckets, next_t, rem)
            bucket = jnp.where(more_buckets, next_b, bucket)
            started = started & ~(to_comm | iter_done | more_buckets)
        else:
            started = started & ~(to_comm | iter_done)
        phase = jnp.where(next_compute, COMPUTE, phase)
        rem = jnp.where(next_compute, trace["t_iter"], rem)
        phase = jnp.where(job_done, DONE, phase)
        finish = jnp.where(job_done, t, st["finish"])
        free = free + (servers * job_done[:, None].astype(jnp.int32)).sum(0)
        servers = jnp.where(job_done[:, None], 0, servers)
        loads = loads & ~job_done[:, None]

        new_state = {
            "phase": phase,
            "loads": loads,
            "iters_left": iters_left,
            "rem": rem,
            "servers": servers,
            "finish": finish,
            "free": free,
            "t": t,
            "n_done": (phase == DONE).sum().astype(jnp.int32),
            "i": step_i + 1,
            "started": started,
        }
        if wfbp:
            new_state["bucket"] = bucket
        if not cfg.skip:
            return new_state

        # ---- next-event skip: bulk-advance eventless ticks ------------------
        # An executed tick is exactly the legacy tick above; ``extra`` is a
        # per-lane lower bound on the number of *following* ticks at which
        # provably nothing discrete happens — no admission, no compute/comm
        # completion, no gating decision that could flip (the threshold
        # predicate is antitone in the active set and non-increasing in
        # time while the set is fixed: min_old_rem only drains).  Those
        # ticks reduce to linear drains, applied in closed form.
        rem2, phase2, iters2 = new_state["rem"], new_state["phase"], iters_left
        in_comm2 = phase2 == COMM
        is_comp2 = phase2 == COMPUTE
        started2 = new_state["started"]
        active2 = in_comm2 & started2 & (rem2 > 0)
        waiting2 = jnp.any(in_comm2 & ~started2)
        # Post-tick drain ratio: the active set may have changed this tick
        # (starts / completions), the member rows of draining jobs cannot
        # have (only job_done zeroes servers) — so loads and the slowest-
        # member scale are reusable and only counts/k_eff need refreshing.
        counts2 = netmodel.domain_counts(loads, active2)
        k_eff2 = netmodel.domain_k(loads, counts2.astype(jnp.float32) * oversub)
        ratio2 = (ratio / netmodel.rate_ratio(k_eff, cfg.b, cfg.eta)
                  ) * netmodel.rate_ratio(k_eff2, cfg.b, cfg.eta)
        # Gating must re-run next tick when: a passing candidate was not
        # started (one-start cap / closure pessimism), a completion freed
        # capacity while transfers wait (antitone: shrinking the active
        # set can flip a predicate True), a barrier or fresh bucket just
        # arrived, or the policy is an exact k-way cost comparison (not
        # monotone in time — never skip while anything waits).
        gate_block = (
            jnp.any(leftover)
            | (jnp.any(comm_done) & waiting2)
            | jnp.any(to_comm)
            | jnp.any(more_buckets)
            | (jnp.asarray(exact_kway) & waiting2)
        )
        # Per-job caps: ticks strictly before the next arrival of a job
        # that fits (free GPUs are constant during a skip), the next
        # compute completion (non-spanning jobs fast-forward whole
        # invisible iterations), and the next comm completion.
        t2 = t
        queued2 = phase2 == QUEUED
        fits2 = trace["n_gpus"].astype(jnp.float32) <= new_state["free"].sum()
        cap_arr = jnp.where(
            queued2 & fits2,
            _ticks_to_zero(trace["arrival"] - t2, inv_dt) - 1,
            _BIG_TICKS,
        )
        k_cur = _ticks_to_zero(rem2, inv_dt)
        iters_i = iters2.astype(jnp.int32)
        spans2 = (new_state["servers"] > 0).sum(axis=1) > 1
        ns_comp = is_comp2 & ~spans2
        cap_comp = jnp.where(
            is_comp2 & spans2,
            k_cur - 1,
            jnp.where(
                ns_comp, k_cur - 1 + k_iter * (iters_i - 1), _BIG_TICKS
            ),
        )
        cap_comm = jnp.where(
            active2 & (ratio2 > 0),
            _ticks_to_zero(rem2 / jnp.where(ratio2 > 0, ratio2, 1.0), inv_dt) - 1,
            _BIG_TICKS,
        )
        caps = jnp.minimum(jnp.minimum(cap_arr, cap_comp), cap_comm).min()
        extra = jnp.clip(
            jnp.minimum(caps, cfg.max_steps - new_state["i"]), 0, _BIG_TICKS
        )
        extra = jnp.where(gate_block, 0, extra)
        nf = extra.astype(jnp.float32)
        # Bulk advance: linear drains, plus whole-iteration jumps for
        # non-spanning compute jobs crossing >= 1 invisible boundary.
        cross = ns_comp & (extra >= k_cur) & (extra > 0)
        m = jnp.maximum(extra - k_cur, 0)
        aq = m // k_iter
        rq = m - aq * k_iter
        rem3 = jnp.where(
            cross,
            trace["t_iter"] - rq.astype(jnp.float32) * cfg.dt,
            jnp.where(
                is_comp2,
                rem2 - nf * cfg.dt,
                jnp.where(active2, rem2 - nf * cfg.dt * ratio2, rem2),
            ),
        )
        new_state["rem"] = rem3
        new_state["iters_left"] = jnp.where(
            cross, iters2 - (1 + aq).astype(jnp.float32), iters2
        )
        new_state["i"] = new_state["i"] + extra
        new_state["t"] = new_state["i"].astype(jnp.float32) * cfg.dt
        return new_state

    return step


def _lane_chunk(trace, st, cfg: JaxSimConfig, max_ways, gated):
    """One ``cfg.chunk_steps``-tick scan segment of a single lane; frozen
    (via the per-leaf ``live`` select) once the lane finishes or hits the
    step cap, so a vmapped batch can run past early finishers."""
    n_jobs = trace["arrival"].shape[0]
    step = _make_lane_step(trace, cfg, max_ways, gated)

    def body(st, _):
        live = (st["n_done"] < n_jobs) & (st["i"] < cfg.max_steps)
        st2 = step(st)
        st2 = {k: jnp.where(live, v, st[k]) for k, v in st2.items()}
        return st2, None

    st, _ = jax.lax.scan(body, st, None, length=cfg.chunk_steps)
    return st


@functools.partial(jax.jit, static_argnames=("cfg",))
def _init_jit(traces, cfg: JaxSimConfig):
    return jax.vmap(lambda tr: _init_lane_state(tr, cfg))(traces)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_jit(traces, state, cfg: JaxSimConfig, max_ways, gated):
    return jax.vmap(
        lambda tr, st: _lane_chunk(tr, st, cfg, max_ways, gated)
    )(traces, state)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _drive_batched(traces: Dict[str, jnp.ndarray], cfg: JaxSimConfig,
                   max_ways, gated) -> Dict[str, np.ndarray]:
    """Host driver: chunked scan segments with early exit and (optional)
    lane/job/bucket compaction.  ``cfg`` is the policy-stripped static
    key from :func:`_policy_args`.  Returns numpy result planes shaped
    like the input batch."""
    arrival0 = np.asarray(traces["arrival"], np.float32)
    n_lanes0, n_jobs0 = arrival0.shape
    if "valid" not in traces:
        traces = dict(traces)
        traces["valid"] = jnp.ones((n_lanes0, n_jobs0), bool)
    wfbp = "bucket_bytes" in traces and int(traces["bucket_bytes"].shape[-1]) > 1
    results = {
        "jct": np.full((n_lanes0, n_jobs0), np.inf, np.float32),
        "finished": np.zeros((n_lanes0, n_jobs0), bool),
        "makespan": np.zeros((n_lanes0,), np.float32),
    }
    orig = np.arange(n_lanes0)  # current lane -> original row (-1 = retired)
    state = _init_jit(traces, cfg)

    while True:
        state = _chunk_jit(traces, state, cfg, max_ways, gated)
        n_jobs_cur = int(traces["arrival"].shape[1])
        n_done = np.asarray(state["n_done"])
        tick = np.asarray(state["i"])
        done = (n_done >= n_jobs_cur) | (tick >= cfg.max_steps)
        newly = [l for l in np.nonzero(done)[0] if orig[l] >= 0]
        if newly:
            phase = np.asarray(state["phase"])
            finish = np.asarray(state["finish"])
            t_now = np.asarray(state["t"])
            valid = np.asarray(traces["valid"])
            arr = np.asarray(traces["arrival"], np.float32)
            for l in newly:
                row = orig[l]
                fin = (phase[l] == DONE) & valid[l]
                results["jct"][row, :n_jobs_cur] = finish[l] - arr[l]
                results["finished"][row, :n_jobs_cur] = fin
                results["makespan"][row] = (
                    finish[l][fin].max() if fin.any() else t_now[l]
                )
                orig[l] = -1
        if done.all():
            break
        if not (cfg.compact and done.any()):
            continue

        # ---- compaction: retire finished lanes, shrink the batch --------
        # Shapes are bucketed (pow2 lanes, jobs in multiples of 8, >= 2
        # buckets) to bound recompiles; dropped lanes are finished (their
        # results are already finalized) and dropped job columns are
        # all-invalid across the surviving lanes, so results are
        # unchanged bit-for-bit (padded jobs are inert in every
        # reduction of the step).
        live = np.nonzero(~done)[0]
        n_live = len(live)
        lanes_new = _next_pow2(n_live)
        valid = np.asarray(traces["valid"])
        pad_lane = int(np.nonzero(done)[0][0])
        sel = np.concatenate(
            [live, np.full(lanes_new - n_live, pad_lane, live.dtype)]
        )
        col_used = valid[live].any(axis=0)
        jobs_need = (
            int(np.nonzero(col_used)[0][-1]) + 1 if col_used.any() else 1
        )
        jobs_new = min(n_jobs_cur, max(8, -(-jobs_need // 8) * 8))
        if lanes_new >= len(done) and jobs_new > 3 * n_jobs_cur // 4:
            continue
        sel_dev = jnp.asarray(sel)
        traces = {
            k: jnp.take(v, sel_dev, axis=0)[:, :jobs_new]
            for k, v in traces.items()
        }
        state = {
            k: (
                jnp.take(v, sel_dev, axis=0)[:, :jobs_new]
                if v.ndim >= 2 and v.shape[1] == n_jobs_cur
                else jnp.take(v, sel_dev, axis=0)
            )
            for k, v in state.items()
        }
        state["n_done"] = (state["phase"] == DONE).sum(axis=1).astype(jnp.int32)
        if wfbp:
            b_cur = int(traces["bucket_bytes"].shape[-1])
            # keep >= 2 bucket columns: collapsing to one would flip the
            # static wfbp flag (a different gating cadence, not just a
            # smaller graph)
            b_need = max(2, int(np.asarray(traces["n_buckets"]).max()))
            if b_need < b_cur:
                traces["bucket_bytes"] = traces["bucket_bytes"][:, :, :b_need]
        orig = np.concatenate(
            [orig[live], np.full(lanes_new - n_live, -1, orig.dtype)]
        )
    return results


def simulate_one(key, n_jobs: int, cfg: JaxSimConfig):
    trace = _sample_trace_jit(key, n_jobs)
    return simulate_trace(trace, cfg)


@functools.partial(jax.jit, static_argnames=("n_jobs",))
def _sample_trace_jit(key, n_jobs: int):
    return sample_trace(key, n_jobs)


def simulate_trace(trace: Dict[str, jnp.ndarray], cfg: JaxSimConfig):
    """Fluid-simulate a *fixed* workload (scenario-engine entry point).

    The gating policy enters the jitted graph as runtime scalars
    (:func:`_policy_args`), so sweeping policies over one trace shape
    reuses a single XLA compilation."""
    max_ways, gated, cfg_key = _policy_args(cfg)
    batch = {k: jnp.asarray(v)[None] for k, v in trace.items()}
    out = _drive_batched(batch, cfg_key, max_ways, gated)
    return {
        "jct": jnp.asarray(out["jct"][0]),
        "finished": jnp.asarray(out["finished"][0]),
        "makespan": jnp.asarray(out["makespan"][0]),
    }


def simulate_traces_batched(traces: Dict[str, jnp.ndarray], cfg: JaxSimConfig):
    """Chunked-scan launches over a stacked batch of traces (leading axis
    = seed; see :func:`stack_traces`).  Returns per-lane jct/finished
    arrays and a per-lane makespan vector — the scenario Monte-Carlo
    entry point.  Policy-dynamic like :func:`simulate_trace`; finished
    lanes retire between chunks (``cfg.compact``) so stragglers don't pay
    full batch width."""
    max_ways, gated, cfg_key = _policy_args(cfg)
    out = _drive_batched(
        {k: jnp.asarray(v) for k, v in traces.items()}, cfg_key, max_ways, gated
    )
    return {
        "jct": jnp.asarray(out["jct"]),
        "finished": jnp.asarray(out["finished"]),
        "makespan": jnp.asarray(out["makespan"]),
    }


def trace_from_jobs(jobs, fusion: object = "all") -> Dict[str, jnp.ndarray]:
    """Convert ``JobSpec`` lists (trace generator / scenario engine output)
    into the struct-of-arrays layout the fluid simulator consumes.

    ``fusion`` ('all' | 'none' | a byte threshold) adds the WFBP bucket
    planes: a static ``(jobs, B)`` ``bucket_bytes`` matrix (zero-padded)
    plus per-job ``n_buckets``, from ``netmodel.fusion_plan`` over each
    model's layer data.  Models without layer data (the paper's Table III
    profiles) stay one monolithic bucket; ``fusion="all"`` omits the
    planes entirely, which is bit-identical to the legacy trace."""
    tr = {
        "arrival": jnp.asarray([j.arrival for j in jobs], jnp.float32),
        "iters": jnp.asarray([j.iterations for j in jobs], jnp.float32),
        "t_iter": jnp.asarray([j.model.t_iter_compute for j in jobs], jnp.float32),
        "msg_bytes": jnp.asarray([j.model.size_bytes for j in jobs], jnp.float32),
        "n_gpus": jnp.asarray([j.n_gpus for j in jobs], jnp.int32),
    }
    thr = netmodel.fusion_threshold(fusion)
    if thr == float("inf"):
        return tr
    plans = []
    for j in jobs:
        m = j.model
        if getattr(m, "has_layers", False):
            plans.append(netmodel.fusion_plan(m.layer_grad_bytes, m.layer_t_b, thr)[0])
        else:
            plans.append((m.size_bytes,))
    b_max = max(len(p) for p in plans)
    bb = np.zeros((len(plans), b_max), np.float32)
    for i, p in enumerate(plans):
        bb[i, : len(p)] = p
    tr["bucket_bytes"] = jnp.asarray(bb)
    tr["n_buckets"] = jnp.asarray([len(p) for p in plans], jnp.int32)
    return tr


def stack_traces(traces: Sequence[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack per-seed traces into one rectangular batch for
    :func:`simulate_traces_batched`, padding ragged job counts with inert
    jobs masked out by a boolean ``valid`` plane (padded lanes start DONE
    and are excluded from ``finished``).  WFBP bucket planes
    (``bucket_bytes``/``n_buckets``, see :func:`trace_from_jobs`) are
    padded along both the job and the bucket axis; lanes missing the
    planes get monolithic ones when any lane carries them."""
    if not traces:
        raise ValueError("need at least one trace to stack")
    n_max = max(int(tr["arrival"].shape[0]) for tr in traces)
    has_buckets = any("bucket_bytes" in tr for tr in traces)
    b_max = max(
        (int(tr["bucket_bytes"].shape[-1]) for tr in traces if "bucket_bytes" in tr),
        default=1,
    )

    def pad(x, fill):
        pad_n = n_max - x.shape[0]
        if x.ndim == 2:  # (jobs, buckets): zero-fill both axes
            return jnp.pad(
                x, ((0, pad_n), (0, b_max - x.shape[1])), constant_values=fill
            )
        return jnp.concatenate([x, jnp.full((pad_n,), fill, x.dtype)])

    out: Dict[str, List[jnp.ndarray]] = {}
    for tr in traces:
        n = int(tr["arrival"].shape[0])
        lane = dict(tr)
        lane.setdefault("valid", jnp.ones((n,), bool))
        if has_buckets and "bucket_bytes" not in lane:
            lane["bucket_bytes"] = lane["msg_bytes"][:, None]
            lane["n_buckets"] = jnp.ones((n,), jnp.int32)
        fills = {"arrival": 0.0, "iters": 1.0, "t_iter": 1.0,
                 "msg_bytes": 0.0, "n_gpus": 1, "valid": False,
                 "bucket_bytes": 0.0, "n_buckets": 1}
        for k, v in lane.items():
            out.setdefault(k, []).append(pad(v, fills[k]))
    return {k: jnp.stack(vs) for k, vs in out.items()}


def simulate_jobs(jobs, cfg: JaxSimConfig, fusion: object = "all") -> Dict[str, np.ndarray]:
    """One fluid simulation of a fixed job list; numpy outputs."""
    out = simulate_trace(trace_from_jobs(jobs, fusion=fusion), cfg)
    return {
        "jct": np.asarray(out["jct"]),
        "finished": np.asarray(out["finished"]),
        "makespan": float(out["makespan"]),
    }


def monte_carlo_jct(
    n_seeds: int = 16,
    n_jobs: int = 64,
    policy: str = "ada",
    base_seed: int = 0,
    **cfg_kw,
) -> Dict[str, np.ndarray]:
    """vmap over seeds; returns mean/std of avg-JCT across sampled traces.

    Sampling is one vmapped jit; the simulation runs through the chunked
    batched driver — no per-seed recompiles or redundant jit nesting."""
    cfg = JaxSimConfig(policy=policy, **cfg_kw)
    keys = jax.random.split(jax.random.PRNGKey(base_seed), n_seeds)
    traces = jax.vmap(lambda k: sample_trace(k, n_jobs))(keys)
    out = simulate_traces_batched(traces, cfg)
    jct = np.asarray(out["jct"])
    fin = np.asarray(out["finished"])
    avg = np.array([jct[i][fin[i]].mean() for i in range(n_seeds)])
    return {
        "avg_jct_mean": float(avg.mean()),
        "avg_jct_std": float(avg.std()),
        "per_seed": avg,
        "finished_frac": float(fin.mean()),
    }
