"""Backend-agnostic policy/network layer shared by both simulators.

This module is the single home of the paper's rate/gating math so the exact
event simulator (``core/simulator.py``) and the vectorized fluid simulator
(``core/jaxsim.py``) cannot drift apart:

* the Eq. (5) contended-rate model (:func:`rate_ratio`, :func:`rate`);
* per-server NIC bandwidth heterogeneity (:func:`server_bandwidth_array`,
  :func:`slowest_member_scale` — a ring all-reduce drains at the rate of
  its slowest member server);
* the communication gating predicates — AdaDUAL (Theorem 2), SRSF(n), and
  the k-way AdaDUAL generalization — expressed once as a
  :class:`PolicySpec` plus one branchless predicate (:func:`may_start`);
* the placement-mode ranking keys the fluid backend's gang placement
  shares with the event backend's Algorithm 1 family
  (:func:`placement_rank`).

Everything is a pure function of plain scalars/arrays: the same expression
evaluates on Python floats, numpy arrays, and traced ``jax.numpy`` arrays,
so the event backend calls these with scalars while the fluid backend maps
them over whole job vectors inside ``jit``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Eq. (5) rate model
# ---------------------------------------------------------------------------


def rate_ratio(k, b: float, eta: float):
    """Fraction of the contention-free bandwidth one task retains under
    k-way contention: ``b / (k*b + (k-1)*eta)`` (Eq. 5 per-byte cost
    inverted and normalized by the k=1 cost).  ``k`` may be a scalar or an
    array; ``k=1`` gives exactly 1.0."""
    return b / (k * b + (k - 1) * eta)


def rate(k, b: float, eta: float):
    """Instantaneous drain rate [B/s] under k-way contention (Eq. 5)."""
    return 1.0 / (k * b + (k - 1) * eta)


def domain_loads(member_mask, incidence):
    """Which contention domains each task loads, as a boolean
    ``(..., n_domains)`` array: a task's ring crosses domain d's cut iff it
    has member servers both inside and outside d (``core/topology.py``'s
    one load rule, lowered to mask algebra).

    ``member_mask`` is a numeric {0,1} ``(..., n_servers)`` array;
    ``incidence`` a numeric {0,1} ``(n_domains, n_servers)`` matrix
    (:meth:`Topology.incidence`).  Works on numpy and jax arrays — two
    matmuls against static matrices, no branching.  For the NIC-only
    incidence (identity) this reduces to ``member & spans_multiple``,
    exactly the paper's per-server rule."""
    inside = member_mask @ incidence.T
    outside = member_mask @ (1.0 - incidence).T
    return (inside > 0) & (outside > 0)


def domain_counts(loads, active):
    """Per-domain count of in-flight tasks: ``loads`` is ``(jobs,
    n_domains)`` boolean, ``active`` ``(jobs,)`` boolean; returns
    ``(n_domains,)``."""
    return (loads & active[..., None]).sum(axis=-2)


def domain_k(loads, weighted_counts, extra=0):
    """Each task's contention level: the max of ``weighted_counts + extra``
    over the domains the task loads, clamped to >= 1 (a task loading no
    domain is uncontended).  Pass raw counts for the gating-side k, or
    ``counts * oversub`` for the Eq. (5) effective k (float)."""
    k = (loads * (weighted_counts + extra)[..., None, :]).max(axis=-1)
    return k.clip(1)


def server_bandwidth_array(
    server_bandwidth: Sequence[float], n_servers: int
) -> np.ndarray:
    """Per-server relative NIC bandwidth multipliers as a dense
    ``(n_servers,)`` float array: servers beyond the configured tuple are
    nominal (1.0), extra entries are dropped.  Empty input = homogeneous
    network (all ones), exactly the paper's model."""
    bw = np.ones((max(0, n_servers),), dtype=np.float64)
    for s, scale in enumerate(server_bandwidth[:n_servers]):
        bw[s] = scale
    return bw


def slowest_member_scale(bw, member_mask):
    """Drain-rate multiplier of each task: the slowest member server
    bottlenecks the ring.  ``bw`` is ``(n_servers,)``, ``member_mask`` a
    boolean ``(..., n_servers)``; tasks with no member servers get 1.0.

    Works on numpy and jax arrays (pure mask algebra — a large finite
    sentinel instead of ``inf`` keeps ``0 * sentinel`` NaN-free).
    """
    big = 1e30
    masked = member_mask * bw + (1 - member_mask) * big
    lo = masked.min(axis=-1)
    has_member = member_mask.any(axis=-1)
    return lo * has_member + 1.0 * (1 - has_member)


# ---------------------------------------------------------------------------
# Tensor fusion (wait-free backpropagation, WFBP)
# ---------------------------------------------------------------------------

#: ``fusion`` accepts ``"all"`` (one bucket = the paper's monolithic
#: all-reduce, today's behaviour bit-for-bit), ``"none"`` (one bucket per
#: layer, fully unfused WFBP), or a positive byte threshold (DDP-style
#: ``bucket_cap``: greedily accumulate layers until the bucket reaches the
#: threshold).
FUSION_ALL = "all"
FUSION_NONE = "none"


def fusion_threshold(fusion) -> float:
    """Normalize a fusion spec to a byte threshold: ``"all"`` -> inf,
    ``"none"``/0 -> 0.0 (per-layer buckets), a positive number -> itself."""
    if isinstance(fusion, str):
        f = fusion.lower()
        if f == FUSION_ALL:
            return float("inf")
        if f == FUSION_NONE:
            return 0.0
        raise ValueError(
            f"unknown fusion spec {fusion!r}; expected 'all', 'none' or bytes"
        )
    thr = float(fusion)
    if thr < 0:
        raise ValueError(f"fusion threshold must be >= 0, got {fusion}")
    return thr


def fusion_plan(
    layer_bytes: Sequence[float],
    layer_t_b: Sequence[float],
    threshold: float,
) -> Tuple[Tuple[float, ...], Tuple[float, ...]]:
    """Greedy WFBP tensor fusion over layers in *backward-ready* order
    (output layer first — the order gradients materialize during backprop).

    Layers accumulate into the current bucket until its size reaches
    ``threshold`` bytes, then the bucket seals (PyTorch-DDP ``bucket_cap``
    semantics: the threshold is a *lower* bound on a sealed bucket, so
    every bucket except possibly the last is >= threshold).  Returns
    ``(bucket_bytes, bucket_t_b)``: per-bucket gradient bytes and the
    backward-compute segment time that must elapse — beyond the previous
    bucket's segment — before the bucket is ready to all-reduce.
    ``threshold=inf`` yields one bucket (``fusion="all"``); ``threshold=0``
    one bucket per layer (fully unfused).  Sums are preserved exactly:
    ``sum(bucket_bytes) == sum(layer_bytes)`` and likewise for time.
    """
    if len(layer_bytes) != len(layer_t_b):
        raise ValueError(
            f"layer_bytes ({len(layer_bytes)}) and layer_t_b "
            f"({len(layer_t_b)}) must align"
        )
    if not layer_bytes:
        raise ValueError("fusion_plan needs at least one layer")
    sizes: list = []
    times: list = []
    acc_b = acc_t = 0.0
    for lb, lt in zip(layer_bytes, layer_t_b):
        acc_b += float(lb)
        acc_t += float(lt)
        if acc_b >= threshold:
            sizes.append(acc_b)
            times.append(acc_t)
            acc_b = acc_t = 0.0
    if acc_b > 0.0 or acc_t > 0.0 or not sizes:
        sizes.append(acc_b)
        times.append(acc_t)
    return tuple(sizes), tuple(times)


def plan_for_model(model, fusion) -> Optional[Tuple[Tuple[float, ...], Tuple[float, ...]]]:
    """The fusion plan of one ``ModelProfile`` under a fusion spec, or
    ``None`` when the monolithic (legacy iteration-level) path applies:
    ``fusion="all"``, or a model without per-layer data (the paper's
    Table III profiles carry none)."""
    thr = fusion_threshold(fusion)
    if thr == float("inf") or not getattr(model, "layer_grad_bytes", ()):
        return None
    return fusion_plan(model.layer_grad_bytes, model.layer_t_b, thr)


# ---------------------------------------------------------------------------
# Preemption / checkpoint-restore cost (preemptive & elastic scheduling)
# ---------------------------------------------------------------------------

#: Default checkpoint-storage bandwidths [B/s] (save to / restore from a
#: shared filesystem over the same 10 GbE class network as the paper's
#: all-reduce: ~1.2 GB/s effective per direction) and the fixed
#: orchestration overhead of stopping and relaunching a gang [s].
CHECKPOINT_SAVE_BPS = 1.2e9
CHECKPOINT_RESTORE_BPS = 1.2e9
CHECKPOINT_FIXED_S = 1.0


def preemption_cost(
    state_bytes: float,
    save_bps: float = CHECKPOINT_SAVE_BPS,
    restore_bps: float = CHECKPOINT_RESTORE_BPS,
    fixed_s: float = CHECKPOINT_FIXED_S,
) -> float:
    """Wall-clock penalty of preempting (or resizing) a job: checkpoint its
    ``state_bytes`` of model state, then restore it on the next placement,
    plus a fixed stop/relaunch overhead.  Shared by both the event engine
    and any analytic model so the penalty cannot drift between layers.

    The restore half is charged when the job next starts (it delays the
    first forward of every worker); modeling save+restore as one lump at
    restart keeps the preemption event itself instantaneous — the saved
    GPU time is what preemption frees, and the paper's cluster writes
    checkpoints out-of-band.
    """
    if state_bytes < 0:
        raise ValueError(f"state_bytes must be >= 0, got {state_bytes}")
    if save_bps <= 0 or restore_bps <= 0:
        raise ValueError("checkpoint bandwidths must be positive")
    return fixed_s + state_bytes / save_bps + state_bytes / restore_bps


# ---------------------------------------------------------------------------
# Communication gating policies
# ---------------------------------------------------------------------------

#: Canonical names of the gating policies both backends understand.
POLICY_PATTERN = re.compile(r"^(ada|srsf([1-9])|kway([2-9]))$")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """One communication gating policy, reduced to two static parameters.

    ``max_ways``      — accept a start only if the resulting contention on
                        every touched server stays <= max_ways.
    ``threshold_gated`` — additionally require Theorem 2's ratio test
                        ``M_new < dual_threshold * min(M_old_remaining)``
                        when the start would contend (k_would > 1).

    AdaDUAL is (2, gated); SRSF(n) is (n, blind).  The k-way AdaDUAL
    generalization is (K, gated, exact): ``exact_lookahead`` routes the
    fluid backend to :func:`kway_exact_start` — the closed-form equivalent
    of the event backend's ``kway_adadual_should_start`` integrator —
    instead of the Theorem 2 pairwise-threshold approximation.
    """

    name: str
    max_ways: int
    threshold_gated: bool
    #: use the exact option-A/option-B average-finish-time comparison
    #: (k-way policies) instead of the pairwise ratio test
    exact_lookahead: bool = False


def parse_policy(name: str) -> PolicySpec:
    """'ada' | 'srsfN' | 'kwayK' -> a :class:`PolicySpec`."""
    m = POLICY_PATTERN.match(name)
    if not m:
        raise ValueError(
            f"unknown comm policy {name!r}; expected 'ada', 'srsfN' or 'kwayK'"
        )
    if name == "ada":
        return PolicySpec("ada", 2, True)
    if name.startswith("srsf"):
        return PolicySpec(name, int(m.group(2)), False)
    return PolicySpec(name, int(m.group(3)), True, exact_lookahead=True)


def may_start(
    k_would,
    new_cost,
    min_old_rem,
    *,
    max_ways: int,
    threshold_gated: bool,
    dual_threshold: float,
):
    """Branchless gating predicate shared by both backends.

    Args:
      k_would: contention level the new task *would* see if it started now
        (1 = uncontended); scalar or per-job array.
      new_cost: remaining size of the new task (bytes, or any unit
        proportional to bytes — the Theorem 2 test is a pure ratio).
      min_old_rem: smallest remaining size among the in-flight tasks that
        overlap the new one, in the same unit as ``new_cost``; pass
        ``inf`` when there is none.
      max_ways / threshold_gated: static policy parameters
        (:class:`PolicySpec`).
      dual_threshold: ``b / (2*(b + eta))`` (Theorem 2).

    Returns a boolean (array) — True where the task may start.  Uncontended
    starts are always allowed; a zero/negative ``min_old_rem`` fails the
    ratio test (matching the event backend's ``old_rem > 0`` guard, since
    ``new_cost`` is positive).
    """
    uncontended = k_would <= 1
    under_cap = k_would <= max_ways
    if threshold_gated:
        contended_ok = under_cap & (new_cost < dual_threshold * min_old_rem)
    else:
        contended_ok = under_cap
    return uncontended | contended_ok


def may_start_dynamic(
    k_would,
    new_cost,
    min_old_rem,
    max_ways,
    threshold_gated,
    dual_threshold: float,
    *,
    exact_kway_olds=None,
    rem=None,
    eta_over_b=None,
    exact_tol: float = 1e-9,
):
    """:func:`may_start` with the policy parameters as *runtime* values
    (arrays/traced scalars) instead of Python statics.

    Boolean-algebra-identical to :func:`may_start` for both values of
    ``threshold_gated`` (locked in tests/test_netmodel.py), but because
    nothing here is compile-time static, a jitted simulator can evaluate
    every gating policy through ONE compiled graph — the fluid backend
    uses this so AdaDUAL/SRSF(n)/k-way share a single XLA compilation per
    trace shape instead of recompiling per policy.

    ``threshold_gated`` must be a boolean *array* (numpy or jax; ``~`` is
    logical-not for those — a bare Python bool would bit-invert).

    Exact k-way lookahead: when ``exact_kway_olds`` (a ``(J, J)`` boolean
    matrix — row ``i`` marks the in-flight tasks overlapping candidate
    ``i``'s domains) is supplied together with ``rem`` (per-job remaining
    cost) and ``eta_over_b``, the threshold approximation above is replaced
    by :func:`kway_exact_start` — the closed form of the event backend's
    option-A/option-B average-finish-time comparison.  The fluid backend
    routes ``kwayK`` policies here (``PolicySpec.exact_lookahead``)."""
    if exact_kway_olds is not None:
        return kway_exact_start(
            new_cost, rem, exact_kway_olds, max_ways, eta_over_b, tol=exact_tol
        )
    uncontended = k_would <= 1
    under_cap = k_would <= max_ways
    ratio_ok = new_cost < dual_threshold * min_old_rem
    contended_ok = under_cap & (ratio_ok | ~threshold_gated)
    return uncontended | contended_ok


def gating_fixed_point(
    r1,
    priority,
    loads,
    counts,
    overlap,
    active,
    rem,
    new_cost,
    max_ways,
    threshold_gated,
    dual_threshold: float,
    *,
    exact_kway: bool = False,
    eta_over_b=None,
):
    """One-shot fixed point of the per-step greedy re-gating loop.

    The fluid backend's bucketed (WFBP) traces used to run FOUR sequential
    gating rounds per step — start the smallest-remaining-service eligible
    candidate, recompute the contention state, repeat — mirroring the event
    backend's re-evaluate-after-each-start loop.  This computes the greedy
    closure in a single masked pass instead.

    Why a single pass suffices — monotonicity of the gating predicate in
    the active set.  Write the threshold predicate for candidate ``i``
    against an active in-flight set ``A``::

        P_i(A) = (k_i(A∪{i}) <= 1)
               | (k_i(A∪{i}) <= max_ways
                  & (~gated | new_cost_i < thr * min_old_rem_i(A)))

    Growing ``A`` by another started task ``j`` can only (a) *increase*
    every per-domain count, hence ``k_i`` is non-decreasing in ``A``, and
    (b) add one more term to the min over overlapping in-flight
    remainders, hence ``min_old_rem_i`` is non-increasing in ``A``.  The
    predicate is non-increasing in ``k_i`` and non-decreasing in
    ``min_old_rem_i``, so ``P_i`` is *antitone* in the active set: adding
    starts can flip a candidate True -> False but never False -> True.
    Consequences for the greedy loop seeded with candidates
    ``r1 = {i : P_i(A0)}`` against the base set ``A0``:

    * no candidate outside ``r1`` can enter in a later round (the active
      set only grows), so ``r1`` bounds the closure from above;
    * any candidate passing the *pessimistic* test
      ``r2_i = P_i(A0 ∪ r1 \\ {i})`` passes against every intermediate
      active set of every greedy order (each is a subset), so
      ``r1 & r2`` is a sound start set under any order;
    * the greedy head ``c1`` (smallest ``priority`` in ``r1``) is started
      first by the loop against ``A0`` itself — sound by construction.

    The returned set ``(r1 & r2) | c1`` therefore never violates a cap or
    threshold that the sequential loop enforces, and equals the loop's
    closure whenever the greedy outcome is order-independent (the loop was
    itself truncated at 4 rounds, so neither side is the untruncated
    closure in pathological many-simultaneous-barrier steps).  Bit-exact
    agreement with the 4-round loop across the fusion × policy grid is
    locked in tests/test_fastpath.py.

    For exact-lookahead k-way policies (``exact_kway=True``) the predicate
    is a cost *comparison* (option A vs option B), not an antitone
    threshold, so the same pessimistic construction is used but the
    monotonicity argument does not apply; the simulator compensates by
    never skipping gating re-evaluation steps under exact k-way (see
    core/jaxsim.py) and the same grid lock applies.

    Args:
      r1: ``(J,)`` bool — candidates passing the predicate vs the base
        active set (round 1's eligibility).
      priority: ``(J,)`` float — greedy order key, smallest first
        (remaining service).
      loads: ``(J, D)`` bool — per-job domain loads.
      counts: ``(D,)`` int — per-domain in-flight counts of the base set.
      overlap: ``(J, J)`` bool — jobs sharing a contention domain.
      active: ``(J,)`` bool — base in-flight set.
      rem: ``(J,)`` float — remaining cost of each job's current transfer.
      new_cost: ``(J,)`` float — cost of each candidate's next transfer.
      max_ways / threshold_gated / dual_threshold: runtime policy params
        (:func:`may_start_dynamic`).
      exact_kway: route the pessimistic re-test through
        :func:`kway_exact_start`.
      eta_over_b: required when ``exact_kway``.

    Returns the ``(J,)`` bool start set.
    """
    import numpy as _np

    n_jobs = r1.shape[-1]
    eye = _np.eye(n_jobs, dtype=bool)  # constant under jit
    # Pessimistic active set per candidate: base ∪ (r1 \ {self}).  Every
    # r1 member contributes 1 to each domain it loads; excluding self from
    # its own lookahead reduces, for i ∈ r1, to the raw counts2 (the +1 of
    # k_would and the -1 of self-exclusion cancel).
    counts2 = counts + domain_counts(loads, r1)
    k_would2 = domain_k(loads, counts2)
    olds2 = (overlap & (active | r1)[..., None, :]) & ~eye
    big = 1e30  # finite "absent" sentinel: 0 * big stays NaN-free
    o2 = olds2 * 1.0
    min_old2 = (o2 * rem[..., None, :] + (1.0 - o2) * big).min(-1)
    if exact_kway:
        r2 = kway_exact_start(new_cost, rem, olds2, max_ways, eta_over_b)
    else:
        r2 = may_start_dynamic(
            k_would2, new_cost, min_old2, max_ways, threshold_gated,
            dual_threshold,
        )
    # Greedy head: smallest-priority r1 candidate (round 1's start).
    head = (r1 * priority + (1.0 - r1 * 1.0) * big).argmin(-1)
    c1 = r1 & (_np.arange(n_jobs) == head)
    return (r1 & r2) | c1


def _pairwise_min(x, y):
    """Branchless elementwise min (broadcasting) that works identically on
    numpy and jax arrays: ``min(x, y) = (x + y - |x - y|) / 2``."""
    return 0.5 * (x + y - abs(x - y))


def kway_exact_start(
    new_cost,
    rem,
    olds_mask,
    max_ways,
    eta_over_b,
    tol: float = 1e-9,
):
    """Exact k-way AdaDUAL gate, vectorized over candidates — the closed
    form of ``core/adadual.py``'s ``kway_adadual_should_start`` integrator
    (locked against it in tests/test_netmodel.py).

    Under Eq. (5) fair sharing, a set ``S`` of tasks all active from one
    instant with remaining sizes ``s_x`` finishes (in units where ``b = 1``,
    with ``e = eta/b``) at::

        t_x = (1 + e) * sum_y min(s_x, s_y)  -  e * s_x

    (phase-by-phase telescoping of the piecewise-constant rates; the
    latency ``a`` cancels from the A-vs-B comparison).  Summing over ``x``
    turns the option averages into quadratic forms of the pairwise-min
    matrix, so one batched masked matmul evaluates every candidate's
    lookahead at once — no per-candidate integration loop, and it jits.

    * Option A (start now): ``S = olds ∪ {new}``.
    * Option B (wait): the olds run alone until the smallest finishes at
      ``t1 = m_min * (k + (k-1)e)``; every survivor has drained exactly
      ``m_min``, then ``{survivors - m_min} ∪ {new}`` are simultaneous —
      the same closed form, shifted (``min(a-c, b-c) = min(a,b) - c``).

    Args:
      new_cost: ``(J,)`` remaining cost of each candidate's next transfer
        (the current WFBP *bucket* for bucketed traces — the per-bucket
        check — or the whole message for monolithic ones).  Any unit
        proportional to bytes: the decision is scale-invariant.
      rem: ``(J,)`` remaining cost of each job's in-flight transfer.
      olds_mask: ``(J, J)`` boolean; row ``i`` marks in-flight tasks
        overlapping candidate ``i``'s contention domains.
      max_ways: cap K (scalar or array) — reject when ``k + 1 > K``.
      eta_over_b: the contention penalty ratio ``eta / b``.
      tol: survivor threshold matching the event integrator's 1e-9.

    Returns a boolean ``(J,)`` — True where starting now gives a strictly
    smaller average finish time (or the candidate is uncontended).
    """
    e = eta_over_b
    big = 1e30  # f32-safe "no old task" sentinel
    olds = olds_mask * 1.0  # (J, J) float mask
    k = olds.sum(-1)  # (J,) in-flight tasks overlapping each candidate
    m = _pairwise_min(rem[..., None], rem[None, :])  # (J, J) pairwise mins
    # Option A — olds ∪ {new} simultaneous from now:
    q_a = ((olds @ m) * olds).sum(-1)  # sum_{j,l in olds} min(m_j, m_l)
    cross_a = (olds * _pairwise_min(new_cost[..., None], rem[None, :])).sum(-1)
    pairmin_a = q_a + 2.0 * cross_a + new_cost
    sum_a = (olds * rem[None, :]).sum(-1) + new_cost
    avg_a = ((1.0 + e) * pairmin_a - e * sum_a) / (k + 1.0)
    # Option B — wait for the first old to finish, then start:
    m_min = (rem[None, :] * olds + big * (1.0 - olds)).min(-1) * (k > 0)
    t1 = m_min * (k + (k - 1.0) * e)
    shifted = rem[None, :] - m_min[..., None]  # survivor sizes after t1
    sv = olds * (shifted > tol)
    kp = sv.sum(-1)
    q_sv = ((sv @ m) * sv).sum(-1) - kp * kp * m_min  # shifted quadratic form
    cross_b = (sv * _pairwise_min(shifted, new_cost[..., None])).sum(-1)
    pairmin_b = q_sv + 2.0 * cross_b + new_cost
    sum_b = (sv * shifted).sum(-1) + new_cost
    f_b = (1.0 + e) * pairmin_b - e * sum_b
    avg_b = t1 + f_b / (k + 1.0)
    return (k <= 0) | ((k + 1.0 <= max_ways) & (avg_a < avg_b))


# ---------------------------------------------------------------------------
# Placement-mode ranking (fluid backend's gang analogue of Algorithm 1)
# ---------------------------------------------------------------------------

#: Gang placement modes of the fluid backend and the event-backend
#: placement each one mirrors (see docs/scenarios.md parity matrix).
PLACEMENT_MODES = ("consolidate", "first_fit", "least_loaded", "random", "rack_pack")

#: Event-backend placement names -> fluid gang analogue.
FLUID_PLACEMENT_ALIASES = {
    "lwf": "consolidate",
    "gang": "consolidate",
    "consolidate": "consolidate",
    "ff": "first_fit",
    "first_fit": "first_fit",
    "ls": "least_loaded",
    "least_loaded": "least_loaded",
    "rand": "random",
    "random": "random",
    "lwf_rack": "rack_pack",
    "rack_pack": "rack_pack",
}


def canonical_placement(name: str) -> str:
    """Map an event-backend placement name ('lwf', 'ff', 'ls', 'rand',
    'lwf_rack', ...) to the fluid gang placement mode."""
    try:
        return FLUID_PLACEMENT_ALIASES[name.lower()]
    except KeyError:
        raise ValueError(
            f"fluid backend supports placements {sorted(FLUID_PLACEMENT_ALIASES)}, "
            f"got {name!r}"
        ) from None


def rack_pack_rank(free, server_rack, n_racks: int, gpus_per_server: int):
    """Rank key for the ``rack_pack`` gang mode: fill the rack with the most
    free GPUs first (locality — a job that fits in one rack lands entirely
    inside it and never crosses the rack uplink), servers within a rack by
    most-free (the consolidate shape).  Both terms are small bounded
    integers, so the composite key is exact in float32.

    ``free`` is ``(n_servers,)``; ``server_rack`` the ``(n_servers,)`` rack
    index of each server (:meth:`Topology.server_rack`)."""
    one_hot = (server_rack[..., None] == np.arange(n_racks)).astype(
        free.dtype
    )  # (n_servers, n_racks); the numpy constant broadcasts under jax too
    rack_free = (one_hot * free[..., None]).sum(axis=-2)  # (n_racks,)
    rack_free_per_server = (one_hot * rack_free[..., None, :]).sum(axis=-1)
    return -(rack_free_per_server * (gpus_per_server + 1) + free)


def placement_rank(mode: str, free, load, server_index, rank_extra=None):
    """Primary sort key per server for gang placement — servers are filled
    in ascending key order (stable sort; ties break by server index):

    * ``consolidate``  — most free GPUs first (``-free``): whole servers
      first, the LWF-1 consolidation shape;
    * ``first_fit``    — server index order, regardless of load;
    * ``least_loaded`` — smallest remaining-service workload first
      (Algorithm 1's L_S ordering, the LWF/LS shape);
    * ``random``       — caller-supplied random key (``rank_extra``): a
      uniformly random server order per admission (the gang analogue of
      the event backend's per-GPU RAND);
    * ``rack_pack``    — caller-supplied :func:`rack_pack_rank` key
      (``rank_extra``): emptiest rack first, then consolidate within it.

    ``free``/``load``/``server_index`` are ``(n_servers,)`` arrays (numpy
    or jax); ``mode`` is static.
    """
    if mode == "consolidate":
        return -free
    if mode == "first_fit":
        return server_index
    if mode == "least_loaded":
        return load
    if mode in ("random", "rack_pack"):
        if rank_extra is None:
            raise ValueError(f"mode {mode!r} needs a caller-supplied rank_extra key")
        return rank_extra
    raise ValueError(f"unknown placement mode {mode!r}; expected {PLACEMENT_MODES}")


__all__ = [
    "FLUID_PLACEMENT_ALIASES",
    "FUSION_ALL",
    "FUSION_NONE",
    "PLACEMENT_MODES",
    "PolicySpec",
    "canonical_placement",
    "domain_counts",
    "domain_k",
    "domain_loads",
    "fusion_plan",
    "fusion_threshold",
    "gating_fixed_point",
    "kway_exact_start",
    "may_start",
    "may_start_dynamic",
    "parse_policy",
    "placement_rank",
    "plan_for_model",
    "preemption_cost",
    "rack_pack_rank",
    "rate",
    "rate_ratio",
    "server_bandwidth_array",
    "slowest_member_scale",
]
