"""Event-driven cluster simulator for multi-job DDL training
(paper Algorithm 3 and Section V, exact continuous-time variant) —
compatibility entry point of the engine/policy split.

The former 859-line monolith now lives in two layers:

* ``core/engine.py``  — :class:`~repro.core.engine.EventEngine`: the
  mechanism (event calendar, cluster/GPU/comm-stream state, WFBP bucket
  pipelines, trace recording, preempt/resize plumbing);
* ``core/schedpolicy.py`` — the strategy layer: job scheduling policies
  (:class:`~repro.core.schedpolicy.StaticGangPolicy` — the paper's
  Algorithm 3 admission, bit-exact with the pre-split simulator;
  :class:`~repro.core.schedpolicy.PreemptiveSrsfPolicy` — Tiresias-style
  checkpoint/requeue; :class:`~repro.core.schedpolicy.ElasticPolicy` —
  min/max-GPU gangs resized at iteration boundaries) and the
  communication gating policies (AdaDUAL Algorithm 2, SRSF(n), k-way).

This module re-exports the public names so existing imports keep working,
and provides the one-call :func:`simulate` runner.  Semantics preserved:

* jobs arrive online (1 s ticks from the trace generator), queue in Q and
  are placed by a pluggable placement policy (Alg. 3 lines 6-13);
* GPUs may host several resident jobs (memory admission) and execute one
  non-preemptive ``f``/``b`` task at a time, picked by SRSF priority
  (lines 22-30);
* each multi-server job's All-Reduce is gated by a pluggable communication
  policy — AdaDUAL (lines 14-21), SRSF(n), or the beyond-paper k-way
  AdaDUAL — and drains under the Eq. (5) contention model with exact
  piecewise-constant-rate integration;
* job priority everywhere is SRSF: smallest remaining service
  ``(remaining iters) x (t_f + t_b + comm) x n_gpus`` first;
* beyond-paper (``fusion=``): wait-free backpropagation with tensor
  fusion — per-bucket gated transfers overlap the remaining backward
  (``core/dag.py``'s layer-granular DAG); ``fusion="all"`` is the paper's
  monolithic model, bit-for-bit;
* beyond-paper (``sched=``): gang preemption and elastic resizing — see
  ``core/schedpolicy.py``; the default ``sched="static"`` holds every
  placement until completion, exactly the paper (and the pre-split
  simulator, regression-locked in ``tests/test_engine.py``).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Union

from repro.core.chaos import ChaosSpec  # noqa: F401  (re-export)
from repro.core.cluster import Cluster, JobSpec
from repro.core.contention import ContentionParams
from repro.core.engine import (  # noqa: F401  (re-exports)
    CommTask,
    EventEngine,
    JobRun,
    SimResult,
    median,
    percentile,
)
from repro.core.placement import PlacementPolicy
from repro.core.schedpolicy import (  # noqa: F401  (re-exports)
    AdaDual,
    CommPolicy,
    ElasticPolicy,
    KWayAdaDual,
    PreemptiveSrsfPolicy,
    SchedPolicy,
    SrsfN,
    StaticGangPolicy,
    comm_policy_from_name,
    sched_policy_from_name,
)
from repro.core.topology import Topology
from repro.core.trace import ListTraceSource, TraceSource  # noqa: F401

#: Pre-split name of the engine: the constructor signature is unchanged
#: (plus the new ``sched``/``preemption_quantum``/``checkpoint_cost``
#: keywords), so existing call sites work verbatim.
ClusterSimulator = EventEngine


def simulate(
    jobs: Union[Sequence[JobSpec], TraceSource],
    placement: str = "lwf",
    kappa: int = 1,
    comm: str = "ada",
    params: Optional[ContentionParams] = None,
    n_servers: int = 16,
    gpus_per_server: int = 4,
    seed: int = 0,
    fuse_fb: bool = True,
    record_trace: bool = False,
    comm_chunks: int = 1,
    contention_domain: str = "server",
    exclusive_gpus: bool = False,
    bandwidth_aware_srsf: bool = False,
    topology: Optional[Topology] = None,
    fusion: object = "all",
    gpu_mem_mb: float = 16160.0,
    sched: Union[SchedPolicy, str, None] = None,
    preemption_quantum: Optional[float] = None,
    checkpoint_cost: Optional[float] = None,
    chaos: Optional[ChaosSpec] = None,
    max_time: float = math.inf,
    gating: Optional[str] = None,
    profile_phases: bool = False,
    observe: Optional[object] = None,
) -> SimResult:
    """One-call simulation with string-configured policies.

    jobs may be a materialized JobSpec list (every arrival pushed up
    front, the legacy behaviour) or a ``TraceSource`` — a streaming
    arrival feed that keeps the event calendar O(cluster) for 100k+-job
    trace replays.
    gating ('incremental', the default, or 'rescan'; REPRO_GATING
    overrides) selects the communication-gating evaluation strategy —
    bit-exact event streams either way, see core/engine.py.
    profile_phases=True records per-phase wall-clock totals in
    ``SimResult.phase_seconds``.
    observe (a ``repro.obs.ObsConfig`` or None) arms the contention
    observability layer — JCT decomposition, per-domain timelines, the
    gating audit log, and Perfetto span export — in ``SimResult.obs``.
    None (or an all-off config) keeps every hook cold: the run is
    bit-exact with, and as fast as, an unobserved one.

    comm: 'ada' (AdaDUAL), 'srsf1'/'srsf2'/'srsf3', or 'kway2'/'kway3'/'kway4'.
    placement: 'rand' | 'ff' | 'ls' | 'lwf' | 'lwf_rack'.
    comm_chunks > 1 enables the beyond-paper chunked/preemptible all-reduce.
    contention_domain: 'server' (NIC bottleneck) or 'link' (paper's wording).
    topology (core/topology.py) supersedes contention_domain with explicit
    fabric contention domains (NIC / rack uplink / oversubscribed two-tier)
    and supplies the rack grouping for the 'lwf_rack' placement.
    bandwidth_aware_srsf scales the SRSF remaining-service estimate by each
    job's slowest member NIC under server_bandwidth heterogeneity (default
    False = the paper-faithful nominal estimate).
    fusion ('all' | 'none' | a byte threshold) enables the WFBP
    layer-granular communication subsystem for jobs whose model carries
    layer data (repro.workloads); 'all' is the paper's monolithic
    iteration-level all-reduce, bit-for-bit.
    sched ('static' | 'preemptive_srsf' | 'elastic', or a SchedPolicy
    instance) selects the job scheduling policy; 'static' is the paper's
    hold-until-completion gang scheduling.  preemption_quantum overrides
    the named policy's tick period; checkpoint_cost overrides the
    netmodel.preemption_cost checkpoint/restore penalty [s].
    chaos (a ``core/chaos.py`` ChaosSpec) arms fault injection: server
    breakdown/repair, NIC degradation windows, straggler jitter, and
    stochastic cancellation — event backend only.
    max_time cuts the simulation at a horizon — jobs still running are
    reported in ``SimResult.censored`` (0 when the run drains fully).
    """
    policy = comm_policy_from_name(comm)
    sim = EventEngine(
        jobs,
        cluster=Cluster(
            n_servers=n_servers,
            gpus_per_server=gpus_per_server,
            gpu_mem_mb=gpu_mem_mb,
        ),
        placement=PlacementPolicy(placement, kappa=kappa, seed=seed, topology=topology),
        comm_policy=policy,
        params=params,
        fuse_fb=fuse_fb,
        record_trace=record_trace,
        comm_chunks=comm_chunks,
        contention_domain=contention_domain,
        exclusive_gpus=exclusive_gpus,
        bandwidth_aware_srsf=bandwidth_aware_srsf,
        topology=topology,
        fusion=fusion,
        sched=sched,
        preemption_quantum=preemption_quantum,
        checkpoint_cost=checkpoint_cost,
        chaos=chaos,
        gating=gating,
        profile_phases=profile_phases,
        observe=observe,
    )
    return sim.run(max_time=max_time)
