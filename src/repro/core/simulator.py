"""Event-driven cluster simulator for multi-job DDL training
(paper Algorithm 3 and Section V, exact continuous-time variant).

The paper presents Ada-SRSF as a time-discrete loop; because task durations
are tens of milliseconds while the paper's slot is one second, we integrate
the same dynamics exactly with an event queue instead (documented in
DESIGN.md).  Semantics preserved:

* jobs arrive online (1 s ticks from the trace generator), queue in Q and
  are placed by a pluggable placement policy (Alg. 3 lines 6-13);
* GPUs may host several resident jobs (memory admission) and execute one
  non-preemptive ``f``/``b`` task at a time, picked by SRSF priority
  (lines 22-30);
* each multi-server job's All-Reduce is gated by a pluggable communication
  policy — AdaDUAL (lines 14-21), SRSF(n), or the beyond-paper k-way
  AdaDUAL — and drains under the Eq. (5) contention model with exact
  piecewise-constant-rate integration;
* job priority everywhere is SRSF: smallest remaining service
  ``(remaining iters) x (t_f + t_b + comm) x n_gpus`` first;
* beyond-paper (``fusion=``): wait-free backpropagation with tensor
  fusion — for models carrying layer data (``repro.workloads``), the
  backward pass runs in per-bucket segments and each bucket's all-reduce
  is gated individually (same policy stack, the bucket's bytes, its own
  topology domain set) on a FIFO per-job comm stream that OVERLAPS the
  remaining backward compute; only the last bucket blocks the next
  iteration's forward (the layer-granular DAG in ``core/dag.py``).
  ``fusion="all"`` is the paper's monolithic model, bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core import dag as dag_mod
from repro.core import netmodel
from repro.core.adadual import (
    adadual_should_start,
    kway_adadual_should_start,
    srsf_n_should_start,
)
from repro.core.cluster import Cluster, GpuId, JobSpec
from repro.core.contention import ContentionParams
from repro.core.placement import PlacementPolicy
from repro.core.topology import RingEdgeTopology, Topology, nic_topology

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Communication gating policies
# ---------------------------------------------------------------------------


class CommPolicy:
    """Decides whether a ready communication task may start now.

    ``max_concurrent`` and ``old_remaining`` describe the in-flight
    communication tasks on the servers the new task touches (Alg. 2 inputs).
    """

    name = "base"

    def should_start(
        self,
        new_bytes: float,
        old_remaining: Sequence[float],
        max_concurrent: int,
        params: ContentionParams,
    ) -> bool:
        raise NotImplementedError


class SrsfN(CommPolicy):
    """SRSF(n): accept at most n-way contention, blindly (paper baselines)."""

    def __init__(self, n: int) -> None:
        self.n = n
        self.name = f"SRSF({n})"

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return srsf_n_should_start(max_concurrent, self.n)


class AdaDual(CommPolicy):
    """The paper's AdaDUAL (Algorithm 2)."""

    name = "Ada-SRSF"

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return adadual_should_start(new_bytes, old_remaining, max_concurrent, params)


class KWayAdaDual(CommPolicy):
    """Beyond-paper: exact-lookahead k-way generalization (future work #2)."""

    def __init__(self, max_ways: int = 3) -> None:
        self.max_ways = max_ways
        self.name = f"KWay({max_ways})-SRSF"

    def should_start(self, new_bytes, old_remaining, max_concurrent, params) -> bool:
        return kway_adadual_should_start(
            new_bytes, old_remaining, params, max_ways=self.max_ways
        )


# ---------------------------------------------------------------------------
# Runtime state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommTask:
    job_id: int
    servers: Set[int]
    remaining_bytes: float
    latency_left: float  # the fixed 'a' consumed in wall time before draining
    #: contention domains this task loads: topology domain indices (the
    #: fabric cuts its ring crosses — NICs, rack uplinks, ...; see
    #: core/topology.py) or, under the legacy "link" reading
    #: (``RingEdgeTopology``), the directed ring edges themselves (the
    #: paper's "each link between two nodes" wording)
    domains: frozenset = frozenset()
    #: WFBP bucket index this transfer carries (-1 = the monolithic
    #: iteration-level all-reduce)
    bucket: int = -1


@dataclasses.dataclass
class JobRun:
    spec: JobSpec
    gpus: List[GpuId]
    servers: Set[int]
    placed_at: float
    iter_done: int = 0
    # Per-worker progress within the current iteration:
    f_done: Set[int] = dataclasses.field(default_factory=set)
    b_done: Set[int] = dataclasses.field(default_factory=set)
    comm_ready_at: Optional[float] = None  # all-reduce ready, not yet started
    comm_active: bool = False
    #: chunks of the current iteration's all-reduce still to send (beyond-
    #: paper: tensor-fusion-style chunked, hence preemptible, communication)
    comm_chunks_left: int = 0
    #: WFBP fusion plan ``(bucket_bytes, bucket_t_b)`` from
    #: ``netmodel.fusion_plan`` — None = the monolithic legacy path (the
    #: paper's iteration-level all-reduce, bit-for-bit).
    plan: Optional[Tuple[Tuple[float, ...], Tuple[float, ...]]] = None
    #: WFBP per-worker backward progress: completed segments (len n_gpus).
    b_prog: List[int] = dataclasses.field(default_factory=list)
    #: WFBP comm pipeline: next bucket to hand to the (FIFO) comm stream
    #: and buckets whose transfer already completed this iteration.
    next_bucket: int = 0
    buckets_done: int = 0
    finished_at: Optional[float] = None

    @property
    def has_comm(self) -> bool:
        return len(self.servers) > 1

    @property
    def n_buckets(self) -> int:
        return len(self.plan[0]) if self.plan is not None else 1

    def per_iter_service(
        self, params: ContentionParams, bandwidth_aware: bool = False
    ) -> float:
        """Per-iteration service time: compute + contention-free comm (the
        per-message latency ``a`` is paid once per WFBP bucket).

        ``bandwidth_aware`` (beyond-paper, ROADMAP item) divides the
        per-byte term by the slowest member server's NIC multiplier, so a
        job placed on degraded links is recognized as having more service
        left.  Default False = the paper-faithful nominal estimate.
        """
        t = self.spec.model.t_iter_compute
        if self.has_comm:
            scale = params.bandwidth_scale(self.servers) if bandwidth_aware else 1.0
            t += self.n_buckets * params.a + params.b * self.spec.model.size_bytes / scale
        return t

    def remaining_service(
        self, params: ContentionParams, bandwidth_aware: bool = False
    ) -> float:
        """SRSF key: remaining time x allocated GPUs (Tiresias-style)."""
        rem_iters = self.spec.iterations - self.iter_done
        return rem_iters * self.per_iter_service(params, bandwidth_aware) * self.spec.n_gpus


def median(xs: Sequence[float]) -> float:
    """Median (mean of the middle two for even-length lists)."""
    if not xs:
        return math.nan
    ys = sorted(xs)
    n = len(ys)
    return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile, q in [0, 1] (the convention all JCT
    reporting in this repo shares)."""
    if not xs:
        return math.nan
    ys = sorted(xs)
    idx = min(len(ys) - 1, int(math.ceil(q * len(ys))) - 1)
    return ys[max(0, idx)]


@dataclasses.dataclass
class SimResult:
    policy_name: str
    placement_name: str
    jct: Dict[int, float]  # job_id -> completion - arrival
    finish: Dict[int, float]
    makespan: float
    gpu_busy: Dict[GpuId, float]
    gpu_util: float  # mean busy fraction over makespan
    queueing_delay: Dict[int, float]
    events_processed: int
    comm_started_contended: int
    comm_started_clean: int
    task_trace: Optional[List[Tuple]] = None  # (job, iter, kind, worker, t0, t1)

    def avg_jct(self) -> float:
        return sum(self.jct.values()) / len(self.jct)

    def median_jct(self) -> float:
        return median(list(self.jct.values()))

    def p95_jct(self) -> float:
        return percentile(list(self.jct.values()), 0.95)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------


class ClusterSimulator:
    """Exact event-driven simulation of Algorithm 3's dynamics."""

    def __init__(
        self,
        jobs: Sequence[JobSpec],
        cluster: Optional[Cluster] = None,
        placement: Optional[PlacementPolicy] = None,
        comm_policy: Optional[CommPolicy] = None,
        params: Optional[ContentionParams] = None,
        fuse_fb: bool = True,
        record_trace: bool = False,
        comm_chunks: int = 1,
        contention_domain: str = "server",  # server (NIC) | link (ring edges)
        exclusive_gpus: bool = False,  # paper assumption 3 reading
        bandwidth_aware_srsf: bool = False,  # hetero-aware remaining-service
        topology: Optional[Topology] = None,  # fabric contention domains
        fusion: object = "all",  # WFBP tensor fusion: 'all' | 'none' | bytes
    ) -> None:
        self.jobs = {j.job_id: j for j in jobs}
        self.cluster = cluster or Cluster()
        self.placement = placement or PlacementPolicy("lwf", kappa=1)
        self.comm_policy = comm_policy or AdaDual()
        self.params = params or ContentionParams()
        # Fusing f+b into one GPU occupancy halves event count; a newly
        # placed higher-priority job can then preempt only at (f+b)
        # boundaries instead of f|b boundaries (distortion <= t_b ~ 50 ms).
        # Fidelity tests set fuse_fb=False.
        self.fuse_fb = fuse_fb and not record_trace
        self.record_trace = record_trace
        # Beyond-paper (future-work #3 adjacent): split each all-reduce into
        # N chunks scheduled independently — a long transfer can lose the
        # link to a shorter job's message at every chunk boundary, making
        # communication effectively preemptible.  The per-message latency
        # `a` is charged per chunk (that is the real cost of chunking).
        self.comm_chunks = max(1, comm_chunks)
        # WFBP tensor fusion (layer-granular communication subsystem):
        # 'all' = one monolithic all-reduce per iteration (the paper's model
        # and today's behaviour bit-for-bit); 'none' / a byte threshold =
        # per-bucket transfers (netmodel.fusion_plan) that overlap the
        # remaining backward pass, gated per bucket.  Only jobs whose
        # ModelProfile carries layer data (repro.workloads) are affected;
        # Table III profiles always run monolithic.
        self._fusion_threshold = netmodel.fusion_threshold(fusion)
        self.fusion = fusion
        if self._fusion_threshold != math.inf and self.comm_chunks > 1:
            raise ValueError(
                "comm_chunks and WFBP fusion are mutually exclusive — the "
                "fusion plan already chunks the all-reduce"
            )
        self._plan_cache: Dict[int, Optional[tuple]] = {}
        # "server": the server's NIC is the shared resource (conservative —
        # all flows through one 10GbE port contend).  "link": the paper's
        # wording — contention only between tasks sharing a ring edge
        # (server pair), allowing disjoint transfers to proceed in parallel.
        if contention_domain not in ("server", "link"):
            raise ValueError(f"unknown contention domain {contention_domain!r}")
        self.contention_domain = contention_domain
        # An explicit fabric topology (core/topology.py) supersedes the
        # contention_domain string; the default NIC-only topology is the
        # identical computation as "server" (one domain per server, all
        # oversub 1.0), so behaviour is bit-for-bit unchanged.  The legacy
        # ring-edge "link" reading is the dynamic RingEdgeTopology: the same
        # per-task domains the old inline code produced (regression-locked
        # in tests/test_chunked_comm.py), expressed as topology domains.
        if topology is not None and topology.n_servers != self.cluster.n_servers:
            raise ValueError(
                f"topology covers {topology.n_servers} servers, cluster has "
                f"{self.cluster.n_servers}"
            )
        if topology is None:
            topology = (
                nic_topology(self.cluster.n_servers)
                if contention_domain == "server"
                else RingEdgeTopology(self.cluster.n_servers)
            )
        self.topology = topology
        self.cluster.exclusive = exclusive_gpus
        # SRSF priority estimate under server_bandwidth heterogeneity: the
        # paper's nominal homogeneous comm time (False, default) or scaled
        # by the slowest member NIC (True) — see JobRun.per_iter_service.
        self.bandwidth_aware_srsf = bandwidth_aware_srsf

        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._queue: List[int] = []  # unplaced job ids
        self._runs: Dict[int, JobRun] = {}
        self._active_comm: Dict[int, CommTask] = {}
        self._waiting_comm: List[int] = []  # job ids with gated all-reduce
        self._comm_epoch = 0
        self._last_comm_update = 0.0
        self._dirty_gpus: Set[GpuId] = set()
        self._events = 0
        self._comm_contended = 0
        self._comm_clean = 0
        self._trace: List[Tuple] = []
        self._unfinished = set(self.jobs)

    # -- event helpers -------------------------------------------------------
    def _push(self, t: float, kind: str, data: tuple) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    # -- SRSF priority ---------------------------------------------------------
    def _srsf_key_queued(self, job_id: int):
        spec = self.jobs[job_id]
        # E_J = 0 before placement (paper Section IV-A "Job Priority").
        rem = spec.compute_time * spec.n_gpus
        return (rem, spec.arrival, job_id)

    def _srsf_key_running(self, job_id: int):
        run = self._runs[job_id]
        rem = run.remaining_service(self.params, self.bandwidth_aware_srsf)
        return (rem, run.spec.arrival, job_id)

    # -- communication bookkeeping --------------------------------------------
    def _domains_of(self, servers: Set[int]) -> frozenset:
        """Contention domains a comm task over ``servers`` loads: the
        topology cuts its ring crosses (domain indices), or — under the
        legacy "link" reading, now ``RingEdgeTopology`` — the directed ring
        edges themselves."""
        return self.topology.loaded_domains(servers)

    def _comm_k_eff(self, task: CommTask) -> float:
        """Effective contention for the Eq. (5) *rate*: per-domain count
        scaled by that domain's oversubscription factor (an uplink with
        oversub f delivers 1/f of nominal bandwidth, so k tasks crossing it
        drain like k*f tasks on a NIC).  All-1.0 oversub (the NIC-only
        topology, and the legacy ring-link reading) reduces to the raw k."""
        k = 1.0
        for d in task.domains:
            c = sum(1 for t in self._active_comm.values() if d in t.domains)
            k = max(k, c * self.topology.oversub_of(d))
        return k

    def _advance_comm(self, now: float) -> List[int]:
        """Drain all in-flight comm tasks from the last update to ``now``.
        Returns job ids whose all-reduce completed in this window."""
        dt = now - self._last_comm_update
        self._last_comm_update = now
        finished: List[int] = []
        if dt <= 0 or not self._active_comm:
            return finished
        # Rates are piecewise constant between events because the active set
        # only changes at events (domain loads are a pure function of the
        # active set); use the rate as of the window start — this stays an
        # exact piecewise-rate integration under any topology.
        ks = {jid: self._comm_k_eff(t) for jid, t in self._active_comm.items()}
        for jid, task in list(self._active_comm.items()):
            lat = min(task.latency_left, dt)
            task.latency_left -= lat
            drain_t = dt - lat
            if drain_t > 0:
                rate = self.params.rate(ks[jid]) * self.params.bandwidth_scale(
                    task.servers
                )
                task.remaining_bytes -= drain_t * rate
            if task.latency_left <= _EPS and task.remaining_bytes <= 1.0:
                # tolerance: 1 byte ~ 1e-9 s — absorbs float drift in the
                # piecewise integration
                finished.append(jid)
        for jid in finished:
            del self._active_comm[jid]
        return finished

    def _next_comm_finish(self) -> Optional[float]:
        if not self._active_comm:
            return None
        t_min = math.inf
        for task in self._active_comm.values():
            k = self._comm_k_eff(task)
            rate = self.params.rate(k) * self.params.bandwidth_scale(task.servers)
            t = self._last_comm_update + task.latency_left + task.remaining_bytes / rate
            t_min = min(t_min, t)
        return t_min

    def _reschedule_comm_check(self) -> None:
        self._comm_epoch += 1
        t = self._next_comm_finish()
        if t is not None:
            self._push(t, "comm_check", (self._comm_epoch,))

    # -- WFBP fusion plans -------------------------------------------------------
    def _assign_plan(self, run: JobRun) -> None:
        """Attach the WFBP fusion plan to a freshly-placed run: per-bucket
        (bytes, backward-segment seconds) when fusion is finite, the model
        carries layer data, and the placement actually spans servers —
        otherwise the monolithic legacy path (plan None)."""
        if self._fusion_threshold == math.inf or not run.has_comm:
            return
        model = run.spec.model
        if not getattr(model, "has_layers", False):
            return
        key = id(model)
        if key not in self._plan_cache:
            self._plan_cache[key] = netmodel.fusion_plan(
                model.layer_grad_bytes, model.layer_t_b, self._fusion_threshold
            )
        run.plan = self._plan_cache[key]
        run.b_prog = [0] * run.spec.n_gpus

    def _maybe_enqueue_bucket(self, run: JobRun) -> None:
        """Hand the next WFBP bucket to the gating queue once (a) all
        workers have finished its backward segment and (b) the job's comm
        stream is free (buckets serialize FIFO, the PyTorch-DDP model)."""
        jid = run.spec.job_id
        if run.comm_active or jid in self._waiting_comm:
            return
        if run.next_bucket >= run.n_buckets:
            return
        if run.next_bucket < min(run.b_prog):
            self._waiting_comm.append(jid)

    # -- placement --------------------------------------------------------------
    def _refresh_workloads(self) -> None:
        """Alg. 3 line 3: recompute every GPU's remaining workload L_g as the
        sum of its resident jobs' remaining service (shared per GPU)."""
        for g in self.cluster.gpus.values():
            g.workload = 0.0
        for jid, run in self._runs.items():
            if run.finished_at is not None:
                continue
            share = run.remaining_service(self.params, self.bandwidth_aware_srsf)
            for gid in run.gpus:
                self.cluster.gpus[gid].workload += share

    def _try_place(self, now: float) -> None:
        if not self._queue:
            return
        self._refresh_workloads()
        self._queue.sort(key=self._srsf_key_queued)
        placed: List[int] = []
        for jid in self._queue:
            spec = self.jobs[jid]
            gpu_ids = self.placement(self.cluster, spec)
            if gpu_ids is None:
                continue  # no head-of-line blocking (Alg. 3 loops the queue)
            servers = self.cluster.servers_of(gpu_ids)
            run = JobRun(spec=spec, gpus=list(gpu_ids), servers=servers, placed_at=now)
            self._assign_plan(run)
            workload = run.remaining_service(self.params, self.bandwidth_aware_srsf)
            self.cluster.place(spec, gpu_ids, workload)
            self._runs[jid] = run
            self._dirty_gpus.update(gpu_ids)
            placed.append(jid)
        for jid in placed:
            self._queue.remove(jid)

    # -- communication gating -----------------------------------------------------
    def _try_start_comms(self, now: float) -> bool:
        if not self._waiting_comm:
            return False
        any_started = False
        # Alg. 3 line 16: consider ready communication tasks in SRSF order.
        self._waiting_comm.sort(key=self._srsf_key_running)
        started_any = True
        while started_any:
            started_any = False
            for jid in list(self._waiting_comm):
                run = self._runs[jid]
                if run.comm_active or jid in self._active_comm:
                    self._waiting_comm.remove(jid)
                    continue
                servers = run.servers
                domains = self._domains_of(servers)
                olds = [
                    t for t in self._active_comm.values() if t.domains & domains
                ]
                max_conc = 0
                for d in domains:
                    max_conc = max(
                        max_conc,
                        sum(1 for t in self._active_comm.values() if d in t.domains),
                    )
                # WFBP: the gating decision and the transfer carry the
                # current *bucket's* bytes, not the whole message.
                if run.plan is not None:
                    bucket = run.next_bucket
                    new_bytes = run.plan[0][bucket]
                else:
                    bucket = -1
                    new_bytes = run.spec.model.size_bytes
                ok = self.comm_policy.should_start(
                    new_bytes,
                    [t.remaining_bytes for t in olds],
                    max_conc,
                    self.params,
                )
                if not ok:
                    continue
                self._waiting_comm.remove(jid)
                self._active_comm[jid] = CommTask(
                    job_id=jid,
                    servers=set(servers),
                    remaining_bytes=(
                        new_bytes
                        if run.plan is not None
                        else run.spec.model.size_bytes / self.comm_chunks
                    ),
                    latency_left=self.params.a,
                    domains=domains,
                    bucket=bucket,
                )
                if run.plan is not None:
                    run.next_bucket += 1
                else:
                    run.comm_chunks_left -= 1
                run.comm_active = True
                if max_conc > 0:
                    self._comm_contended += 1
                else:
                    self._comm_clean += 1
                if self.record_trace:
                    kind = "c" if bucket < 0 else f"c{bucket}"
                    self._trace.append(
                        (jid, run.iter_done, kind, -1, now, None)
                    )
                started_any = True
                any_started = True
                break  # re-evaluate contention state after each start
        return any_started

    # -- iteration/worker state machine ---------------------------------------------
    def _begin_iteration(self, run: JobRun, now: float) -> None:
        run.f_done.clear()
        run.b_done.clear()
        run.comm_ready_at = None
        run.comm_active = False
        if run.plan is not None:
            run.b_prog = [0] * run.spec.n_gpus
            run.next_bucket = 0
            run.buckets_done = 0
        self._dirty_gpus.update(run.gpus)

    def _complete_iteration(self, run: JobRun, now: float) -> None:
        run.iter_done += 1
        if run.iter_done >= run.spec.iterations:
            self._finish_job(run, now)
        else:
            self._begin_iteration(run, now)

    def _finish_job(self, run: JobRun, now: float) -> None:
        run.finished_at = now
        self.cluster.release(run.spec, run.gpus)
        self._dirty_gpus.update(run.gpus)
        self._unfinished.discard(run.spec.job_id)

    def _on_backward_done(self, run: JobRun, now: float) -> None:
        if len(run.b_done) < run.spec.n_gpus:
            return
        # Barrier reached (Fig. 3: all-reduce waits for all backprops).
        if run.has_comm:
            jid = run.spec.job_id
            assert jid not in self._waiting_comm and not run.comm_active, (
                f"duplicate barrier for job {jid}"
            )
            run.comm_ready_at = now
            run.comm_chunks_left = self.comm_chunks
            self._waiting_comm.append(jid)
        else:
            self._complete_iteration(run, now)

    # -- GPU scheduling (Alg. 3 lines 22-30) -------------------------------------
    def _ready_compute_tasks(self, gid: GpuId):
        """Yield (job_id, worker, kind, duration, segment) ready on this
        GPU; segment is the WFBP backward-segment index (-1 = monolithic)."""
        g = self.cluster.gpus[gid]
        for jid in g.resident_jobs:
            run = self._runs.get(jid)
            if run is None or run.finished_at is not None:
                continue
            try:
                w = run.gpus.index(gid)
            except ValueError:
                continue
            if run.plan is not None:
                # WFBP: backward runs in per-bucket segments that overlap
                # in-flight transfers — comm never blocks compute within
                # the iteration (only the iteration boundary barriers).
                if w not in run.f_done:
                    yield (jid, w, "f", run.spec.model.t_f, -1)
                elif run.b_prog[w] < run.n_buckets:
                    s = run.b_prog[w]
                    yield (jid, w, "b", run.plan[1][s], s)
                continue
            if run.comm_ready_at is not None or run.comm_active:
                continue  # between barrier and next iteration
            if w not in run.f_done:
                if self.fuse_fb:
                    yield (jid, w, "fb", run.spec.model.t_iter_compute, -1)
                else:
                    yield (jid, w, "f", run.spec.model.t_f, -1)
            elif w not in run.b_done:
                yield (jid, w, "b", run.spec.model.t_b, -1)

    def _schedule_gpus(self, now: float) -> None:
        for gid in list(self._dirty_gpus):
            self._dirty_gpus.discard(gid)
            g = self.cluster.gpus[gid]
            # busy_job is cleared only by this GPU's own gpu_done event, so a
            # task ending exactly at `now` (event still in the heap) cannot be
            # double-scheduled by another same-timestamp event.
            if g.busy_job is not None:
                continue
            candidates = list(self._ready_compute_tasks(gid))
            if not candidates:
                g.busy_until = None
                g.busy_job = None
                continue
            # SRSF among resident jobs' ready tasks.
            candidates.sort(key=lambda c: self._srsf_key_running(c[0]))
            jid, w, kind, dur, seg = candidates[0]
            g.busy_until = now + dur
            g.busy_job = jid
            g.busy_accum += dur
            self._push(now + dur, "gpu_done", (gid, jid, w, kind, seg))
            if self.record_trace:
                if kind == "fb":
                    run = self._runs[jid]
                    self._trace.append((jid, run.iter_done, "f", w, now, now + run.spec.model.t_f))
                    self._trace.append((jid, run.iter_done, "b", w, now + run.spec.model.t_f, now + dur))
                else:
                    tkind = kind if seg < 0 else f"{kind}{seg}"
                    self._trace.append((jid, self._runs[jid].iter_done, tkind, w, now, now + dur))

    # -- main loop ----------------------------------------------------------------
    def run(self, max_time: float = math.inf) -> SimResult:
        for spec in self.jobs.values():
            self._push(spec.arrival, "arrival", (spec.job_id,))
        now = 0.0
        while self._heap and self._unfinished:
            t, _, kind, data = heapq.heappop(self._heap)
            if kind == "comm_check" and data[0] != self._comm_epoch:
                continue
            if t > max_time:
                break
            now = t
            self._events += 1
            comm_state_changed = False

            finished_comms = self._advance_comm(now)
            for jid in finished_comms:
                run = self._runs[jid]
                run.comm_active = False
                comm_state_changed = True
                if self.record_trace:
                    # patch the open comm record ("c" or a WFBP "c<bucket>")
                    for i in range(len(self._trace) - 1, -1, -1):
                        r = self._trace[i]
                        if r[0] == jid and r[2].startswith("c") and r[5] is None:
                            self._trace[i] = (r[0], r[1], r[2], r[3], r[4], now)
                            break
                if run.plan is not None:
                    # WFBP: bucket done; the iteration completes with the
                    # LAST bucket's transfer (earlier ones only overlapped
                    # the remaining backward), else hand the next ready
                    # bucket to the FIFO comm stream.
                    run.buckets_done += 1
                    if run.buckets_done >= run.n_buckets:
                        self._complete_iteration(run, now)
                    else:
                        self._maybe_enqueue_bucket(run)
                elif run.comm_chunks_left > 0:
                    # chunked comm: re-queue the next chunk (it competes for
                    # the link like a fresh task — preemption point)
                    self._waiting_comm.append(jid)
                else:
                    self._complete_iteration(run, now)

            if kind == "arrival":
                self._queue.append(data[0])
                self._try_place(now)
            elif kind == "gpu_done":
                gid, jid, w, tkind, seg = data
                g = self.cluster.gpus[gid]
                g.busy_until = None
                g.busy_job = None
                self._dirty_gpus.add(gid)
                run = self._runs[jid]
                if run.plan is not None:
                    if tkind == "f":
                        run.f_done.add(w)
                    else:  # backward segment `seg` of worker w
                        run.b_prog[w] += 1
                        self._maybe_enqueue_bucket(run)
                elif tkind == "fb":
                    run.f_done.add(w)
                    run.b_done.add(w)
                    self._on_backward_done(run, now)
                elif tkind == "f":
                    run.f_done.add(w)
                elif tkind == "b":
                    run.b_done.add(w)
                    self._on_backward_done(run, now)
                if run.finished_at is not None:
                    # memory freed -> queued jobs may fit now
                    self._try_place(now)
            elif kind == "comm_check":
                comm_state_changed = comm_state_changed or bool(finished_comms)

            if finished_comms:
                # job finishing via comm also frees memory
                if any(self._runs[j].finished_at is not None for j in finished_comms):
                    self._try_place(now)

            # Gating re-evaluated whenever comm state may have changed or new
            # barriers were reached this event.
            started = self._try_start_comms(now)
            self._schedule_gpus(now)
            # Rates only change when the active comm set changes, so the
            # pending finish prediction stays valid otherwise.  A comm_check
            # that finished nothing (float drift) must still reschedule, or
            # the in-flight task would stall forever.
            if started or finished_comms or kind == "comm_check":
                self._reschedule_comm_check()

        return self._collect(now)

    # -- results ------------------------------------------------------------------
    def _collect(self, now: float) -> SimResult:
        jct, finish, qdelay = {}, {}, {}
        for jid, run in self._runs.items():
            if run.finished_at is not None:
                finish[jid] = run.finished_at
                jct[jid] = run.finished_at - run.spec.arrival
                qdelay[jid] = run.placed_at - run.spec.arrival
        makespan = max(finish.values()) if finish else now
        busy = {gid: g.busy_accum for gid, g in self.cluster.gpus.items()}
        util = (
            sum(busy.values()) / (len(busy) * makespan) if makespan > 0 else 0.0
        )
        return SimResult(
            policy_name=self.comm_policy.name,
            placement_name=repr(self.placement),
            jct=jct,
            finish=finish,
            makespan=makespan,
            gpu_busy=busy,
            gpu_util=util,
            queueing_delay=qdelay,
            events_processed=self._events,
            comm_started_contended=self._comm_contended,
            comm_started_clean=self._comm_clean,
            task_trace=self._trace if self.record_trace else None,
        )


# ---------------------------------------------------------------------------
# Convenience runner
# ---------------------------------------------------------------------------


def comm_policy_from_name(comm: str) -> CommPolicy:
    """'ada' (AdaDUAL), 'srsfN', or 'kwayK' -> a CommPolicy instance."""
    if comm == "ada":
        return AdaDual()
    if comm.startswith("srsf"):
        return SrsfN(int(comm[4:]))
    if comm.startswith("kway"):
        return KWayAdaDual(int(comm[4:]))
    raise ValueError(f"unknown comm policy {comm!r}")


def simulate(
    jobs: Sequence[JobSpec],
    placement: str = "lwf",
    kappa: int = 1,
    comm: str = "ada",
    params: Optional[ContentionParams] = None,
    n_servers: int = 16,
    gpus_per_server: int = 4,
    seed: int = 0,
    fuse_fb: bool = True,
    record_trace: bool = False,
    comm_chunks: int = 1,
    contention_domain: str = "server",
    exclusive_gpus: bool = False,
    bandwidth_aware_srsf: bool = False,
    topology: Optional[Topology] = None,
    fusion: object = "all",
    gpu_mem_mb: float = 16160.0,
) -> SimResult:
    """One-call simulation with string-configured policies.

    comm: 'ada' (AdaDUAL), 'srsf1'/'srsf2'/'srsf3', or 'kway2'/'kway3'/'kway4'.
    placement: 'rand' | 'ff' | 'ls' | 'lwf' | 'lwf_rack'.
    comm_chunks > 1 enables the beyond-paper chunked/preemptible all-reduce.
    contention_domain: 'server' (NIC bottleneck) or 'link' (paper's wording).
    topology (core/topology.py) supersedes contention_domain with explicit
    fabric contention domains (NIC / rack uplink / oversubscribed two-tier)
    and supplies the rack grouping for the 'lwf_rack' placement.
    bandwidth_aware_srsf scales the SRSF remaining-service estimate by each
    job's slowest member NIC under server_bandwidth heterogeneity (default
    False = the paper-faithful nominal estimate).
    fusion ('all' | 'none' | a byte threshold) enables the WFBP
    layer-granular communication subsystem for jobs whose model carries
    layer data (repro.workloads); 'all' is the paper's monolithic
    iteration-level all-reduce, bit-for-bit.
    """
    policy = comm_policy_from_name(comm)
    sim = ClusterSimulator(
        jobs,
        cluster=Cluster(
            n_servers=n_servers,
            gpus_per_server=gpus_per_server,
            gpu_mem_mb=gpu_mem_mb,
        ),
        placement=PlacementPolicy(placement, kappa=kappa, seed=seed, topology=topology),
        comm_policy=policy,
        params=params,
        fuse_fb=fuse_fb,
        record_trace=record_trace,
        comm_chunks=comm_chunks,
        contention_domain=contention_domain,
        exclusive_gpus=exclusive_gpus,
        bandwidth_aware_srsf=bandwidth_aware_srsf,
        topology=topology,
        fusion=fusion,
    )
    return sim.run()
