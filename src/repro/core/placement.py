"""Job placement algorithms (paper Section IV-A, Algorithm 1).

Given a job needing ``n`` GPUs and the current cluster state, pick the GPU
set G(J):

* ``RAND``  — uniformly random among memory-feasible GPUs (baseline).
* ``FF``    — First-Fit: first ``n`` feasible GPUs in (server, gpu) order.
* ``LS``    — List-Scheduling: top-``n`` feasible GPUs by least workload L_g.
* ``LWF-k`` — the paper's algorithm:   n <= kappa  ->  same as LS;
              n  > kappa  ->  sort *servers* by total workload L_S and take
              feasible GPUs server-by-server (consolidation), Alg. 1 lines
              10-21.
* ``LWF_RACK-k`` — beyond-paper, topology-aware LWF: racks (from
              ``core/topology.py``) are ordered by total rack workload and
              filled one at a time, servers within a rack in LWF order, so
              a job that fits inside a rack never crosses its (possibly
              oversubscribed) uplink.  Without a topology it degenerates to
              plain LWF (one rack = the whole cluster).

All functions return a list of GpuIds (len == n) or ``None`` when the job
cannot be admitted (Alg. 1 line 22 returns the empty set).  They never
mutate the cluster — the simulator commits via ``Cluster.place``.
"""

from __future__ import annotations

import random
from typing import List, Optional, Sequence, Tuple

from repro.core.cluster import Cluster, GpuId, GpuState, JobSpec
from repro.core.topology import Topology


def _feasible(cluster: Cluster, job: JobSpec) -> List[GpuState]:
    return cluster.available_gpus(job.model.mem_mb)


def place_random(cluster: Cluster, job: JobSpec, rng: random.Random) -> Optional[List[GpuId]]:
    avail = _feasible(cluster, job)
    if len(avail) < job.n_gpus:
        return None
    return [g.gpu_id for g in rng.sample(avail, job.n_gpus)]


def place_first_fit(cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
    avail = sorted(_feasible(cluster, job), key=lambda g: g.gpu_id)
    if len(avail) < job.n_gpus:
        return None
    return [g.gpu_id for g in avail[: job.n_gpus]]


def place_list_scheduling(cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
    avail = _feasible(cluster, job)
    if len(avail) < job.n_gpus:
        return None
    avail.sort(key=lambda g: (g.workload, g.gpu_id))
    return [g.gpu_id for g in avail[: job.n_gpus]]


def place_lwf(cluster: Cluster, job: JobSpec, kappa: int = 1) -> Optional[List[GpuId]]:
    """Algorithm 1 (LWF-kappa): the one-rack special case of
    :func:`place_lwf_rack` — least-loaded servers first (lines 10-21),
    global least-workload-first for small jobs (lines 2-9)."""
    return place_lwf_rack(cluster, job, (tuple(range(cluster.n_servers)),), kappa)


def place_lwf_rack(
    cluster: Cluster,
    job: JobSpec,
    racks: Sequence[Sequence[int]],
    kappa: int = 1,
) -> Optional[List[GpuId]]:
    """Rack-locality-aware LWF-kappa: least-loaded *racks* first, then LWF
    server order within each rack.  Filling a whole rack before touching the
    next keeps jobs that fit inside one rack off the rack uplink — the
    placement-side answer to oversubscribed two-tier fabrics."""
    n = job.n_gpus
    if n <= kappa:
        return place_list_scheduling(cluster, job)
    # one workload sum per server per call (the sort keys previously
    # recomputed the per-server sum for every key evaluation; identical
    # values, identical ordering)
    load = [cluster.server_workload(s) for s in range(cluster.n_servers)]
    rack_order = sorted(
        range(len(racks)),
        key=lambda r: (sum(load[s] for s in racks[r]), r),
    )
    ordered: List[GpuState] = []
    for r in rack_order:
        servers = sorted(racks[r], key=lambda s: (load[s], s))
        for s in servers:
            gpus = [
                g
                for g in cluster.gpus_of_server(s)
                if not g.down
                and g.mem_free_mb() >= job.model.mem_mb
                and not (cluster.exclusive and g.resident_jobs)
            ]
            gpus.sort(key=lambda g: (g.workload, g.gpu_id))
            ordered.extend(gpus)
    if len(ordered) < n:
        return None
    return [g.gpu_id for g in ordered[:n]]


class PlacementPolicy:
    """Callable wrapper so the simulator takes one pluggable object.

    ``topology`` supplies the rack grouping for ``lwf_rack``; without one,
    every server shares one rack and ``lwf_rack`` degenerates to ``lwf``.
    """

    def __init__(
        self,
        name: str,
        kappa: int = 1,
        seed: int = 0,
        topology: Optional[Topology] = None,
    ) -> None:
        name = name.lower()
        if name not in ("rand", "ff", "ls", "lwf", "lwf_rack"):
            raise ValueError(f"unknown placement policy {name!r}")
        self.name = name
        self.kappa = kappa
        self.topology = topology
        self._rng = random.Random(seed)

    def _racks(self, cluster: Cluster) -> Tuple[Tuple[int, ...], ...]:
        if self.topology is not None:
            return self.topology.rack_groups()
        return (tuple(range(cluster.n_servers)),)

    def __call__(self, cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
        if self.name == "rand":
            return place_random(cluster, job, self._rng)
        if self.name == "ff":
            return place_first_fit(cluster, job)
        if self.name == "ls":
            return place_list_scheduling(cluster, job)
        if self.name == "lwf_rack":
            return place_lwf_rack(cluster, job, self._racks(cluster), self.kappa)
        return place_lwf(cluster, job, self.kappa)

    def __repr__(self) -> str:
        if self.name == "lwf":
            return f"LWF-{self.kappa}"
        if self.name == "lwf_rack":
            return f"LWF_RACK-{self.kappa}"
        return self.name.upper()
