"""Job placement algorithms (paper Section IV-A, Algorithm 1).

Given a job needing ``n`` GPUs and the current cluster state, pick the GPU
set G(J):

* ``RAND``  — uniformly random among memory-feasible GPUs (baseline).
* ``FF``    — First-Fit: first ``n`` feasible GPUs in (server, gpu) order.
* ``LS``    — List-Scheduling: top-``n`` feasible GPUs by least workload L_g.
* ``LWF-k`` — the paper's algorithm:   n <= kappa  ->  same as LS;
              n  > kappa  ->  sort *servers* by total workload L_S and take
              feasible GPUs server-by-server (consolidation), Alg. 1 lines
              10-21.

All functions return a list of GpuIds (len == n) or ``None`` when the job
cannot be admitted (Alg. 1 line 22 returns the empty set).  They never
mutate the cluster — the simulator commits via ``Cluster.place``.
"""

from __future__ import annotations

import random
from typing import List, Optional, Sequence

from repro.core.cluster import Cluster, GpuId, GpuState, JobSpec


def _feasible(cluster: Cluster, job: JobSpec) -> List[GpuState]:
    return cluster.available_gpus(job.model.mem_mb)


def place_random(cluster: Cluster, job: JobSpec, rng: random.Random) -> Optional[List[GpuId]]:
    avail = _feasible(cluster, job)
    if len(avail) < job.n_gpus:
        return None
    return [g.gpu_id for g in rng.sample(avail, job.n_gpus)]


def place_first_fit(cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
    avail = sorted(_feasible(cluster, job), key=lambda g: g.gpu_id)
    if len(avail) < job.n_gpus:
        return None
    return [g.gpu_id for g in avail[: job.n_gpus]]


def place_list_scheduling(cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
    avail = _feasible(cluster, job)
    if len(avail) < job.n_gpus:
        return None
    avail.sort(key=lambda g: (g.workload, g.gpu_id))
    return [g.gpu_id for g in avail[: job.n_gpus]]


def place_lwf(cluster: Cluster, job: JobSpec, kappa: int = 1) -> Optional[List[GpuId]]:
    """Algorithm 1 (LWF-kappa)."""
    n = job.n_gpus
    if n <= kappa:
        # Lines 2-9: global least-workload-first (identical to LS).
        return place_list_scheduling(cluster, job)
    # Lines 10-21: consolidate — least-loaded servers first, then their
    # feasible GPUs sorted by workload, appended server by server.
    servers = sorted(
        range(cluster.n_servers), key=lambda s: (cluster.server_workload(s), s)
    )
    ordered: List[GpuState] = []
    for s in servers:
        gpus = [
            g
            for g in cluster.gpus_of_server(s)
            if g.mem_free_mb() >= job.model.mem_mb
        ]
        gpus.sort(key=lambda g: (g.workload, g.gpu_id))
        ordered.extend(gpus)
    if len(ordered) < n:
        return None
    return [g.gpu_id for g in ordered[:n]]


class PlacementPolicy:
    """Callable wrapper so the simulator takes one pluggable object."""

    def __init__(self, name: str, kappa: int = 1, seed: int = 0) -> None:
        name = name.lower()
        if name not in ("rand", "ff", "ls", "lwf"):
            raise ValueError(f"unknown placement policy {name!r}")
        self.name = name
        self.kappa = kappa
        self._rng = random.Random(seed)

    def __call__(self, cluster: Cluster, job: JobSpec) -> Optional[List[GpuId]]:
        if self.name == "rand":
            return place_random(cluster, job, self._rng)
        if self.name == "ff":
            return place_first_fit(cluster, job)
        if self.name == "ls":
            return place_list_scheduling(cluster, job)
        return place_lwf(cluster, job, self.kappa)

    def __repr__(self) -> str:
        if self.name == "lwf":
            return f"LWF-{self.kappa}"
        return self.name.upper()
