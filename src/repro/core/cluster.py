"""Cluster, server, GPU and job state for the scheduling framework
(paper Section III, Table II notation).

The cluster is ``N_s`` servers x ``N_g`` GPUs; each GPU has a memory
capacity and may host several *resident* jobs (admission by memory,
Alg. 1 line 3) that time-share it at task granularity.  Each server's
network is one contention domain shared by the communication tasks of the
jobs that span servers (Eq. 5).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# Job descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Measured per-model constants (paper Table III, Tesla V100, PyTorch).

    ``t_f``/``t_b`` are seconds per iteration at the listed batch size;
    ``size_bytes`` is the model (gradient message) size; ``mem_mb`` the GPU
    memory footprint used for admission.

    ``layer_grad_bytes``/``layer_t_b`` (beyond-paper, WFBP subsystem)
    optionally resolve the gradient message and the backward pass to layer
    granularity, in *backward-ready* order (output layer first — the order
    gradients materialize during backprop), so the simulators can overlap
    per-bucket all-reduces with the remaining backward compute
    (``repro.workloads`` derives them from real model configs).  Empty
    tuples (the paper's Table III profiles) mean the monolithic
    iteration-level model.  Invariants when present:
    ``sum(layer_grad_bytes) == size_bytes`` and ``sum(layer_t_b) == t_b``.
    """

    name: str
    size_bytes: float
    mem_mb: float
    batch_size: int
    t_f: float
    t_b: float
    layer_grad_bytes: Tuple[float, ...] = ()
    layer_t_b: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if len(self.layer_grad_bytes) != len(self.layer_t_b):
            raise ValueError(
                f"{self.name}: layer_grad_bytes ({len(self.layer_grad_bytes)}) "
                f"and layer_t_b ({len(self.layer_t_b)}) must align"
            )

    @property
    def t_iter_compute(self) -> float:
        return self.t_f + self.t_b

    @property
    def has_layers(self) -> bool:
        return bool(self.layer_grad_bytes)


# Paper Table III.
TABLE_III = {
    "vgg16": ModelProfile("vgg16", 526.4e6, 4527.0, 16, 35.8e-3, 53.7e-3),
    "resnet50": ModelProfile("resnet50", 99.2e6, 3213.0, 16, 25.0e-3, 37.4e-3),
    "inception_v3": ModelProfile("inception_v3", 103.0e6, 3291.0, 16, 34.9e-3, 52.4e-3),
    "lstm_ptb": ModelProfile("lstm_ptb", 251.8e6, 2751.0, 64, 31.5e-3, 47.3e-3),
}


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One DDL training job (Table II: A_k, |G(J_k)|, I_k and the model).

    ``min_gpus``/``max_gpus`` (beyond-paper, elastic scheduling) optionally
    declare the job elastic: its total work is fixed in *samples*
    (``iterations x n_gpus`` per-GPU batches) and an elastic scheduling
    policy (``core/schedpolicy.ElasticPolicy``) may run it at any world
    size within the bounds, resizing at iteration boundaries.  ``None``
    (default) = the paper's rigid gang of exactly ``n_gpus``.
    """

    job_id: int
    arrival: float
    n_gpus: int
    iterations: int
    model: ModelProfile
    min_gpus: Optional[int] = None
    max_gpus: Optional[int] = None

    def __post_init__(self) -> None:
        lo, hi = self.gpu_bounds
        if not (1 <= lo <= self.n_gpus <= hi):
            raise ValueError(
                f"job {self.job_id}: elastic bounds must satisfy "
                f"1 <= min_gpus <= n_gpus <= max_gpus, got "
                f"({self.min_gpus}, {self.n_gpus}, {self.max_gpus})"
            )

    @property
    def gpu_bounds(self) -> "Tuple[int, int]":
        """(lo, hi) world-size bounds; unset bounds default to the rigid
        ``n_gpus`` — the ONE place the defaulting rule lives."""
        lo = self.min_gpus if self.min_gpus is not None else self.n_gpus
        hi = self.max_gpus if self.max_gpus is not None else self.n_gpus
        return lo, hi

    @property
    def is_elastic(self) -> bool:
        return self.gpu_bounds != (self.n_gpus, self.n_gpus)

    @property
    def total_samples(self) -> int:
        """Total work in per-GPU batches: elastic resizes conserve this."""
        return self.iterations * self.n_gpus

    @property
    def compute_time(self) -> float:
        """C_J (Eq. 7): total compute time of the whole job."""
        return self.model.t_iter_compute * self.iterations

    def comm_time(self, n_servers: int, a: float, b: float) -> float:
        """E_J (Eq. 8): total contention-free communication time."""
        if n_servers <= 1:
            return 0.0
        return (a + b * self.model.size_bytes) * self.iterations

    def initial_workload(self, n_servers_hint: int, a: float, b: float) -> float:
        """L_J = (C_J + E_J) * |G(J)| (Alg. 1/3 initialization).  The paper
        sets E_J = 0 before placement (servers unknown); pass
        ``n_servers_hint=1`` for that convention."""
        return (self.compute_time + self.comm_time(n_servers_hint, a, b)) * self.n_gpus


# ---------------------------------------------------------------------------
# Cluster state
# ---------------------------------------------------------------------------

GpuId = Tuple[int, int]  # (server index, gpu index)


@dataclasses.dataclass
class GpuState:
    """One GPU: memory admission + remaining-workload bookkeeping (L_g)."""

    server: int
    index: int
    mem_capacity_mb: float
    mem_used_mb: float = 0.0
    #: Remaining workload assigned to this GPU, Alg. 1's L_{g_{i,j}} —
    #: maintained by the simulator as jobs are placed and progress.
    workload: float = 0.0
    #: Job ids resident on this GPU (admitted by memory).
    resident_jobs: Set[int] = dataclasses.field(default_factory=set)
    #: Busy with a compute task until this time (None = idle).
    busy_until: Optional[float] = None
    busy_job: Optional[int] = None
    #: Total busy seconds accumulated (for the utilization metric).
    busy_accum: float = 0.0
    #: Server is broken down (fault injection, core/chaos.py): excluded
    #: from every placement and from compute scheduling until repair.
    down: bool = False

    @property
    def gpu_id(self) -> GpuId:
        return (self.server, self.index)

    def mem_free_mb(self) -> float:
        return self.mem_capacity_mb - self.mem_used_mb


class Cluster:
    """N_s servers x N_g GPUs with per-server shared network (one 10GbE NIC
    per server in the paper; one DCN uplink per pod-host in the TPU port)."""

    def __init__(
        self,
        n_servers: int = 16,
        gpus_per_server: int = 4,
        gpu_mem_mb: float = 16160.0,
    ) -> None:
        self.n_servers = n_servers
        self.gpus_per_server = gpus_per_server
        self.gpus: Dict[GpuId, GpuState] = {
            (s, g): GpuState(s, g, gpu_mem_mb)
            for s in range(n_servers)
            for g in range(gpus_per_server)
        }
        # The structure is static (GpuState objects mutate, the grouping
        # never does): build the per-server lists once — gpus_of_server is
        # the innermost call of every LWF placement scan.
        self._server_gpus: List[List[GpuState]] = [
            [self.gpus[(s, g)] for g in range(gpus_per_server)]
            for s in range(n_servers)
        ]
        #: bumped whenever placeable capacity can have *grown* (release,
        #: server repair).  Placement feasibility of a resource profile is
        #: monotone between bumps — placing jobs only shrinks the feasible
        #: set — so a failed-placement memo keyed on this epoch stays valid
        #: across events (StaticGangPolicy._place_queue).
        self.capacity_epoch: int = 0

    # -- queries -------------------------------------------------------------
    def gpu(self, gpu_id: GpuId) -> GpuState:
        return self.gpus[gpu_id]

    def all_gpu_ids(self) -> List[GpuId]:
        return list(self.gpus.keys())

    def gpus_of_server(self, server: int) -> List[GpuState]:
        """Per-server GpuState list (shared cached list — do not mutate)."""
        return self._server_gpus[server]

    def server_workload(self, server: int) -> float:
        """L_{S_i} = sum_j L_{g_{i,j}}."""
        return sum(g.workload for g in self.gpus_of_server(server))

    #: when True, a GPU may host at most one job (paper assumption 3:
    #: "Each GPU can only be occupied by one job at any time slot"); when
    #: False, jobs share GPUs by memory admission (the Alg. 1 line-3 /
    #: Alg. 3 line-25 reading).  Both readings have textual support — the
    #: simulator exposes both (EXPERIMENTS.md §Reproduction).
    exclusive: bool = False

    def available_gpus(self, mem_required_mb: float) -> List[GpuState]:
        """GPUs with enough *rest* memory (Alg. 1 lines 3/14)."""
        return [
            g
            for g in self.gpus.values()
            if not g.down
            and g.mem_free_mb() >= mem_required_mb
            and not (self.exclusive and g.resident_jobs)
        ]

    def servers_of(self, gpu_ids: Sequence[GpuId]) -> Set[int]:
        return {s for (s, _) in gpu_ids}

    # -- mutation ------------------------------------------------------------
    def place(self, job: JobSpec, gpu_ids: Sequence[GpuId], workload_share: float) -> None:
        """Commit a placement: admit memory and add workload L_J to each GPU
        (Alg. 1 lines 6/18 add the *job's* workload to every chosen GPU)."""
        for gid in gpu_ids:
            g = self.gpus[gid]
            if g.mem_free_mb() < job.model.mem_mb:
                raise RuntimeError(f"placement violates memory on {gid}")
            g.mem_used_mb += job.model.mem_mb
            g.workload += workload_share
            g.resident_jobs.add(job.job_id)

    def release(self, job: JobSpec, gpu_ids: Sequence[GpuId]) -> None:
        for gid in gpu_ids:
            g = self.gpus[gid]
            g.mem_used_mb -= job.model.mem_mb
            g.resident_jobs.discard(job.job_id)
        self.capacity_epoch += 1
