"""AdaDUAL — adaptive scheduling of communication tasks (paper Section IV-B).

The paper proves (Theorems 1-2) the optimal policy for two communication
tasks on the contended-link model of Eq. (5):

* Two tasks become ready together (or the new task is *larger* than what is
  left of the running one): run the smaller to completion first, then the
  larger (no contention is optimal) — Theorem 1.
* A new task of size ``M_new`` arrives while one task with remaining size
  ``M_old`` is in flight: start it immediately (accepting 2-way contention)
  iff ``M_new / M_old < b / (2*(b + eta))`` — Theorem 2.
* Against >= 2 in-flight tasks the paper always waits (k>2 contention
  empirically destroys bandwidth efficiency).

This module implements the decision rule (:func:`adadual_should_start`), the
closed forms of the three candidate minima of Eq. (14) used by the property
tests, an exact tiny-system integrator (:func:`simulate_two_tasks`,
:func:`simulate_task_set`) used both to *verify* the theorems numerically and
to power our beyond-paper k-way generalization
(:func:`kway_adadual_should_start`), which the paper leaves as future work.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

from repro.core import netmodel
from repro.core.contention import ContentionParams

# ---------------------------------------------------------------------------
# Closed forms from the paper (Eqs. 10-14), used by tests.
# ---------------------------------------------------------------------------


def c1_average_completion(t: float, m1: float, m2: float, p: ContentionParams) -> float:
    """Eq. (10c): average completion when the *small* task c1 starts at 0 and
    c2 starts at ``t`` in [0, b*M1].  (Latency ``a`` neglected, as in P1.)"""
    b, eta = p.b, p.eta
    return (-(1.0 + 2.0 * eta / b) * t + (3.0 * b + 2.0 * eta) * m1 + b * m2) / 2.0


def c2a_average_completion(t: float, m1: float, m2: float, p: ContentionParams) -> float:
    """Eq. (11c): c2 (large) starts at 0, c1 starts at t in [0, b*(M2-M1)]."""
    b, eta = p.b, p.eta
    return (t + (3.0 * b + 2.0 * eta) * m1 + b * m2) / 2.0


def c2b_average_completion(t: float, m1: float, m2: float, p: ContentionParams) -> float:
    """Eq. (12c): c2 starts at 0, c1 starts at t in (b*(M2-M1), b*M2]."""
    b, eta = p.b, p.eta
    return (-(1.0 + 2.0 * eta / b) * t + (3.0 * b + 2.0 * eta) * m2 + b * m1) / 2.0


def candidate_minima(m1: float, m2: float, p: ContentionParams) -> Tuple[float, float, float]:
    """Eq. (14): (t_C1, t_C2a, t_C2b) candidate minimum average completions."""
    b, eta = p.b, p.eta
    c1 = (2.0 * b * m1 + b * m2) / 2.0
    c2a = ((3.0 * b + 2.0 * eta) * m1 + b * m2) / 2.0
    c2b = (b * m1 + 2.0 * b * m2) / 2.0
    return c1, c2a, c2b


# ---------------------------------------------------------------------------
# The AdaDUAL decision rule (Algorithm 2).
# ---------------------------------------------------------------------------


def adadual_should_start(
    new_bytes: float,
    old_remaining_bytes: Sequence[float],
    max_concurrent: int,
    params: ContentionParams,
) -> bool:
    """Algorithm 2 decision: should the newly-ready communication task start
    at the current time slot?

    Args:
      new_bytes: message size of the new task.
      old_remaining_bytes: remaining sizes of the in-flight communication
        tasks on the servers the new task would touch (``C_old`` in Alg. 2).
      max_concurrent: ``max_task`` in Alg. 2 — the max number of in-flight
        communication tasks over those servers.
      params: the (a, b, eta) contention model.

    When ``max_concurrent == 1`` but several distinct in-flight tasks touch
    disjoint servers of the new task, the paper's Alg. 2 line 12 implicitly
    assumes a single old task; we apply Theorem 2 against *each* and start
    only if every test passes (conservative; documented in DESIGN.md) —
    equivalent to testing against the smallest remaining old size, which is
    how the shared predicate (``netmodel.may_start``) expresses it.
    """
    min_old = min(old_remaining_bytes, default=math.inf)
    return bool(
        netmodel.may_start(
            max_concurrent + 1,
            new_bytes,
            min_old,
            max_ways=2,
            threshold_gated=True,
            dual_threshold=params.dual_threshold,
        )
    )


# ---------------------------------------------------------------------------
# Exact integrator for a small set of contending tasks.
#
# This is an exact piecewise-constant-rate integration of Eq. (5) dynamics
# for tasks that all share one contention domain (every task counts every
# other as a contender, i.e. k = number of active tasks).  It is used to
# (a) numerically verify Theorems 1-2 against brute force over start times,
# and (b) implement the k-way lookahead policy.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Flight:
    idx: int
    remaining: float


def simulate_task_set(
    start_times: Sequence[float],
    sizes: Sequence[float],
    params: ContentionParams,
) -> List[float]:
    """Exact completion times for tasks sharing one contention domain.

    Task i becomes ready/starts at ``start_times[i]`` with ``sizes[i]`` bytes.
    While k tasks are in flight, each drains at ``1/(k*b + (k-1)*eta)`` B/s.
    Returns the list of completion times.  The fixed latency ``a`` is
    neglected, exactly as in the paper's problem P1.
    """
    n = len(sizes)
    assert len(start_times) == n
    events = sorted(range(n), key=lambda i: start_times[i])
    finish = [0.0] * n
    in_flight: List[_Flight] = []
    t = 0.0
    next_arrival = 0

    def rate(k: int) -> float:
        return params.rate(k)

    while next_arrival < n or in_flight:
        k = len(in_flight)
        # time to next arrival
        t_arr = start_times[events[next_arrival]] if next_arrival < n else float("inf")
        # time to next completion at current rate
        if k > 0:
            r = rate(k)
            min_rem = min(f.remaining for f in in_flight)
            t_fin = t + min_rem / r
        else:
            t_fin = float("inf")
        if t_arr <= t_fin:
            # advance to arrival
            if k > 0:
                drained = (t_arr - t) * rate(k)
                for f in in_flight:
                    f.remaining -= drained
            t = t_arr
            idx = events[next_arrival]
            in_flight.append(_Flight(idx, float(sizes[idx])))
            next_arrival += 1
        else:
            drained = (t_fin - t) * rate(k)
            if drained <= 0.0:
                # float underflow guard: the smallest remainder is too tiny
                # for `t + rem/rate` to advance the clock — force-drain it,
                # otherwise the loop cannot make progress.
                drained = min(f.remaining for f in in_flight)
            t = t_fin
            still: List[_Flight] = []
            for f in in_flight:
                f.remaining -= drained
                if f.remaining <= 1e-6:  # < 1e-6 bytes ~ femtoseconds
                    finish[f.idx] = t
                else:
                    still.append(f)
            in_flight = still
    return finish


def simulate_two_tasks(
    t_start_second: float, m_first: float, m_second: float, params: ContentionParams
) -> Tuple[float, float]:
    """Completion times (T_first, T_second) when the first task starts at 0
    and the second at ``t_start_second`` (problem P1's setting)."""
    f = simulate_task_set([0.0, t_start_second], [m_first, m_second], params)
    return f[0], f[1]


# ---------------------------------------------------------------------------
# Beyond-paper: k-way AdaDUAL (the paper's future-work item #2).
# ---------------------------------------------------------------------------


def kway_adadual_should_start(
    new_bytes: float,
    old_remaining_bytes: Sequence[float],
    params: ContentionParams,
    max_ways: int = 4,
) -> bool:
    """Decide start-now vs wait against k >= 1 in-flight tasks by exact
    lookahead on the Eq. (5) dynamics.

    Option A (start now): completion times of {olds..., new} all starting at
    the current instant (olds resume with their remaining bytes).
    Option B (wait): the new task starts when the *first* old task finishes
    and then contends with the survivors (one-step lookahead; the online
    scheduler re-evaluates the rule at every state change, so the effective
    policy is the fixed point of this one-step rule).

    Starts only if Option A's average completion time (over the new task and
    all in-flight tasks) is strictly smaller, and never exceeds ``max_ways``
    concurrent tasks (bandwidth efficiency collapse guard, mirroring the
    paper's empirical k<=2 observation but tunable).
    """
    olds = [m for m in old_remaining_bytes if m > 0]
    k = len(olds)
    if k == 0:
        return True
    if k + 1 > max_ways:
        return False
    avg_a, avg_b = kway_lookahead_costs(new_bytes, olds, params)
    return avg_a < avg_b


def kway_lookahead_costs(
    new_bytes: float,
    olds: Sequence[float],
    params: ContentionParams,
) -> Tuple[float, float]:
    """The two evaluated averages of the k-way rule: ``(avg_start_now,
    avg_wait)`` over {olds..., new}.  Factored out of the decision so the
    observability audit log can record exactly what the policy compared.
    ``olds`` must be non-empty with positive remaining bytes."""
    k = len(olds)
    # Option A: everything in flight now.
    now = [0.0] * (k + 1)
    sizes_a = list(olds) + [new_bytes]
    fin_a = simulate_task_set(now, sizes_a, params)
    avg_a = sum(fin_a) / len(fin_a)

    # Option B: olds run contended among themselves; new starts when the first
    # old finishes, then (recursively) contends with the survivors.
    fin_olds = simulate_task_set([0.0] * k, olds, params)
    t_first = min(fin_olds)
    # Remaining bytes of the surviving olds at t_first: all k contended from
    # 0 to t_first, so each drained exactly the smallest task's bytes.
    # (``t_first * rate(k)`` recomputes the same quantity through a
    # division/multiplication round-trip whose float noise used to leave a
    # ~1e-8-byte ghost survivor that was *also* counted as finished,
    # skewing borderline decisions — use the exact value instead and keep
    # done/survivors an exact partition of the olds.)
    drained = min(olds)
    survivors = [m - drained for m in olds if m - drained > 1e-9]
    start_b = [0.0] * len(survivors) + [0.0]
    fin_b_rel = simulate_task_set(start_b, survivors + [new_bytes], params)
    # olds that finished at t_first (ties with the smallest included):
    n_done = k - len(survivors)
    avg_b = (
        n_done * t_first + sum(t_first + f for f in fin_b_rel)
    ) / (n_done + len(fin_b_rel))
    return avg_a, avg_b


def srsf_n_should_start(
    max_concurrent: int,
    n: int,
) -> bool:
    """SRSF(n) baseline gating: start iff the resulting contention on every
    touched server stays <= n (SRSF(1) = avoid all contention; SRSF(2)/(3)
    blindly accept 2-/3-way contention)."""
    return bool(
        netmodel.may_start(
            max_concurrent + 1,
            0.0,
            math.inf,
            max_ways=n,
            threshold_gated=False,
            dual_threshold=0.0,
        )
    )
