"""Communication cost and contention models (paper Sections II-B, III-A2).

Two models:

* Eq. (2): contention-free All-Reduce time  ``T_ar = a + b*M``.
* Eq. (5): k-way contended All-Reduce time ``T_ar(k) = a + k*b*M + (k-1)*eta*M``
  where ``k`` is the maximum number of concurrently running communication
  tasks over all servers the task touches.  ``k*b*M`` models fair bandwidth
  sharing; ``(k-1)*eta*M`` is the super-linear contention penalty the paper
  measures on 10 GbE.

Table I of the paper (cost of classic All-Reduce algorithms in the
alpha-beta-gamma model) is provided by :func:`allreduce_cost_terms` so the
simulator can be parameterized by algorithm instead of only by the fitted
``(a, b)`` constants.

Everything here is a pure function of its arguments so it can be used both
from the Python event-driven simulator and from the vectorized JAX simulator
(``core/jaxsim.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Measured constants (paper Section III-A2, Fig. 2(a); 10 GbE, ring all-reduce)
# ---------------------------------------------------------------------------

#: Latency component fitted on real hardware [s].
PAPER_A = 6.69e-4
#: Per-byte transmission time fitted on real hardware [s/B] (~= 9.4 Gbps eff).
PAPER_B = 8.53e-10
#: Contention penalty per byte [s/B].  The paper plots the k-sweep (Fig. 2(b))
#: but never prints eta.  Calibration finding (EXPERIMENTS.md §Reproduction):
#: Ada-SRSF's pairwise-optimal gating is globally beneficial only for mild
#: eta — at eta >= b the externality on queued third tasks flips the
#: Ada-vs-SRSF(1) ordering on the paper workload; the paper's +20% claim is
#: therefore consistent with a small measured eta.  Default eta = 0.2*b
#: (threshold 0.417): reproduces SRSF(1)'s absolute avg JCT within 2% of the
#: paper's Table V and Ada-SRSF's improvement direction.  Exposed everywhere
#: as a parameter; benchmarks and EXPERIMENTS.md sweep it.
DEFAULT_ETA = 1.706e-10

#: TPU-pod flavoured constants used by the multi-job launcher demo: DCN-ish
#: latency and per-byte time for a 2-pod v5e slice (25 GB/s effective per host
#: pair).  Contention across pods behaves like the paper's shared NIC.
TPU_DCN_A = 2.0e-5
TPU_DCN_B = 4.0e-11
TPU_DCN_ETA = 8.0e-12


@dataclasses.dataclass(frozen=True)
class ContentionParams:
    """Parameters (a, b, eta) of the contended All-Reduce model, Eq. (5).

    ``server_bandwidth`` (beyond-paper, scenario engine) optionally assigns
    each server a relative NIC bandwidth multiplier (1.0 = nominal ``1/b``).
    A communication task spanning several servers drains at the rate of its
    slowest member; servers beyond the tuple's length are nominal.  Empty
    tuple (default) = homogeneous network, exactly the paper's model.
    """

    a: float = PAPER_A
    b: float = PAPER_B
    eta: float = DEFAULT_ETA
    server_bandwidth: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if self.b <= 0:
            raise ValueError(f"b must be positive, got {self.b}")
        if self.a < 0 or self.eta < 0:
            raise ValueError("a and eta must be non-negative")
        if any(s <= 0 for s in self.server_bandwidth):
            raise ValueError("server_bandwidth multipliers must be positive")

    def bandwidth_scale(self, servers) -> float:
        """Relative drain-rate multiplier for a task touching ``servers``:
        the slowest member NIC bottlenecks the ring."""
        if not self.server_bandwidth:
            return 1.0
        n = len(self.server_bandwidth)
        return min((self.server_bandwidth[s] if s < n else 1.0) for s in servers)

    def mean_bandwidth_scale(self, n_servers: int) -> float:
        """Cluster-mean multiplier — the homogeneous-network equivalent.

        Kept as a diagnostic/summary statistic; the fluid (JAX) backend now
        models per-server rates directly (``core/netmodel.py``) and no
        longer collapses heterogeneity to this mean.  ``n_servers <= 0``
        returns the nominal 1.0.
        """
        if not self.server_bandwidth or n_servers <= 0:
            return 1.0
        n = len(self.server_bandwidth)
        return sum(
            (self.server_bandwidth[s] if s < n else 1.0) for s in range(n_servers)
        ) / n_servers

    # -- Eq. (5) -----------------------------------------------------------
    def allreduce_time(self, message_bytes: float, k: int = 1) -> float:
        """Total time of one All-Reduce of ``message_bytes`` under k-way
        contention (Eq. 5).  ``k=1`` reduces to Eq. (2)."""
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        return self.a + (k * self.b + (k - 1) * self.eta) * message_bytes

    def rate(self, k: float) -> float:
        """Instantaneous drain rate [B/s] of one task under k-way contention.

        Derived from Eq. (5): transferring M bytes takes
        ``(k*b + (k-1)*eta) * M`` seconds (excluding the one-off latency a),
        so each byte costs ``k*b + (k-1)*eta`` seconds.  ``k`` may be a
        float >= 1: the topology layer (``core/topology.py``) evaluates
        Eq. (5) at the *effective* contention ``k_raw * oversub`` of an
        oversubscribed domain.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        return 1.0 / (k * self.b + (k - 1) * self.eta)

    def seconds_per_byte(self, k: float) -> float:
        return k * self.b + (k - 1) * self.eta

    # -- AdaDUAL threshold (Theorem 2) --------------------------------------
    @property
    def dual_threshold(self) -> float:
        """``b / (2*(b + eta))`` — Theorem 2's ratio test.  A newly-ready task
        of size M_new should start against one existing task with remaining
        size M_old iff ``M_new / M_old < dual_threshold``."""
        return self.b / (2.0 * (self.b + self.eta))


# ---------------------------------------------------------------------------
# Table I — All-Reduce algorithm costs in the (alpha, beta, gamma) model
# ---------------------------------------------------------------------------

ALLREDUCE_ALGORITHMS = (
    "binary_tree",
    "recursive_doubling",
    "recursive_halving_doubling",
    "ring",
)


def allreduce_cost_terms(
    algorithm: str, n_nodes: int, alpha: float, beta: float, gamma: float
) -> Tuple[float, float]:
    """Return ``(a, b)`` of ``T = a + b*M`` for a classic All-Reduce algorithm
    (paper Table I).

    alpha: per-message latency [s]; beta: per-byte transfer time [s/B];
    gamma: per-byte reduction compute time [s/B]; n_nodes: number of nodes
    (power of two assumed by the paper).
    """
    if n_nodes < 2:
        return (0.0, 0.0)
    log_n = math.log2(n_nodes)
    n = float(n_nodes)
    if algorithm == "binary_tree":
        return (2 * alpha * log_n, (2 * beta + gamma) * log_n)
    if algorithm == "recursive_doubling":
        return (alpha * log_n, (beta + gamma) * log_n)
    if algorithm == "recursive_halving_doubling":
        return (2 * alpha * log_n, 2 * beta - (2 * beta + gamma) / n + gamma)
    if algorithm == "ring":
        return (
            2 * (n - 1) * alpha,
            2 * (n - 1) / n * beta + (n - 1) / n * gamma,
        )
    raise ValueError(
        f"unknown all-reduce algorithm {algorithm!r}; "
        f"expected one of {ALLREDUCE_ALGORITHMS}"
    )


# ---------------------------------------------------------------------------
# Model fitting (reproduces the Fig. 2(a) fit) — offline, float64 numpy.
# ---------------------------------------------------------------------------


def fit_linear_cost(message_bytes, times) -> Tuple[float, float]:
    """Least-squares fit of ``T = a + b*M`` (Fig. 2(a)).  Returns (a, b).

    float64 numpy: the design matrix columns span ~12 orders of magnitude
    (1 vs bytes), far beyond f32 conditioning; this is offline calibration,
    not part of a jitted path.
    """
    m = np.asarray(message_bytes, dtype=np.float64)
    t = np.asarray(times, dtype=np.float64)
    b, a = np.polyfit(m, t, 1)
    return float(a), float(b)


def fit_contention_penalty(ks, times, message_bytes: float, a: float, b: float) -> float:
    """Fit eta from a k-sweep at fixed message size (Fig. 2(b)).

    Model: T(k) = a + k*b*M + (k-1)*eta*M  ->  eta from least squares over k>1.
    """
    ks = np.asarray(ks, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    resid = times - (a + ks * b * message_bytes)
    x = (ks - 1.0) * message_bytes
    mask = ks > 1
    if not mask.any():
        return 0.0
    eta = float(np.dot(x[mask], resid[mask]) / np.dot(x[mask], x[mask]))
    return max(eta, 0.0)


def simulate_contention_sweep(
    params: ContentionParams, message_bytes: float, max_k: int
) -> np.ndarray:
    """Average per-task completion time for k identical concurrent tasks
    (the Fig. 2(b) experiment shape): all k tasks share every link, so each
    sees k-way contention for its entire transfer."""
    return np.asarray(
        [params.allreduce_time(message_bytes, k) for k in range(1, max_k + 1)]
    )
