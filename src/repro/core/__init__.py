"""Core of the paper's contribution: communication-contention-aware
scheduling of multiple DDL training jobs (LWF-kappa, AdaDUAL, Ada-SRSF)."""

from repro.core.adadual import (
    adadual_should_start,
    kway_adadual_should_start,
    simulate_task_set,
    simulate_two_tasks,
)
from repro.core.cluster import TABLE_III, Cluster, JobSpec, ModelProfile
from repro.core.contention import (
    DEFAULT_ETA,
    PAPER_A,
    PAPER_B,
    ContentionParams,
    allreduce_cost_terms,
    fit_linear_cost,
)
from repro.core.netmodel import PolicySpec, may_start, parse_policy, preemption_cost
from repro.core.placement import PlacementPolicy
from repro.core.topology import Domain, Topology, nic_topology, two_tier, uplink_only
from repro.core.engine import EventEngine
from repro.core.schedpolicy import (
    ElasticPolicy,
    PreemptiveSrsfPolicy,
    SchedPolicy,
    StaticGangPolicy,
    sched_policy_from_name,
)
from repro.core.simulator import (
    AdaDual,
    ClusterSimulator,
    CommPolicy,
    KWayAdaDual,
    SimResult,
    SrsfN,
    simulate,
)
from repro.core.trace import paper_trace

__all__ = [
    "adadual_should_start",
    "kway_adadual_should_start",
    "simulate_task_set",
    "simulate_two_tasks",
    "TABLE_III",
    "Cluster",
    "JobSpec",
    "ModelProfile",
    "DEFAULT_ETA",
    "PAPER_A",
    "PAPER_B",
    "ContentionParams",
    "allreduce_cost_terms",
    "fit_linear_cost",
    "PolicySpec",
    "may_start",
    "parse_policy",
    "preemption_cost",
    "PlacementPolicy",
    "EventEngine",
    "ElasticPolicy",
    "PreemptiveSrsfPolicy",
    "SchedPolicy",
    "StaticGangPolicy",
    "sched_policy_from_name",
    "Domain",
    "Topology",
    "nic_topology",
    "two_tier",
    "uplink_only",
    "AdaDual",
    "ClusterSimulator",
    "CommPolicy",
    "KWayAdaDual",
    "SimResult",
    "SrsfN",
    "simulate",
    "paper_trace",
]
