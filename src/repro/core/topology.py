"""Pluggable network-fabric layer: contention domains beyond the server NIC.

The paper's Eq. (5) models contention only at the server NIC — every
communication task crossing a server loads that server's one shared 10 GbE
port.  Real fabrics add more shared resources: rack (ToR) uplinks, blocking
two-tier switches with an oversubscription factor.  This module lifts the
hard-coded NIC model into a declarative :class:`Topology` both simulation
backends consume:

* a **domain** is a *cut* of the fabric — a server set whose boundary is a
  shared resource.  A communication task with member-server set ``S`` loads
  domain ``D`` iff its ring crosses the cut: ``S ∩ D ≠ ∅ and S ∖ D ≠ ∅``.
  A per-server NIC is the cut around that single server, so the NIC-only
  topology reproduces the paper's model *exactly* (locked by regression
  tests in ``tests/test_topology.py``).
* each domain carries an ``oversub`` factor ``f ≥ 1``: the cut's usable
  bandwidth is ``1/f`` of a nominal NIC, so ``k`` tasks sharing it drain at
  the Eq. (5) rate evaluated at the *effective* contention ``k·f``
  (``netmodel.rate`` accepts float k).  Gating policies keep counting raw
  contenders ``k`` — AdaDUAL's Theorem 2 reasons about task counts, not
  link capacity.

The event backend (``core/simulator.py``) queries :meth:`Topology.
loaded_domains` per task; the fluid backend (``core/jaxsim.py``) lowers the
same rule to a static ``[domains, servers]`` incidence matrix
(:meth:`Topology.incidence`) so the per-step contention state stays
branchless and vmap-safe.  Constructors:

* :func:`nic_topology` — one domain per server NIC (the paper's model);
* :func:`two_tier` — NIC domains plus one oversubscribed uplink domain per
  rack (a blocking two-tier leaf/spine fabric);
* :func:`uplink_only` — rack uplinks without NIC domains (intra-rack
  traffic contention-free; an idealized full-bisection leaf).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Domain:
    """One contention domain: the cut around ``servers``.

    ``oversub`` is the oversubscription factor of the shared resource at
    the cut (1.0 = a full-bandwidth NIC; an uplink with ``oversub=3`` has a
    third of nominal bandwidth, so k tasks crossing it behave like ``3k``
    tasks on a NIC).
    """

    name: str
    servers: Tuple[int, ...]
    oversub: float = 1.0

    def __post_init__(self) -> None:
        if not self.servers:
            raise ValueError(f"domain {self.name!r} covers no servers")
        if self.oversub <= 0:
            raise ValueError(
                f"domain {self.name!r}: oversub must be positive, got {self.oversub}"
            )
        object.__setattr__(self, "servers", tuple(sorted(set(self.servers))))


@dataclasses.dataclass(frozen=True)
class Topology:
    """A network fabric as a tuple of contention domains.

    Frozen and built from tuples only, so instances are hashable (they ride
    inside ``JaxSimConfig`` as a jit-static argument) and picklable (they
    cross the sweep runner's multiprocessing boundary).

    ``racks`` optionally groups servers for locality-aware placement
    (``PlacementPolicy('lwf_rack')`` / the fluid ``rack_pack`` gang mode);
    empty means one rack containing every server.
    """

    name: str
    n_servers: int
    domains: Tuple[Domain, ...]
    racks: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self) -> None:
        if self.n_servers < 1:
            raise ValueError(f"n_servers must be >= 1, got {self.n_servers}")
        for d in self.domains:
            if d.servers[0] < 0 or d.servers[-1] >= self.n_servers:
                raise ValueError(
                    f"domain {d.name!r} references servers outside "
                    f"[0, {self.n_servers}): {d.servers}"
                )
        seen: set = set()
        for rack in self.racks:
            for s in rack:
                if s in seen:
                    raise ValueError(f"server {s} appears in two racks")
                if not 0 <= s < self.n_servers:
                    raise ValueError(f"rack server {s} out of range")
                seen.add(s)

    @property
    def n_domains(self) -> int:
        return len(self.domains)

    # -- the one load rule -------------------------------------------------
    def loaded_domains(self, servers: Iterable[int]) -> frozenset:
        """Indices of the domains a comm task with member-server set
        ``servers`` loads: the cuts its ring crosses (members both inside
        and outside).  A single-server task crosses no cut and loads
        nothing."""
        s = set(servers)
        return frozenset(
            i
            for i, d in enumerate(self.domains)
            if not s.isdisjoint(d.servers) and not s.issubset(d.servers)
        )

    def oversub_of(self, domain_index: int) -> float:
        return self.domains[domain_index].oversub

    # -- dense forms for the fluid backend ---------------------------------
    def incidence(self) -> np.ndarray:
        """Static ``(n_domains, n_servers)`` float incidence matrix:
        ``inc[d, s] = 1`` iff server s is inside domain d's cut.  The fluid
        backend derives per-step loads branchlessly as
        ``(m @ inc.T > 0) & (m @ (1-inc).T > 0)`` for occupancy mask m."""
        inc = np.zeros((self.n_domains, self.n_servers), dtype=np.float32)
        for i, d in enumerate(self.domains):
            inc[i, list(d.servers)] = 1.0
        return inc

    def oversub_array(self) -> np.ndarray:
        return np.asarray([d.oversub for d in self.domains], dtype=np.float32)

    # -- rack helpers for locality-aware placement -------------------------
    def rack_groups(self) -> Tuple[Tuple[int, ...], ...]:
        """Rack server groups; servers not assigned to any rack form one
        trailing catch-all rack (so every server has a rack)."""
        if not self.racks:
            return (tuple(range(self.n_servers)),)
        assigned = {s for rack in self.racks for s in rack}
        rest = tuple(s for s in range(self.n_servers) if s not in assigned)
        return self.racks + ((rest,) if rest else ())

    def server_rack(self) -> np.ndarray:
        """``(n_servers,)`` int array: rack index of each server."""
        out = np.zeros((self.n_servers,), dtype=np.int32)
        for r, rack in enumerate(self.rack_groups()):
            out[list(rack)] = r
        return out


@dataclasses.dataclass(frozen=True)
class RingEdgeTopology(Topology):
    """The legacy ``contention_domain="link"`` reading — the paper's "each
    link between two nodes" wording — expressed as *dynamic* topology
    domains (closes the PR 3 ROADMAP leftover).

    A comm task over member-server set ``S`` loads the edges of the ring
    over ``sorted(S)``; two tasks contend iff they share a ring edge, so
    transfers over disjoint edge sets proceed in parallel even when they
    touch a common server.  Unlike the static fabric cuts above, the
    domains depend on the member set itself (the ring over {0,1,2} uses
    edge (0,2), the ring over {0,2,5} uses (0,5)), so there is no static
    incidence matrix: :meth:`incidence` raises, and the fluid backend
    cannot lower this reading (documented in the parity matrix).  Domains
    are ``("edge", u, v)`` tuples at unit oversubscription.
    """

    def __init__(self, n_servers: int) -> None:
        # bypass Topology's tuple-of-domains plumbing: domains are dynamic
        object.__setattr__(self, "name", "ring_edges")
        object.__setattr__(self, "n_servers", n_servers)
        object.__setattr__(self, "domains", ())
        object.__setattr__(self, "racks", ())
        Topology.__post_init__(self)

    @staticmethod
    def ring_edges(servers: Iterable[int]) -> frozenset:
        """The *directed* ring edges of a member-server set: consecutive
        pairs of the sorted ring, wrap-around included — exactly the edge
        set the event simulator used inline before this class existed.
        Direction matters: a ring all-reduce sends one way around the ring,
        so opposite directions of a full-duplex link are distinct domains
        (a 2-server ring loads both)."""
        ring = sorted(set(servers))
        return frozenset(
            ("edge", ring[i], ring[(i + 1) % len(ring)])
            for i in range(len(ring))
        )

    def loaded_domains(self, servers: Iterable[int]) -> frozenset:
        s = {x for x in servers if not 0 <= x < self.n_servers}
        if s:
            raise ValueError(f"servers {sorted(s)} outside [0, {self.n_servers})")
        members = set(servers)
        if len(members) < 2:
            return frozenset()  # single-server task: no shared link loaded
        return self.ring_edges(members)

    def oversub_of(self, domain) -> float:
        return 1.0  # every ring edge is a full-bandwidth link

    def incidence(self) -> np.ndarray:
        raise NotImplementedError(
            "ring-edge domains depend on each task's member set; there is no "
            "static [domains, servers] incidence matrix — the fluid backend "
            "does not support the legacy 'link' reading (use uplink_only/"
            "two_tier fabrics instead)"
        )


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------


def nic_topology(n_servers: int) -> Topology:
    """The paper's model: one full-bandwidth NIC domain per server."""
    return Topology(
        name="nic",
        n_servers=n_servers,
        domains=tuple(
            Domain(name=f"nic{s}", servers=(s,)) for s in range(n_servers)
        ),
    )


def _rack_partition(n_servers: int, servers_per_rack: int) -> List[Tuple[int, ...]]:
    if servers_per_rack < 1:
        raise ValueError(f"servers_per_rack must be >= 1, got {servers_per_rack}")
    return [
        tuple(range(lo, min(lo + servers_per_rack, n_servers)))
        for lo in range(0, n_servers, servers_per_rack)
    ]


def two_tier(
    n_servers: int,
    servers_per_rack: int,
    oversub: float = 3.0,
    name: str = "",
) -> Topology:
    """Blocking two-tier fabric: per-server NIC domains plus one uplink
    domain per rack with oversubscription factor ``oversub``.  Cross-rack
    traffic loads the uplinks of every rack it touches; intra-rack traffic
    only the NICs.  With a single rack (``servers_per_rack >= n_servers``)
    the uplink is never a cut boundary, so the fabric degenerates to the
    NIC-only model (tested)."""
    racks = _rack_partition(n_servers, servers_per_rack)
    domains = list(nic_topology(n_servers).domains)
    domains += [
        Domain(name=f"uplink{r}", servers=rack, oversub=oversub)
        for r, rack in enumerate(racks)
    ]
    return Topology(
        name=name or f"two_tier:{servers_per_rack}x{oversub:g}",
        n_servers=n_servers,
        domains=tuple(domains),
        racks=tuple(racks),
    )


def uplink_only(
    n_servers: int, servers_per_rack: int, oversub: float = 3.0
) -> Topology:
    """Rack uplinks without NIC domains: intra-rack communication is
    contention-free (idealized non-blocking leaf), only cross-rack traffic
    contends on the oversubscribed uplinks."""
    racks = _rack_partition(n_servers, servers_per_rack)
    return Topology(
        name=f"uplink_only:{servers_per_rack}x{oversub:g}",
        n_servers=n_servers,
        domains=tuple(
            Domain(name=f"uplink{r}", servers=rack, oversub=oversub)
            for r, rack in enumerate(racks)
        ),
        racks=tuple(racks),
    )


__all__ = [
    "Domain",
    "RingEdgeTopology",
    "Topology",
    "nic_topology",
    "two_tier",
    "uplink_only",
]
