"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory / cost / collective analysis.

Usage (module must be the process entry point so the device-count flag is
set before jax initializes):

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh single multi \
        --out results/dryrun.json [--profile tuned] [--resume]

The very first lines force 512 host-platform devices — dry-run only; tests
and benchmarks see the real single CPU device.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gc  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, canonical, get_config  # noqa: E402
from repro.launch import hlo as hlo_mod  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    HBM_BYTES,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    n_chips,
)
from repro.launch.specs import decode_specs, supports_shape, train_like_specs  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig  # noqa: E402
from repro.models.lm import LM, RunFlags  # noqa: E402
from repro.optim.adamw import AdamWConfig, abstract_opt_state  # noqa: E402
from repro.sharding.rules import (  # noqa: E402
    ShardingStrategy,
    cache_shardings,
    embeds_sharding,
    moment_shardings,
    param_shardings,
    replicated,
    token_sharding,
)

# ---------------------------------------------------------------------------
# Per-arch runtime profiles.
#
# "baseline" is the naive first config (tensor-parallel everywhere, f32
# moments, no remat): the starting point of the §Perf iteration log.
# "tuned" is the post-iteration profile (see EXPERIMENTS.md §Perf for the
# hypothesis -> change -> measurement chain that produced each entry).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Profile:
    strategy: str = "tp"          # tp | fsdp | zero1 | dp
    moment_dtype: str = "float32"  # float32 | bfloat16
    remat: str = "none"            # none | block | dots
    q_chunk: int = 512
    include_model_in_dp: bool = False
    # §Perf knobs (benchmarks/hillclimb.py)
    loss_impl: str = "dense"       # dense | chunked
    loss_chunk: int = 512
    capacity_factor: float = 0.0   # 0 -> keep the config's value
    decode_cache_mode: str = "auto"  # auto | seq | batch
    decode_constrain: bool = False
    constrain_acts: bool = False


BASELINE_PROFILES: Dict[str, Profile] = {a: Profile() for a in ARCH_IDS}
BASELINE_PROFILES["mamba2_130m"] = Profile(strategy="dp", include_model_in_dp=True)

# decode_constrain (flash-decode sharding, §Perf pair 2) is set exactly on
# the GQA archs whose kv-heads don't divide the 16-way model axis — their
# caches are seq-sharded and would otherwise be all-gathered every step.
# constrain_acts (§Perf pair 1) pins the residual stream batch-sharded.
TUNED_PROFILES: Dict[str, Profile] = {
    "mamba2_130m": Profile(strategy="dp", include_model_in_dp=True, remat="block"),
    "llama32_1b": Profile(strategy="zero1", remat="block", decode_constrain=True),
    "phi4_mini_3_8b": Profile(strategy="zero1", remat="block", decode_constrain=True),
    "gemma_7b": Profile(strategy="zero1", remat="block"),
    "yi_9b": Profile(strategy="zero1", remat="block", decode_constrain=True),
    "olmoe_1b_7b": Profile(strategy="zero1", remat="block"),
    "seamless_m4t_large_v2": Profile(strategy="zero1", remat="block"),
    "llama32_vision_11b": Profile(strategy="zero1", remat="block", decode_constrain=True),
    "jamba_v01_52b": Profile(strategy="zero1", remat="block", decode_constrain=True),
    "arctic_480b": Profile(
        strategy="fsdp", moment_dtype="bfloat16", remat="block",
        decode_constrain=True, constrain_acts=True,
    ),
}


from repro.launch.roofline import (  # noqa: E402
    config_for_shape,
    model_flops,
    roofline_terms as _roofline_terms,
)


def with_n_blocks(cfg: ModelConfig, nb: int) -> ModelConfig:
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=nb * cfg.block_len)
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, n_layers=nb * cfg.cross_attn_every)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=nb, enc_layers=nb)
    return dataclasses.replace(cfg, n_layers=nb)


# ---------------------------------------------------------------------------
# Lower + compile one variant
# ---------------------------------------------------------------------------


def _build_and_lower(cfg, shape, mesh, profile: Profile, flags: RunFlags):
    if profile.capacity_factor:
        cfg = dataclasses.replace(cfg, capacity_factor=profile.capacity_factor)
    lm = LM(cfg)
    strategy = ShardingStrategy.from_name(profile.strategy)
    ap = lm.abstract_params()
    p_sh = param_shardings(lm.logical_axes(), ap, mesh, strategy)
    inc = profile.include_model_in_dp
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(moment_dtype=jnp.dtype(profile.moment_dtype))
            opt = abstract_opt_state(ap, opt_cfg)
            m_sh = moment_shardings(p_sh, ap, mesh, strategy)
            o_sh = {"m": m_sh, "v": m_sh, "step": replicated(mesh)}
            batch = train_like_specs(cfg, shape)
            b_sh = {
                k: (
                    token_sharding(mesh, shape.global_batch, include_model=inc)
                    if v.ndim == 2
                    else embeds_sharding(mesh, shape.global_batch, include_model=inc)
                )
                for k, v in batch.items()
            }
            step = make_train_step(lm, opt_cfg, flags)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            return jitted.lower(ap, opt, batch)
        if shape.kind == "prefill":
            batch = train_like_specs(cfg, shape)
            b_sh = {
                k: (
                    token_sharding(mesh, shape.global_batch, include_model=inc)
                    if v.ndim == 2
                    else embeds_sharding(mesh, shape.global_batch, include_model=inc)
                )
                for k, v in batch.items()
            }
            step = make_prefill_step(lm, max_seq=shape.seq_len, flags=flags)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            return jitted.lower(ap, batch)
        # decode
        cache, token = decode_specs(lm, shape)
        c_sh = cache_shardings(
            cache, mesh, shape.global_batch, cfg, mode=profile.decode_cache_mode
        )
        t_sh = token_sharding(mesh, shape.global_batch, include_model=inc)
        step = make_serve_step(lm, flags)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, t_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        return jitted.lower(ap, cache, token)


def _compile_and_analyze(lowered) -> Dict[str, Any]:
    compiled = lowered.compile()
    cost = hlo_mod.normalize_cost(compiled.cost_analysis())
    mem = hlo_mod.memory_stats(compiled)
    coll = hlo_mod.collective_bytes(compiled.as_text())
    del compiled
    gc.collect()
    return {"cost": cost, "memory": mem, "collectives": coll}


def run_combo(
    arch: str,
    shape: InputShape,
    mesh,
    profile: Profile,
    correct_scan: bool = True,
) -> Dict[str, Any]:
    """Full dry-run of one (arch, shape, mesh): compile the production model
    plus (optionally) the two small-unroll variants for the scan-body cost
    correction (DESIGN.md §4)."""
    cfg = config_for_shape(arch, shape)
    if cfg is None:
        _, note = supports_shape(get_config(arch), shape)
        return {"status": "skipped", "note": note}

    from repro.sharding.rules import batch_spec_axes

    decode_dp = batch_spec_axes(mesh, shape.global_batch) or ()
    flags = RunFlags(
        remat=profile.remat,
        q_chunk=profile.q_chunk,
        loss_impl=profile.loss_impl,
        loss_chunk=profile.loss_chunk,
        decode_constrain=profile.decode_constrain and shape.kind == "decode",
        decode_dp=tuple(decode_dp),
        constrain_acts=profile.constrain_acts and shape.kind != "decode",
        act_dp=tuple(decode_dp),
    )
    t0 = time.time()
    lowered = _build_and_lower(cfg, shape, mesh, profile, flags)
    res = _compile_and_analyze(lowered)
    del lowered
    gc.collect()
    res["compile_s"] = round(time.time() - t0, 1)

    lm = LM(cfg)
    nb_full = lm.n_blocks
    if correct_scan and nb_full > 1:
        nb_small = min(4, nb_full)
        small = with_n_blocks(cfg, nb_small)
        u1 = _compile_and_analyze(
            _build_and_lower(small, shape, mesh, profile, dataclasses.replace(flags, scan_unroll=1))
        )
        u2 = _compile_and_analyze(
            _build_and_lower(small, shape, mesh, profile, dataclasses.replace(flags, scan_unroll=2))
        )
        corr: Dict[str, Any] = {}
        for key in ("flops", "bytes_accessed", "transcendentals"):
            delta = u2["cost"][key] - u1["cost"][key]
            corr[key] = res["cost"][key] + (nb_full - 1) * delta
        coll_delta = u2["collectives"]["total"] - u1["collectives"]["total"]
        corr["collective_total"] = res["collectives"]["total"] + (nb_full - 1) * coll_delta
        res["cost_corrected"] = corr
        res["correction_deltas"] = {
            "per_layer_flops": u2["cost"]["flops"] - u1["cost"]["flops"],
            "per_layer_bytes": u2["cost"]["bytes_accessed"] - u1["cost"]["bytes_accessed"],
            "per_layer_collective": coll_delta,
        }
    else:
        res["cost_corrected"] = {
            "flops": res["cost"]["flops"],
            "bytes_accessed": res["cost"]["bytes_accessed"],
            "transcendentals": res["cost"]["transcendentals"],
            "collective_total": res["collectives"]["total"],
        }

    res["roofline"] = _roofline_terms(cfg, shape, n_chips(mesh), res)
    res["status"] = "ok"
    res["config"] = cfg.name
    res["profile"] = dataclasses.asdict(profile)
    return res


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single"], choices=["single", "multi"])
    ap.add_argument("--profile", default="tuned", choices=["baseline", "tuned"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-correction", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == ["all"] else [canonical(a) for a in args.arch]
    shapes = (
        list(INPUT_SHAPES) if args.shape == ["all"] else args.shape
    )
    profiles = BASELINE_PROFILES if args.profile == "baseline" else TUNED_PROFILES

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    meshes = {}
    for m in args.mesh:
        meshes[m] = make_production_mesh(multi_pod=(m == "multi"))

    for mesh_name, mesh in meshes.items():
        for arch in archs:
            for shape_name in shapes:
                shape = INPUT_SHAPES[shape_name]
                key = f"{arch}|{shape_name}|{mesh_name}|{args.profile}"
                if args.resume and key in results and results[key].get("status") in ("ok", "skipped"):
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                t0 = time.time()
                try:
                    res = run_combo(
                        arch, shape, mesh, profiles[arch],
                        correct_scan=not args.no_correction,
                    )
                except Exception as e:  # record failures, keep going
                    res = {
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                res["wall_s"] = round(time.time() - t0, 1)
                results[key] = res
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (
                        f" dominant={r['dominant']} compute={r['compute_s']:.4f}s "
                        f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                        f"hbm={r['hbm_peak_frac']:.2f} useful={r['useful_flops_ratio']:.2f}"
                    )
                elif status == "error":
                    extra = " " + res["error"][:160]
                print(f"[dryrun] {key}: {status}{extra} ({res['wall_s']}s)", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
