"""Multi-job launcher: Ada-SRSF orchestrating real JAX training jobs.

This is the framework integration of the paper's technique (the analog of
the paper's PyTorch prototype): a set of training jobs — real models, real
jitted train steps — is admitted to a cluster, placed by LWF-kappa, and
their gradient all-reduces are gated by AdaDUAL under the Eq. (5)
contention model.

Because this container has one CPU device, the *network* is virtual (the
measured-constants contention model, 10GbE or TPU-DCN flavoured) while the
*compute profile* of every job is real: each job's jitted train step is
executed and timed on the actual device, and its all-reduce message size
is its actual parameter byte count.  On a real cluster the same scheduler
state machine drives per-slice launches; the decision logic is identical.

    PYTHONPATH=src python -m repro.launch.multi_job \
        --jobs llama3.2-1b:4:300 mamba2-130m:2:500 olmoe-1b-7b:8:200 \
        --policy ada --fabric 10gbe
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cluster import Cluster, JobSpec, ModelProfile
from repro.core.contention import (
    TPU_DCN_A,
    TPU_DCN_B,
    TPU_DCN_ETA,
    ContentionParams,
)
from repro.core.placement import PlacementPolicy
from repro.core.simulator import AdaDual, ClusterSimulator, KWayAdaDual, SrsfN
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.lm import LM, RunFlags
from repro.optim.adamw import AdamWConfig, adamw_init

FABRICS = {
    "10gbe": ContentionParams(),
    "tpu-dcn": ContentionParams(a=TPU_DCN_A, b=TPU_DCN_B, eta=TPU_DCN_ETA),
}


@dataclasses.dataclass
class JobRequest:
    arch: str
    n_gpus: int
    iterations: int
    arrival: float = 0.0
    batch: int = 4
    seq: int = 64
    reduced: bool = True

    @classmethod
    def parse(cls, spec: str, arrival: float = 0.0) -> "JobRequest":
        arch, n, iters = spec.split(":")
        return cls(arch=arch, n_gpus=int(n), iterations=int(iters), arrival=arrival)


@dataclasses.dataclass
class ProfiledJob:
    request: JobRequest
    lm: LM
    params: object
    opt_state: object
    step_fn: object
    dataset: SyntheticLMDataset
    profile: ModelProfile


def profile_job(req: JobRequest, seed: int = 0, timing_steps: int = 3) -> ProfiledJob:
    """Build the real jitted train step and measure (t_f+t_b, sigma, mem)."""
    cfg = get_config(req.arch, reduced=req.reduced)
    lm = LM(cfg)
    opt_cfg = AdamWConfig()
    flags = RunFlags(remat="none", q_chunk=min(256, req.seq))
    params = lm.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(lm, opt_cfg, flags))
    ds = SyntheticLMDataset(cfg, req.batch, req.seq, seed=seed)

    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    p, o, _ = step_fn(params, opt_state, batch)  # compile
    jax.block_until_ready(p)
    t0 = time.time()
    for i in range(timing_steps):
        p, o, m = step_fn(p, o, batch)
    jax.block_until_ready(p)
    t_iter = (time.time() - t0) / timing_steps

    size_bytes = float(
        sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(params))
    )
    mem_mb = (
        sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves((params, opt_state)))
        / 1e6
        * 3.0  # params+opt+activations headroom
    )
    profile = ModelProfile(
        name=cfg.name,
        size_bytes=size_bytes,
        mem_mb=mem_mb,
        batch_size=req.batch,
        t_f=t_iter / 3.0,        # fwd ~1/3, bwd+update ~2/3 of a step
        t_b=t_iter * 2.0 / 3.0,
    )
    return ProfiledJob(req, lm, params, opt_state, step_fn, ds, profile)


def run_multi_job(
    requests: List[JobRequest],
    policy: str = "ada",
    fabric: str = "10gbe",
    kappa: int = 1,
    n_servers: int = 4,
    gpus_per_server: int = 4,
    execute_steps: int = 8,
    seed: int = 0,
) -> Dict:
    """Schedule the jobs with Ada-SRSF and execute a slice of each job's
    real training steps in the order the schedule completes them."""
    params = FABRICS[fabric]
    profiled = [profile_job(r, seed=seed + i) for i, r in enumerate(requests)]
    specs = [
        JobSpec(i, pj.request.arrival, pj.request.n_gpus, pj.request.iterations, pj.profile)
        for i, pj in enumerate(profiled)
    ]
    if policy == "ada":
        comm = AdaDual()
    elif policy.startswith("srsf"):
        comm = SrsfN(int(policy[4:]))
    else:
        comm = KWayAdaDual(int(policy[4:]))
    sim = ClusterSimulator(
        specs,
        cluster=Cluster(n_servers, gpus_per_server, gpu_mem_mb=64000.0),
        placement=PlacementPolicy("lwf", kappa=kappa),
        comm_policy=comm,
        params=params,
    )
    res = sim.run()

    # Execute real training steps in schedule completion order.
    losses: Dict[int, List[float]] = {}
    order = sorted(res.finish, key=res.finish.get)
    for jid in order:
        pj = profiled[jid]
        p, o = pj.params, pj.opt_state
        losses[jid] = []
        for s in range(execute_steps):
            batch = {k: jnp.asarray(v) for k, v in pj.dataset.batch_at(s).items()}
            p, o, m = pj.step_fn(p, o, batch)
            losses[jid].append(float(m["loss"]))
    return {
        "schedule": res,
        "losses": losses,
        "profiles": {i: pj.profile for i, pj in enumerate(profiled)},
        "order": order,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--jobs",
        nargs="+",
        default=["llama3.2-1b:4:300", "mamba2-130m:2:500", "olmoe-1b-7b:8:200"],
        help="arch:n_gpus:iterations",
    )
    ap.add_argument("--policy", default="ada")
    ap.add_argument("--fabric", default="10gbe", choices=list(FABRICS))
    ap.add_argument("--kappa", type=int, default=1)
    ap.add_argument("--execute-steps", type=int, default=8)
    args = ap.parse_args()
    reqs = [JobRequest.parse(s, arrival=2.0 * i) for i, s in enumerate(args.jobs)]
    out = run_multi_job(
        reqs, policy=args.policy, fabric=args.fabric, kappa=args.kappa,
        execute_steps=args.execute_steps,
    )
    res = out["schedule"]
    print(f"[multi-job] policy={res.policy_name} placement={res.placement_name}")
    for jid, prof in out["profiles"].items():
        jct = res.jct.get(jid, float("nan"))
        ls = out["losses"][jid]
        print(
            f"  J{jid} {prof.name}: t_iter={prof.t_iter_compute*1e3:.1f}ms "
            f"sigma={prof.size_bytes/1e6:.1f}MB virtual-JCT={jct:.1f}s "
            f"loss {ls[0]:.3f}->{ls[-1]:.3f}"
        )
    print(
        f"[multi-job] avg JCT {res.avg_jct():.1f}s util {res.gpu_util:.1%} "
        f"contended-starts {res.comm_started_contended}"
    )


if __name__ == "__main__":
    main()
