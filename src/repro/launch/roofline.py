"""Roofline-term derivation (side-effect-free; importable by benchmarks).

    compute    = HLO_FLOPs  / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes  / (chips x 819 GB/s HBM)
    collective = coll_bytes / (chips x 50 GB/s ICI)

All three numerators come from the dry-run's compiled artifact
(cost_analysis + HLO collective parse), scan-corrected per DESIGN.md §4.
cost_analysis is per-device on the SPMD-partitioned module, so global =
per-device x chips.  MODEL_FLOPS (6*N_active*D etc.) gives the
useful-compute ratio that catches remat/dispatch waste.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, Optional

from repro.configs import canonical, get_config
from repro.launch.mesh import HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import supports_shape
from repro.models.config import InputShape, ModelConfig


def config_for_shape(arch: str, shape: InputShape) -> Optional[ModelConfig]:
    """Resolve the config, switching dense archs to their sliding-window
    variant for long_500k.  Returns None when the combo is skipped."""
    cfg = get_config(arch)
    ok, _ = supports_shape(cfg, shape)
    if not ok:
        return None
    if shape.name == "long_500k" and cfg.family == "dense":
        mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
        cfg = mod.LONG_CONTEXT_VARIANT
    return cfg


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Useful FLOPs per step: 6*N_active*tokens (train), 2*N_active*tokens
    (prefill), 2*N_active*batch (decode, one token per sequence).

    Token counts are per-stack: the audio encoder sees ``audio_frames``
    tokens (not the decoder's seq_len); the VLM's cross-attention params
    fire once per decoder token and count with the decoder.
    """
    n_active = cfg.active_param_count()
    mult = {"train": 6.0, "prefill": 2.0}.get(shape.kind)
    dec_tokens = shape.tokens if mult else shape.global_batch
    mult = mult or 2.0
    if cfg.family != "audio":
        return mult * n_active * dec_tokens
    enc_params = cfg.enc_layers * cfg._enc_layer_params(False)
    dec_params = n_active - enc_params
    if shape.kind == "decode":
        return mult * dec_params * dec_tokens  # encoder output is cached
    enc_tokens = cfg.audio_frames * shape.global_batch
    enc_mult = 6.0 if shape.kind == "train" else 2.0
    return mult * dec_params * dec_tokens + enc_mult * enc_params * enc_tokens


def roofline_terms(cfg, shape, chips: int, res: Dict[str, Any]) -> Dict[str, Any]:
    cc = res["cost_corrected"]
    # cost_analysis is per-device (SPMD-partitioned module)
    flops_global = cc["flops"] * chips
    bytes_global = cc["bytes_accessed"] * chips
    coll_global = cc["collective_total"] * chips
    t_compute = flops_global / (chips * PEAK_FLOPS_BF16)
    t_memory = bytes_global / (chips * HBM_BW)
    t_collective = coll_global / (chips * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": flops_global,
        "useful_flops_ratio": mf / flops_global if flops_global else 0.0,
        "hbm_peak_frac": res["memory"]["peak_bytes"] / HBM_BYTES,
        "chips": chips,
    }
