"""Production meshes for the TPU v5e target.

Importing this module never touches jax device state — meshes are built by
functions only (the dry-run sets ``xla_force_host_platform_device_count``
before any jax import; tests and benches see the real single device).
"""

from __future__ import annotations

import jax

#: TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip [FLOP/s]
HBM_BW = 819e9                # per chip [B/s]
ICI_BW = 50e9                 # per link [B/s]
HBM_BYTES = 16 * 1024**3      # per chip

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and the
    ``AxisType`` enum itself) only exist on newer releases; all axes are
    Auto there, which is also the older releases' only behaviour."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across jax versions: newer releases take
    ``(shape, axis_names)``, older ones a single ``(("name", size), ...)``."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    return make_mesh_compat((data, model), ("data", "model"))


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
