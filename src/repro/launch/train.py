"""Training driver: end-to-end single-process training on the local devices.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-1b --reduced --steps 100 --batch 8 --seq 128 \
        --ckpt-dir /tmp/ckpt --log-every 10

On this CPU container it trains the reduced configs (the quickstart
example trains a ~27M model); the same driver drives full configs on a
real mesh (``--mesh-data/--mesh-model``).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.config import ModelConfig
from repro.models.lm import LM, RunFlags
from repro.optim.adamw import AdamWConfig, adamw_init, cosine_schedule
from repro.sharding.rules import ShardingStrategy, param_shardings, token_sharding


def train(
    cfg: ModelConfig,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    mesh_shape=(1, 1),
    remat: str = "none",
):
    lm = LM(cfg)
    mesh = make_host_mesh(*mesh_shape)
    strategy = ShardingStrategy.from_name("tp" if mesh_shape[1] > 1 else "dp")
    opt_cfg = AdamWConfig(lr=lr)
    flags = RunFlags(remat=remat, q_chunk=min(512, seq))

    key = jax.random.PRNGKey(seed)
    params = lm.init(key)
    opt_state = adamw_init(params, opt_cfg)
    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), start, _ = restore(ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start}")

    p_sh = param_shardings(lm.logical_axes(), lm.abstract_params(), mesh, strategy)
    with mesh:
        params = jax.device_put(params, p_sh)
        step_fn = jax.jit(make_train_step(lm, opt_cfg, flags), donate_argnums=(0, 1))

        ds = SyntheticLMDataset(cfg, batch, seq, seed=seed)
        tok_sh = token_sharding(mesh, batch)
        it = make_train_iterator(
            ds, start_step=start, shardings={"tokens": tok_sh, "labels": tok_sh}
        )
        n_params = sum(p.size for p in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
              f"{steps} steps, batch {batch} x seq {seq}")

        losses = []
        t0 = time.time()
        for step in range(start, steps):
            batch_data = next(it)
            params, opt_state, metrics = step_fn(params, opt_state, batch_data)
            losses.append(float(metrics["loss"]))
            if log_every and (step + 1) % log_every == 0:
                dt = time.time() - t0
                tput = log_every * batch * seq / dt
                print(
                    f"[train] step {step+1}: loss={losses[-1]:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} tok/s={tput:.0f}"
                )
                t0 = time.time()
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                save(ckpt_dir, step + 1, (params, opt_state), {"loss": losses[-1]})
        it.close()
        if ckpt_dir:
            save(ckpt_dir, steps, (params, opt_state), {"loss": losses[-1]})
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    losses = train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=args.log_every,
        mesh_shape=(args.mesh_data, args.mesh_model),
        remat=args.remat,
    )
    print(f"[train] done: first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
