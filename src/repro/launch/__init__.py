"""Launch layer: production meshes, dry-run, training/serving drivers, and
the multi-job Ada-SRSF launcher."""
