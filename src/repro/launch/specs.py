"""ShapeDtypeStruct input stand-ins for every (architecture x input shape).

``input_specs`` returns exactly what the corresponding step function takes,
weak-type-correct and shardable, with no device allocation — the dry-run
lowers against these.  The audio/VLM modality frontends are stubbed here:
``audio_embeds`` / ``image_embeds`` stand in for the frontend outputs
(the one allowed stub; see DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import InputShape, ModelConfig
from repro.models.lm import LM


def train_like_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Batch specs for train/prefill step functions."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "audio":
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.audio_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs


def decode_specs(
    lm: LM, shape: InputShape
) -> Tuple[Any, jax.ShapeDtypeStruct]:
    """(abstract cache of seq_len slots, next-token spec) for serve_step."""
    cache = lm.abstract_cache(shape.global_batch, shape.seq_len)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return cache, token


def supports_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """long_500k applicability (DESIGN.md §Arch-applicability)."""
    if shape.name != "long_500k":
        return True, ""
    if cfg.family in ("ssm", "hybrid"):
        return True, ""
    if cfg.family == "dense":
        return True, "requires sliding-window variant"
    reasons = {
        "moe": "full-attention MoE, 4k-context model card",
        "audio": "enc-dec speech model; 500k-token decode meaningless",
        "vlm": "full self-attn + image cross-attn; card max 128k",
    }
    return False, reasons.get(cfg.family, "full attention")
