"""Serving driver: batched prefill + decode with a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mamba2-130m --reduced --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM, RunFlags


def serve_batch(
    cfg, batch: int = 4, prompt_len: int = 64, gen: int = 32, seed: int = 0,
    greedy: bool = True, temperature: float = 1.0,
):
    lm = LM(cfg)
    key = jax.random.PRNGKey(seed)
    params = lm.init(key)
    flags = RunFlags(remat="none", q_chunk=min(512, prompt_len))

    rng = np.random.default_rng(seed)
    batch_data = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32
        )
    }
    if cfg.family == "audio":
        batch_data["audio_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.audio_frames, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch_data["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vision_tokens, cfg.d_model)), jnp.bfloat16
        )

    prefill = jax.jit(
        lambda p, b: lm.prefill_fn(p, b, max_seq=prompt_len + gen, flags=flags)
    )
    decode = jax.jit(
        lambda p, c, t: lm.decode_fn(p, c, t, flags), donate_argnums=(1,)
    )

    t0 = time.time()
    logits, cache = prefill(params, batch_data)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    def sample(lg, k):
        if greedy:
            return jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature)[:, None].astype(jnp.int32)

    tok = sample(logits, key)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, tok)
        tok = sample(logits, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen_tokens = jnp.concatenate(out_tokens, axis=1)
    return {
        "generated": np.asarray(gen_tokens),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "prefill_tok_per_s": batch * prompt_len / max(t_prefill, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    res = serve_batch(cfg, args.batch, args.prompt_len, args.gen, args.seed)
    print(
        f"[serve] {cfg.name}: prefill {res['prefill_tok_per_s']:.0f} tok/s, "
        f"decode {res['decode_tok_per_s']:.1f} tok/s "
        f"(batch {args.batch}, {args.gen} new tokens)"
    )
    print(f"[serve] sample tokens: {res['generated'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
