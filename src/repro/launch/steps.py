"""Step builders shared by the dry-run, the trainer and the server.

All steps are pure pytree->pytree functions suitable for jax.jit with
explicit shardings and donation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import LM, RunFlags
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(lm: LM, opt_cfg: AdamWConfig, flags: RunFlags = RunFlags()):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def lf(p):
            return lm.loss_fn(p, batch, flags)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        out = {"loss": loss, **metrics, **om}
        return params, opt_state, out

    return train_step


def make_prefill_step(lm: LM, max_seq: int, flags: RunFlags = RunFlags()):
    """(params, batch) -> (last-token logits, cache)."""

    def prefill_step(params, batch):
        return lm.prefill_fn(params, batch, max_seq=max_seq, flags=flags)

    return prefill_step


def make_serve_step(lm: LM, flags: RunFlags = RunFlags()):
    """(params, cache, token) -> (logits, cache); cache donated by callers."""

    def serve_step(params, cache, token):
        return lm.decode_fn(params, cache, token, flags)

    return serve_step
