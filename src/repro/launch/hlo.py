"""HLO-text analysis: collective payload bytes per op kind.

``compiled.cost_analysis()`` has no collective accounting, so the roofline's
collective term is derived here by parsing the (SPMD-partitioned, per-device)
HLO and summing the output payload bytes of every collective op.  Ops inside
a ``while`` body (the layer scan) appear once in the text; the dry-run's
two-point unroll correction scales them by trip count (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, Tuple

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# e.g. "bf16[16,4096,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# LHS of an HLO instruction: "%name = <type> opcode(".  The opcode for
# collectives may carry suffixes like "all-reduce-start".
_INSTR_RE = re.compile(
    r"=\s+(\(?[^()=]*?\)?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\("
)


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device payload bytes by collective kind (+ op counts).

    '-done' ops are skipped so async start/done pairs count once.
    """
    out: Counter = Counter()
    counts: Counter = Counter()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _bytes_of_type(type_str)
        counts[kind] += 1
    result = {k: float(v) for k, v in out.items()}
    result["total"] = float(sum(out.values()))
    result["op_counts"] = dict(counts)
    return result


def normalize_cost(ca) -> Dict[str, float]:
    """cost_analysis() may be a dict or a 1-list of dicts depending on
    version; normalize and keep the scalar keys we use."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        # peak per-device estimate: live args + temps (aliased outputs reuse
        # argument space)
        "peak_bytes": float(
            ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + max(ma.output_size_in_bytes - ma.alias_size_in_bytes, 0)
        ),
    }
