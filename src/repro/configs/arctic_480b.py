"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168, 56 q-heads (GQA kv=8), MoE d_ff=4864 x 128 experts top-2,
dense-residual FFN in parallel with the MoE (Arctic's dense-MoE hybrid),
vocab=32000.

Sharding note: 56 q-heads don't divide the 16-way model axis, so q-heads
are padded to 64 (zero-init extra heads; their output-projection rows are
zero so they contribute nothing).  Documented FLOP inflation 64/56 on the
attention part only.  long_500k: SKIPPED — full-attention 4k-context model
card (DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    vocab_size=32000,
    n_heads=56,
    padded_heads=64,
    n_kv_heads=8,
    d_ff=4864,       # dense-residual FFN width
    moe_d_ff=4864,   # per-expert FFN width
    act="swiglu",
    n_experts=128,
    experts_per_token=2,
    dense_residual=True,
    rope_theta=10000.0,
    source="hf:Snowflake/snowflake-arctic-base (dense-MoE hybrid)",
)

REDUCED = ModelConfig(
    name="arctic-reduced",
    family="moe",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=7,
    padded_heads=8,
    n_kv_heads=1,
    d_ff=128,
    moe_d_ff=128,
    act="swiglu",
    n_experts=4,
    experts_per_token=2,
    dense_residual=True,
    source="reduced smoke variant",
)
