"""yi-9b [dense] — llama-arch GQA kv=4 [arXiv:2403.04652].

48L d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
Sharding note: 4 kv heads < 16-way model axis -> kv projections stay
replicated under TP (standard GQA practice).  long_500k: runs via the
sliding-window variant (window 8192) (DESIGN.md §Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    vocab_size=64000,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    act="swiglu",
    rope_theta=10000.0,
    source="arXiv:2403.04652 (Yi), 01-ai/Yi-9B",
)

LONG_CONTEXT_VARIANT = dataclasses.replace(
    CONFIG, name=CONFIG.name + "-swa8k", sliding_window=8192
)

REDUCED = ModelConfig(
    name="yi-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    source="reduced smoke variant",
)
