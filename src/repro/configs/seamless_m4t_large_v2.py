"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone
[arXiv:2308.11596].

24L d_model=1024, 16 heads (kv=16), d_ff=8192, vocab=256206.  We implement
the TRANSFORMER BACKBONE: a 24L (full-attention) encoder consuming stubbed
audio-frame embeddings (the mel + conformer-conv frontend is the one
allowed stub; ``input_specs`` provides (B, audio_frames, d_model)
embeddings) and a 24L causal decoder with per-layer cross-attention.

Decode shapes exercise the decoder with precomputed encoder K/V.
long_500k: SKIPPED — enc-dec speech model; 500k-token decode is
meaningless for the task and the architecture is full-attention
(DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,       # decoder layers
    enc_layers=24,     # encoder layers
    d_model=1024,
    vocab_size=256206,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    act="gelu",
    audio_frames=3000,  # ~60 s at 50 Hz frontend output
    rope_theta=10000.0,
    source="arXiv:2308.11596 (SeamlessM4T), facebook/seamless-m4t-v2-large",
)

REDUCED = ModelConfig(
    name="seamless-reduced",
    family="audio",
    n_layers=2,
    enc_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    act="gelu",
    audio_frames=64,
    source="reduced smoke variant",
)
