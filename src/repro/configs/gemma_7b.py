"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295].

28L d_model=3072, 16 heads (kv=16 == MHA; MQA is on the 2b variant),
head_dim=256 (q/k/v project 3072 -> 4096), d_ff=24576 (GeGLU),
vocab=256000.  long_500k: runs via the sliding-window variant (window
8192) — a variant config (DESIGN.md §Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    vocab_size=256000,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    act="geglu",
    rope_theta=10000.0,
    source="arXiv:2403.08295 (Gemma), google/gemma-7b",
)

LONG_CONTEXT_VARIANT = dataclasses.replace(
    CONFIG, name=CONFIG.name + "-swa8k", sliding_window=8192
)

REDUCED = ModelConfig(
    name="gemma-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    act="geglu",
    source="reduced smoke variant",
)
