"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=200064.
long_500k: runs via the sliding-window variant (window 8192) — explicitly
a variant config, not the model card's context claim
(DESIGN.md §Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    vocab_size=200064,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    act="swiglu",
    rope_theta=10000.0,
    source="arXiv:2412.08905 (Phi-4), microsoft/Phi-4-mini-instruct",
)

#: sliding-window variant used only for the long_500k decode shape
LONG_CONTEXT_VARIANT = dataclasses.replace(
    CONFIG, name=CONFIG.name + "-swa8k", sliding_window=8192
)

REDUCED = ModelConfig(
    name="phi4-mini-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    source="reduced smoke variant",
)
