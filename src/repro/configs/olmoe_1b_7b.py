"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048, 16 heads (GQA kv=16 == MHA), per-expert d_ff=1024,
vocab=50304, MoE on every layer.  long_500k: SKIPPED — full-attention MoE,
4k-context model card (DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    vocab_size=50304,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    act="swiglu",
    n_experts=64,
    experts_per_token=8,
    rope_theta=10000.0,
    source="arXiv:2409.02060 (OLMoE), allenai/OLMoE-1B-7B-0924",
)

REDUCED = ModelConfig(
    name="olmoe-reduced",
    family="moe",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    act="swiglu",
    n_experts=4,
    experts_per_token=2,
    source="reduced smoke variant",
)
