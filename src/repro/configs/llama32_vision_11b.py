"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256.  Every 5th
layer is a gated cross-attention layer over vision tokens (8 cross layers
of 40, matching the model's cross_attention_layers).  The ViT vision
encoder + projector is the allowed stub: ``input_specs`` provides
(B, vision_tokens=1601, d_model) projected patch embeddings.

long_500k: SKIPPED — full self-attention + image cross-attention; card max
128k and image-conditioned 500k decode is out of scope
(DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    vocab_size=128256,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    act="swiglu",
    cross_attn_every=5,
    vision_tokens=1601,  # one 448px tile: 1600 patches + cls
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision (+ arXiv:2407.21783)",
)

REDUCED = ModelConfig(
    name="llama-vision-reduced",
    family="vlm",
    n_layers=5,  # one pattern block: 4 self + 1 cross
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    cross_attn_every=5,
    vision_tokens=17,
    rope_theta=500000.0,
    source="reduced smoke variant",
)
