"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free (d_ff=0), vocab=50280, ssm_state=128.
Mamba-2 130m: expand=2 -> d_inner=1536, head_dim=64 -> 24 SSD heads,
conv width 4.  Decode state is sequence-length independent, so long_500k
runs natively (DESIGN.md §Arch-applicability).

Sharding note: at 130M params the model is far below the 256-chip TP
regime; the sharding strategy for this arch is pure data-parallel with
replicated parameters (batch sharded over both mesh axes).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    source="arXiv:2405.21060 (Mamba-2 / SSD), state-spaces/mamba2-130m",
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    ssm_state=32,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=16,
    source="reduced smoke variant",
)
