"""Assigned architecture configs (public pool) + the paper's own workload.

Each ``<id>.py`` exports ``CONFIG`` (the exact assigned hyper-parameters,
with the source citation) and ``REDUCED`` (a <=512-d, 2-layer, <=4-expert
variant of the same family for CPU smoke tests).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS = (
    "mamba2_130m",
    "jamba_v01_52b",
    "olmoe_1b_7b",
    "seamless_m4t_large_v2",
    "arctic_480b",
    "llama32_vision_11b",
    "phi4_mini_3_8b",
    "gemma_7b",
    "yi_9b",
    "llama32_1b",
)

_ALIASES = {
    "mamba2-130m": "mamba2_130m",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "arctic-480b": "arctic_480b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma-7b": "gemma_7b",
    "yi-9b": "yi_9b",
    "llama3.2-1b": "llama32_1b",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
