"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048, 32 heads (GQA kv=8, head_dim=64), d_ff=8192,
vocab=128256.  long_500k: runs via the sliding-window variant (window
8192) (DESIGN.md §Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    vocab_size=128256,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    act="swiglu",
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-1B (+ arXiv:2407.21783)",
)

LONG_CONTEXT_VARIANT = dataclasses.replace(
    CONFIG, name=CONFIG.name + "-swa8k", sliding_window=8192
)

REDUCED = ModelConfig(
    name="llama32-1b-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab_size=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    rope_theta=500000.0,
    source="reduced smoke variant",
)
