"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave + MoE
[arXiv:2403.19887].

32L d_model=4096, 32 q-heads (GQA kv=8), d_ff=14336, vocab=65536,
MoE 16 experts top-2 on every other layer.  Layer pattern: one attention
layer per 8-layer block (index 4 in the Jamba paper's figure; we use the
same 1:7 ratio), MoE replaces the MLP on odd layer indices (16 MoE layers
of 32).  SSM sub-layers are Mamba(-1 style in the paper; we use the SSD
mixer shared with mamba2, state 16 -> we keep the assigned ssm_state=128
hyper-parameterization of our SSD mixer).

long_500k: runs — SSM state is O(1) and the 4 attention layers' 500k KV
cache shards over the model axis.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    vocab_size=65536,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    act="swiglu",
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    block_len=8,
    attn_index_in_block=4,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=64,
    rope_theta=10000.0,
    source="arXiv:2403.19887 (Jamba), ai21labs/Jamba-v0.1",
)

REDUCED = ModelConfig(
    name="jamba-reduced",
    family="hybrid",
    n_layers=8,  # one pattern block
    d_model=128,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    n_experts=4,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    block_len=8,
    attn_index_in_block=4,
    ssm_state=32,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=16,
    source="reduced smoke variant",
)
