"""Scenario registry — named, parameterized workload/cluster scenarios.

A *scenario* bundles everything one simulation run needs:

* a cluster shape (``n_servers`` x ``gpus_per_server``, GPU memory),
* a job list (``JobSpec`` tuple, sorted by arrival),
* the contention model (:class:`~repro.core.contention.ContentionParams`,
  optionally with per-server heterogeneous bandwidth).

Builders are registered by name via :func:`register` and instantiated with
:func:`get_scenario`; every builder takes ``seed`` plus scenario-specific
keyword overrides (``n_jobs``, iteration ranges, cluster shape, ...) so the
same scenario scales from a seconds-long regression test to a paper-scale
benchmark.  Both simulation backends — the exact event simulator
(``core/simulator.py``) and the vectorized fluid simulator
(``core/jaxsim.py``) — consume scenarios through this one interface (see
``scenarios/sweep.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.chaos import ChaosSpec
from repro.core.cluster import Cluster, JobSpec
from repro.core.contention import ContentionParams
from repro.core.topology import Topology
from repro.core.trace import TraceSource


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-instantiated workload + cluster + network scenario."""

    name: str
    seed: int
    n_servers: int
    gpus_per_server: int
    jobs: Tuple[JobSpec, ...]
    params: ContentionParams
    gpu_mem_mb: float = 16160.0
    description: str = ""
    #: network fabric (core/topology.py); None = the paper's NIC-only model.
    #: Both backends consume it: the event simulator via per-task domain
    #: sets, the fluid simulator via a static incidence matrix.
    topology: Optional[Topology] = None
    #: WFBP tensor fusion ('all' | 'none' | a byte threshold): how each
    #: job's gradient exchange is bucketed (netmodel.fusion_plan) for
    #: models that carry layer data (repro.workloads).  'all' = the
    #: paper's monolithic iteration-level all-reduce, bit-for-bit.  Both
    #: backends consume it: the event simulator overlaps per-bucket
    #: transfers with the remaining backward pass, the fluid simulator
    #: drains the static (jobs, buckets) size matrix per bucket.
    fusion: object = "all"
    #: Job scheduling policy of the event backend ('static' |
    #: 'preemptive_srsf' | 'elastic', see core/schedpolicy.py).  'static'
    #: is the paper's hold-until-completion gang scheduling and the only
    #: mode the fluid backend supports (preemption/elasticity are
    #: event-only — documented in the docs/scenarios.md parity matrix).
    sched: str = "static"
    #: Tick period [s] of the preemptive/elastic policies (None = the
    #: policy's default; ignored by 'static', which never ticks).
    preemption_quantum: Optional[float] = None
    #: Checkpoint/restore penalty [s] charged when a preempted or resized
    #: job next runs (None = netmodel.preemption_cost of the model state).
    checkpoint_cost: Optional[float] = None
    #: Paper assumption-3 reading: one job per GPU (no memory
    #: time-sharing).  The regime where gang preemption is the only way a
    #: waiting job can take resources from a running one.
    exclusive_gpus: bool = False
    #: Fault-injection spec (core/chaos.py): server breakdown/repair, NIC
    #: degradation windows, straggler jitter, stochastic cancellation.
    #: Event-only — the fluid backend's static traces cannot express gang
    #: teardown mid-run (sweep.py raises; see the parity matrix).
    chaos: Optional["ChaosSpec"] = None
    #: Streaming arrival feed (trace-replay scale): when set, the event
    #: backend consumes arrivals lazily from this source instead of the
    #: materialized ``jobs`` tuple (which is then empty).  ``job_list()``
    #: still materializes on demand for tests, the fluid handoff, and
    #: small-scale runs.  Event-only at replay scale — sweep.py raises for
    #: the fluid backend.
    source: Optional[TraceSource] = None

    def make_cluster(self) -> Cluster:
        """A fresh (mutable) cluster — one per simulation run."""
        return Cluster(
            n_servers=self.n_servers,
            gpus_per_server=self.gpus_per_server,
            gpu_mem_mb=self.gpu_mem_mb,
        )

    def job_list(self) -> List[JobSpec]:
        if self.source is not None and not self.jobs:
            return self.source.materialize()
        return list(self.jobs)

    def build(self) -> Tuple[Cluster, List[JobSpec], ContentionParams]:
        """The ``(Cluster, List[JobSpec], ContentionParams)`` interface both
        simulator backends consume."""
        return self.make_cluster(), self.job_list(), self.params

    @property
    def n_jobs(self) -> int:
        if self.source is not None and not self.jobs:
            hint = self.source.n_jobs_hint()
            return hint if hint is not None else len(self.job_list())
        return len(self.jobs)

    @property
    def total_gpus(self) -> int:
        return self.n_servers * self.gpus_per_server


ScenarioBuilder = Callable[..., Scenario]

_REGISTRY: Dict[str, ScenarioBuilder] = {}
_DESCRIPTIONS: Dict[str, str] = {}


def register(name: str, description: str = ""):
    """Decorator: register ``fn(seed=0, **kw) -> Scenario`` under ``name``."""

    def deco(fn: ScenarioBuilder) -> ScenarioBuilder:
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = fn
        _DESCRIPTIONS[name] = description or (fn.__doc__ or "").strip()
        return fn

    return deco


def scenario_names() -> List[str]:
    return sorted(_REGISTRY)


def describe(name: str) -> str:
    return _DESCRIPTIONS.get(name, "")


def get_scenario(name: str, seed: int = 0, **overrides) -> Scenario:
    """Instantiate a registered scenario (same name+seed+overrides => same
    jobs, bitwise — builders must derive all randomness from ``seed``)."""
    try:
        builder = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {scenario_names()}"
        ) from None
    return builder(seed=seed, **overrides)
