"""Scenario engine: named workload/cluster scenarios + sweep runner.

Importing this package registers the built-in library (``library.py``).
"""

from repro.scenarios.registry import (
    Scenario,
    describe,
    get_scenario,
    register,
    scenario_names,
)
from repro.scenarios.library import QUICK_OVERRIDES  # also registers the library
from repro.scenarios.tracesource import (  # registers the trace_replay_* scenarios
    CsvTraceSource,
    SyntheticTraceSource,
    trace_source_from_spec,
)
from repro.scenarios.metrics import (
    CellCI,
    RunMetrics,
    ci_from_runs,
    from_event_result,
    from_jcts,
    summarize,
)
from repro.scenarios.sweep import (
    FLUID_POLICIES,
    SweepCell,
    canonical_comm,
    monte_carlo_fluid,
    run_cell,
    run_scenario_event,
    run_scenario_fluid,
    sweep,
    sweep_ci,
)

__all__ = [
    "QUICK_OVERRIDES",
    "CsvTraceSource",
    "SyntheticTraceSource",
    "trace_source_from_spec",
    "Scenario",
    "describe",
    "get_scenario",
    "register",
    "scenario_names",
    "CellCI",
    "RunMetrics",
    "ci_from_runs",
    "from_event_result",
    "from_jcts",
    "summarize",
    "FLUID_POLICIES",
    "SweepCell",
    "canonical_comm",
    "monte_carlo_fluid",
    "run_cell",
    "run_scenario_event",
    "run_scenario_fluid",
    "sweep",
    "sweep_ci",
]
