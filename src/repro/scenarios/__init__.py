"""Scenario engine: named workload/cluster scenarios + sweep runner.

Importing this package registers the built-in library (``library.py``).
"""

from repro.scenarios.registry import (
    Scenario,
    describe,
    get_scenario,
    register,
    scenario_names,
)
from repro.scenarios.library import QUICK_OVERRIDES  # also registers the library
from repro.scenarios.metrics import RunMetrics, from_event_result, from_jcts, summarize
from repro.scenarios.sweep import (
    SweepCell,
    canonical_comm,
    run_cell,
    run_scenario_event,
    run_scenario_fluid,
    sweep,
)

__all__ = [
    "QUICK_OVERRIDES",
    "Scenario",
    "describe",
    "get_scenario",
    "register",
    "scenario_names",
    "RunMetrics",
    "from_event_result",
    "from_jcts",
    "summarize",
    "SweepCell",
    "canonical_comm",
    "run_cell",
    "run_scenario_event",
    "run_scenario_fluid",
    "sweep",
]
