"""The scenario library.

Ten named scenarios (importing this module registers them):

* ``paper``              — the paper's Section V-A Microsoft-like 160-job trace.
* ``philly_heavy_tail``  — Philly-derived heavy tails: mostly small jobs plus
                           rare huge/long ones (Pareto iterations).
* ``bursty_diurnal``     — diurnal baseline with synchronized arrival bursts
                           (multi-tenant "everyone submits at 9am" shape).
* ``hetero_bandwidth``   — paper workload on a cluster whose servers have
                           heterogeneous per-link NIC bandwidth.
* ``large_job_dominated``— majority multi-server 8..32-GPU jobs; communication
                           dominates and placement quality is decisive.
* ``adversarial_allbig`` — contention-adversarial: identical big-message jobs
                           all arriving at once, every all-reduce collides.
* ``contended_residue``  — 5-GPU jobs on 4-GPU servers: every gang placement
                           leaves a cross-server residue, so concurrent jobs
                           share servers and all-reduces persistently collide
                           even under exclusive (fluid) placement.
* ``oversub_fabric``     — paper workload on a blocking two-tier fabric with
                           oversubscribed rack uplinks (``core/topology.py``).
* ``rack_locality``      — rack-sized jobs behind heavily oversubscribed
                           uplinks; rack-aware placement avoids the crossings.
* ``model_zoo``          — jobs sampled from the config-derived layer-granular
                           model profiles (``repro.workloads``) with WFBP
                           tensor fusion at a finite bucket threshold.
* ``fusion_sweep``       — the fusion threshold x policy grid cell: identical
                           many-layer jobs where a finite threshold beats both
                           ``fusion="all"`` and fully unfused under Ada-SRSF.
* ``preemption_gain``    — heavy-tailed service on an exclusive-GPU cluster:
                           elephants grab everything, mice stream in — where
                           Tiresias-style gang preemption pays.
* ``elastic_surge``      — elastic min/max-GPU trainings hit by a burst of
                           rigid small jobs — where boundary resizes pay.
* ``smoke``              — tiny, fully deterministic; for differential and CI
                           tests (seconds on one CPU, no RNG at all).

All randomness derives from the builder's ``seed`` argument, so a
``(name, seed, overrides)`` triple pins a workload bitwise — that is what the
fixed-seed regression tests in ``tests/test_scenarios.py`` rely on.
"""

from __future__ import annotations

import math
import random
from typing import List, Optional, Sequence, Tuple

from repro.core.chaos import ChaosSpec
from repro.core.cluster import TABLE_III, JobSpec, ModelProfile
from repro.core.contention import ContentionParams
from repro.core.topology import two_tier
from repro.core.trace import paper_trace
from repro.scenarios.registry import Scenario, register


#: Hand-tuned downsized overrides per scenario: small enough for a
#: seconds-long run on one CPU, large enough that every job finishes and
#: the paper's policy orderings hold (validated by the fixed-seed cells in
#: tests/test_scenarios.py).  Shared by the quick bench path
#: (benchmarks/run.py) and the regression suite — retune here, not there.
QUICK_OVERRIDES = {
    "paper": dict(n_jobs=40, min_iters=100, max_iters=600),
    "philly_heavy_tail": dict(n_jobs=32, min_iters=80, max_iters=1500),
    "bursty_diurnal": dict(n_jobs=32, min_iters=100, max_iters=600),
    "hetero_bandwidth": dict(n_jobs=28, min_iters=100, max_iters=600),
    "large_job_dominated": dict(n_jobs=14, min_iters=100, max_iters=500),
    "adversarial_allbig": dict(n_jobs=8, base_iters=120),
    "contended_residue": {},
    "oversub_fabric": dict(n_jobs=32, min_iters=100, max_iters=600),
    "rack_locality": {},
    "model_zoo": dict(n_jobs=12, min_iters=15, max_iters=60, horizon_s=600.0),
    "fusion_sweep": dict(base_iters=25),
    "preemption_gain": {},
    "elastic_surge": {},
    "smoke": {},
    "chaos_steady": {},
    "chaos_recovery_storm": {},
    "chaos_stragglers": {},
    # trace-replay family (scenarios/tracesource.py): the synth cell
    # downsizes to a seconds-long stream; the CSV cells replay their
    # bundled 40-row samples as-is
    "trace_replay_synth": dict(n_jobs=64),
    "trace_replay_philly": {},
    "trace_replay_alibaba": {},
}


def _finalize(jobs: List[JobSpec]) -> tuple:
    jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    return tuple(jobs)


def _sample_models(rng: random.Random) -> ModelProfile:
    return rng.choice(list(TABLE_III.values()))


# ---------------------------------------------------------------------------
# 1. The paper's trace
# ---------------------------------------------------------------------------


@register("paper", "Paper Section V-A Microsoft-like trace (160 jobs / 20 min)")
def paper_scenario(
    seed: int = 0,
    n_jobs: int = 160,
    horizon_s: float = 1200.0,
    min_iters: int = 1000,
    max_iters: int = 6000,
    n_servers: int = 16,
    gpus_per_server: int = 4,
    params: Optional[ContentionParams] = None,
) -> Scenario:
    jobs = paper_trace(
        seed=seed,
        n_jobs=n_jobs,
        horizon_s=horizon_s,
        min_iters=min_iters,
        max_iters=max_iters,
    )
    return Scenario(
        name="paper",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=tuple(jobs),
        params=params or ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 2. Philly-like heavy tail — calibrated against published trace statistics
# ---------------------------------------------------------------------------

#: Published Philly-trace job statistics (Jeon et al., "Analysis of
#: Large-Scale Multi-Tenant GPU Clusters for DNN Training Workloads",
#: USENIX ATC 2019; approximate values read off the duration CDF and the
#: GPU-request distribution).  We calibrate the *shape* of the generator
#: against the scale-free duration-quantile ratios (median ~13 min,
#: p90 ~3.8 h, p95 ~12 h) rather than absolute seconds, since every
#: scenario here is rescaled for simulation budget anyway.  Locked by the
#: fixed-seed quantile test in tests/test_scenarios.py.
PHILLY_DURATION_P90_OVER_P50 = 17.5
PHILLY_DURATION_P95_OVER_P50 = 55.0
#: Pareto tail index alpha solving the untruncated-Pareto identity
#: p90/p50 = 5**(1/alpha) for the published ratio (~0.56: much heavier
#: than the previous hand-picked 1.2 — the real trace's mean is dominated
#: by the rare day-long jobs).
PHILLY_PARETO_ALPHA = math.log(5.0) / math.log(PHILLY_DURATION_P90_OVER_P50)
#: GPU-request mix (same source): single-GPU jobs dominate.
PHILLY_GPU_WEIGHTS = (
    (1, 0.80),
    (2, 0.055),
    (4, 0.065),
    (8, 0.06),
    (16, 0.015),
    (32, 0.005),
)


@register(
    "philly_heavy_tail",
    "Philly-calibrated heavy tails: Pareto iterations matching the published "
    "duration-quantile ratios, single-GPU-dominated request mix",
)
def philly_heavy_tail(
    seed: int = 0,
    n_jobs: int = 120,
    horizon_s: float = 1200.0,
    min_iters: int = 100,
    max_iters: int = 35000,
    pareto_alpha: float = PHILLY_PARETO_ALPHA,
    n_servers: int = 16,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    sizes = [g for g, _ in PHILLY_GPU_WEIGHTS]
    weights = [w for _, w in PHILLY_GPU_WEIGHTS]
    jobs = []
    for k in range(n_jobs):
        arrival = float(int(rng.uniform(1.0, horizon_s)))
        iters = min(max_iters, int(min_iters * rng.paretovariate(pareto_alpha)))
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=arrival,
                n_gpus=rng.choices(sizes, weights)[0],
                iterations=iters,
                model=_sample_models(rng),
            )
        )
    return Scenario(
        name="philly_heavy_tail",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 3. Bursty / diurnal arrivals
# ---------------------------------------------------------------------------


#: Calibrated default arrival intensity for ``bursty_diurnal``: the ratio
#: of the peak arrival rate (at a burst center) to the horizon-mean rate.
#: 4.0 reproduces the previous hand-picked ``burst_frac=0.6`` at the
#: default shape (H=1200, 4 bursts, sigma=H/60) via the identity below —
#: locked by the fixed-seed intensity test in tests/test_scenarios.py.
BURSTY_PEAK_TO_MEAN = 4.0


def burst_fraction(
    peak_to_mean: float, horizon_s: float, n_bursts: int, sigma: float
) -> float:
    """Fraction of jobs routed into bursts so the realized peak-to-mean
    arrival-rate ratio hits ``peak_to_mean``.

    With a fraction ``f`` of N jobs split over ``n_bursts`` Gaussian bursts
    of width ``sigma`` and the rest at roughly the mean baseline rate, the
    rate at a burst center is ``f*N/(n_bursts*sigma*sqrt(2*pi)) +
    (1-f)*N/H``; dividing by the mean ``N/H`` and solving for ``f``:

        f = (P - 1) / (H / (n_bursts*sigma*sqrt(2*pi)) - 1)

    (clipped to [0, 0.95]).  P=1 means no bursts; the ceiling keeps a
    nonzero diurnal baseline."""
    if peak_to_mean < 1.0:
        raise ValueError(f"peak_to_mean must be >= 1, got {peak_to_mean}")
    gain = horizon_s / (n_bursts * sigma * math.sqrt(2.0 * math.pi))
    if gain <= 1.0:
        return 0.0  # bursts wider than the horizon cannot exceed the mean
    return min(0.95, max(0.0, (peak_to_mean - 1.0) / (gain - 1.0)))


@register(
    "bursty_diurnal",
    "Diurnal arrival baseline plus synchronized submission bursts; burst "
    "mass set by the calibrated peak-to-mean arrival-intensity knob",
)
def bursty_diurnal(
    seed: int = 0,
    n_jobs: int = 120,
    horizon_s: float = 1200.0,
    n_bursts: int = 4,
    peak_to_mean: float = BURSTY_PEAK_TO_MEAN,
    min_iters: int = 500,
    max_iters: int = 4000,
    n_servers: int = 16,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    centers = [rng.uniform(0.1, 0.9) * horizon_s for _ in range(n_bursts)]
    sigma = horizon_s / 60.0
    frac = burst_fraction(peak_to_mean, horizon_s, n_bursts, sigma)
    jobs = []
    for k in range(n_jobs):
        if rng.random() < frac:
            c = rng.choice(centers)
            arrival = min(horizon_s - 1.0, max(1.0, rng.gauss(c, sigma)))
        else:
            # diurnal baseline: accept-reject against a raised sine
            while True:
                t = rng.uniform(1.0, horizon_s)
                if rng.random() < 0.5 * (1.0 + math.sin(2 * math.pi * t / horizon_s)):
                    arrival = t
                    break
        gpus = rng.choices([1, 2, 4, 8], [0.45, 0.2, 0.2, 0.15])[0]
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(int(arrival)),
                n_gpus=gpus,
                iterations=rng.randint(min_iters, max_iters),
                model=_sample_models(rng),
            )
        )
    return Scenario(
        name="bursty_diurnal",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 4. Heterogeneous per-link bandwidth
# ---------------------------------------------------------------------------


@register(
    "hetero_bandwidth",
    "Paper workload on a cluster with heterogeneous per-server NIC bandwidth",
)
def hetero_bandwidth(
    seed: int = 0,
    n_jobs: int = 100,
    horizon_s: float = 1200.0,
    min_iters: int = 1000,
    max_iters: int = 6000,
    slow_fraction: float = 0.5,
    slow_scale: float = 0.4,
    n_servers: int = 16,
    gpus_per_server: int = 4,
) -> Scenario:
    jobs = paper_trace(
        seed=seed,
        n_jobs=n_jobs,
        horizon_s=horizon_s,
        min_iters=min_iters,
        max_iters=max_iters,
    )
    # evenly spread slow servers so consolidation can't simply avoid them
    n_slow = int(round(slow_fraction * n_servers))
    slow_ids = {int(i * n_servers / max(1, n_slow)) for i in range(n_slow)}
    bandwidth = tuple(
        slow_scale if s in slow_ids else 1.0 for s in range(n_servers)
    )
    return Scenario(
        name="hetero_bandwidth",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=tuple(jobs),
        params=ContentionParams(server_bandwidth=bandwidth),
    )


# ---------------------------------------------------------------------------
# 5. Large-job dominated
# ---------------------------------------------------------------------------


@register(
    "large_job_dominated",
    "Majority 8..32-GPU multi-server jobs — communication dominates",
)
def large_job_dominated(
    seed: int = 0,
    n_jobs: int = 48,
    horizon_s: float = 900.0,
    min_iters: int = 500,
    max_iters: int = 3000,
    n_servers: int = 16,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    jobs = []
    for k in range(n_jobs):
        gpus = rng.choices([4, 8, 16, 32], [0.15, 0.45, 0.28, 0.12])[0]
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(int(rng.uniform(1.0, horizon_s))),
                n_gpus=gpus,
                iterations=rng.randint(min_iters, max_iters),
                model=_sample_models(rng),
            )
        )
    return Scenario(
        name="large_job_dominated",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 6. Contention-adversarial: all big jobs at once
# ---------------------------------------------------------------------------


@register(
    "adversarial_allbig",
    "All identical big-message multi-server jobs arriving at once — every "
    "all-reduce collides; worst case for blind comm acceptance",
)
def adversarial_allbig(
    seed: int = 0,
    n_jobs: int = 12,
    n_gpus_per_job: int = 8,
    base_iters: int = 300,
    iter_jitter: float = 0.2,
    model: str = "vgg16",
    n_servers: int = 4,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    profile = TABLE_III[model]
    jobs = []
    for k in range(n_jobs):
        iters = int(base_iters * (1.0 + rng.uniform(-iter_jitter, iter_jitter)))
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(k % 2),  # two back-to-back waves, 1 s apart
                n_gpus=n_gpus_per_job,
                iterations=max(1, iters),
                model=profile,
            )
        )
    return Scenario(
        name="adversarial_allbig",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 7. Contended residue: gang placements that must share servers
# ---------------------------------------------------------------------------


@register(
    "contended_residue",
    "Jobs one GPU wider than a server: every placement leaves a cross-server "
    "residue, so resident jobs share servers and their all-reduces collide — "
    "the cell where comm gating policies differentiate on both backends",
)
def contended_residue(
    seed: int = 0,
    n_jobs: int = 6,
    n_gpus_per_job: int = 5,
    base_iters: int = 40,
    iter_jitter: float = 0.2,
    wave_size: int = 3,
    model: str = "vgg16",
    n_servers: int = 4,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    profile = TABLE_III[model]
    jobs = []
    for k in range(n_jobs):
        iters = int(base_iters * (1.0 + rng.uniform(-iter_jitter, iter_jitter)))
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(k // wave_size),  # waves of simultaneous barriers
                n_gpus=n_gpus_per_job,
                iterations=max(1, iters),
                model=profile,
            )
        )
    return Scenario(
        name="contended_residue",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 8. Oversubscribed two-tier fabric
# ---------------------------------------------------------------------------


@register(
    "oversub_fabric",
    "Paper workload on a blocking two-tier fabric: per-server NICs plus "
    "oversubscribed rack (ToR) uplinks — cross-rack all-reduces drain at the "
    "oversub-weighted Eq. (5) rate, so topology-blind placement pays",
)
def oversub_fabric(
    seed: int = 0,
    n_jobs: int = 120,
    horizon_s: float = 1200.0,
    min_iters: int = 1000,
    max_iters: int = 6000,
    n_servers: int = 16,
    gpus_per_server: int = 4,
    servers_per_rack: int = 4,
    oversub: float = 3.0,
) -> Scenario:
    jobs = paper_trace(
        seed=seed,
        n_jobs=n_jobs,
        horizon_s=horizon_s,
        min_iters=min_iters,
        max_iters=max_iters,
    )
    return Scenario(
        name="oversub_fabric",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=tuple(jobs),
        params=ContentionParams(),
        topology=two_tier(n_servers, servers_per_rack, oversub=oversub),
    )


# ---------------------------------------------------------------------------
# 9. Rack locality: placement quality decides uplink crossings
# ---------------------------------------------------------------------------


@register(
    "rack_locality",
    "Small racks behind heavily oversubscribed uplinks, with a job mix of "
    "rack-sized multi-server jobs plus fragmenting small jobs: rack-aware "
    "placement (lwf_rack / rack_pack) keeps the big jobs off the uplinks, "
    "topology-blind placement splits them across racks",
)
def rack_locality(
    seed: int = 0,
    n_jobs: int = 24,
    horizon_s: float = 240.0,
    min_iters: int = 60,
    max_iters: int = 300,
    n_servers: int = 8,
    gpus_per_server: int = 4,
    servers_per_rack: int = 2,
    oversub: float = 6.0,
) -> Scenario:
    rng = random.Random(seed)
    jobs = []
    for k in range(n_jobs):
        if rng.random() < 0.5:
            # fragmenters: odd-sized small jobs that leave partial servers
            gpus = rng.choice([1, 2, 3])
        else:
            # rack-sized: spans servers but fits inside one 2-server rack
            # (8 GPUs) when placed with locality in mind
            gpus = rng.choice([6, 8])
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(int(rng.uniform(0.0, horizon_s))),
                n_gpus=gpus,
                iterations=rng.randint(min_iters, max_iters),
                model=_sample_models(rng),
            )
        )
    return Scenario(
        name="rack_locality",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        topology=two_tier(n_servers, servers_per_rack, oversub=oversub),
    )


# ---------------------------------------------------------------------------
# 10. Model zoo: jobs sampled from config-derived layer-granular profiles
# ---------------------------------------------------------------------------


@register(
    "model_zoo",
    "Jobs sampled from the config-derived model zoo (repro.workloads): "
    "layer-granular profiles of the real architectures under "
    "src/repro/configs/ on an A100-80G-class data-parallel cluster, with "
    "WFBP tensor fusion at a finite bucket threshold",
)
def model_zoo(
    seed: int = 0,
    n_jobs: int = 48,
    horizon_s: float = 2400.0,
    min_iters: int = 60,
    max_iters: int = 400,
    fusion: object = 64e6,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    from repro.workloads import ZOO_GPU_MEM_MB, zoo_profiles

    zoo = zoo_profiles()
    #: small models arrive often, 7-9B trainings are rarer (survey-flavoured
    #: mix) — and GPU requests skew single-digit like the Philly trace
    archs = list(zoo)
    weights = [0.30, 0.25, 0.15, 0.12, 0.09, 0.09][: len(archs)]
    rng = random.Random(seed)
    jobs = []
    for k in range(n_jobs):
        arch = rng.choices(archs, weights)[0]
        gpus = rng.choices([1, 2, 4, 8, 16], [0.35, 0.2, 0.2, 0.17, 0.08])[0]
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(int(rng.uniform(1.0, horizon_s))),
                n_gpus=gpus,
                iterations=rng.randint(min_iters, max_iters),
                model=zoo[arch],
            )
        )
    return Scenario(
        name="model_zoo",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        gpu_mem_mb=ZOO_GPU_MEM_MB,
        fusion=fusion,
    )


# ---------------------------------------------------------------------------
# 11. Fusion sweep: the threshold x policy grid cell
# ---------------------------------------------------------------------------


@register(
    "fusion_sweep",
    "Alternating many-layer zoo jobs (mamba2-130m / llama3.2-1b) forced to "
    "span servers: the cell where the WFBP fusion threshold matters — a "
    "finite threshold overlaps comm with backward while avoiding the "
    "per-layer latency tax, beating both fusion='all' and fully unfused "
    "under Ada-SRSF (regression-locked in tests/test_wfbp.py)",
)
def fusion_sweep(
    seed: int = 0,
    n_jobs: int = 6,
    n_gpus_per_job: int = 8,
    base_iters: int = 40,
    iter_jitter: float = 0.2,
    wave_size: int = 3,
    fusion: object = 32e6,
    archs: Sequence[str] = ("mamba2_130m", "llama32_1b"),
    n_servers: int = 4,
    gpus_per_server: int = 4,
) -> Scenario:
    from repro.workloads import ZOO_GPU_MEM_MB, zoo_profiles

    zoo = zoo_profiles()
    rng = random.Random(seed)
    jobs = []
    for k in range(n_jobs):
        iters = int(base_iters * (1.0 + rng.uniform(-iter_jitter, iter_jitter)))
        jobs.append(
            JobSpec(
                job_id=k,
                arrival=float(k // wave_size),  # waves of simultaneous barriers
                n_gpus=n_gpus_per_job,
                iterations=max(1, iters),
                # alternating message sizes: AdaDUAL's ratio test gets real
                # small-vs-big decisions (identical sizes always refuse)
                model=zoo[archs[k % len(archs)]],
            )
        )
    return Scenario(
        name="fusion_sweep",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        gpu_mem_mb=ZOO_GPU_MEM_MB,
        fusion=fusion,
    )


# ---------------------------------------------------------------------------
# 12. Preemption gain: heavy-tailed service on an exclusive cluster
# ---------------------------------------------------------------------------


@register(
    "preemption_gain",
    "Heavy-tailed service mix on an exclusive-GPU cluster: early elephants "
    "(multi-GPU, long) grab every GPU, then a stream of mice (small, short) "
    "arrives — the cell where Tiresias-style gang preemption "
    "(sched='preemptive_srsf') beats hold-until-completion static SRSF "
    "(regression-locked in tests/test_engine.py)",
)
def preemption_gain(
    seed: int = 0,
    n_elephants: int = 4,
    n_mice: int = 24,
    horizon_s: float = 120.0,
    elephant_iters: Tuple[int, int] = (600, 1200),
    mouse_iters: Tuple[int, int] = (20, 80),
    preemption_quantum: float = 10.0,
    n_servers: int = 4,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    jobs = []
    jid = 0
    for k in range(n_elephants):
        # elephants arrive first and fill the cluster; every other one
        # spans two servers so preemption also exercises the comm path
        gpus = gpus_per_server if k % 2 == 0 else 2 * gpus_per_server
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival=float(k),
                n_gpus=gpus,
                iterations=rng.randint(*elephant_iters),
                model=TABLE_III["vgg16"],
            )
        )
        jid += 1
    for _ in range(n_mice):
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival=float(int(rng.uniform(5.0, horizon_s))),
                n_gpus=rng.choices([1, 2], [0.7, 0.3])[0],
                iterations=rng.randint(*mouse_iters),
                model=TABLE_III["resnet50"],
            )
        )
        jid += 1
    return Scenario(
        name="preemption_gain",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        exclusive_gpus=True,
        preemption_quantum=preemption_quantum,
    )


# ---------------------------------------------------------------------------
# 13. Elastic surge: min/max-GPU jobs absorbing a rigid burst
# ---------------------------------------------------------------------------


@register(
    "elastic_surge",
    "Elastic trainings (min/max GPU bounds) on big exclusive servers, hit "
    "by a mid-run burst of rigid small jobs: sched='elastic' grows the "
    "gangs across idle capacity (2x iteration throughput inside a server), "
    "shrinks them to min at the surge, and regrows afterwards — the "
    "workload where boundary resizes pay for their checkpoint cost",
)
def elastic_surge(
    seed: int = 0,
    n_elastic: int = 4,
    n_surge: int = 12,
    surge_at: float = 12.0,
    elastic_iters: Tuple[int, int] = (700, 1000),
    surge_iters: Tuple[int, int] = (40, 120),
    n_servers: int = 4,
    gpus_per_server: int = 8,
) -> Scenario:
    rng = random.Random(seed)
    jobs = []
    jid = 0
    for k in range(n_elastic):
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival=float(k),
                n_gpus=4,
                iterations=rng.randint(*elastic_iters),
                model=TABLE_III["resnet50"],
                min_gpus=2,
                max_gpus=gpus_per_server,  # growth stays inside one server
            )
        )
        jid += 1
    for _ in range(n_surge):
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival=float(int(surge_at + rng.uniform(0.0, 20.0))),
                n_gpus=rng.choices([1, 2], [0.5, 0.5])[0],
                iterations=rng.randint(*surge_iters),
                model=TABLE_III["inception_v3"],
            )
        )
        jid += 1
    return Scenario(
        name="elastic_surge",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        exclusive_gpus=True,
    )


# ---------------------------------------------------------------------------
# 14. Smoke (deterministic, tiny)
# ---------------------------------------------------------------------------


@register(
    "smoke",
    "Tiny deterministic 6-job / 8-GPU scenario for differential + CI tests",
)
def smoke(seed: int = 0, n_servers: int = 4, gpus_per_server: int = 2) -> Scenario:
    t3 = TABLE_III
    jobs = (
        # (job_id, arrival, n_gpus, iterations, model)
        JobSpec(0, 0.0, 4, 30, t3["resnet50"]),      # spans 2 servers -> comm
        JobSpec(1, 0.0, 4, 25, t3["vgg16"]),         # big message, spans 2
        JobSpec(2, 1.0, 1, 60, t3["lstm_ptb"]),      # single GPU, no comm
        JobSpec(3, 2.0, 2, 40, t3["inception_v3"]),  # fits one server
        JobSpec(4, 3.0, 4, 20, t3["resnet50"]),      # queued until GPUs free
        JobSpec(5, 5.0, 1, 50, t3["resnet50"]),
    )
    return Scenario(
        name="smoke",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=jobs,
        params=ContentionParams(),
    )


# ---------------------------------------------------------------------------
# 15-17. Chaos family: fault injection (core/chaos.py), event-backend only.
#
# Registered specs keep cancel_prob=0 so every job eventually finishes
# (the universal censored==0 / len(jct)==n_jobs locks stay meaningful);
# cancellation is exercised by the unit tests in tests/test_chaos.py.
# ---------------------------------------------------------------------------


def _chaos_mixed_jobs(
    rng: random.Random,
    n_jobs: int,
    horizon_s: float,
    iters: Tuple[int, int],
    big_frac: float,
    gpus_per_server: int,
) -> List[JobSpec]:
    """Seed-random mix of single-GPU mice and multi-server gangs (the jobs
    whose all-reduce a breakdown actually aborts)."""
    jobs = []
    for jid in range(n_jobs):
        if rng.random() < big_frac:
            n_gpus = gpus_per_server * rng.choice((1, 2))
            model = _sample_models(rng)
        else:
            n_gpus = rng.choice((1, 2))
            model = TABLE_III["resnet50"]
        jobs.append(
            JobSpec(
                job_id=jid,
                arrival=float(int(rng.uniform(0.0, horizon_s))),
                n_gpus=n_gpus,
                iterations=rng.randint(*iters),
                model=model,
            )
        )
    return jobs


@register(
    "chaos_steady",
    "Steady-state faults: stochastic per-server exponential MTBF/MTTR "
    "breakdowns plus mild straggler jitter over a mixed mouse/gang "
    "workload — the SLO cell (goodput under faults, work lost to "
    "restarts, p99 JCT) of the nightly chaos grid",
)
def chaos_steady(
    seed: int = 0,
    n_jobs: int = 24,
    horizon_s: float = 120.0,
    min_iters: int = 60,
    max_iters: int = 300,
    server_mtbf_s: float = 900.0,
    server_mttr_s: float = 25.0,
    straggler_prob: float = 0.02,
    straggler_slowdown: float = 0.5,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    jobs = _chaos_mixed_jobs(
        rng, n_jobs, horizon_s, (min_iters, max_iters), 0.4, gpus_per_server
    )
    return Scenario(
        name="chaos_steady",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        chaos=ChaosSpec(
            seed=seed,
            server_mtbf_s=server_mtbf_s,
            server_mttr_s=server_mttr_s,
            straggler_prob=straggler_prob,
            straggler_slowdown=straggler_slowdown,
        ),
    )


@register(
    "chaos_recovery_storm",
    "Rack-repair recovery storm: half the servers fail at one scripted "
    "instant and all repair together, so every preempted gang re-admits "
    "simultaneously and their catch-up all-reduces collide — the cell "
    "behind the regression-locked finding on whether contention-aware "
    "gating helps or hurts synchronized re-admission "
    "(tests/test_chaos.py::TestRecoveryStormFinding)",
)
def chaos_recovery_storm(
    seed: int = 0,
    n_jobs: int = 20,
    horizon_s: float = 60.0,
    min_iters: int = 80,
    max_iters: int = 260,
    fail_at: float = 70.0,
    repair_at: float = 100.0,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    # gang-heavy mix: the storm is about colliding catch-up all-reduces
    jobs = _chaos_mixed_jobs(
        rng, n_jobs, horizon_s, (min_iters, max_iters), 0.7, gpus_per_server
    )
    dead_rack = tuple(range(n_servers // 2))
    return Scenario(
        name="chaos_recovery_storm",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        chaos=ChaosSpec(
            seed=seed,
            scripted_failures=tuple(
                (s, fail_at, repair_at) for s in dead_rack
            ),
        ),
    )


@register(
    "chaos_stragglers",
    "Straggler-heavy cell: frequent large compute jitter plus transient "
    "NIC degradation windows, no breakdowns — isolates the slow-worker / "
    "slow-link tail (every gang iterates at its slowest member) from the "
    "fault-restart dynamics of chaos_steady",
)
def chaos_stragglers(
    seed: int = 0,
    n_jobs: int = 24,
    horizon_s: float = 120.0,
    min_iters: int = 60,
    max_iters: int = 300,
    straggler_prob: float = 0.15,
    straggler_slowdown: float = 2.0,
    nic_mtbf_s: float = 600.0,
    nic_mttr_s: float = 40.0,
    nic_degraded_scale: float = 0.3,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    rng = random.Random(seed)
    jobs = _chaos_mixed_jobs(
        rng, n_jobs, horizon_s, (min_iters, max_iters), 0.5, gpus_per_server
    )
    return Scenario(
        name="chaos_stragglers",
        seed=seed,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
        jobs=_finalize(jobs),
        params=ContentionParams(),
        chaos=ChaosSpec(
            seed=seed,
            straggler_prob=straggler_prob,
            straggler_slowdown=straggler_slowdown,
            nic_mtbf_s=nic_mtbf_s,
            nic_mttr_s=nic_mttr_s,
            nic_degraded_scale=nic_degraded_scale,
        ),
    )
