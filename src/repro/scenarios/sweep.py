"""Sweep runner: scenario x policy x seed matrices over both backends.

* :func:`run_scenario_event` — one exact event-driven simulation of a
  scenario (the reference backend; supports every placement/comm policy and
  heterogeneous per-server bandwidth).
* :func:`run_scenario_fluid` — one vectorized fluid (JAX) simulation of the
  same scenario through the ``core/jaxsim.py`` fixed-trace entry point.
  Approximations: gang-exclusive placement, fixed dt, and heterogeneous
  bandwidth collapsed to its cluster mean.
* :func:`sweep` — the full matrix, optionally fanned out over a
  ``multiprocessing`` pool (event backend only: jax jits don't fork well),
  returning one :class:`~repro.scenarios.metrics.RunMetrics` per cell.

Policy strings accept the simulator's names ('ada', 'srsf1', 'kway3', ...)
plus the paper aliases 'adadual'/'ada-srsf'.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement import PlacementPolicy
from repro.core.simulator import ClusterSimulator, SimResult, comm_policy_from_name
from repro.scenarios import metrics as metrics_mod
from repro.scenarios.registry import Scenario, get_scenario

COMM_ALIASES = {
    "adadual": "ada",
    "ada-srsf": "ada",
    "ada_srsf": "ada",
}

#: Fluid backend supports the branchless policies only.
FLUID_POLICIES = ("ada", "srsf1", "srsf2", "srsf3")


def canonical_comm(comm: str) -> str:
    return COMM_ALIASES.get(comm.lower(), comm.lower())


# ---------------------------------------------------------------------------
# Single runs
# ---------------------------------------------------------------------------


def run_scenario_event(
    scenario: Scenario,
    placement: str = "lwf",
    kappa: int = 1,
    comm: str = "ada",
    **sim_kw,
) -> SimResult:
    """Exact event-driven simulation of one scenario instance."""
    cluster, jobs, params = scenario.build()
    sim = ClusterSimulator(
        jobs,
        cluster=cluster,
        placement=PlacementPolicy(placement, kappa=kappa, seed=scenario.seed),
        comm_policy=comm_policy_from_name(canonical_comm(comm)),
        params=params,
        **sim_kw,
    )
    return sim.run()


def fluid_config(
    scenario: Scenario,
    comm: str = "ada",
    dt: float = 0.05,
    max_steps: int = 400_000,
):
    """JaxSimConfig for a scenario (heterogeneous bandwidth -> mean b)."""
    from repro.core.jaxsim import JaxSimConfig

    comm = canonical_comm(comm)
    if comm not in FLUID_POLICIES:
        raise ValueError(
            f"fluid backend supports {FLUID_POLICIES}, got {comm!r}"
        )
    p = scenario.params
    scale = p.mean_bandwidth_scale(scenario.n_servers)
    return JaxSimConfig(
        n_servers=scenario.n_servers,
        gpus_per_server=scenario.gpus_per_server,
        dt=dt,
        max_steps=max_steps,
        policy=comm,
        a=p.a,
        b=p.b / scale,
        eta=p.eta / scale,
        dual_threshold=p.dual_threshold,
    )


def run_scenario_fluid(
    scenario: Scenario,
    comm: str = "ada",
    dt: float = 0.05,
    max_steps: int = 400_000,
) -> Dict[str, object]:
    """Fluid (vectorized JAX) simulation of one scenario instance."""
    from repro.core.jaxsim import simulate_jobs

    cfg = fluid_config(scenario, comm=comm, dt=dt, max_steps=max_steps)
    return simulate_jobs(scenario.job_list(), cfg)


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One picklable cell of the sweep matrix (workers rebuild the scenario
    from (name, seed, overrides) so nothing heavyweight crosses processes)."""

    scenario: str
    seed: int
    placement: str
    kappa: int
    comm: str
    backend: str  # "event" | "fluid"
    overrides: Tuple[Tuple[str, object], ...] = ()
    dt: float = 0.05


def run_cell(cell: SweepCell) -> metrics_mod.RunMetrics:
    scn = get_scenario(cell.scenario, seed=cell.seed, **dict(cell.overrides))
    t0 = time.time()
    if cell.backend == "event":
        res = run_scenario_event(
            scn, placement=cell.placement, kappa=cell.kappa, comm=cell.comm
        )
        return metrics_mod.from_event_result(
            res,
            scenario=cell.scenario,
            seed=cell.seed,
            n_jobs=scn.n_jobs,
            wall_s=time.time() - t0,
        )
    if cell.backend == "fluid":
        out = run_scenario_fluid(scn, comm=cell.comm, dt=cell.dt)
        jcts = [float(j) for j, fin in zip(out["jct"], out["finished"]) if fin]
        return metrics_mod.from_jcts(
            jcts,
            scenario=cell.scenario,
            backend="fluid",
            placement="gang-lwf1",
            comm=canonical_comm(cell.comm),
            seed=cell.seed,
            n_jobs=scn.n_jobs,
            makespan=out["makespan"],
            wall_s=time.time() - t0,
        )
    raise ValueError(f"unknown backend {cell.backend!r}")


def sweep(
    scenarios: Sequence[str],
    comms: Sequence[str] = ("ada", "srsf1", "srsf2"),
    placements: Sequence[str] = ("lwf",),
    kappa: int = 1,
    seeds: Sequence[int] = (0,),
    backend: str = "event",
    overrides: Optional[Dict[str, object]] = None,
    per_scenario_overrides: Optional[Dict[str, Dict[str, object]]] = None,
    processes: Optional[int] = None,
    dt: float = 0.05,
) -> List[metrics_mod.RunMetrics]:
    """Run the full scenario x placement x comm x seed matrix.

    ``overrides`` applies to every scenario; ``per_scenario_overrides``
    (keyed by scenario name, e.g. ``QUICK_OVERRIDES``) layers on top, so
    one call — and hence one worker pool — can span scenarios that need
    different sizing.  ``processes > 1`` fans cells out over a
    multiprocessing pool (event backend only — jitted jax functions don't
    survive fork well)."""
    if backend == "fluid":
        # the fluid backend has one built-in gang placement; collapsing the
        # placement axis avoids duplicate identical runs/rows
        placements = ("gang",)

    def cell_overrides(name: str) -> Tuple[Tuple[str, object], ...]:
        d = dict(overrides or {})
        d.update((per_scenario_overrides or {}).get(name, {}))
        return tuple(sorted(d.items()))

    cells = [
        SweepCell(
            scenario=s,
            seed=seed,
            placement=pl,
            kappa=kappa,
            comm=c,
            backend=backend,
            overrides=cell_overrides(s),
            dt=dt,
        )
        for s in scenarios
        for pl in placements
        for c in comms
        for seed in seeds
    ]
    if processes and processes > 1 and backend == "event" and len(cells) > 1:
        import multiprocessing as mp

        # spawn, not fork: the caller may hold jitted jax state and worker
        # imports are cheap (the event backend is jax-free)
        with mp.get_context("spawn").Pool(processes) as pool:
            return pool.map(run_cell, cells)
    return [run_cell(c) for c in cells]
