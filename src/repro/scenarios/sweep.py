"""Sweep runner: scenario x policy x seed matrices over both backends.

* :func:`run_scenario_event` — one exact event-driven simulation of a
  scenario (the reference backend; supports every placement/comm policy and
  heterogeneous per-server bandwidth).
* :func:`run_scenario_fluid` — one vectorized fluid (JAX) simulation of the
  same scenario through the ``core/jaxsim.py`` fixed-trace entry point.
  Feature parity via the shared ``core/netmodel.py`` layer: every gating
  policy (AdaDUAL, SRSF(n), exact closed-form k-way), per-server
  heterogeneous bandwidth, and three gang placement modes.  Remaining
  approximations: gang-exclusive placement, fixed dt.  Fault injection
  (``Scenario.chaos``) is event-only — :func:`fluid_config` raises.
* :func:`sweep` — the full matrix, optionally fanned out over a
  ``multiprocessing`` pool (event backend only: jax jits don't fork well),
  returning one :class:`~repro.scenarios.metrics.RunMetrics` per cell.
* :func:`monte_carlo_fluid` / :func:`sweep_ci` — seeds batched into ONE
  vmapped device launch per fluid cell (padded via
  ``jaxsim.stack_traces``), aggregated to mean +/- std
  :class:`~repro.scenarios.metrics.CellCI` rows.

Policy strings accept the simulator's names ('ada', 'srsf1', 'kway3', ...)
plus the paper aliases 'adadual'/'ada-srsf'.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import netmodel
from repro.core.placement import PlacementPolicy
from repro.core.simulator import ClusterSimulator, SimResult, comm_policy_from_name
from repro.scenarios import metrics as metrics_mod
from repro.scenarios.registry import Scenario, get_scenario

COMM_ALIASES = {
    "adadual": "ada",
    "ada-srsf": "ada",
    "ada_srsf": "ada",
}

#: Gating policies the fluid backend supports (branchless masks from the
#: shared layer): AdaDUAL, SRSF(n), and threshold-gated k-way AdaDUAL.
FLUID_POLICIES = ("ada", "srsf1", "srsf2", "srsf3", "kway2", "kway3")


def canonical_comm(comm: str) -> str:
    return COMM_ALIASES.get(comm.lower(), comm.lower())


# ---------------------------------------------------------------------------
# Single runs
# ---------------------------------------------------------------------------


def run_scenario_event(
    scenario: Scenario,
    placement: str = "lwf",
    kappa: int = 1,
    comm: str = "ada",
    **sim_kw,
) -> SimResult:
    """Exact event-driven simulation of one scenario instance.  The
    scenario's scheduling knobs (``sched``, ``preemption_quantum``,
    ``checkpoint_cost``, ``exclusive_gpus``) are defaults; any ``sim_kw``
    override wins — that is how the regression tests compare
    preemptive-vs-static on the same workload.

    A scenario carrying a streaming ``source`` (trace-replay scale) feeds
    the engine the lazy arrival stream instead of a materialized list, so
    the event calendar stays O(live jobs + cluster) at 100k+-job scale —
    results are identical either way (the engine's streaming mode is
    regression-locked against list mode in tests/test_tracesource.py)."""
    cluster = scenario.make_cluster()
    params = scenario.params
    jobs = scenario.source if scenario.source is not None else scenario.job_list()
    sim_kw.setdefault("fusion", scenario.fusion)
    sim_kw.setdefault("sched", scenario.sched)
    sim_kw.setdefault("preemption_quantum", scenario.preemption_quantum)
    sim_kw.setdefault("checkpoint_cost", scenario.checkpoint_cost)
    sim_kw.setdefault("exclusive_gpus", scenario.exclusive_gpus)
    sim_kw.setdefault("chaos", scenario.chaos)
    max_time = sim_kw.pop("max_time", math.inf)  # run() arg, not ctor
    sim = ClusterSimulator(
        jobs,
        cluster=cluster,
        placement=PlacementPolicy(
            placement, kappa=kappa, seed=scenario.seed, topology=scenario.topology
        ),
        comm_policy=comm_policy_from_name(canonical_comm(comm)),
        params=params,
        topology=scenario.topology,
        **sim_kw,
    )
    return sim.run(max_time=max_time)


def fluid_config(
    scenario: Scenario,
    comm: str = "ada",
    placement: str = "lwf",
    dt: float = 0.05,
    max_steps: int = 400_000,
    **fast_kw,
):
    """JaxSimConfig for a scenario: per-server bandwidth and the fabric
    topology pass through verbatim (the fluid backend drains each transfer
    at its slowest member server and at the oversub-weighted per-domain
    contention); event placement names map to their gang analogues
    (lwf->consolidate, ff->first_fit, ls->least_loaded, rand->random,
    lwf_rack->rack_pack).  ``fast_kw`` forwards the fast-path knobs
    (``skip``, ``gating``, ``compact``, ``chunk_steps``, ``kernel``) —
    how the equivalence tests pin e.g. ``gating="rounds", skip=False``."""
    from repro.core.jaxsim import JaxSimConfig

    comm = canonical_comm(comm)
    if comm not in FLUID_POLICIES:
        raise ValueError(
            f"fluid backend supports {FLUID_POLICIES}, got {comm!r}"
        )
    if scenario.chaos is not None and scenario.chaos.active:
        raise ValueError(
            f"scenario {scenario.name!r} arms fault injection (chaos=), "
            "which is event-backend only: the fluid backend's static "
            "traces cannot express mid-run gang teardown/repair"
        )
    if scenario.source is not None and not scenario.jobs:
        raise ValueError(
            f"scenario {scenario.name!r} is an unmaterialized streaming "
            "trace replay (source= without jobs), which is event-backend "
            "only: the fluid backend needs the whole trace as one static "
            "tensor, defeating the O(live jobs) replay memory bound"
        )
    p = scenario.params
    gang_mode = netmodel.canonical_placement(placement)
    return JaxSimConfig(
        n_servers=scenario.n_servers,
        gpus_per_server=scenario.gpus_per_server,
        dt=dt,
        max_steps=max_steps,
        policy=comm,
        placement=gang_mode,
        a=p.a,
        b=p.b,
        eta=p.eta,
        dual_threshold=p.dual_threshold,
        server_bandwidth=tuple(p.server_bandwidth),
        topology=scenario.topology,
        # the seed is jit-static config: keep it constant unless the
        # placement actually consumes it, so seed sweeps share one compile
        placement_seed=scenario.seed if gang_mode == "random" else 0,
        **fast_kw,
    )


def run_scenario_fluid(
    scenario: Scenario,
    comm: str = "ada",
    placement: str = "lwf",
    dt: float = 0.05,
    max_steps: int = 400_000,
    **fast_kw,
) -> Dict[str, object]:
    """Fluid (vectorized JAX) simulation of one scenario instance (the
    scenario's WFBP ``fusion`` spec shapes the bucket planes of the
    trace — ``"all"`` leaves the legacy trace untouched, bit-for-bit)."""
    from repro.core.jaxsim import simulate_jobs

    cfg = fluid_config(
        scenario, comm=comm, placement=placement, dt=dt,
        max_steps=max_steps, **fast_kw,
    )
    return simulate_jobs(scenario.job_list(), cfg, fusion=scenario.fusion)


def _dedupe_fluid_placements(placements: Sequence[str]) -> Tuple[str, ...]:
    """Map event placement names to their gang analogues up front (so
    'rand' fails fast) and dedupe aliases that collapse to one mode."""
    seen: Dict[str, str] = {}
    for pl in placements:
        seen.setdefault(netmodel.canonical_placement(pl), pl)
    return tuple(seen.values())


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One picklable cell of the sweep matrix (workers rebuild the scenario
    from (name, seed, overrides) so nothing heavyweight crosses processes).

    ``sim_kw`` carries extra event-simulator keyword overrides (e.g.
    ``sched="preemptive_srsf"`` or ``bandwidth_aware_srsf=True``) — the
    event backend only; a fluid cell with ``sim_kw`` raises rather than
    silently ignoring the knobs."""

    scenario: str
    seed: int
    placement: str
    kappa: int
    comm: str
    backend: str  # "event" | "fluid"
    overrides: Tuple[Tuple[str, object], ...] = ()
    dt: float = 0.05
    sim_kw: Tuple[Tuple[str, object], ...] = ()


def run_cell(cell: SweepCell) -> metrics_mod.RunMetrics:
    scn = get_scenario(cell.scenario, seed=cell.seed, **dict(cell.overrides))
    if cell.sim_kw and cell.backend != "event":
        raise ValueError(
            f"sim_kw {dict(cell.sim_kw)} is event-backend only "
            f"(got backend {cell.backend!r})"
        )
    t0 = time.time()
    if cell.backend == "event":
        res = run_scenario_event(
            scn,
            placement=cell.placement,
            kappa=cell.kappa,
            comm=cell.comm,
            **dict(cell.sim_kw),
        )
        return metrics_mod.from_event_result(
            res,
            scenario=cell.scenario,
            seed=cell.seed,
            n_jobs=scn.n_jobs,
            wall_s=time.time() - t0,
        )
    if cell.backend == "fluid":
        out = run_scenario_fluid(
            scn, comm=cell.comm, placement=cell.placement, dt=cell.dt
        )
        jcts = [float(j) for j, fin in zip(out["jct"], out["finished"]) if fin]
        return metrics_mod.from_jcts(
            jcts,
            scenario=cell.scenario,
            backend="fluid",
            placement=f"gang-{netmodel.canonical_placement(cell.placement)}",
            comm=canonical_comm(cell.comm),
            seed=cell.seed,
            n_jobs=scn.n_jobs,
            makespan=out["makespan"],
            wall_s=time.time() - t0,
        )
    raise ValueError(f"unknown backend {cell.backend!r}")


def sweep(
    scenarios: Sequence[str],
    comms: Sequence[str] = ("ada", "srsf1", "srsf2"),
    placements: Sequence[str] = ("lwf",),
    kappa: int = 1,
    seeds: Sequence[int] = (0,),
    backend: str = "event",
    overrides: Optional[Dict[str, object]] = None,
    per_scenario_overrides: Optional[Dict[str, Dict[str, object]]] = None,
    processes: Optional[int] = None,
    dt: float = 0.05,
    sim_kw: Optional[Dict[str, object]] = None,
) -> List[metrics_mod.RunMetrics]:
    """Run the full scenario x placement x comm x seed matrix.

    ``overrides`` applies to every scenario; ``per_scenario_overrides``
    (keyed by scenario name, e.g. ``QUICK_OVERRIDES``) layers on top, so
    one call — and hence one worker pool — can span scenarios that need
    different sizing.  ``sim_kw`` forwards event-simulator keyword
    overrides to every cell (e.g. ``sched=`` or ``bandwidth_aware_srsf=``
    — how the nightly grid runs the same cells under different scheduling
    modes).  ``processes > 1`` fans cells out over a multiprocessing pool
    (event backend only — jitted jax functions don't survive fork well)."""
    if backend == "fluid":
        placements = _dedupe_fluid_placements(placements)

    def cell_overrides(name: str) -> Tuple[Tuple[str, object], ...]:
        d = dict(overrides or {})
        d.update((per_scenario_overrides or {}).get(name, {}))
        return tuple(sorted(d.items()))

    cells = [
        SweepCell(
            scenario=s,
            seed=seed,
            placement=pl,
            kappa=kappa,
            comm=c,
            backend=backend,
            overrides=cell_overrides(s),
            dt=dt,
            sim_kw=tuple(sorted((sim_kw or {}).items())),
        )
        for s in scenarios
        for pl in placements
        for c in comms
        for seed in seeds
    ]
    if processes and processes > 1 and backend == "event" and len(cells) > 1:
        import multiprocessing as mp

        # spawn, not fork: the caller may hold jitted jax state and worker
        # imports are cheap (the event backend is jax-free)
        with mp.get_context("spawn").Pool(processes) as pool:
            return pool.map(run_cell, cells)
    return [run_cell(c) for c in cells]


# ---------------------------------------------------------------------------
# Batched Monte-Carlo (confidence intervals per cell)
# ---------------------------------------------------------------------------


def monte_carlo_fluid(
    scenario: str,
    seeds: Sequence[int],
    comm: str = "ada",
    placement: str = "lwf",
    overrides: Optional[Dict[str, object]] = None,
    dt: float = 0.05,
    max_steps: int = 400_000,
    **fast_kw,
) -> List[metrics_mod.RunMetrics]:
    """All seeds of one scenario x policy x placement cell in ONE vmapped
    fluid launch: per-seed traces are padded/stacked
    (``jaxsim.stack_traces``) and swept by ``simulate_traces_batched`` —
    one XLA compilation, one device launch, one :class:`RunMetrics` per
    seed.  The contention model/cluster shape must not vary with the seed
    (true for every registered scenario); the seed only resamples jobs.

    Stacking pads every seed's trace to the batch-max job count, but the
    padding does NOT persist for the whole run: the chunked driver re-pads
    per chunk, retiring finished lanes and trimming the job axis down to
    the widest *live* lane after each compaction, so one long-tailed seed
    no longer drags the whole batch at max width (the old driver ran every
    lane at the global max shape for every step)."""
    import numpy as np

    from repro.core.jaxsim import (
        simulate_traces_batched,
        stack_traces,
        trace_from_jobs,
    )

    seeds = list(seeds)
    scns = [get_scenario(scenario, seed=s, **(overrides or {})) for s in seeds]
    cfg = fluid_config(
        scns[0], comm=comm, placement=placement, dt=dt,
        max_steps=max_steps, **fast_kw,
    )
    t0 = time.time()
    batch = stack_traces(
        [trace_from_jobs(s.job_list(), fusion=s.fusion) for s in scns]
    )
    out = simulate_traces_batched(batch, cfg)
    jct = np.asarray(out["jct"])
    fin = np.asarray(out["finished"])
    mks = np.asarray(out["makespan"])
    wall = (time.time() - t0) / len(seeds)
    return [
        metrics_mod.from_jcts(
            jct[i][fin[i]].tolist(),
            scenario=scenario,
            backend="fluid",
            placement=f"gang-{cfg.placement}",
            comm=cfg.policy,
            seed=seed,
            n_jobs=scn.n_jobs,
            makespan=float(mks[i]),
            wall_s=wall,
        )
        for i, (seed, scn) in enumerate(zip(seeds, scns))
    ]


def sweep_ci(
    scenarios: Sequence[str],
    comms: Sequence[str] = ("ada", "srsf1", "srsf2"),
    placements: Sequence[str] = ("lwf",),
    kappa: int = 1,
    seeds: Sequence[int] = (0, 1, 2, 3, 4),
    backend: str = "fluid",
    overrides: Optional[Dict[str, object]] = None,
    per_scenario_overrides: Optional[Dict[str, Dict[str, object]]] = None,
    processes: Optional[int] = None,
    dt: float = 0.05,
    sim_kw: Optional[Dict[str, object]] = None,
) -> List[metrics_mod.CellCI]:
    """Mean +/- std avg-JCT per scenario x placement x comm cell over
    ``seeds``.  Fluid backend: one vmapped batch per cell
    (:func:`monte_carlo_fluid`); event backend: the exact per-seed sweep
    (optionally multiprocessed), aggregated the same way.  ``sim_kw`` is
    event-only (see :func:`sweep`)."""
    if backend == "fluid":
        if sim_kw:
            raise ValueError(f"sim_kw {sim_kw} is event-backend only")
        placements = _dedupe_fluid_placements(placements)
        records: List[metrics_mod.RunMetrics] = []
        for s in scenarios:
            cell_over = dict(overrides or {})
            cell_over.update((per_scenario_overrides or {}).get(s, {}))
            for pl in placements:
                for c in comms:
                    records.extend(
                        monte_carlo_fluid(
                            s, seeds, comm=c, placement=pl,
                            overrides=cell_over, dt=dt,
                        )
                    )
    else:
        records = sweep(
            scenarios,
            comms=comms,
            placements=placements,
            kappa=kappa,
            seeds=seeds,
            backend=backend,
            overrides=overrides,
            per_scenario_overrides=per_scenario_overrides,
            processes=processes,
            dt=dt,
            sim_kw=sim_kw,
        )
    return metrics_mod.ci_from_runs(records)
