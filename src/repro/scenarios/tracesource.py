"""Trace-replay sources: streaming arrival feeds at 100k+-job scale.

Concrete :class:`~repro.core.trace.TraceSource` implementations behind one
iterator protocol (the event engine pulls arrivals one at a time, so the
calendar holds O(live jobs + cluster) entries instead of the whole trace):

* :class:`SyntheticTraceSource` — lazy paper-style workload generator:
  Poisson arrivals, Table III model mix, Philly-flavoured GPU-request
  weights.  O(1) memory per yielded job, deterministic per seed,
  restartable (each ``arrivals()`` call reseeds a fresh RNG).
* :class:`CsvTraceSource` — Philly/Alibaba-style CSV replays, streamed row
  by row (the file is never materialized).  Dialects map the published
  column conventions onto :class:`~repro.core.cluster.JobSpec`; wall-clock
  durations convert to iteration counts through each model's measured
  per-iteration compute time.

Importing this module registers the ``trace_replay_*`` scenarios
(``trace_replay_synth`` / ``trace_replay_philly`` / ``trace_replay_alibaba``)
— at registry scale the job tuple is ALSO materialized so the fixed-seed
regression locks (``tests/test_scenarios.py``) can compare workloads, while
``run_scenario_event`` still consumes the streaming source; at replay scale
(``benchmarks/run.py --only engine --n-jobs 100000``) only the source
exists and memory stays O(live jobs).

:func:`trace_source_from_spec` parses the bench CLI's ``--trace-source``
strings (``"synth"``, ``"philly"``, ``"alibaba"``, or
``"csv:<dialect>:<path>"``).
"""

from __future__ import annotations

import csv
import pathlib
import random
from typing import Iterator, Optional, Sequence, Tuple

from repro.core.cluster import TABLE_III, JobSpec, ModelProfile
from repro.core.contention import ContentionParams
from repro.core.trace import TraceSource
from repro.scenarios.registry import Scenario, register

#: Bundled sample replays (tiny excerpt-style CSVs in the published column
#: conventions) — the data the registered CSV scenarios and the CI replay
#: smoke tests run against.
DATA_DIR = pathlib.Path(__file__).parent / "data"

#: GPU-request mix of the synthetic replay stream: single-GPU dominated
#: (Philly-flavoured) so a sustained open-arrival stream drains on a
#: moderate cluster while multi-server gangs still exercise the comm path.
REPLAY_GPU_WEIGHTS: Tuple[Tuple[int, float], ...] = (
    (1, 0.55),
    (2, 0.20),
    (4, 0.14),
    (8, 0.09),
    (16, 0.02),
)


def _default_models() -> Tuple[ModelProfile, ...]:
    """Table III profiles in sorted-name order (deterministic: dict order
    is insertion order, but sorting decouples the stream from it)."""
    return tuple(TABLE_III[k] for k in sorted(TABLE_III))


class SyntheticTraceSource(TraceSource):
    """Lazy paper-style workload at open-ended scale.

    Arrivals form a Poisson process of ``rate`` jobs/s (floored to the
    trace generator's 1 s submission ticks, hence nondecreasing);
    iterations ~ U{min_iters..max_iters}; models sampled from Table III;
    GPU requests from ``gpu_weights``.  Every draw derives from ``seed``,
    so one ``(n_jobs, seed)`` pair pins the stream bitwise and
    ``arrivals()`` can be replayed any number of times.
    """

    def __init__(
        self,
        n_jobs: int,
        seed: int = 0,
        rate: float = 1.0,
        min_iters: int = 30,
        max_iters: int = 120,
        gpu_weights: Tuple[Tuple[int, float], ...] = REPLAY_GPU_WEIGHTS,
        models: Optional[Sequence[ModelProfile]] = None,
        start_at: float = 1.0,
    ) -> None:
        if n_jobs < 1:
            raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
        if rate <= 0.0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.n_jobs = int(n_jobs)
        self.seed = seed
        self.rate = float(rate)
        self.min_iters = int(min_iters)
        self.max_iters = int(max_iters)
        self.gpu_weights = tuple(gpu_weights)
        self.models = tuple(models) if models is not None else _default_models()
        self.start_at = float(start_at)

    def arrivals(self) -> Iterator[JobSpec]:
        rng = random.Random(self.seed)
        sizes = [g for g, _ in self.gpu_weights]
        weights = [w for _, w in self.gpu_weights]
        t = self.start_at
        for k in range(self.n_jobs):
            t += rng.expovariate(self.rate)
            yield JobSpec(
                job_id=k,
                arrival=float(int(t)),  # 1 s submission ticks
                n_gpus=rng.choices(sizes, weights)[0],
                iterations=rng.randint(self.min_iters, self.max_iters),
                model=rng.choice(self.models),
            )

    def n_jobs_hint(self) -> Optional[int]:
        return self.n_jobs


#: CSV dialects: column names for (arrival, gpus, duration) in the two
#: published trace conventions.  ``gpu_scale`` divides the raw GPU column
#: (Alibaba's ``plan_gpu`` is a percentage: 800 -> 8 GPUs).
CSV_DIALECTS = {
    "philly": dict(
        arrival="submit_time", gpus="ngpus", duration="runtime_s",
        gpu_scale=1.0,
    ),
    "alibaba": dict(
        arrival="submit_time", gpus="plan_gpu", duration=None,
        end="end_time", gpu_scale=100.0,
    ),
}


class CsvTraceSource(TraceSource):
    """Philly/Alibaba-style CSV replay, streamed row by row.

    The file must be sorted by arrival (the real published traces are;
    the engine validates and raises otherwise).  Rows map to jobs as:

    * ``job_id`` — the 0-based row index (stable across replays),
    * ``arrival`` — the dialect's submit column times ``time_scale``,
    * ``n_gpus`` — the dialect's GPU column over its ``gpu_scale``
      (rounded up to >= 1),
    * ``model`` — Table III profile ``index % len(models)`` (a
      deterministic round-robin; NOT ``hash()``, which is salted),
    * ``iterations`` — the row's wall-clock duration times ``time_scale``
      divided by the model's per-iteration compute time (>= 1).

    ``time_scale`` compresses day-long production traces into simulation
    budgets; ``max_jobs`` truncates the stream (for smoke runs against a
    full-size file).  Only the path/dialect/knobs are held in memory —
    each ``arrivals()`` call re-opens the file.
    """

    def __init__(
        self,
        path: str,
        dialect: str = "philly",
        time_scale: float = 1.0,
        max_jobs: Optional[int] = None,
        models: Optional[Sequence[ModelProfile]] = None,
    ) -> None:
        if dialect not in CSV_DIALECTS:
            raise ValueError(
                f"unknown CSV dialect {dialect!r}; known: {sorted(CSV_DIALECTS)}"
            )
        if time_scale <= 0.0:
            raise ValueError(f"time_scale must be > 0, got {time_scale}")
        self.path = str(path)
        self.dialect = dialect
        self.time_scale = float(time_scale)
        self.max_jobs = max_jobs
        self.models = tuple(models) if models is not None else _default_models()

    def arrivals(self) -> Iterator[JobSpec]:
        spec = CSV_DIALECTS[self.dialect]
        with open(self.path, newline="") as fh:
            reader = csv.DictReader(fh)
            for k, row in enumerate(reader):
                if self.max_jobs is not None and k >= self.max_jobs:
                    return
                arrival = float(row[spec["arrival"]]) * self.time_scale
                if spec["duration"] is not None:
                    duration = float(row[spec["duration"]])
                else:
                    duration = float(row[spec["end"]]) - float(
                        row[spec["arrival"]]
                    )
                raw_gpus = float(row[spec["gpus"]]) / spec["gpu_scale"]
                n_gpus = max(1, int(round(raw_gpus)))
                model = self.models[k % len(self.models)]
                iters = max(
                    1,
                    int(duration * self.time_scale / model.t_iter_compute),
                )
                yield JobSpec(
                    job_id=k,
                    arrival=arrival,
                    n_gpus=n_gpus,
                    iterations=iters,
                    model=model,
                )


def trace_source_from_spec(
    spec: str, n_jobs: int = 100_000, seed: int = 0
) -> TraceSource:
    """Parse a ``--trace-source`` CLI string into a source.

    ``"synth"`` — :class:`SyntheticTraceSource` of ``n_jobs`` jobs at
    replay-bench sizing (short jobs, 2/s: the cell measures engine
    event throughput and calendar footprint, not policy quality, so the
    event count per job is kept small and the stream steady);
    ``"philly"`` / ``"alibaba"`` — the bundled sample CSV of that dialect
    (``max_jobs=n_jobs``); ``"csv:<dialect>:<path>"`` — an external CSV.
    """
    if spec == "synth":
        return SyntheticTraceSource(
            n_jobs=n_jobs, seed=seed, rate=2.0, min_iters=3, max_iters=9
        )
    if spec in CSV_DIALECTS:
        return CsvTraceSource(
            str(DATA_DIR / f"{spec}_sample.csv"), dialect=spec, max_jobs=n_jobs
        )
    if spec.startswith("csv:"):
        try:
            _, dialect, path = spec.split(":", 2)
        except ValueError:
            raise ValueError(
                f"bad --trace-source {spec!r}: expected csv:<dialect>:<path>"
            ) from None
        return CsvTraceSource(path, dialect=dialect, max_jobs=n_jobs)
    raise ValueError(
        f"unknown trace source {spec!r}: expected 'synth', "
        f"{sorted(CSV_DIALECTS)}, or 'csv:<dialect>:<path>'"
    )


# ---------------------------------------------------------------------------
# Registered trace-replay scenarios
# ---------------------------------------------------------------------------

#: Above this job count the registered builders stop materializing the job
#: tuple (the fixed-seed `.jobs` regression locks only run at small scale);
#: the scenario then carries ONLY the lazy source and memory stays O(live).
MATERIALIZE_BELOW = 20_000


def _replay_scenario(
    name: str, source: TraceSource, seed: int, materialize: bool, **kw
) -> Scenario:
    jobs: Tuple[JobSpec, ...] = ()
    if materialize:
        jobs = tuple(source.materialize())
    return Scenario(
        name=name,
        seed=seed,
        jobs=jobs,
        source=source,
        params=ContentionParams(),
        **kw,
    )


@register(
    "trace_replay_synth",
    "Streaming synthetic replay: Poisson open arrivals of Philly-mix jobs "
    "consumed lazily through the TraceSource protocol — the event calendar "
    "holds O(live jobs + cluster) entries, so the same scenario scales from "
    "the seconds-long regression cell to the nightly 100k-job replay",
)
def trace_replay_synth(
    seed: int = 0,
    n_jobs: int = 400,
    rate: float = 1.0,
    min_iters: int = 30,
    max_iters: int = 120,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    src = SyntheticTraceSource(
        n_jobs=n_jobs,
        seed=seed,
        rate=rate,
        min_iters=min_iters,
        max_iters=max_iters,
    )
    return _replay_scenario(
        "trace_replay_synth",
        src,
        seed,
        materialize=n_jobs <= MATERIALIZE_BELOW,
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
    )


@register(
    "trace_replay_philly",
    "Philly-dialect CSV replay (bundled sample in the published "
    "submit/ngpus/runtime column convention), streamed row by row through "
    "the TraceSource protocol; point ``path=`` at a full cluster_job_log "
    "export for production-scale replays",
)
def trace_replay_philly(
    seed: int = 0,
    path: Optional[str] = None,
    time_scale: float = 1.0,
    max_jobs: Optional[int] = None,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    src = CsvTraceSource(
        path or str(DATA_DIR / "philly_sample.csv"),
        dialect="philly",
        time_scale=time_scale,
        max_jobs=max_jobs,
    )
    return _replay_scenario(
        "trace_replay_philly",
        src,
        seed,
        # bundled sample: tiny; an external file is materialized only when
        # max_jobs bounds it to regression scale
        materialize=path is None
        or (max_jobs is not None and max_jobs <= MATERIALIZE_BELOW),
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
    )


@register(
    "trace_replay_alibaba",
    "Alibaba-dialect CSV replay (bundled sample in the cluster-trace "
    "submit/end/plan_gpu convention, plan_gpu in GPU-percent), streamed "
    "through the TraceSource protocol",
)
def trace_replay_alibaba(
    seed: int = 0,
    path: Optional[str] = None,
    time_scale: float = 1.0,
    max_jobs: Optional[int] = None,
    n_servers: int = 8,
    gpus_per_server: int = 4,
) -> Scenario:
    src = CsvTraceSource(
        path or str(DATA_DIR / "alibaba_sample.csv"),
        dialect="alibaba",
        time_scale=time_scale,
        max_jobs=max_jobs,
    )
    return _replay_scenario(
        "trace_replay_alibaba",
        src,
        seed,
        materialize=path is None
        or (max_jobs is not None and max_jobs <= MATERIALIZE_BELOW),
        n_servers=n_servers,
        gpus_per_server=gpus_per_server,
    )
