"""Per-run scheduling metrics, uniform across both simulator backends.

One :class:`RunMetrics` record per (scenario, seed, placement, comm policy,
backend) simulation: JCT statistics (avg/median/p95), makespan, GPU
utilization and contention-event counts, plus the wall-clock cost of the
simulation itself.  The sweep runner (``scenarios/sweep.py``) emits lists of
these; ``benchmarks/run.py`` prints them as CSV rows.

:class:`CellCI` aggregates the per-seed records of one scenario x policy x
placement cell into mean +/- std confidence intervals
(:func:`ci_from_runs`) — the output format of the Monte-Carlo sweeps
(``benchmarks/run.py --scenario ... --ci``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.engine import SimResult, median, percentile

CSV_FIELDS = (
    "scenario",
    "backend",
    "placement",
    "comm",
    "sched",
    "seed",
    "n_jobs",
    "n_finished",
    "censored",
    "avg_jct",
    "median_jct",
    "p95_jct",
    "makespan",
    "gpu_util",
    "comm_contended",
    "comm_clean",
    "preemptions",
    "resizes",
    "faults",
    "cancelled",
    "work_lost",
    "p99_jct",
    "goodput",
    "wall_s",
    "peak_calendar",
    "stretch_frac",
    "gating_frac",
)


@dataclasses.dataclass(frozen=True)
class RunMetrics:
    scenario: str
    backend: str  # "event" | "fluid"
    placement: str
    comm: str
    seed: int
    n_jobs: int
    n_finished: int
    avg_jct: float
    median_jct: float
    p95_jct: float
    makespan: float
    gpu_util: float
    comm_contended: int = 0
    comm_clean: int = 0
    wall_s: float = 0.0
    #: job scheduling policy (engine/policy split; fluid is always static)
    sched: str = "static"
    #: jobs with no finish time (horizon cutoff, or never placeable) —
    #: excluded from the JCT stats above, surfaced so truncation is
    #: never silent
    censored: int = 0
    #: gang preemptions / elastic resizes performed during the run
    preemptions: int = 0
    resizes: int = 0
    #: fault-injection SLO metrics (core/chaos.py; zero on fault-free runs):
    #: fault events injected (server breakdowns + NIC degradation
    #: windows), jobs stochastically cancelled, samples
    #: of in-progress iterations lost to fault/preemption restarts, tail
    #: JCT, and goodput — delivered samples (finished + partial progress
    #: carried by preempted jobs) per second of makespan
    faults: int = 0
    cancelled: int = 0
    work_lost: int = 0
    p99_jct: float = math.nan
    goodput: float = 0.0
    #: event-calendar high-water mark (O(cluster) bound check; 0 = fluid
    #: backend or pre-obs record)
    peak_calendar: int = 0
    #: observability-layer JCT decomposition aggregates (repro.obs): mean
    #: fraction of a finished job's JCT lost to contention stretch /
    #: gating wait.  NaN when the run was not observed
    #: (``observe=None``) — absent data, not zero.
    stretch_frac: float = math.nan
    gating_frac: float = math.nan

    def as_csv_row(self) -> str:
        vals = []
        for f in CSV_FIELDS:
            v = getattr(self, f)
            if isinstance(v, float):
                # fractions are small (often < 0.01): two decimals would
                # round every cell to 0.00
                vals.append(f"{v:.4f}" if f.endswith("_frac") else f"{v:.2f}")
            else:
                vals.append(str(v))
        return ",".join(vals)

    @staticmethod
    def csv_header() -> str:
        return ",".join(CSV_FIELDS)


def from_jcts(
    jcts: Sequence[float],
    *,
    scenario: str,
    backend: str,
    placement: str,
    comm: str,
    seed: int,
    n_jobs: int,
    makespan: float,
    gpu_util: float = math.nan,
    comm_contended: int = 0,
    comm_clean: int = 0,
    wall_s: float = 0.0,
    sched: str = "static",
    censored: Optional[int] = None,
    preemptions: int = 0,
    resizes: int = 0,
    faults: int = 0,
    cancelled: int = 0,
    work_lost: int = 0,
    p99_jct: Optional[float] = None,
    goodput: float = 0.0,
    peak_calendar: int = 0,
    stretch_frac: float = math.nan,
    gating_frac: float = math.nan,
) -> RunMetrics:
    jcts = [float(x) for x in jcts]
    n_fin = len(jcts)
    return RunMetrics(
        scenario=scenario,
        backend=backend,
        placement=placement,
        comm=comm,
        seed=seed,
        n_jobs=n_jobs,
        n_finished=n_fin,
        avg_jct=(sum(jcts) / n_fin) if n_fin else math.nan,
        median_jct=median(jcts),
        p95_jct=percentile(jcts, 0.95),
        makespan=float(makespan),
        gpu_util=float(gpu_util),
        comm_contended=comm_contended,
        comm_clean=comm_clean,
        wall_s=wall_s,
        sched=sched,
        censored=(n_jobs - n_fin) if censored is None else censored,
        preemptions=preemptions,
        resizes=resizes,
        faults=faults,
        cancelled=cancelled,
        work_lost=work_lost,
        p99_jct=percentile(jcts, 0.99) if p99_jct is None else float(p99_jct),
        goodput=goodput,
        peak_calendar=peak_calendar,
        stretch_frac=stretch_frac,
        gating_frac=gating_frac,
    )


def from_event_result(
    res: SimResult,
    *,
    scenario: str,
    seed: int,
    n_jobs: int,
    wall_s: float = 0.0,
) -> RunMetrics:
    return from_jcts(
        list(res.jct.values()),
        scenario=scenario,
        backend="event",
        placement=res.placement_name,
        comm=res.policy_name,
        seed=seed,
        n_jobs=n_jobs,
        makespan=res.makespan,
        gpu_util=res.gpu_util,
        comm_contended=res.comm_started_contended,
        comm_clean=res.comm_started_clean,
        wall_s=wall_s,
        sched=res.sched_name,
        censored=res.censored,
        preemptions=res.preemptions,
        resizes=res.resizes,
        faults=res.faults,
        cancelled=res.cancelled,
        work_lost=res.work_lost_samples,
        p99_jct=res.p99_jct(),
        goodput=res.goodput,
        peak_calendar=res.peak_calendar,
        stretch_frac=(
            res.obs.mean_stretch_frac() if res.obs is not None else math.nan
        ),
        gating_frac=(
            res.obs.mean_gating_frac() if res.obs is not None else math.nan
        ),
    )


def replay_summary(
    res: SimResult, window_s: float, warmup_frac: float = 0.1
) -> Dict[str, float]:
    """Flat windowed steady-state summary of one (typically streaming)
    replay run — the ``SimResult.steady_state`` sliding-horizon metrics
    (sustained goodput, sustained finish rate, p99 JCT, queueing delay)
    plus the run-level scale counters, in one JSON-ready dict.  This is
    what ``benchmarks/run.py --only engine`` records for the trace-replay
    cell."""
    out = dict(res.steady_state(window_s, warmup_frac=warmup_frac))
    out.update(
        makespan=res.makespan,
        n_finished=float(len(res.jct)),
        censored=float(res.censored),
        goodput=res.goodput,
        events=float(res.events_processed),
        peak_calendar=float(res.peak_calendar),
    )
    if res.phase_seconds:
        # profile_phases=True: where the simulator's own wall-clock went
        # (comm integration / event dispatch / gating / GPU scheduling)
        out.update({f"phase_{k}_s": float(v) for k, v in res.phase_seconds.items()})
    return out


CI_CSV_FIELDS = (
    "scenario",
    "backend",
    "placement",
    "comm",
    "n_seeds",
    "avg_jct_mean",
    "avg_jct_std",
    "p95_jct_mean",
    "makespan_mean",
    "makespan_std",
    "finished_frac",
    "wall_s",
)


@dataclasses.dataclass(frozen=True)
class CellCI:
    """Mean +/- std over seeds for one scenario x backend x placement x comm
    cell — the Monte-Carlo confidence-interval row."""

    scenario: str
    backend: str
    placement: str
    comm: str
    n_seeds: int
    avg_jct_mean: float
    avg_jct_std: float
    p95_jct_mean: float
    makespan_mean: float
    makespan_std: float
    finished_frac: float
    wall_s: float

    def as_csv_row(self) -> str:
        vals = []
        for f in CI_CSV_FIELDS:
            v = getattr(self, f)
            vals.append(f"{v:.2f}" if isinstance(v, float) else str(v))
        return ",".join(vals)

    @staticmethod
    def csv_header() -> str:
        return ",".join(CI_CSV_FIELDS)


def _mean_std(xs: Sequence[float]) -> Tuple[float, float]:
    if not xs:
        return math.nan, math.nan
    mu = sum(xs) / len(xs)
    var = sum((x - mu) ** 2 for x in xs) / len(xs)
    return mu, math.sqrt(var)


def ci_from_runs(records: Sequence[RunMetrics]) -> List[CellCI]:
    """Collapse per-seed :class:`RunMetrics` into one :class:`CellCI` per
    (scenario, backend, placement, comm) cell — population std over seeds."""
    groups: Dict[Tuple[str, str, str, str], List[RunMetrics]] = {}
    for r in records:
        groups.setdefault((r.scenario, r.backend, r.placement, r.comm), []).append(r)
    out: List[CellCI] = []
    for (scn, backend, placement, comm), rs in sorted(groups.items()):
        avg_mu, avg_sd = _mean_std([r.avg_jct for r in rs])
        p95_mu, _ = _mean_std([r.p95_jct for r in rs])
        mk_mu, mk_sd = _mean_std([r.makespan for r in rs])
        out.append(
            CellCI(
                scenario=scn,
                backend=backend,
                placement=placement,
                comm=comm,
                n_seeds=len(rs),
                avg_jct_mean=avg_mu,
                avg_jct_std=avg_sd,
                p95_jct_mean=p95_mu,
                makespan_mean=mk_mu,
                makespan_std=mk_sd,
                finished_frac=sum(r.n_finished for r in rs)
                / max(1, sum(r.n_jobs for r in rs)),
                wall_s=sum(r.wall_s for r in rs),
            )
        )
    return out


def summarize(records: Sequence[RunMetrics]) -> Dict[str, Dict[str, float]]:
    """Aggregate per (scenario, backend, placement, comm): mean avg-JCT,
    mean makespan, mean utilization and total finished over seeds."""
    groups: Dict[str, List[RunMetrics]] = {}
    for r in records:
        groups.setdefault(
            f"{r.scenario}/{r.backend}/{r.placement}/{r.comm}", []
        ).append(r)
    out: Dict[str, Dict[str, float]] = {}
    for key, rs in sorted(groups.items()):
        out[key] = {
            "avg_jct": sum(r.avg_jct for r in rs) / len(rs),
            "p95_jct": sum(r.p95_jct for r in rs) / len(rs),
            "makespan": sum(r.makespan for r in rs) / len(rs),
            "gpu_util": sum(r.gpu_util for r in rs) / len(rs),
            "finished_frac": sum(r.n_finished for r in rs)
            / max(1, sum(r.n_jobs for r in rs)),
            "n_runs": float(len(rs)),
        }
    return out
