"""Throughput regression gate over ``BENCH_*.json`` artifacts.

Compares a freshly-measured bench JSON against a committed baseline and
fails (exit 1) when any throughput metric dropped by more than the
threshold (default 20%).  Throughput keys are auto-detected: every
numeric top-level key ending in ``_per_sec`` that both files share
(``fluid_traces_per_sec``, ``events_per_sec``, ``stress_events_per_sec``,
...).  Higher is better for all of them; improvements never fail.

    PYTHONPATH=src python -m benchmarks.compare BENCH_topology.json \
        results/BENCH_topology.json [--threshold 0.2]

Multiple baseline/current pairs can be gated in one invocation:

    python -m benchmarks.compare a_base.json a_new.json b_base.json b_new.json

``--max-wall KEY=SECONDS`` (repeatable) additionally bounds absolute
wall-clock keys in the *current* files — how the nightly run asserts the
100k-job trace replay still finishes inside its budget:

    python -m benchmarks.compare BENCH_engine.json results/BENCH_engine.json \
        --max-wall replay_wall_s=900

A named key missing from every current file fails the gate too (a silent
key rename must not disarm the bound).

Provenance blocks (git sha / timestamp / host) from both files are
printed alongside any regression so a nightly alert is attributable —
absolute throughput is machine-dependent, and a cross-host comparison is
flagged as such rather than silently trusted.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def throughput_keys(base: Dict, cur: Dict) -> List[str]:
    return sorted(
        k
        for k in base
        if k.endswith("_per_sec")
        and isinstance(base.get(k), (int, float))
        and isinstance(cur.get(k), (int, float))
    )


def vanished_keys(base: Dict, cur: Dict) -> List[str]:
    """Baseline ``*_per_sec`` keys with no numeric counterpart in the
    current file — a renamed or dropped bench cell.  Warned about loudly:
    a silently-vanishing key would detach that cell from the gate."""
    return sorted(
        k
        for k in base
        if k.endswith("_per_sec")
        and isinstance(base.get(k), (int, float))
        and not isinstance(cur.get(k), (int, float))
    )


def compare_pair(
    base_path: str, cur_path: str, threshold: float
) -> Tuple[List[str], List[str], List[str]]:
    """Returns (report_lines, regression_lines, warning_lines) for one
    baseline/current pair; an empty regression list means the pair
    passes.  Warnings flag baseline ``*_per_sec`` keys that vanished from
    the current file — a renamed bench cell must be renamed in the
    committed baseline too, not silently dropped from the gate."""
    with open(base_path) as f:
        base = json.load(f)
    with open(cur_path) as f:
        cur = json.load(f)
    lines: List[str] = [f"{base_path} -> {cur_path}"]
    regressions: List[str] = []
    warnings: List[str] = []
    for k in vanished_keys(base, cur):
        warnings.append(
            f"{cur_path}: baseline key {k!r} has no numeric counterpart in "
            "the current file — renamed or dropped bench cell? it is no "
            "longer gated (update the committed baseline)"
        )
        lines.append(f"  {k}: {float(base[k]):.4g} -> MISSING (ungated!)")
    keys = throughput_keys(base, cur)
    if not keys:
        lines.append("  (no shared *_per_sec keys — nothing to gate)")
        return lines, regressions, warnings
    bp = base.get("provenance") or {}
    cp = cur.get("provenance") or {}
    if bp or cp:
        lines.append(
            f"  baseline: sha={bp.get('git_sha', '?')[:12]} "
            f"host={bp.get('host', '?')} at={bp.get('timestamp_utc', '?')}"
        )
        lines.append(
            f"  current:  sha={cp.get('git_sha', '?')[:12]} "
            f"host={cp.get('host', '?')} at={cp.get('timestamp_utc', '?')}"
        )
        if bp.get("host") and cp.get("host") and bp["host"] != cp["host"]:
            lines.append(
                "  WARNING: different hosts — absolute throughput is "
                "machine-dependent, treat the gate with suspicion"
            )
    for k in keys:
        b, c = float(base[k]), float(cur[k])
        change = (c - b) / b if b else 0.0
        verdict = "ok"
        if b > 0 and c < b * (1.0 - threshold):
            verdict = "REGRESSION"
            regressions.append(
                f"{cur_path}: {k} fell {-change * 100.0:.1f}% "
                f"({b:.4g} -> {c:.4g}, threshold {threshold * 100.0:.0f}%)"
            )
        lines.append(f"  {k}: {b:.4g} -> {c:.4g} ({change:+.1%}) {verdict}")
    return lines, regressions, warnings


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "files",
        nargs="+",
        metavar="BASELINE CURRENT",
        help="baseline/current JSON pairs (even count)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.2,
        help="max allowed fractional throughput drop (default 0.2 = 20%%)",
    )
    ap.add_argument(
        "--max-wall",
        action="append",
        default=[],
        metavar="KEY=SECONDS",
        help="absolute wall-clock bound on a numeric key of the current "
        "files (repeatable); exceeding it — or the key being absent from "
        "every current file — fails the gate",
    )
    args = ap.parse_args(argv)
    if len(args.files) % 2:
        ap.error("expected an even number of files (baseline/current pairs)")
    bounds: List[Tuple[str, float]] = []
    for spec in args.max_wall:
        key, _, limit = spec.partition("=")
        try:
            bounds.append((key, float(limit)))
        except ValueError:
            ap.error(f"bad --max-wall {spec!r}: expected KEY=SECONDS")
    all_regressions: List[str] = []
    all_warnings: List[str] = []
    for i in range(0, len(args.files), 2):
        lines, regressions, warnings = compare_pair(
            args.files[i], args.files[i + 1], args.threshold
        )
        print("\n".join(lines))
        all_regressions.extend(regressions)
        all_warnings.extend(warnings)
    if all_warnings:
        print("\nWARNINGS (ungated keys):", file=sys.stderr)
        for w in all_warnings:
            print(f"  {w}", file=sys.stderr)
    for key, limit in bounds:
        found = False
        for cur_path in args.files[1::2]:
            with open(cur_path) as f:
                cur = json.load(f)
            val = cur.get(key)
            if isinstance(val, (int, float)):
                found = True
                verdict = "ok" if val <= limit else "OVER BUDGET"
                print(f"{cur_path}: {key} = {val:.4g}s (max {limit:.4g}s) {verdict}")
                if val > limit:
                    all_regressions.append(
                        f"{cur_path}: {key} {val:.4g}s exceeds the "
                        f"{limit:.4g}s wall-clock bound"
                    )
        if not found:
            all_regressions.append(
                f"--max-wall {key}: key absent from every current file "
                "(renamed or dropped? the bound cannot be enforced)"
            )
    if all_regressions:
        print("\nTHROUGHPUT REGRESSIONS:", file=sys.stderr)
        for r in all_regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print("\nall throughput metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
