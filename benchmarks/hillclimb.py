"""§Perf hillclimbing driver: named experiment variants for the three
chosen (arch x shape) pairs, each recording the full dry-run analysis to
results/hillclimb.json.  EXPERIMENTS.md §Perf narrates the
hypothesis -> change -> before/after -> confirmed/refuted chain over these
entries.

    PYTHONPATH=src python -m benchmarks.hillclimb --pair arctic_train --variant v1_chunked_ce
    PYTHONPATH=src python -m benchmarks.hillclimb --pair arctic_train --all
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

from repro.launch.dryrun import Profile, run_combo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import INPUT_SHAPES  # noqa: E402

# ---------------------------------------------------------------------------
# The three hillclimb pairs (chosen from the 40-combo baseline table):
#   arctic_train : worst roofline fraction (HBM 17.7x over budget,
#                  memory term 87.8 s) — memory-dominant
#   vlm_decode   : most collective-bound (coll 1.37 s vs mem 0.44 s,
#                  involuntary resharding of the KV cache every step)
#   jamba_train  : gradient all-reduce pathology (138.8 GB/chip payload) —
#                  the communication-contention cost the paper itself
#                  schedules around
# ---------------------------------------------------------------------------

PAIRS = {
    "arctic_train": ("arctic_480b", "train_4k"),
    "vlm_decode": ("llama32_vision_11b", "decode_32k"),
    "jamba_train": ("jamba_v01_52b", "train_4k"),
}

# Variants: name -> Profile fields (the Profile carries every §Perf knob).
VARIANTS = {
    # naive starting point (paper has no sharding opinion; this is the
    # "first thing one would write"): tensor-parallel, f32 moments, no remat
    "v0_baseline": dict(strategy="tp", moment_dtype="float32", remat="none"),
    # tuned profile as used in the 40-combo table
    "v0_tuned": None,  # filled from dryrun.TUNED_PROFILES
    # memory ladder
    "v1_chunked_ce": dict(loss_impl="chunked"),
    "v2_dots_remat": dict(remat="dots"),
    "v3_capacity_1_0": dict(capacity_factor=1.0),
    "v4_q_chunk_256": dict(q_chunk=256),
    "v5_constrain_acts": dict(constrain_acts=True),
    "v6_acts_plus_chunked_ce": dict(constrain_acts=True, loss_impl="chunked"),
    # collective ladder
    "c1_no_zero1": dict(strategy="tp"),
    "c2_moments_bf16": dict(moment_dtype="bfloat16"),
    "c3_fsdp": dict(strategy="fsdp"),
    # decode ladder
    "d1_seq_major_cache": dict(decode_cache_mode="seq"),
    "d2_batch_only_cache": dict(decode_cache_mode="batch"),
    "d3_constrained_attn": dict(decode_constrain=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", nargs="+", default=None)
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()

    from repro.launch.dryrun import TUNED_PROFILES

    arch, shape_name = PAIRS[args.pair]
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    tuned = TUNED_PROFILES[arch]

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for name in args.variant:
        overrides = VARIANTS[name]
        profile = tuned if overrides is None else dataclasses.replace(tuned, **overrides)
        key = f"{args.pair}|{name}"
        print(f"[hillclimb] {key}: profile={profile}", flush=True)
        t0 = time.time()
        try:
            res = run_combo(arch, shape, mesh, profile, correct_scan=True)
        except Exception as e:
            res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
        res["wall_s"] = round(time.time() - t0, 1)
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if res["status"] == "ok":
            r = res["roofline"]
            m = res["memory"]
            print(
                f"[hillclimb] {key}: compute={r['compute_s']:.3f}s "
                f"mem={r['memory_s']:.3f}s coll={r['collective_s']:.3f}s "
                f"dominant={r['dominant']} hbm={r['hbm_peak_frac']:.2f} "
                f"temp={m['temp_bytes']/2**30:.1f}GiB useful={r['useful_flops_ratio']:.3f}",
                flush=True,
            )
        else:
            print(f"[hillclimb] {key}: {res['status']} {res.get('error','')[:200]}", flush=True)


if __name__ == "__main__":
    main()
