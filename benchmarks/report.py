"""Render EXPERIMENTS.md tables from the dry-run artifact.

    PYTHONPATH=src python -m benchmarks.report [--json results/dryrun.json]

Prints the §Dry-run and §Roofline markdown tables; EXPERIMENTS.md embeds
the output (regenerate after re-running the dry-run).
"""

from __future__ import annotations

import argparse
import json
from typing import Dict


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TB"


def recompute_rooflines(data: Dict, mesh: str = "single") -> Dict:
    """Re-derive the roofline block from stored cost_corrected (keeps the
    table consistent when the analytic MODEL_FLOPS model is refined after a
    sweep)."""
    from repro.launch.roofline import config_for_shape, roofline_terms
    from repro.models.config import INPUT_SHAPES

    chips = 256 if mesh == "single" else 512
    for key, res in data.items():
        if res.get("status") != "ok" or f"|{mesh}|" not in key:
            continue
        arch, shape_name, _, _ = key.split("|")
        cfg = config_for_shape(arch, INPUT_SHAPES[shape_name])
        res["roofline"] = roofline_terms(cfg, INPUT_SHAPES[shape_name], chips, res)
    return data


def render(data: Dict, mesh: str = "single", profile: str = "tuned") -> str:
    data = recompute_rooflines(data, mesh)
    rows = []
    for key in sorted(data):
        arch, shape, m, p = key.split("|")
        if m != mesh or p != profile:
            continue
        res = data[key]
        if res.get("status") == "skipped":
            rows.append((arch, shape, "skipped", res.get("note", "")))
        elif res.get("status") == "ok":
            rows.append((arch, shape, "ok", res))
        else:
            rows.append((arch, shape, "ERROR", res.get("error", "")[:80]))

    out = []
    out.append(f"### Dry-run ({mesh}-pod mesh, profile={profile})\n")
    out.append(
        "| arch | shape | status | per-chip args | per-chip temp | HBM frac "
        "| collectives (per-chip payload) | compile |"
    )
    out.append("|---|---|---|---|---|---|---|---|")
    for arch, shape, status, res in rows:
        if status != "ok":
            out.append(f"| {arch} | {shape} | {status} | — | — | — | {res} | — |")
            continue
        mem = res["memory"]
        coll = res["collectives"]
        kinds = ", ".join(
            f"{k}:{fmt_bytes(v)}"
            for k, v in coll.items()
            if k not in ("total", "op_counts") and v > 0
        ) or "none"
        out.append(
            f"| {arch} | {shape} | ok | {fmt_bytes(mem['argument_bytes'])} "
            f"| {fmt_bytes(mem['temp_bytes'])} "
            f"| {res['roofline']['hbm_peak_frac']:.2f} "
            f"| {kinds} | {res.get('compile_s', '?')}s |"
        )

    out.append(f"\n### Roofline ({mesh}-pod, 256 chips, per step)\n")
    out.append(
        "| arch | shape | compute [s] | memory [s] | collective [s] | dominant "
        "| MODEL_FLOPS | useful ratio | next move |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|")
    moves = {
        "compute": "raise arithmetic intensity / bigger per-chip batch",
        "memory": "remat policy + fused attention (cut bytes accessed)",
        "collective": "reshard (cut all-gathers), overlap collectives",
    }
    for arch, shape, status, res in rows:
        if status != "ok":
            continue
        r = res["roofline"]
        out.append(
            f"| {arch} | {shape} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_flops']:.3e} | {r['useful_flops_ratio']:.3f} "
            f"| {moves[r['dominant']]} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--profile", default="tuned")
    args = ap.parse_args()
    with open(args.json) as f:
        data = json.load(f)
    print(render(data, args.mesh, args.profile))


if __name__ == "__main__":
    main()
