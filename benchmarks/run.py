"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default scale is reduced so
``python -m benchmarks.run`` completes in minutes on one CPU; pass
``--full`` for the paper-scale 160-job/64-GPU configuration used in
EXPERIMENTS.md (the headline numbers there come from --full runs).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table5 ...]

Scenario-engine sweeps (``--scenario``) print one RunMetrics CSV row per
scenario x placement x comm x seed cell, on either backend:

    # event backend, one scenario x policy matrix
    PYTHONPATH=src python -m benchmarks.run --scenario philly_heavy_tail \
        --policy adadual srsf1 srsf2
    # fluid backend incl. k-way AdaDUAL and placement modes
    PYTHONPATH=src python -m benchmarks.run --scenario hetero_bandwidth \
        --backend fluid --policy ada kway3 --placement lwf ff
    # mean +/- std confidence intervals per cell; fluid batches every seed
    # into ONE vmapped device launch (CellCI CSV rows)
    PYTHONPATH=src python -m benchmarks.run --scenario all --ci \
        --seeds 0 1 2 3 4 --backend fluid
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List, Tuple

from repro.core import (
    ContentionParams,
    PAPER_A,
    PAPER_B,
    allreduce_cost_terms,
    fit_linear_cost,
    paper_trace,
    simulate,
)
from repro.core.contention import fit_contention_penalty, simulate_contention_sweep

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def provenance() -> Dict[str, object]:
    """Run provenance stamped into every ``BENCH_*.json``: git sha (with a
    ``-dirty`` suffix when the tree has local edits), UTC timestamp and
    host identity.  ``benchmarks/compare.py`` prints these when flagging a
    regression so a nightly alert is attributable to a commit + machine."""
    import datetime
    import platform
    import subprocess

    repo = os.path.dirname(os.path.abspath(__file__))
    sha = "unknown"
    try:
        p = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, cwd=repo, timeout=10,
        )
        if p.returncode == 0:
            sha = p.stdout.strip()
            q = subprocess.run(
                ["git", "status", "--porcelain"],
                capture_output=True, text=True, cwd=repo, timeout=10,
            )
            if q.returncode == 0 and q.stdout.strip():
                sha += "-dirty"
    except (OSError, subprocess.SubprocessError):
        pass
    try:
        from importlib.metadata import version

        jax_version = version("jax")
    except Exception:  # jax absent: the event-only benches still stamp
        jax_version = "unknown"
    return {
        "git_sha": sha,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "jax_version": jax_version,
    }


def trace_for(full: bool, seed: int = 0):
    if full:
        return paper_trace(seed=seed)
    return paper_trace(seed=seed, n_jobs=64, min_iters=200, max_iters=1200)


# ---------------------------------------------------------------------------
# Table I — All-Reduce algorithm costs
# ---------------------------------------------------------------------------


def bench_table1(full: bool) -> None:
    alpha, beta, gamma = 5e-5, 8e-10, 1e-10  # 10GbE-flavoured
    m = 100e6
    for alg in ("binary_tree", "recursive_doubling", "recursive_halving_doubling", "ring"):
        a, b = allreduce_cost_terms(alg, 16, alpha, beta, gamma)
        t = (a + b * m) * 1e6
        emit(f"table1/{alg}", t, f"a={a:.3e};b={b:.3e}")


# ---------------------------------------------------------------------------
# Fig. 2(a) — single All-Reduce cost model fit
# ---------------------------------------------------------------------------


def bench_fig2a(full: bool) -> None:
    import numpy as np

    rng = np.random.default_rng(0)
    ms = np.linspace(1e6, 500e6, 60)
    ts = PAPER_A + PAPER_B * ms
    ts = ts * (1 + rng.normal(0, 0.02, ts.shape))  # 2% measurement noise
    t0 = time.time()
    a, b = fit_linear_cost(ms, ts)
    dt = (time.time() - t0) * 1e6
    emit(
        "fig2a/fit",
        dt,
        f"a={a:.3e}(paper {PAPER_A:.3e});b={b:.3e}(paper {PAPER_B:.3e})",
    )


# ---------------------------------------------------------------------------
# Fig. 2(b) — k-way contention sweep
# ---------------------------------------------------------------------------


def bench_fig2b(full: bool) -> None:
    p = ContentionParams()
    m = 100e6
    times = simulate_contention_sweep(p, m, 8)
    ideal_share = [(p.a + k * p.b * m) for k in range(1, 9)]
    for k, (t, ideal) in enumerate(zip(times, ideal_share), start=1):
        eff = ideal / t
        emit(f"fig2b/k={k}", t * 1e6, f"bandwidth_efficiency={eff:.3f}")
    import numpy as np

    eta = fit_contention_penalty(np.arange(1, 9), times, m, p.a, p.b)
    emit("fig2b/eta_refit", 0.0, f"eta={eta:.3e}(truth {p.eta:.3e})")


# ---------------------------------------------------------------------------
# Table IV / Fig. 4 — placement comparison under Ada-SRSF
# ---------------------------------------------------------------------------


def bench_table4(full: bool) -> None:
    jobs = trace_for(full)
    for placement in ("rand", "ff", "ls", "lwf"):
        t0 = time.time()
        res = simulate(jobs, placement=placement, kappa=1, comm="ada")
        dt = (time.time() - t0) * 1e6
        emit(
            f"table4/{placement}",
            dt,
            f"avg_jct={res.avg_jct():.1f};median={res.median_jct():.1f};"
            f"p95={res.p95_jct():.1f};util={res.gpu_util:.4f};finished={len(res.jct)}",
        )


# ---------------------------------------------------------------------------
# Fig. 5 — kappa sweep for LWF
# ---------------------------------------------------------------------------


def bench_fig5(full: bool) -> None:
    jobs = trace_for(full)
    for kappa in (1, 2, 4, 8):
        t0 = time.time()
        res = simulate(jobs, placement="lwf", kappa=kappa, comm="ada")
        dt = (time.time() - t0) * 1e6
        emit(
            f"fig5/kappa={kappa}",
            dt,
            f"avg_jct={res.avg_jct():.1f};util={res.gpu_util:.4f}",
        )


# ---------------------------------------------------------------------------
# Table V / Fig. 6 — communication scheduling comparison under LWF-1
# ---------------------------------------------------------------------------


def bench_table5(full: bool) -> None:
    jobs = trace_for(full)
    for comm in ("srsf1", "srsf2", "srsf3", "ada", "kway3"):
        t0 = time.time()
        res = simulate(jobs, placement="lwf", kappa=1, comm=comm)
        dt = (time.time() - t0) * 1e6
        tag = "table5" if comm != "kway3" else "beyond/kway"
        emit(
            f"{tag}/{comm}",
            dt,
            f"avg_jct={res.avg_jct():.1f};median={res.median_jct():.1f};"
            f"p95={res.p95_jct():.1f};util={res.gpu_util:.4f};"
            f"contended={res.comm_started_contended};finished={len(res.jct)}",
        )


# ---------------------------------------------------------------------------
# Beyond-paper: chunked / preemptible communication (future-work #3 adjacent)
# ---------------------------------------------------------------------------


def bench_chunked(full: bool) -> None:
    """Contention-heavy scenario: many multi-server jobs share few servers;
    chunking lets short messages preempt long in-flight transfers."""
    from repro.core.cluster import TABLE_III, JobSpec

    jobs = []
    jid = 0
    for wave in range(6 if full else 3):
        for model, iters in (("vgg16", 400), ("resnet50", 400), ("resnet50", 400)):
            jobs.append(JobSpec(jid, wave * 5.0, 8, iters, TABLE_III[model]))
            jid += 1
    for chunks in (1, 4, 8):
        for comm in ("srsf1", "ada"):
            t0 = time.time()
            res = simulate(jobs, placement="lwf", comm=comm, comm_chunks=chunks,
                           n_servers=4, gpus_per_server=4)
            dt = (time.time() - t0) * 1e6
            emit(
                f"beyond/chunked{chunks}/{comm}",
                dt,
                f"avg_jct={res.avg_jct():.1f};p95={res.p95_jct():.1f};"
                f"util={res.gpu_util:.4f};finished={len(res.jct)}",
            )


# ---------------------------------------------------------------------------
# Scenario engine sweep (src/repro/scenarios)
# ---------------------------------------------------------------------------

def _scenario_sweep(
    names, policies, placements, seeds, backend, processes, full, ci=False,
    kappas=(1,), sched=None, bw_aware_srsf=False, obs=False,
) -> None:
    from repro.scenarios import QUICK_OVERRIDES, metrics as metrics_mod
    from repro.scenarios import scenario_names, sweep, sweep_ci

    if names == ["all"]:
        names = scenario_names()
        if backend == "fluid":
            # fault injection and streaming trace replay are event-only
            # (run_scenario_fluid raises on an armed chaos spec or an
            # unmaterialized source): 'all' means 'all supported' here,
            # while naming such a scenario explicitly still fails loudly
            names = [
                n for n in names
                if not n.startswith(("chaos_", "trace_replay_"))
            ]
    sim_kw = {}
    if sched is not None:
        sim_kw["sched"] = sched
    if bw_aware_srsf:
        sim_kw["bandwidth_aware_srsf"] = True
    if obs:
        # arm the JCT decomposition so the stretch_frac / gating_frac CSV
        # columns carry data (event backend only — the fluid sweep rejects
        # engine sim_kw)
        if backend == "fluid":
            raise SystemExit("--obs requires the event backend")
        from repro.obs import ObsConfig

        sim_kw["observe"] = ObsConfig(decompose=True)
    header_done = False
    for kappa in kappas:
        kw = dict(
            comms=policies,
            placements=placements,
            kappa=kappa,
            seeds=seeds,
            backend=backend,
            per_scenario_overrides={} if full else QUICK_OVERRIDES,
            processes=processes,
            sim_kw=sim_kw or None,
        )
        if ci:
            if not header_done:
                print(metrics_mod.CellCI.csv_header(), flush=True)
                header_done = True
            for r in sweep_ci(names, **kw):
                print(r.as_csv_row(), flush=True)
            continue
        if not header_done:
            print(metrics_mod.RunMetrics.csv_header(), flush=True)
            header_done = True
        for r in sweep(names, **kw):
            print(r.as_csv_row(), flush=True)


def bench_scenarios(full: bool) -> None:
    """Default-path smoke of the scenario sweep: two cheap scenarios."""
    from repro.scenarios import QUICK_OVERRIDES, sweep

    for name in ("smoke", "adversarial_allbig"):
        t0 = time.time()
        records = sweep(
            [name],
            comms=("ada", "srsf1", "srsf2"),
            seeds=(0,),
            per_scenario_overrides={} if full else QUICK_OVERRIDES,
        )
        dt = (time.time() - t0) * 1e6 / max(1, len(records))
        for r in records:
            emit(
                f"scenarios/{name}/{r.comm}",
                dt,
                f"avg_jct={r.avg_jct:.1f};p95={r.p95_jct:.1f};"
                f"makespan={r.makespan:.1f};util={r.gpu_util:.4f};"
                f"finished={r.n_finished}",
            )


# ---------------------------------------------------------------------------
# Topology-aware scheduling (core/topology.py) + fluid batched throughput
# ---------------------------------------------------------------------------


def bench_topology(full: bool) -> None:
    """oversub_fabric on both backends, the rack-aware placement payoff on
    rack_locality, and the fluid backend's batched Monte-Carlo throughput
    (traces/sec through one vmapped launch), persisted to
    ``BENCH_topology.json`` (path override: ``REPRO_BENCH_TOPOLOGY_JSON``)
    so the nightly workflow can track the trend."""
    import numpy as np

    from repro.core.jaxsim import (
        simulate_traces_batched,
        stack_traces,
        trace_from_jobs,
    )
    from repro.scenarios import QUICK_OVERRIDES, get_scenario
    from repro.scenarios.sweep import fluid_config, run_scenario_event

    overrides = {} if full else QUICK_OVERRIDES["oversub_fabric"]
    seeds = list(range(8))
    scns = [get_scenario("oversub_fabric", seed=s, **overrides) for s in seeds]
    cfg = fluid_config(scns[0], comm="ada", placement="lwf")
    batch = stack_traces([trace_from_jobs(s.job_list()) for s in scns])

    # compile once, then time steady-state launches (numpy conversion syncs)
    np.asarray(simulate_traces_batched(batch, cfg)["makespan"])
    n_rep = 3
    t0 = time.time()
    for _ in range(n_rep):
        out = simulate_traces_batched(batch, cfg)
        np.asarray(out["makespan"])
    wall = (time.time() - t0) / n_rep
    traces_per_sec = len(seeds) / wall
    jct = np.asarray(out["jct"])
    fin = np.asarray(out["finished"])
    fluid_avg = float(np.mean([jct[i][fin[i]].mean() for i in range(len(seeds))]))

    t0 = time.time()
    ev = run_scenario_event(scns[0], comm="ada")
    ev_wall = time.time() - t0

    rack = get_scenario("rack_locality", seed=1)
    plain = run_scenario_event(rack, comm="ada", placement="lwf")
    aware = run_scenario_event(rack, comm="ada", placement="lwf_rack")
    speedup = plain.makespan / aware.makespan

    emit(
        "topology/fluid_batched",
        wall * 1e6,
        f"traces_per_sec={traces_per_sec:.2f};avg_jct={fluid_avg:.1f};"
        f"n_seeds={len(seeds)}",
    )
    emit(
        "topology/event_oversub",
        ev_wall * 1e6,
        f"avg_jct={ev.avg_jct():.1f};finished={len(ev.jct)}",
    )
    emit("topology/rack_aware_speedup", 0.0, f"makespan_ratio={speedup:.2f}")

    path = os.environ.get("REPRO_BENCH_TOPOLOGY_JSON", "BENCH_topology.json")
    with open(path, "w") as f:
        json.dump(
            {
                "provenance": provenance(),
                "scenario": "oversub_fabric",
                "full": full,
                "n_seeds": len(seeds),
                "n_jobs": scns[0].n_jobs,
                "fluid_traces_per_sec": traces_per_sec,
                "fluid_wall_s_per_batch": wall,
                "fluid_avg_jct": fluid_avg,
                "event_avg_jct": ev.avg_jct(),
                "event_wall_s": ev_wall,
                "rack_aware_makespan_speedup": speedup,
            },
            f,
            indent=2,
        )
        f.write("\n")


# ---------------------------------------------------------------------------
# WFBP layer-granular communication subsystem (repro.workloads + fusion)
# ---------------------------------------------------------------------------


def bench_wfbp(full: bool) -> None:
    """The fusion threshold x policy grid on the event backend (the
    acceptance cell: finite fusion vs 'all' vs 'none' under Ada-SRSF), the
    model_zoo cell on both backends, and the fluid batched throughput over
    bucketed traces; key numbers persist to ``BENCH_wfbp.json`` (path
    override: ``REPRO_BENCH_WFBP_JSON``) for nightly trend tracking."""
    import dataclasses as _dc

    import numpy as np

    from repro.core.jaxsim import (
        simulate_traces_batched,
        stack_traces,
        trace_from_jobs,
    )
    from repro.scenarios import QUICK_OVERRIDES, get_scenario
    from repro.scenarios.sweep import fluid_config, run_scenario_event

    # fusion threshold x policy grid on the regression cell
    base = get_scenario("fusion_sweep", seed=1,
                        base_iters=80 if full else 40)
    grid: Dict[str, Dict[str, float]] = {}
    for fusion in ("all", "none", 16e6, 32e6, 128e6):
        tag = fusion if isinstance(fusion, str) else f"{int(fusion/1e6)}MB"
        scn = _dc.replace(base, fusion=fusion)
        grid[tag] = {}
        for comm in ("ada", "srsf1", "srsf2"):
            t0 = time.time()
            res = run_scenario_event(scn, comm=comm)
            dt = (time.time() - t0) * 1e6
            grid[tag][comm] = res.avg_jct()
            emit(
                f"wfbp/fusion={tag}/{comm}",
                dt,
                f"avg_jct={res.avg_jct():.2f};makespan={res.makespan:.2f};"
                f"contended={res.comm_started_contended};finished={len(res.jct)}",
            )
    finite_vs_all = grid["all"]["ada"] / grid["32MB"]["ada"]
    finite_vs_none = grid["none"]["ada"] / grid["32MB"]["ada"]
    emit("wfbp/finite_vs_all", 0.0, f"speedup={finite_vs_all:.3f}")
    emit("wfbp/finite_vs_none", 0.0, f"speedup={finite_vs_none:.3f}")

    # model_zoo on the event backend + fluid batched throughput
    overrides = {} if full else QUICK_OVERRIDES["model_zoo"]
    seeds = list(range(4))
    scns = [get_scenario("model_zoo", seed=s, **overrides) for s in seeds]
    t0 = time.time()
    ev = run_scenario_event(scns[0], comm="ada")
    ev_wall = time.time() - t0
    emit(
        "wfbp/event_model_zoo",
        ev_wall * 1e6,
        f"avg_jct={ev.avg_jct():.1f};finished={len(ev.jct)}",
    )
    cfg = fluid_config(scns[0], comm="ada", dt=0.01)
    batch = stack_traces(
        [trace_from_jobs(s.job_list(), fusion=s.fusion) for s in scns]
    )
    np.asarray(simulate_traces_batched(batch, cfg)["makespan"])  # compile
    n_rep = 3
    t0 = time.time()
    for _ in range(n_rep):
        out = simulate_traces_batched(batch, cfg)
        np.asarray(out["makespan"])
    wall = (time.time() - t0) / n_rep
    traces_per_sec = len(seeds) / wall
    jct = np.asarray(out["jct"])
    fin = np.asarray(out["finished"])
    fluid_avg = float(np.mean([jct[i][fin[i]].mean() for i in range(len(seeds))]))
    emit(
        "wfbp/fluid_batched",
        wall * 1e6,
        f"traces_per_sec={traces_per_sec:.2f};avg_jct={fluid_avg:.1f};"
        f"n_seeds={len(seeds)};buckets={int(batch['bucket_bytes'].shape[-1])}",
    )

    path = os.environ.get("REPRO_BENCH_WFBP_JSON", "BENCH_wfbp.json")
    with open(path, "w") as f:
        json.dump(
            {
                "provenance": provenance(),
                "full": full,
                "fusion_grid_avg_jct": grid,
                "finite_vs_all_speedup": finite_vs_all,
                "finite_vs_none_speedup": finite_vs_none,
                "model_zoo_event_avg_jct": ev.avg_jct(),
                "model_zoo_event_wall_s": ev_wall,
                "model_zoo_fluid_avg_jct": fluid_avg,
                "fluid_traces_per_sec": traces_per_sec,
                "n_seeds": len(seeds),
                "n_jobs": scns[0].n_jobs,
            },
            f,
            indent=2,
        )
        f.write("\n")


# ---------------------------------------------------------------------------
# Engine/policy split: events/sec + preemptive-vs-static avg JCT
# ---------------------------------------------------------------------------

#: Events/sec of the pre-refactor monolithic ClusterSimulator, measured at
#: the last pre-split commit (PR 4 HEAD) on the quick paper cell (seed 0,
#: n_jobs=40, iters 100-600, comm=ada, lwf, fuse_fb on, single CPU) — the
#: same cell bench_engine times below.  Absolute events/sec is
#: machine-dependent; the nightly artifact tracks the *trend* of the
#: refactored engine and this constant anchors the refactor-time ratio
#: (also recorded in tests/data/engine_regression_baseline.json).
PRE_REFACTOR_EVENTS_PER_SEC = 41984.0


def stream_trace(n_jobs: int, seed: int = 0, mean_gap: float = 0.05,
                 min_iters: int = 3, max_iters: int = 8):
    """Streaming-arrival stress workload: ``n_jobs`` small mixed-size jobs
    with exponential inter-arrival gaps, sized so a 16x4 cluster stays
    moderately loaded and the calendar drains as it fills (rather than the
    paper trace's burst of long jobs).  Shared by the ``--only engine``
    stress cell and the tier-1 linearity smoke test."""
    import numpy as np

    from repro.core.cluster import TABLE_III, JobSpec

    rng = np.random.default_rng(seed)
    models = ("resnet50", "vgg16", "inception_v3", "lstm_ptb")
    arrivals = np.cumsum(rng.exponential(mean_gap, n_jobs))
    return [
        JobSpec(
            j,
            float(arrivals[j]),
            int(rng.choice((1, 1, 2, 4))),
            int(rng.integers(min_iters, max_iters + 1)),
            TABLE_III[models[int(rng.integers(len(models)))]],
        )
        for j in range(n_jobs)
    ]


def bench_engine(
    full: bool, n_jobs: int = None, trace_source: str = "synth"
) -> None:
    """Throughput of the refactored event engine (events/sec on the quick
    paper cell, vs the recorded pre-refactor baseline), the 10k-job
    streaming-arrival stress cell (events/sec + peak calendar size + the
    per-event phase breakdown), the streaming TraceSource replay cell
    (``n_jobs`` lazy arrivals — 100k nightly — with windowed steady-state
    metrics), plus the preemptive-vs-static and elastic-vs-static avg-JCT
    cells on their regression seeds; persists ``BENCH_engine.json`` (path
    override: ``REPRO_BENCH_ENGINE_JSON``) for nightly trend tracking.

    ``n_jobs`` sizes the replay cell (CLI ``--n-jobs``; default 20k quick /
    100k with ``--full``); ``trace_source`` picks its arrival feed (CLI
    ``--trace-source``: 'synth', 'philly', 'alibaba', or
    'csv:<dialect>:<path>')."""
    from repro.scenarios import (
        QUICK_OVERRIDES,
        get_scenario,
        trace_source_from_spec,
    )
    from repro.scenarios import metrics as metrics_mod
    from repro.scenarios.sweep import run_scenario_event

    overrides = {} if full else QUICK_OVERRIDES["paper"]
    scn = get_scenario("paper", seed=0, **overrides)
    run_scenario_event(scn, comm="ada")  # warm caches
    n_rep = 3
    t0 = time.time()
    for _ in range(n_rep):
        res = run_scenario_event(scn, comm="ada")
    wall = (time.time() - t0) / n_rep
    eps = res.events_processed / wall
    emit(
        "engine/events_per_sec",
        wall * 1e6,
        f"events_per_sec={eps:.0f};events={res.events_processed};"
        f"vs_pre_refactor={eps / PRE_REFACTOR_EVENTS_PER_SEC:.3f}",
    )

    # 10k-job streaming-arrival stress cell: online arrivals at ~20 jobs/s
    # against a 16x2 cluster, list mode — the calendar holds every future
    # arrival up front, so peak size ~ n_jobs + O(cluster); events/sec is
    # the engine-scalability headline the nightly run trends.  Profiling is
    # on: 4 perf_counter reads per ~100us event are noise, and the phase
    # split (gating / dispatch / comm-advance / gpu-schedule) is what makes
    # a throughput regression attributable.
    stress_n = 10_000
    jobs = stream_trace(stress_n, seed=0)
    t0 = time.time()
    stress = simulate(jobs, placement="lwf", comm="ada",
                      n_servers=16, gpus_per_server=2, profile_phases=True)
    stress_wall = time.time() - t0
    stress_eps = stress.events_processed / stress_wall
    phases = stress.phase_seconds or {}
    emit(
        "engine/stress_10k_stream",
        stress_wall * 1e6,
        f"events_per_sec={stress_eps:.0f};events={stress.events_processed};"
        f"peak_calendar={stress.peak_calendar};finished={len(stress.jct)};"
        + ";".join(f"phase_{k}={v:.2f}" for k, v in sorted(phases.items())),
    )

    # Streaming TraceSource replay cell: the same engine consuming a lazy
    # arrival feed — the calendar stays O(live jobs + cluster) however long
    # the trace is, and the windowed steady-state metrics (sustained
    # goodput, p99 JCT, queueing delay over a sliding horizon) replace
    # whole-run averages that a 100k-job stream would wash out.
    replay_n = n_jobs if n_jobs is not None else (100_000 if full else 20_000)
    replay_src = trace_source_from_spec(trace_source, n_jobs=replay_n, seed=0)
    t0 = time.time()
    replay = simulate(replay_src, placement="lwf", comm="ada",
                      n_servers=16, gpus_per_server=2)
    replay_wall = time.time() - t0
    replay_eps = replay.events_processed / replay_wall
    replay_ss = metrics_mod.replay_summary(replay, window_s=60.0)
    emit(
        f"engine/trace_replay_{trace_source}",
        replay_wall * 1e6,
        f"events_per_sec={replay_eps:.0f};n_jobs={replay_n};"
        f"events={replay.events_processed};"
        f"peak_calendar={replay.peak_calendar};finished={len(replay.jct)};"
        f"sustained_goodput={replay_ss['sustained_goodput']:.1f};"
        f"p99_jct={replay_ss['p99_jct']:.2f}",
    )

    pre_scn = get_scenario("preemption_gain", seed=2)
    t0 = time.time()
    static = run_scenario_event(pre_scn, comm="ada")
    pre = run_scenario_event(pre_scn, comm="ada", sched="preemptive_srsf")
    pre_wall = time.time() - t0
    emit(
        "engine/preemptive_vs_static",
        pre_wall * 1e6,
        f"static_avg_jct={static.avg_jct():.2f};"
        f"preemptive_avg_jct={pre.avg_jct():.2f};"
        f"speedup={static.avg_jct() / pre.avg_jct():.3f};"
        f"preemptions={pre.preemptions}",
    )

    el_scn = get_scenario("elastic_surge", seed=1)
    el_static = run_scenario_event(el_scn, comm="ada")
    el = run_scenario_event(el_scn, comm="ada", sched="elastic")
    emit(
        "engine/elastic_vs_static",
        0.0,
        f"static_avg_jct={el_static.avg_jct():.2f};"
        f"elastic_avg_jct={el.avg_jct():.2f};"
        f"speedup={el_static.avg_jct() / el.avg_jct():.3f};resizes={el.resizes}",
    )

    path = os.environ.get("REPRO_BENCH_ENGINE_JSON", "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(
            {
                "provenance": provenance(),
                "full": full,
                "events_per_sec": eps,
                "events_processed": res.events_processed,
                "pre_refactor_events_per_sec": PRE_REFACTOR_EVENTS_PER_SEC,
                "vs_pre_refactor": eps / PRE_REFACTOR_EVENTS_PER_SEC,
                "stress_n_jobs": stress_n,
                "stress_events_per_sec": stress_eps,
                "stress_events_processed": stress.events_processed,
                "stress_peak_calendar": stress.peak_calendar,
                "stress_finished": len(stress.jct),
                "stress_phase_seconds": phases,
                "replay_trace_source": trace_source,
                "replay_n_jobs": replay_n,
                "replay_events_per_sec": replay_eps,
                "replay_events_processed": replay.events_processed,
                "replay_peak_calendar": replay.peak_calendar,
                "replay_finished": len(replay.jct),
                "replay_wall_s": replay_wall,
                "replay_steady_state": replay_ss,
                "preemption_gain_seed": 2,
                "static_avg_jct": static.avg_jct(),
                "preemptive_avg_jct": pre.avg_jct(),
                "preemptive_speedup": static.avg_jct() / pre.avg_jct(),
                "preemptions": pre.preemptions,
                "elastic_surge_seed": 1,
                "elastic_static_avg_jct": el_static.avg_jct(),
                "elastic_avg_jct": el.avg_jct(),
                "elastic_speedup": el_static.avg_jct() / el.avg_jct(),
                "resizes": el.resizes,
            },
            f,
            indent=2,
        )
        f.write("\n")


def obs_overhead_paired(
    run_off, run_on, rounds: int = 4
) -> Tuple[float, float, float]:
    """Fractional slowdown of ``run_on`` over ``run_off`` from
    order-alternated paired CPU-time rounds, as a ratio of total times —
    the estimator the slow-marked guard test shares.  Wall-clock
    min-of-N is hopeless for a <3 % signal on a noisy shared host:
    ``process_time`` excludes scheduler preemption and summing over
    alternated pairs cancels drift.  Returns (overhead_frac, t_off,
    t_on)."""
    t_off = t_on = 0.0
    for i in range(rounds):
        pair = (run_off, run_on) if i % 2 == 0 else (run_on, run_off)
        for fn in pair:
            t0 = time.process_time()
            fn()
            dt = time.process_time() - t0
            if fn is run_off:
                t_off += dt
            else:
                t_on += dt
    return (t_on / t_off) - 1.0, t_off, t_on


def bench_obs(full: bool) -> None:
    """Observability overhead cells: ``observe=None`` vs
    ``ObsConfig.full()`` (all four channels armed).

    Two cells, deliberately opposite regimes:

    * ``paper`` quick — the events/sec microbenchmark (~10 us/event, ~2
      obs records per event).  Upper bound: every record's cost is
      visible against the tiny per-event baseline.
    * preemptive streaming replay — the engine's feature-complete mode
      (preemptive SRSF + gating + WFBP over streaming arrivals), where
      scheduling work dominates the event loop.  This is the <3 %
      guard cell (mirrored by the slow-marked test in
      ``tests/test_obs.py``).

    The off-path must be free (the hooks are never entered).  Persists
    ``BENCH_obs.json`` (path override: ``REPRO_BENCH_OBS_JSON``)."""
    from repro.obs import ObsConfig
    from repro.scenarios import QUICK_OVERRIDES, get_scenario
    from repro.scenarios.sweep import run_scenario_event

    overrides = {} if full else QUICK_OVERRIDES["paper"]
    scn = get_scenario("paper", seed=0, **overrides)
    cfg = ObsConfig.full()
    run_scenario_event(scn, comm="ada")  # warm caches

    res_off = run_scenario_event(scn, comm="ada")
    res_on = run_scenario_event(scn, comm="ada", observe=cfg)
    assert res_on.jct == res_off.jct, "observability changed the simulation"
    paper_ov, t_off, _ = obs_overhead_paired(
        lambda: run_scenario_event(scn, comm="ada"),
        lambda: run_scenario_event(scn, comm="ada", observe=cfg),
    )
    eps_off = res_off.events_processed * 4 / t_off
    obs = res_on.obs
    emit(
        "obs/overhead_paper",
        0.0,
        f"events_per_sec_off={eps_off:.0f};overhead_frac={paper_ov:.4f};"
        f"decomposed={len(obs.decomp)};audit={len(obs.audit)};"
        f"spans={len(obs.spans)}",
    )

    guard_n = 800 if full else 400
    jobs = stream_trace(guard_n, seed=0)
    guard_kw = dict(
        placement="lwf", comm="ada", n_servers=16, gpus_per_server=2,
        sched="preemptive_srsf",
    )
    g_off = simulate(jobs, **guard_kw)
    g_on = simulate(jobs, **guard_kw, observe=cfg)
    assert g_on.jct == g_off.jct, "observability changed the guard cell"
    guard_ov, g_t_off, _ = obs_overhead_paired(
        lambda: simulate(jobs, **guard_kw),
        lambda: simulate(jobs, **guard_kw, observe=cfg),
    )
    emit(
        "obs/overhead_guard",
        0.0,
        f"n_jobs={guard_n};events={g_off.events_processed};"
        f"overhead_frac={guard_ov:.4f};budget=0.03",
    )
    path = os.environ.get("REPRO_BENCH_OBS_JSON", "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(
            {
                "provenance": provenance(),
                "full": full,
                "scenario": "paper",
                "obs_off_events_per_sec": eps_off,
                "obs_overhead_frac": paper_ov,
                "obs_guard_overhead_frac": guard_ov,
                "obs_guard_n_jobs": guard_n,
                "n_jobs_decomposed": len(obs.decomp),
                "mean_stretch_frac": obs.mean_stretch_frac(),
                "mean_gating_frac": obs.mean_gating_frac(),
                "audit_entries": len(obs.audit),
                "span_entries": len(obs.spans),
                "timeline_points": len(obs.timeline),
            },
            f,
            indent=2,
        )
        f.write("\n")


def export_traces(
    out_dir: str,
    names,
    comm: str = "ada",
    seed: int = 2,
    full: bool = False,
    sched: str = None,
) -> List[str]:
    """``--trace-out``: one fully-observed run per scenario, written as a
    Perfetto-loadable Chrome trace JSON plus the per-job JCT-decomposition
    CSV.  Returns the written paths."""
    from repro.obs import ObsConfig
    from repro.scenarios import QUICK_OVERRIDES, get_scenario
    from repro.scenarios.sweep import run_scenario_event

    os.makedirs(out_dir, exist_ok=True)
    written: List[str] = []
    for name in names:
        overrides = {} if full else QUICK_OVERRIDES.get(name, {})
        scn = get_scenario(name, seed=seed, **overrides)
        kw = {} if sched is None else {"sched": sched}
        res = run_scenario_event(
            scn, comm=comm, observe=ObsConfig.full(), **kw
        )
        tag = "" if sched is None else f"_{sched}"
        stem = os.path.join(out_dir, f"{name}_seed{seed}_{comm}{tag}")
        trace_path = stem + ".perfetto.json"
        res.obs.to_chrome_trace(trace_path)
        csv_path = stem + ".decomp.csv"
        with open(csv_path, "w") as f:
            f.write(res.obs.decomposition_csv())
        written += [trace_path, csv_path]
        print(
            f"trace-out,{name},seed={seed},comm={comm},"
            f"jobs={len(res.obs.decomp)},spans={len(res.obs.spans)},"
            f"files={trace_path};{csv_path}",
            flush=True,
        )
    return written


def bench_chaos(full: bool) -> None:
    """Fault-injection SLO grid: every ``chaos_*`` scenario under the
    static ada/srsf1/srsf2 schedulers plus ada under ``preemptive_srsf``,
    over multiple seeds.  Prints the full RunMetrics CSV (including the
    goodput / work_lost / p99_jct fault columns) and persists the
    per-cell means plus the per-seed recovery-storm ada/srsf2 ratios to
    ``BENCH_chaos.json`` (path override: ``REPRO_BENCH_CHAOS_JSON``).

    Every run is observed (``ObsConfig(decompose=True)`` — bit-exact with
    unobserved, locked in tests/test_obs.py) so the CSV's
    stretch_frac/gating_frac columns carry data, and each run asserts the
    conservation law: the engine's ``work_lost_samples`` fault counter
    must equal the decomposition's total lost samples."""
    from repro.obs import ObsConfig
    from repro.scenarios import get_scenario
    from repro.scenarios import metrics as metrics_mod
    from repro.scenarios.sweep import run_scenario_event

    scenarios = ("chaos_steady", "chaos_recovery_storm", "chaos_stragglers")
    seeds = (0, 1, 2, 3, 4) if full else (1, 3)
    grid = (
        ("ada", "static"),
        ("srsf1", "static"),
        ("srsf2", "static"),
        ("ada", "preemptive_srsf"),
    )
    records: List[metrics_mod.RunMetrics] = []
    by_cell: Dict[tuple, List[metrics_mod.RunMetrics]] = {}
    storm_ratio: Dict[int, float] = {}
    print(metrics_mod.RunMetrics.csv_header())
    for name in scenarios:
        for seed in seeds:
            scn = get_scenario(name, seed=seed)
            per_comm = {}
            for comm, sched in grid:
                t0 = time.time()
                res = run_scenario_event(
                    scn, comm=comm, sched=sched,
                    observe=ObsConfig(decompose=True),
                )
                assert res.obs.work_lost_total == res.work_lost_samples, (
                    f"{name}/{comm}/{sched} seed={seed}: decomposition lost "
                    f"{res.obs.work_lost_total} samples but the engine "
                    f"counted {res.work_lost_samples}"
                )
                m = metrics_mod.from_event_result(
                    res,
                    scenario=name,
                    seed=seed,
                    n_jobs=scn.n_jobs,
                    wall_s=time.time() - t0,
                )
                print(m.as_csv_row(), flush=True)
                records.append(m)
                by_cell.setdefault((name, comm, sched), []).append(m)
                if sched == "static":
                    per_comm[comm] = res.avg_jct()
            if name == "chaos_recovery_storm":
                storm_ratio[seed] = per_comm["ada"] / per_comm["srsf2"]
    for (name, comm, sched), ms in sorted(by_cell.items()):
        emit(
            f"chaos/{name}/{comm}/{sched}",
            sum(m.wall_s for m in ms) / len(ms) * 1e6,
            f"goodput={sum(m.goodput for m in ms) / len(ms):.1f};"
            f"work_lost={sum(m.work_lost for m in ms) / len(ms):.1f};"
            f"p99_jct={sum(m.p99_jct for m in ms) / len(ms):.2f};"
            f"faults={sum(m.faults for m in ms) / len(ms):.1f}",
        )
    mean_storm = sum(storm_ratio.values()) / len(storm_ratio)
    emit(
        "chaos/recovery_storm/ada_vs_srsf2",
        0.0,
        f"mean_ratio={mean_storm:.3f};"
        + ";".join(f"seed{s}={r:.3f}" for s, r in sorted(storm_ratio.items())),
    )
    path = os.environ.get("REPRO_BENCH_CHAOS_JSON", "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump(
            {
                "provenance": provenance(),
                "full": full,
                "seeds": list(seeds),
                "cells": {
                    f"{name}/{comm}/{sched}": {
                        "goodput_mean": sum(m.goodput for m in ms) / len(ms),
                        "work_lost_mean": sum(m.work_lost for m in ms) / len(ms),
                        "p99_jct_mean": sum(m.p99_jct for m in ms) / len(ms),
                        "avg_jct_mean": sum(m.avg_jct for m in ms) / len(ms),
                        "faults_mean": sum(m.faults for m in ms) / len(ms),
                        "cancelled": sum(m.cancelled for m in ms),
                        "censored": sum(m.censored for m in ms),
                    }
                    for (name, comm, sched), ms in sorted(by_cell.items())
                },
                "recovery_storm_ada_over_srsf2": {
                    str(s): r for s, r in sorted(storm_ratio.items())
                },
                "recovery_storm_ratio_mean": mean_storm,
            },
            f,
            indent=2,
        )
        f.write("\n")


# ---------------------------------------------------------------------------
# Roofline table (from the dry-run artifact)
# ---------------------------------------------------------------------------


def bench_roofline(full: bool) -> None:
    path = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run repro.launch.dryrun first ({path})")
        return
    with open(path) as f:
        data = json.load(f)
    for key, res in sorted(data.items()):
        if res.get("status") != "ok" or "|single|" not in key:
            continue
        arch, shape, _, _ = key.split("|")
        r = res["roofline"]
        dom_t = r[f"{r['dominant']}_s"]
        emit(
            f"roofline/{arch}/{shape}",
            dom_t * 1e6,
            f"dominant={r['dominant']};compute={r['compute_s']:.4f};"
            f"memory={r['memory_s']:.4f};collective={r['collective_s']:.4f};"
            f"useful_ratio={r['useful_flops_ratio']:.3f};hbm_frac={r['hbm_peak_frac']:.2f}",
        )


BENCHES: Dict[str, Callable[[bool], None]] = {
    "table1": bench_table1,
    "fig2a": bench_fig2a,
    "fig2b": bench_fig2b,
    "table4": bench_table4,
    "fig5": bench_fig5,
    "table5": bench_table5,
    "chunked": bench_chunked,
    "scenarios": bench_scenarios,
    "topology": bench_topology,
    "wfbp": bench_wfbp,
    "engine": bench_engine,
    "chaos": bench_chaos,
    "obs": bench_obs,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale 160-job trace")
    ap.add_argument("--only", nargs="+", choices=list(BENCHES), default=None)
    ap.add_argument(
        "--scenario",
        nargs="+",
        default=None,
        metavar="NAME",
        help="run the scenario sweep instead of the table benches "
        "('all' or names from repro.scenarios)",
    )
    ap.add_argument(
        "--policy",
        nargs="+",
        default=["ada", "srsf1", "srsf2"],
        help="comm policies for --scenario (ada/adadual, srsfN, kwayK — "
        "the fluid backend supports ada, srsf1-3, kway2/kway3)",
    )
    ap.add_argument(
        "--placement",
        nargs="+",
        default=["lwf"],
        choices=["rand", "ff", "ls", "lwf", "lwf_rack"],
        help="placement policies for --scenario (fluid maps lwf->consolidate,"
        " ff->first_fit, ls->least_loaded, rand->random, lwf_rack->rack_pack"
        " gang modes)",
    )
    ap.add_argument(
        "--backend",
        default="event",
        choices=["event", "fluid"],
        help="simulator backend for --scenario",
    )
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    ap.add_argument(
        "--kappa",
        nargs="+",
        type=int,
        default=[1],
        help="LWF consolidation thresholds for --scenario; several values "
        "run the whole matrix once per kappa (the placement column carries "
        "the kappa, e.g. LWF_RACK-4)",
    )
    ap.add_argument(
        "--sched",
        default=None,
        choices=["static", "preemptive_srsf", "elastic"],
        help="job scheduling policy override for --scenario (event backend "
        "only; default: each scenario's own sched field, normally static)",
    )
    ap.add_argument(
        "--bw-aware-srsf",
        action="store_true",
        help="enable the bandwidth-aware SRSF remaining-service estimate "
        "for --scenario (event backend only; default: paper-faithful "
        "nominal estimate)",
    )
    ap.add_argument(
        "--ci",
        action="store_true",
        help="with --scenario: aggregate seeds into mean +/- std CellCI rows"
        " (fluid backend runs all seeds of a cell in one vmapped launch)",
    )
    ap.add_argument(
        "--processes",
        type=int,
        default=None,
        help="multiprocessing fan-out for --scenario (event backend)",
    )
    ap.add_argument(
        "--n-jobs",
        type=int,
        default=None,
        help="job count of the --only engine streaming replay cell "
        "(default: 20000, or 100000 with --full)",
    )
    ap.add_argument(
        "--trace-source",
        default="synth",
        help="arrival feed of the --only engine replay cell: 'synth', "
        "'philly', 'alibaba' (bundled samples), or 'csv:<dialect>:<path>'",
    )
    ap.add_argument(
        "--obs",
        action="store_true",
        help="with --scenario (event backend): arm the JCT decomposition "
        "so the stretch_frac/gating_frac CSV columns carry data",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        metavar="DIR",
        help="export one fully-observed run per scenario (--scenario names, "
        "default: paper chaos_recovery_storm fusion_sweep) as Perfetto "
        "trace JSON + JCT-decomposition CSV into DIR, then exit; "
        "--policy/--seeds pick the (single) comm policy and seed",
    )
    args = ap.parse_args()
    if args.trace_out:
        export_traces(
            args.trace_out,
            args.scenario or ["paper", "chaos_recovery_storm", "fusion_sweep"],
            comm=args.policy[0] if args.policy else "ada",
            seed=args.seeds[0],
            full=args.full,
            sched=args.sched,
        )
        return
    if args.scenario:
        _scenario_sweep(
            args.scenario,
            args.policy,
            args.placement,
            args.seeds,
            args.backend,
            args.processes,
            args.full,
            ci=args.ci,
            kappas=args.kappa,
            sched=args.sched,
            bw_aware_srsf=args.bw_aware_srsf,
            obs=args.obs,
        )
        return
    print("name,us_per_call,derived")
    names = args.only or list(BENCHES)
    for name in names:
        if name == "engine":
            bench_engine(
                args.full, n_jobs=args.n_jobs, trace_source=args.trace_source
            )
        else:
            BENCHES[name](args.full)


if __name__ == "__main__":
    main()
